"""Paper Table II: classification accuracy vs templates-per-class (1/2/3),
binary feature-count matching, plus the silhouette-score selection."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import hybrid, templates


def run() -> list[dict]:
    d = common.data()
    m = common.models()
    gtr, ytr = d["gray_tr"]
    gte, yte = d["gray_te"]
    params = m["student_opt"]

    rows = []
    for k in (1, 2, 3):
        head = hybrid.fit_acam_head(common.student_feature_fn, params,
                                    gtr, ytr, 10, k=k)
        clf = hybrid.HybridClassifier(params,
                                      jax.jit(common.student_feature_fn), head)
        rows.append({"templates_per_class": k,
                     "accuracy": clf.accuracy(gte, yte)})

    feats = common.collect_features(params, gtr[:1500])
    best_k, scores = templates.select_k_by_silhouette(
        jnp.asarray(feats), jnp.asarray(ytr[:1500]), 10)
    rows.append({"silhouette_best_k": best_k,
                 "silhouette_scores": {k: round(v, 4) for k, v in scores.items()}})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
