"""Paper Fig. 1: mean vs median per-feature binarisation thresholds, and the
downstream classification accuracy of each (the paper's §II-D-1 argument:
sparse ReLU feature maps make the mean threshold more discriminative)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import hybrid, quant


def run() -> dict:
    d = common.data()
    m = common.models()
    gtr, ytr = d["gray_tr"]
    gte, yte = d["gray_te"]
    params = m["student_opt"]

    feats = jnp.asarray(common.collect_features(params, gtr))
    mean_thr = quant.feature_thresholds(feats, "mean")
    med_thr = quant.feature_thresholds(feats, "median")

    out = {
        "mean_thr_avg": float(jnp.mean(mean_thr)),
        "median_thr_avg": float(jnp.mean(med_thr)),
        "frac_features_mean_below_median": float(jnp.mean(mean_thr < med_thr)),
        "feature_sparsity": float(jnp.mean(feats == 0.0)),
    }
    for method in ("mean", "median"):
        head = hybrid.fit_acam_head(common.student_feature_fn, params,
                                    gtr, ytr, 10, threshold_method=method)
        clf = hybrid.HybridClassifier(params,
                                      jax.jit(common.student_feature_fn), head)
        out[f"accuracy_{method}"] = clf.accuracy(gte, yte)
    return out


if __name__ == "__main__":
    print(run())
