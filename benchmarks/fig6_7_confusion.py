"""Paper Fig. 6/7: confusion matrix + per-class accuracy of the optimised
student with the feature-count pattern-matching classifier."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import hybrid


def run() -> dict:
    d = common.data()
    m = common.models()
    gtr, ytr = d["gray_tr"]
    gte, yte = d["gray_te"]
    params = m["student_opt"]

    head = hybrid.fit_acam_head(common.student_feature_fn, params, gtr, ytr, 10)
    fn = jax.jit(lambda p, x: head(common.student_feature_fn(p, x))[0])
    preds = np.concatenate([np.asarray(fn(params, gte[i:i + 512]))
                            for i in range(0, len(yte), 512)])
    cm = np.zeros((10, 10), np.int64)
    for t, p in zip(yte, preds):
        cm[t, p] += 1
    per_class = (cm.diagonal() / np.maximum(cm.sum(axis=1), 1)).round(4)
    return {
        "confusion_matrix": cm.tolist(),
        "per_class_accuracy": per_class.tolist(),
        "accuracy": float((preds == yte).mean()),
    }


if __name__ == "__main__":
    out = run()
    print(np.asarray(out["confusion_matrix"]))
    print("per-class:", out["per_class_accuracy"])
    print("overall:", out["accuracy"])
