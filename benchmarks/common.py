"""Shared state for the paper-table benchmarks: one synthetic dataset and one
set of trained models (teacher, baseline student, optimised student) reused
by every table/figure script. Scale with REPRO_BENCH_FAST=1 (CI) or
REPRO_BENCH_SCALE=<n_per_class>.
"""
from __future__ import annotations

import functools
import os
import time

import jax
import numpy as np

from repro.data import synthetic
from repro.models import cnn
from repro.train import cnn_trainer as T

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
N_PER_CLASS = int(os.environ.get("REPRO_BENCH_SCALE", "120" if FAST else "400"))
EPOCHS = 2 if FAST else 4


@functools.lru_cache(maxsize=1)
def data():
    tr = synthetic.load("train", n_per_class=N_PER_CLASS, seed=0)
    te = synthetic.load("test", n_per_class=max(N_PER_CLASS // 4, 50), seed=0)
    gray_tr = synthetic.normalize(synthetic.to_grayscale(tr.images))
    gray_te = synthetic.normalize(synthetic.to_grayscale(te.images))
    return {
        "color_tr": (synthetic.normalize(tr.images), tr.labels),
        "color_te": (synthetic.normalize(te.images), te.labels),
        "gray_tr": (gray_tr, tr.labels),
        "gray_te": (gray_te, te.labels),
    }


TEACHER_CFG = cnn.TeacherConfig(width=16, blocks_per_stage=2)
TEACHER_CFG_COLOR = TEACHER_CFG
TEACHER_CFG_GRAY = cnn.TeacherConfig(in_channels=1, width=16, blocks_per_stage=2)


@functools.lru_cache(maxsize=1)
def models():
    """Train the benchmark model set once. Returns a dict of params."""
    d = data()
    t0 = time.time()
    out = {}
    xc, yc = d["color_tr"]
    # the ResNet teacher is data-hungrier than the tiny student: 2x epochs
    out["teacher_color"] = T.train_teacher(xc, yc, TEACHER_CFG_COLOR,
                                           epochs=2 * EPOCHS, batch_size=128)
    xg, yg = d["gray_tr"]
    out["teacher_gray"] = T.train_teacher(xg, yg, TEACHER_CFG_GRAY,
                                          epochs=2 * EPOCHS, batch_size=128)
    # teacher logits over the grey train set (for KD)
    tl = jax.jit(lambda p, x: cnn.teacher_logits(p, x, TEACHER_CFG_GRAY)[0])
    zt = np.concatenate([np.asarray(tl(out["teacher_gray"], xg[i:i + 512]))
                         for i in range(0, len(yg), 512)])
    out["teacher_gray_logits"] = zt

    base_cfg = T.TrainConfig(epochs=EPOCHS, batch_size=128, seed=0)
    out["student_base"], _ = T.train_student(xg, yg, cfg=base_cfg)
    opt_cfg = T.TrainConfig(epochs=EPOCHS, batch_size=128, seed=0,
                            prune_epochs=2, finetune_epochs=1, qat=True)
    out["student_opt"], out["student_opt_masks"] = T.train_student(
        xg, yg, teacher_logits_all=zt, cfg=opt_cfg, do_prune=True)
    out["train_time_s"] = time.time() - t0
    return out


def student_feature_fn(params, x):
    return cnn.student_features(params, x)[0]


def collect_features(params, x, batch=512):
    fn = jax.jit(student_feature_fn)
    return np.concatenate([np.asarray(fn(params, x[i:i + batch]))
                           for i in range(0, len(x), batch)])
