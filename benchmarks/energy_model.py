"""Paper §V-D: energy per classification for the hybrid system vs teacher —
reproduces the paper's arithmetic exactly (Eq. 14 + Horowitz figures), in
both paper-faithful and physical units (see repro.core.energy for the
documented unit-slip note)."""
from __future__ import annotations

from repro.core import energy


def run() -> dict:
    paper = energy.paper_numbers()
    phys = energy.hybrid_report(paper_faithful=False)
    return {
        **{f"paper_{k}": round(v, 4) for k, v in paper.items()},
        "physical_frontend_uj": round(phys.frontend_j * 1e6, 3),
        "physical_teacher_mj": round(phys.teacher_j * 1e3, 3),
        "physical_reduction_x": round(phys.reduction, 1),
    }


if __name__ == "__main__":
    print(run())
