"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, plus
the full result dictionaries. ``REPRO_BENCH_FAST=1`` shrinks the training
budget for CI-speed runs.
"""
from __future__ import annotations

import json
import time


def main() -> None:
    t_all = time.time()
    rows: list[tuple[str, float, str]] = []
    details: dict = {}

    def timed(name, fn):
        t0 = time.time()
        out = fn()
        us = (time.time() - t0) * 1e6
        details[name] = out
        return name, us, out

    from benchmarks import (energy_model, fig1_thresholds, fig6_7_confusion,
                            kernel_bench, table1_compression, table2_templates)
    from benchmarks import common

    # model training is shared; charge it to its own row
    name, us, _ = timed("train_models", common.models)
    rows.append((name, us, f"n_per_class={common.N_PER_CLASS}"))

    name, us, out = timed("table1_compression", table1_compression.run)
    opt = next(r for r in out if r["model"] == "student_optimised")
    rows.append((name, us, f"opt_student_acc={opt['accuracy']:.4f}"))

    name, us, out = timed("table2_templates", table2_templates.run)
    accs = [r["accuracy"] for r in out if "accuracy" in r]
    rows.append((name, us, "k1/k2/k3=" + "/".join(f"{a:.4f}" for a in accs)))

    name, us, out = timed("fig1_thresholds", fig1_thresholds.run)
    rows.append((name, us,
                 f"mean={out['accuracy_mean']:.4f},median={out['accuracy_median']:.4f}"))

    name, us, out = timed("fig6_7_confusion", fig6_7_confusion.run)
    rows.append((name, us, f"acc={out['accuracy']:.4f}"))

    name, us, out = timed("energy_model", energy_model.run)
    rows.append((name, us, f"total={out['paper_total_nj']}nJ,"
                 f"reduction={out['paper_reduction_x']}x"))

    for r in kernel_bench.run():
        rows.append((r["name"], r["us_per_call"], r["derived"]))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    with open("bench_details.json", "w") as f:
        json.dump(details, f, indent=1, default=str)
    print(f"\ntotal {time.time()-t_all:.1f}s; details in bench_details.json")


if __name__ == "__main__":
    main()
