"""Serving-tier benchmark: the multi-tenant ACAM service under load.

Sweeps tenant count x scheduler micro-batch size and measures the service
end to end — admission, cross-tenant micro-batching (one fused classify
dispatch per tick), the confidence cascade, and paper §V-D energy
attribution — emitting ``BENCH_serving.json`` so the serving trajectory is
tracked PR over PR alongside ``BENCH_kernels.json``.

On this CPU container the fused kernels run in Pallas interpret mode, so
requests/s is a correctness-path number, not a TPU number; the JSON records
``backend``/``interpret`` to keep runs distinguishable. Escalation rate and
nJ/request are backend-independent.

BENCH_serving.json schema::

    {"backend": "cpu" | "tpu",
     "interpret": bool,
     "entries": [
       {"tenants": 8, "slots": 256, "requests": 1024,
        "classes": 10,                # classes per synthetic tenant
        "matching_backend": "default",  # or the pinned engine backend
                                        # ("device" = RRAM-physics row)
        "bank_sharding": 1,           # super-bank class-row shards (model
                                      # axis size; 1 = replicated bank)
        "requests_per_s": ...,        # completed / service busy time
        "latency_p50_ms": ..., "latency_p99_ms": ...,
        "escalation_rate": ...,       # cascade escalations / requests
        "nj_per_request": ...,        # E_backend (+ E_frontend if escalated)
        "occupancy": ...,             # mean batch fill fraction
        "classify_dispatches": ...}]}

The **bank-scaling sweep** (`bank_scaling_sweep`) grows tenants x classes
and, when ``REPRO_FORCE_MESH=DxM`` provides a forced host mesh, measures
every point replicated AND bank-sharded — the `bank_sharding` field is how
BENCH json tracks the replicated-vs-sharded crossover as the super-bank
outgrows one device. (On this CPU container both run through Pallas
interpret, so the sharded rows are a correctness-path number; the
crossover itself is a TPU measurement.)

**Resilience rows** (`benchmarks/traces.py` harness) run with the flight
recorder's JSONL event log armed and RE-DERIVE their headline numbers
from it rather than poking service internals: ``"trace": "burst"``
replays a seeded bursty Zipf trace with the overload policy armed and adds
``p99_burst_ms`` / ``p99_calm_ms`` / ``shed_rate`` / ``shed_intervals``
(shed_on..shed_off episodes reconstructed from the log, shed-tick counts
cross-checked against the registry); ``"trace": "chaos"`` kills the
service mid-trace, restores it from its durable snapshot, asserts
bit-identity against a clean build and adds ``recovery_ms`` (the log's
``restore`` event) / ``lost_in_flight`` (queue depth on the dead
incarnation's last ``tick`` line). ``--chaos`` runs only the chaos smoke
and appends its row to an existing ``BENCH_serving.json``; with
``--telemetry-dir DIR`` it leaves ``DIR/events.jsonl`` +
``DIR/metrics.prom`` behind for `python -m repro.obs.export` validation
(the CI telemetry-smoke job).

The **telemetry-overhead row** (`telemetry_overhead_bench`) serves one
identical stream twice — span sampling off / full flight recorder with
the JSONL sink — asserts preds/margins/escalations are bit-identical
either way, and records ``telemetry_overhead_pct`` (the tests hold the
same comparison under 5%).

The **mega-kernel row** (`megakernel_bench`, name ``serving_megakernel``)
uses the same twice-served protocol to price the resident serve kernel:
``serve_fusion="compose"`` (the pre-fusion tick) vs ``"mega"`` (ONE
pallas_call per tick) at tenants=8, slots=32, bit-identity asserted,
``megakernel_speedup_pct`` + both us/request medians recorded.

The **LM semantic-cache rows** (`lm_cache_bench`) price the ACAM tier as
a router in front of the continuous-batching decode engine:
``serving_lm_decode_only`` (marker ``lm_baseline``) is the bare
`serve.Engine` over the prompt set; ``serving_lm_cache_h{0,50,90}``
(marker ``hit_rate``) serve a measured window with EXACTLY that fraction
of warm-template repeats through `repro.serve.semantic_cache`. Each row
records the amortisation-bounded mean ratios (``mean_speedup`` /
``mean_energy_ratio``, ceiling 1/(1-h) — ~10x at h=0.9) next to the
hit-path ratios (``hit_path_speedup`` / ``hit_path_energy_ratio``, the
paper's Eq. 14-vs-decode asymmetry). ``--lm-cache`` runs only this sweep
and appends/replaces its rows in an existing ``BENCH_serving.json``.

``--smoke`` restricts the sweep for CI. `run()` keeps the harness contract
used by benchmarks/run.py: a list of ``{"name", "us_per_call", "derived"}``
rows.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

TENANT_SWEEP = (1, 8, 64)
SLOT_SWEEP = (1, 256)
SMOKE_TENANTS = (1, 8)
SMOKE_SLOTS = (1, 64)
NUM_FEATURES = 64
NUM_CLASSES = 10


def make_spec(slots: int, *, requests: int, backend: str | None = None,
              bank_shards: int = 1, install_mesh: bool = False,
              telemetry_dir: str | None = None, span_sample: float = 1.0):
    """The bench's one `ServiceSpec`: every measurement constructs through
    the spec path (`HybridService.from_spec`), never the legacy keywords.
    Taus ride in explicit match-count units; the service converts to the
    backend's native margin units itself. ``telemetry_dir`` arms the
    flight recorder's JSONL event log (the resilience rows re-derive
    their numbers from it)."""
    from repro import match as match_lib
    from repro.match.config import EngineConfig
    from repro.serve import spec as spec_lib

    return spec_lib.ServiceSpec(
        registry=spec_lib.RegistrySpec(
            num_features=NUM_FEATURES,
            initial_classes=spec_lib.aligned_classes(bank_shards)),
        engine=EngineConfig(backend=backend or match_lib.default_backend(),
                            margin=True),
        mesh=spec_lib.MeshSpec(bank_shards=bank_shards,
                               install=install_mesh),
        scheduler=spec_lib.SchedulerSpec(slots=slots),
        cascade=spec_lib.CascadeSpec(tau=8.0, tau_units="count",
                                     max_queue=max(requests, 4096)),
        obs=spec_lib.ObsSpec(telemetry_dir=telemetry_dir,
                             span_sample=span_sample),
    )


def bench_service(tenants: int, slots: int, *, requests: int | None = None,
                  seed: int = 0, backend: str | None = None,
                  classes: int = NUM_CLASSES, bank_shards: int | None = None,
                  install_mesh: bool = False) -> dict:
    """Serve a mixed-tenant burst through a fresh spec-built service.

    ``bank_shards=None`` keeps the historical behaviour of aligning to
    whatever mesh is installed when this runs (`bank_scaling_sweep`
    toggles it); an explicit value + ``install_mesh=True`` lets the spec
    own the mesh end to end.
    """
    from repro import match as match_lib
    from repro.serve import acam_service as svc_lib
    from repro.serve.control import HybridService

    requests = requests or max(4 * slots, 128)
    if bank_shards is None:
        bank_shards = match_lib.bank_shards_in_mesh()
    svc = HybridService.from_spec(make_spec(
        slots, requests=requests, backend=backend, bank_shards=bank_shards,
        install_mesh=install_mesh))
    protos = []
    for t in range(tenants):
        bank, head, p = svc_lib.make_synthetic_tenant(
            seed * 1000 + t, num_classes=classes,
            num_features=NUM_FEATURES)
        svc.register_tenant(f"t{t}", bank, head=head)
        protos.append(p)

    rng = np.random.RandomState(seed)
    tenant_of = rng.randint(0, tenants, size=requests)
    reqs = []
    for i, t in enumerate(tenant_of):
        feats, _ = svc_lib.sample_tenant_queries(seed + i, protos[t], 1,
                                                 noise=0.8)
        reqs.append(svc_lib.ClassifyRequest(f"t{t}", feats[0]))

    # warmup tick compiles the fused dispatch so requests/s measures the
    # steady state, matching how a long-lived service behaves
    svc.serve(reqs[:1])
    svc.reset_metrics()
    responses = svc.serve(reqs)
    assert len(responses) == requests
    m = svc.metrics()
    return {
        "tenants": tenants,
        "slots": slots,
        "requests": requests,
        "classes": classes,
        "matching_backend": backend or "default",
        "bank_sharding": svc.registry.bank_shards,
        "requests_per_s": m["requests_per_s"],
        "latency_p50_ms": m["latency_p50_ms"],
        "latency_p99_ms": m["latency_p99_ms"],
        "escalation_rate": m["escalation_rate"],
        "nj_per_request": m["nj_per_request"],
        "occupancy": m["occupancy"],
        "classify_dispatches": m["classify_dispatches"],
    }


def _report(e):
    print(f"tenants={e['tenants']:3d} classes={e['classes']:3d} "
          f"slots={e['slots']:4d} shards={e['bank_sharding']} "
          f"backend={e['matching_backend']:9s}: "
          f"{e['requests_per_s']:9.1f} req/s, "
          f"escalation {e['escalation_rate']:.3f}, "
          f"{e['nj_per_request']:.2f} nJ/req, "
          f"occupancy {e['occupancy']:.2f}")


def bank_scaling_sweep(*, smoke: bool = False, seed: int = 0) -> list[dict]:
    """Grow the super-bank (tenants x classes) replicated vs bank-sharded.

    The sharded points need a model mesh axis: when ``REPRO_FORCE_MESH``
    provides forced host devices the sweep installs the mesh around each
    sharded measurement (`repro.distributed.forcemesh`); without it only
    the replicated rows are emitted.
    """
    from repro.distributed import context, forcemesh

    grid = ((4, 16), (8, 32)) if smoke else ((8, 16), (32, 32), (64, 48))
    slots = min(SLOT_SWEEP[-1], 64)
    spec = forcemesh.env_spec()
    entries = []
    for tenants, classes in grid:
        requests = 2 * slots if smoke else 4 * slots
        context.clear()
        entries.append(bench_service(tenants, slots, requests=requests,
                                     seed=seed, classes=classes))
        _report(entries[-1])
        if spec is None:
            continue
        try:
            forcemesh.install(spec)
        except RuntimeError as e:
            print(f"skipping sharded rows: {e}")
            spec = None
            continue
        entries.append(bench_service(tenants, slots, requests=requests,
                                     seed=seed, classes=classes))
        _report(entries[-1])
        context.clear()
    return entries


def reshard_bench(*, seed: int = 0, tenants: int = 8, slots: int = 64,
                  to_shards: int = 2) -> dict | None:
    """Live-reshard downtime: boot a spec-built service at ``bank_shards=1``
    (mesh owned by the spec), load it, then `reconfigure` to ``to_shards``
    mid-stream and measure the drain->resume wall time. Asserts the
    post-reshard scheduler keeps ONE sharded dispatch per tick and that
    predictions are bit-identical across the transition.

    Needs a forced host mesh (``REPRO_FORCE_MESH=DxM`` with D*M divisible
    by ``to_shards``); returns None (with a note) when unavailable.
    """
    import jax

    from repro import match as match_lib
    from repro.distributed import context, forcemesh
    from repro.serve import acam_service as svc_lib
    from repro.serve.control import HybridService

    if forcemesh.env_spec() is None or len(jax.devices()) % to_shards:
        print("skipping reshard row: set REPRO_FORCE_MESH (devices must "
              f"divide {to_shards})")
        return None
    context.clear()
    requests = 4 * slots
    svc = HybridService.from_spec(make_spec(slots, requests=requests,
                                            bank_shards=1,
                                            install_mesh=True))
    protos = []
    for t in range(tenants):
        bank, head, p = svc_lib.make_synthetic_tenant(
            seed * 1000 + t, num_classes=NUM_CLASSES,
            num_features=NUM_FEATURES)
        svc.register_tenant(f"t{t}", bank, head=head)
        protos.append(p)
    rng = np.random.RandomState(seed)
    tenant_of = rng.randint(0, tenants, size=requests)
    reqs = []
    for i, t in enumerate(tenant_of):
        feats, _ = svc_lib.sample_tenant_queries(seed + i, protos[t], 1,
                                                 noise=0.8)
        reqs.append(svc_lib.ClassifyRequest(f"t{t}", feats[0]))
    before = [(r.tenant_id, r.pred, r.escalated, round(r.margin, 6))
              for r in svc.serve(reqs)]

    # mid-stream: enqueue a burst, reconfigure (drains it), resume sharded
    for req in reqs[:slots]:
        svc.submit(req)
    report = svc.reconfigure(svc.spec._replace(
        mesh=svc.spec.mesh._replace(bank_shards=to_shards)))
    assert len(report.drained) == slots, "drain lost queued work"
    assert svc.registry.bank_shards == to_shards
    assert match_lib.bank_shards_in_mesh() == to_shards

    # the tick's shapes now derive a bank-sharded plan: the scheduler's ONE
    # dispatch per tick executes 2D-sharded (batch over data, class rows
    # over model) — this is the actual sharded-dispatch assertion, since
    # classify_dispatches == ticks holds by construction
    plan, _ = match_lib.plan_for(batch=slots,
                                 num_classes=svc.registry.capacity_classes)
    assert plan.bank_shards == to_shards, plan
    svc.reset_metrics()
    after = [(r.tenant_id, r.pred, r.escalated, round(r.margin, 6))
             for r in svc.serve(reqs)]
    assert after == before, "reshard changed served results"
    m = svc.metrics()
    assert m["classify_dispatches"] == m["ticks"], m
    context.clear()
    entry = {
        "tenants": tenants, "slots": slots, "requests": requests,
        "classes": NUM_CLASSES, "matching_backend": "default",
        "bank_sharding": to_shards,
        "reshard_downtime_ms": round(report.downtime_s * 1e3, 3),
        "tenants_moved": report.tenants_moved,
        "requests_per_s": m["requests_per_s"],
        "latency_p50_ms": m["latency_p50_ms"],
        "latency_p99_ms": m["latency_p99_ms"],
        "escalation_rate": m["escalation_rate"],
        "nj_per_request": m["nj_per_request"],
        "occupancy": m["occupancy"],
        "classify_dispatches": m["classify_dispatches"],
    }
    print(f"reshard 1->{to_shards}: downtime "
          f"{entry['reshard_downtime_ms']:.1f} ms "
          f"({entry['tenants_moved']} tenants moved, bit-identical, "
          f"{m['classify_dispatches']} sharded dispatches)")
    return entry


def telemetry_overhead_bench(*, smoke: bool = False, seed: int = 0) -> dict:
    """The flight recorder's tax: serve the IDENTICAL request stream twice
    — spans sampled out and no JSONL sink, then the full recorder (every
    request a span, event log on) — and record the per-request overhead.
    Doubles as the purity check: preds/margins/escalations must be
    bit-identical either way (telemetry observes, never steers)."""
    import tempfile

    from repro.serve import acam_service as svc_lib
    from repro.serve import spec as spec_lib
    from repro.serve.control import HybridService

    tenants, slots = 8, 64
    requests = 256 if smoke else 1024

    def build(obs):
        svc = HybridService.from_spec(make_spec(
            slots, requests=requests)._replace(obs=obs))
        protos = []
        for t in range(tenants):
            bank, head, p = svc_lib.make_synthetic_tenant(
                seed * 1000 + t, num_classes=NUM_CLASSES,
                num_features=NUM_FEATURES)
            svc.register_tenant(f"t{t}", bank, head=head)
            protos.append(p)
        rng = np.random.RandomState(seed)
        reqs = []
        for i, t in enumerate(rng.randint(0, tenants, size=requests)):
            feats, _ = svc_lib.sample_tenant_queries(seed + i, protos[t], 1,
                                                     noise=0.8)
            reqs.append(svc_lib.ClassifyRequest(f"t{t}", feats[0]))
        # full-stream warmup: compiles EVERY bucketed batch shape the
        # measured passes will hit (a 1-request warmup leaves the first
        # run paying all the compiles and poisons the comparison)
        svc.serve(reqs)
        return svc, reqs

    def measure(svc, reqs):
        svc.reset_metrics()
        sig = [(r.tenant_id, r.pred, r.escalated, round(r.margin, 6))
               for r in svc.serve(reqs)]
        return svc.metrics(), sig

    # INTERLEAVED passes (base, telemetry, base, ...) so clock drift
    # across the run hits both arms equally, then the MEDIAN us/request
    # per arm: a one-sided hiccup (GC pause, slow JSONL flush) lands in
    # one pass of one arm and the median rejects it, where a min would
    # bias low and a mean would smear it in
    base_svc, base_reqs = build(spec_lib.ObsSpec(span_sample=0.0))
    with tempfile.TemporaryDirectory() as td:
        tel_svc, tel_reqs = build(spec_lib.ObsSpec(telemetry_dir=td,
                                                   span_sample=1.0))
        base_us_all, tel_us_all = [], []
        base_sig = tel_sig = tel_m = None
        for _ in range(9):
            m, base_sig = measure(base_svc, base_reqs)
            base_us_all.append(1e6 / m["requests_per_s"])
            m, tel_sig = measure(tel_svc, tel_reqs)
            tel_us_all.append(1e6 / m["requests_per_s"])
            if tel_m is None or \
                    m["requests_per_s"] > tel_m["requests_per_s"]:
                tel_m = m
    assert tel_sig == base_sig, \
        "telemetry changed served results (must be pure observation)"
    base_us = float(np.median(base_us_all))
    tel_us = float(np.median(tel_us_all))
    entry = {
        "tenants": tenants, "slots": slots, "requests": requests,
        "classes": NUM_CLASSES, "matching_backend": "default",
        "bank_sharding": 1,
        "telemetry_overhead_pct": round(100.0 * (tel_us - base_us)
                                        / base_us, 2),
        "base_us_per_request": round(base_us, 3),
        "telemetry_us_per_request": round(tel_us, 3),
        "requests_per_s": tel_m["requests_per_s"],
        "latency_p50_ms": tel_m["latency_p50_ms"],
        "latency_p99_ms": tel_m["latency_p99_ms"],
        "escalation_rate": tel_m["escalation_rate"],
        "nj_per_request": tel_m["nj_per_request"],
        "occupancy": tel_m["occupancy"],
        "classify_dispatches": tel_m["classify_dispatches"],
    }
    print(f"telemetry overhead: {entry['telemetry_overhead_pct']:+.2f}% "
          f"({base_us:.1f} -> {tel_us:.1f} us/request, bit-identical "
          "results)")
    return entry


def megakernel_bench(*, smoke: bool = False, seed: int = 0) -> dict:
    """The resident serve mega-kernel's win over the composed tick.

    Serves the IDENTICAL request stream through two spec-built services
    that differ only in ``EngineConfig.serve_fusion`` — "compose" (the
    pre-megakernel jnp gather/shift + fused margins kernel + jnp tau
    compare) vs "mega" (gather, binarize, match, windowed margin and the
    escalation mask in ONE resident pallas_call) — at tenants=8, slots=32.
    Interleaved passes + per-arm median us/request, same protocol as
    `telemetry_overhead_bench`; preds/margins/escalations must be
    bit-identical (the fusion is a pure execution change)."""
    from repro.serve import acam_service as svc_lib
    from repro.serve.control import HybridService

    tenants, slots = 8, 32
    requests = 256 if smoke else 1024

    def build(serve_fusion):
        spec = make_spec(slots, requests=requests)
        spec = spec._replace(engine=spec.engine._replace(
            serve_fusion=serve_fusion))
        svc = HybridService.from_spec(spec)
        protos = []
        for t in range(tenants):
            bank, head, p = svc_lib.make_synthetic_tenant(
                seed * 1000 + t, num_classes=NUM_CLASSES,
                num_features=NUM_FEATURES)
            svc.register_tenant(f"t{t}", bank, head=head)
            protos.append(p)
        rng = np.random.RandomState(seed)
        reqs = []
        for i, t in enumerate(rng.randint(0, tenants, size=requests)):
            feats, _ = svc_lib.sample_tenant_queries(seed + i, protos[t], 1,
                                                     noise=0.8)
            reqs.append(svc_lib.ClassifyRequest(f"t{t}", feats[0]))
        svc.serve(reqs)  # full-stream warmup: compile every batch shape
        return svc, reqs

    def measure(svc, reqs):
        svc.reset_metrics()
        sig = [(r.tenant_id, r.pred, r.escalated, round(r.margin, 6))
               for r in svc.serve(reqs)]
        return svc.metrics(), sig

    comp_svc, comp_reqs = build("compose")
    mega_svc, mega_reqs = build("mega")
    comp_us_all, mega_us_all = [], []
    comp_sig = mega_sig = mega_m = None
    for _ in range(9):
        m, comp_sig = measure(comp_svc, comp_reqs)
        comp_us_all.append(1e6 / m["requests_per_s"])
        m, mega_sig = measure(mega_svc, mega_reqs)
        mega_us_all.append(1e6 / m["requests_per_s"])
        if mega_m is None or m["requests_per_s"] > mega_m["requests_per_s"]:
            mega_m = m
    assert mega_sig == comp_sig, \
        "mega-kernel changed served results (must be a pure fusion)"
    comp_us = float(np.median(comp_us_all))
    mega_us = float(np.median(mega_us_all))
    entry = {
        "tenants": tenants, "slots": slots, "requests": requests,
        "classes": NUM_CLASSES, "matching_backend": "default",
        "bank_sharding": 1,
        "megakernel_speedup_pct": round(100.0 * (comp_us - mega_us)
                                        / comp_us, 2),
        "compose_us_per_request": round(comp_us, 3),
        "mega_us_per_request": round(mega_us, 3),
        "requests_per_s": mega_m["requests_per_s"],
        "latency_p50_ms": mega_m["latency_p50_ms"],
        "latency_p99_ms": mega_m["latency_p99_ms"],
        "escalation_rate": mega_m["escalation_rate"],
        "nj_per_request": mega_m["nj_per_request"],
        "occupancy": mega_m["occupancy"],
        "classify_dispatches": mega_m["classify_dispatches"],
    }
    print(f"serve mega-kernel: {entry['megakernel_speedup_pct']:+.2f}% "
          f"({comp_us:.1f} -> {mega_us:.1f} us/request, bit-identical "
          "results)")
    return entry


def _traces():
    """Import benchmarks/traces.py under both invocation styles (package
    via benchmarks.run, script dir on sys.path via `python
    benchmarks/serving_bench.py`)."""
    try:
        from benchmarks import traces
    except ImportError:
        import traces
    return traces


def burst_bench(*, smoke: bool = False, seed: int = 0) -> dict:
    """p99-under-burst + shed rate: replay a seeded bursty Zipf trace
    against a service whose overload policy is armed (``shed_queue``), so
    burst phases push the queue past the threshold and ticks degrade to
    ACAM-only answers. The row tracks burst-phase p99 separately from calm
    p99 and records how much of the traffic was shed — the shed numbers
    are RE-DERIVED from the flight recorder's event log (tick lines +
    shed_on/shed_off flips) and cross-checked against the registry."""
    import tempfile

    from repro.obs import read_events
    from repro.serve.control import HybridService

    traces = _traces()
    slots = 32
    cfg = traces.TraceConfig(
        seed=seed, tenants=8, classes=NUM_CLASSES,
        num_features=NUM_FEATURES, requests=256 if smoke else 1024,
        burst=128, calm=8, phase_ticks=3)
    with tempfile.TemporaryDirectory() as td:
        spec = make_spec(slots, requests=cfg.requests, telemetry_dir=td)
        spec = spec._replace(
            cascade=spec.cascade._replace(shed_queue=2 * slots))
        svc = HybridService.from_spec(spec)
        pool = traces.TenantPool(cfg)
        pool.register_all(svc)
        svc.serve([pool.request(0, seed + 1)])  # compile warmup
        svc.reset_metrics()
        svc, stats = traces.replay(svc, traces.make_trace(cfg), pool)
        m = svc.metrics()
        # the black box is the source of truth for the shed story: shed
        # ticks are tick lines that dispatched in shed mode, shed requests
        # sum over the same lines, episodes come from the flip events
        events = read_events(svc.obs.events.path)
        tick_lines = [e for e in events if e["kind"] == "tick"]
        shed_ticks = sum(1 for e in tick_lines
                         if e["shed_mode"] and e["fill"])
        shed_requests = sum(e["shed"] for e in tick_lines)
        shed_intervals = sum(1 for e in events if e["kind"] == "shed_on")
    assert shed_ticks == m["load_shed_ticks"], \
        (shed_ticks, m["load_shed_ticks"])
    assert shed_requests == m["shed"], (shed_requests, m["shed"])
    entry = {
        "tenants": cfg.tenants, "slots": slots, "requests": cfg.requests,
        "classes": cfg.classes, "matching_backend": "default",
        "bank_sharding": svc.registry.bank_shards,
        "trace": "burst",
        "p99_burst_ms": stats["p99_burst_ms"],
        "p99_calm_ms": stats["p99_calm_ms"],
        "shed_rate": round(shed_requests / max(m["completed"], 1), 4),
        "load_shed_ticks": shed_ticks,
        "shed_intervals": shed_intervals,
        "requests_per_s": m["requests_per_s"],
        "latency_p50_ms": m["latency_p50_ms"],
        "latency_p99_ms": m["latency_p99_ms"],
        "escalation_rate": m["escalation_rate"],
        "nj_per_request": m["nj_per_request"],
        "occupancy": m["occupancy"],
        "classify_dispatches": m["classify_dispatches"],
    }
    print(f"burst trace: p99 burst {entry['p99_burst_ms']} ms vs calm "
          f"{entry['p99_calm_ms']} ms, shed rate {entry['shed_rate']:.3f} "
          f"({entry['load_shed_ticks']} shed ticks over "
          f"{entry['shed_intervals']} episodes, from the event log)")
    return entry


def chaos_bench(*, smoke: bool = False, seed: int = 0,
                telemetry_dir: str | None = None) -> dict:
    """Kill-and-restore recovery time: replay a trace with a mid-stream
    kill injected (the service object is dropped — in-flight queue lost,
    durable snapshot survives) and measure snapshot-restore-to-serving
    wall time. Asserts the restored service is bit-identical to a clean
    build on a fixed probe set. Under ``REPRO_FORCE_MESH`` the service
    runs bank-sharded (spec-owned mesh), so the restore also exercises the
    mesh-reinstall path.

    The row's resilience numbers come out of the flight recorder's JSONL
    event log — the snapshot rides the ``ObsSpec`` so the restored
    incarnation reopens the SAME ``events.jsonl`` in append mode:
    ``recovery_ms`` is the ``restore`` event's duration, ``lost_in_flight``
    the queue depth on the dead incarnation's last ``tick`` line, both
    cross-checked against the replay harness. ``telemetry_dir`` keeps the
    log (plus a rendered ``metrics.prom``) on disk for the CI
    telemetry-smoke job's `python -m repro.obs.export` pass."""
    import tempfile

    import jax

    from repro.checkpoint.checkpointer import Checkpointer
    from repro.distributed import context, forcemesh
    from repro.obs import read_events, write_prometheus
    from repro.serve.control import HybridService

    traces = _traces()
    sharded = forcemesh.env_spec() is not None \
        and len(jax.devices()) % 2 == 0
    if sharded:
        context.clear()
    slots = 32
    # phase_ticks=1 keeps a standing queue, so the kill catches (and the
    # lost_in_flight row reports) genuinely in-flight work
    cfg = traces.TraceConfig(
        seed=seed, tenants=8, classes=NUM_CLASSES,
        num_features=NUM_FEATURES, requests=192 if smoke else 768,
        burst=64, calm=8, phase_ticks=1)
    with tempfile.TemporaryDirectory() as td:
        tel_dir = telemetry_dir or os.path.join(td, "telemetry")
        spec = make_spec(slots, requests=cfg.requests,
                         bank_shards=2 if sharded else 1,
                         install_mesh=sharded, telemetry_dir=tel_dir)
        ckpt = Checkpointer(os.path.join(td, "ckpt"), keep=3)
        svc = HybridService.from_spec(spec)
        pool = traces.TenantPool(cfg)
        pool.register_all(svc)
        svc.serve([pool.request(0, seed + 1)])  # compile warmup
        svc.reset_metrics()
        chaos = traces.ChaosPlan(ckpt=ckpt, snapshot_every=2,
                                 kill_at_tick=3)
        svc, stats = traces.replay(svc, traces.make_trace(cfg), pool,
                                   chaos=chaos)
        assert stats["killed"] and stats["recovery_ms"] is not None
        m = svc.metrics()

        # restored-vs-clean bit-identity probe: the restored incarnation
        # must serve exactly what a never-killed service would (the clean
        # build runs telemetry-sinks-off — also proving the kill/restore
        # story is identical with and without the recorder's sinks)
        probe = [pool.request(t % cfg.tenants, 999_000 + t)
                 for t in range(64)]
        sig = [(r.tenant_id, r.pred, r.escalated, round(r.margin, 6))
               for r in svc.serve(probe)]
        clean = HybridService.from_spec(spec._replace(
            obs=spec.obs._replace(telemetry_dir=None)))
        pool.register_all(clean)
        clean_sig = [(r.tenant_id, r.pred, r.escalated, round(r.margin, 6))
                     for r in clean.serve(probe)]
        assert sig == clean_sig, "restored service diverged from clean build"

        # re-derive the resilience numbers from the black box (validating
        # every line on the way): one restore event, snapshots before the
        # kill, and the dead incarnation's final tick line still readable
        events = read_events(svc.obs.events.path)
        kills = [i for i, e in enumerate(events) if e["kind"] == "restore"]
        assert len(kills) == 1, f"expected one restore event, got {kills}"
        assert any(e["kind"] == "snapshot" for e in events[:kills[0]]), \
            "no durable snapshot event before the kill"
        pre_ticks = [e for e in events[:kills[0]] if e["kind"] == "tick"]
        lost = pre_ticks[-1]["queue_depth"]
        assert lost == stats["lost_in_flight"], \
            (lost, stats["lost_in_flight"])
        recovery_ms = events[kills[0]]["duration_ms"]
        if telemetry_dir:
            write_prometheus(svc.obs.registry,
                             os.path.join(tel_dir, "metrics.prom"))
    if sharded:
        context.clear()
    entry = {
        "tenants": cfg.tenants, "slots": slots, "requests": cfg.requests,
        "classes": cfg.classes, "matching_backend": "default",
        "bank_sharding": 2 if sharded else 1,
        "trace": "chaos",
        "recovery_ms": recovery_ms,
        "lost_in_flight": lost,
        "requests_per_s": m["requests_per_s"],
        "latency_p50_ms": m["latency_p50_ms"],
        "latency_p99_ms": m["latency_p99_ms"],
        "escalation_rate": m["escalation_rate"],
        "nj_per_request": m["nj_per_request"],
        "occupancy": m["occupancy"],
        "classify_dispatches": m["classify_dispatches"],
    }
    print(f"chaos trace: killed mid-stream, restored bit-identical in "
          f"{entry['recovery_ms']:.1f} ms "
          f"({entry['lost_in_flight']} in-flight lost, "
          f"bank_shards={entry['bank_sharding']}; numbers from the "
          "event log)")
    return entry


def autopilot_bench(*, smoke: bool = True, seed: int = 0,
                    telemetry_dir: str | None = None) -> dict | None:
    """The self-driving fleet row: a bursty Zipf churn trace served with
    the `repro.fleet` autopilot on, under a forced host mesh.

    Tenants arrive via a `FleetManifest` apply; the trace's churn ops are
    expressed as further manifest applies (evict the coldest tenant, then
    the manifest that brings it back). The telemetry policy escalates
    ``bank_shards`` under row pressure through the DOUBLE-BUFFERED rolling
    reshard — shadow bank built between ticks, flipped at a tick boundary,
    no drain — and the row prices that flip (``flip_downtime_ms``, from
    the event log's ``buffer_flip`` lines) against the drained
    ``reconfigure`` alternative measured in-situ on an identical service
    (``drained_downtime_ms``). Asserts: at least one policy-initiated
    flip; flip downtime strictly below the drained downtime; served
    results bit-identical to a pinned-spec run of the same trace (policy
    transitions are pure execution changes); and every logged
    ``policy_decision`` replays to the same action from its frozen view
    alone. Needs ``REPRO_FORCE_MESH`` (even device count); returns None
    with a note when unavailable."""
    import tempfile

    import jax

    from repro.distributed import context, forcemesh
    from repro.fleet import (Autopilot, FleetManifest, PolicySpec,
                             RegistryView, TenantSpec, explain,
                             should_compact)
    from repro.obs import read_events, write_prometheus
    from repro.serve.control import HybridService

    if forcemesh.env_spec() is None or len(jax.devices()) % 2:
        print("skipping autopilot row: set REPRO_FORCE_MESH (even device "
              "count)")
        return None
    traces = _traces()
    slots = 16
    # 6 tenants x 64 classes = 384 registered rows: 0.75 of the doubled
    # 512-row capacity, exactly the policy's row-pressure threshold
    cfg = traces.TraceConfig(
        seed=seed, tenants=6, classes=64, num_features=NUM_FEATURES,
        requests=160 if smoke else 640, burst=48, calm=8, phase_ticks=2,
        churn_every=3)
    manifest = FleetManifest(tenants=tuple(
        TenantSpec(tenant_id=f"t{t}", seed=cfg.seed * 1000 + t,
                   num_classes=cfg.classes)
        for t in range(cfg.tenants)))
    coldest = int(np.argmin(traces.zipf_weights(cfg)))
    without_cold = FleetManifest(tenants=tuple(
        t for t in manifest.tenants if t.tenant_id != f"t{coldest}"))
    pool = traces.TenantPool(cfg)
    trace = traces.make_trace(cfg)

    def serve_trace(svc, pilot):
        sig = []
        for op in trace:
            kind = op[0]
            if kind == "submit":
                svc.submit(pool.request(op[1], op[2]))
            elif kind == "evict":
                svc.apply_manifest(without_cold)
            elif kind == "register":
                svc.apply_manifest(manifest)
            elif kind == "tick":
                sig.extend((r.tenant_id, r.pred, r.escalated,
                            round(r.margin, 6)) for r in svc.step())
                if pilot is not None:
                    pilot.observe_tick()
                    sig.extend((r.tenant_id, r.pred, r.escalated,
                                round(r.margin, 6))
                               for r in pilot.take_drained())
        return sig

    context.clear()
    with tempfile.TemporaryDirectory() as td:
        tel_dir = telemetry_dir or os.path.join(td, "telemetry")
        spec = make_spec(slots, requests=cfg.requests, bank_shards=1,
                         install_mesh=True, telemetry_dir=tel_dir)
        svc = HybridService.from_spec(spec)
        svc.apply_manifest(manifest)
        svc.serve([pool.request(0, seed + 1)])  # compile warmup
        svc.reset_metrics()
        pilot = Autopilot(svc, policy=PolicySpec(interval=4, hysteresis=2,
                                                 cooldown=8))
        sig = serve_trace(svc, pilot)
        m = svc.metrics()

        # the black box is the source of truth: flips, decisions and
        # manifest applies all come off the JSONL event log, and every
        # decision must replay from its own frozen view
        events = read_events(svc.obs.events.path)
        flips = [e for e in events if e["kind"] == "buffer_flip"]
        decisions = [e for e in events if e["kind"] == "policy_decision"]
        applies = sum(1 for e in events if e["kind"] == "manifest_apply")
        assert flips, "autopilot never executed a double-buffered reshard"
        assert applies >= 2, "churn never went through the manifest path"
        for e in decisions:
            view = RegistryView.from_dict(e["view"])
            act = explain(view, pilot.policy)[0]
            if act == "hold" and should_compact(view, pilot.policy):
                act = "compact"
            assert act == e["action"], (act, e["action"])
        if telemetry_dir:
            write_prometheus(svc.obs.registry,
                             os.path.join(tel_dir, "metrics.prom"))

        # pinned-spec control arm: same trace, same manifest churn, no
        # autopilot — the policy's transitions must not change results
        context.clear()
        pinned = HybridService.from_spec(spec._replace(
            obs=spec.obs._replace(telemetry_dir=None)))
        pinned.apply_manifest(manifest)
        pinned.serve([pool.request(0, seed + 1)])
        pinned.reset_metrics()
        pin_sig = serve_trace(pinned, None)
        assert sig == pin_sig, "autopilot changed served results"

        # the drained alternative, priced in-situ: identical service,
        # full queue, quiesce-and-reshard 1->2
        context.clear()
        drained_svc = HybridService.from_spec(spec._replace(
            obs=spec.obs._replace(telemetry_dir=None)))
        drained_svc.apply_manifest(manifest)
        warm = [pool.request(t % cfg.tenants, 777_000 + t)
                for t in range(4 * slots)]
        drained_svc.serve(warm)  # warm every bucketed shape
        for r in warm[:slots]:
            drained_svc.submit(r)
        report = drained_svc.reconfigure(drained_svc.spec._replace(
            mesh=drained_svc.spec.mesh._replace(bank_shards=2)))
        assert len(report.drained) == slots
        drained_ms = round(report.downtime_s * 1e3, 3)
        context.clear()

    flip_ms = max(e["flip_ms"] for e in flips)
    assert flip_ms < drained_ms, \
        f"flip {flip_ms} ms not below drained {drained_ms} ms"
    entry = {
        "tenants": cfg.tenants, "slots": slots, "requests": cfg.requests,
        "classes": cfg.classes, "matching_backend": "default",
        "bank_sharding": svc.registry.bank_shards,
        "trace": "autopilot",
        "flip_downtime_ms": flip_ms,
        "drained_downtime_ms": drained_ms,
        "policy_flips": len(flips),
        "policy_decisions": len(decisions),
        "manifest_applies": applies,
        "requests_per_s": m["requests_per_s"],
        "latency_p50_ms": m["latency_p50_ms"],
        "latency_p99_ms": m["latency_p99_ms"],
        "escalation_rate": m["escalation_rate"],
        "nj_per_request": m["nj_per_request"],
        "occupancy": m["occupancy"],
        "classify_dispatches": m["classify_dispatches"],
    }
    print(f"autopilot trace: {len(flips)} rolling reshards to "
          f"bank_shards={entry['bank_sharding']}, flip "
          f"{flip_ms:.2f} ms vs drained {drained_ms:.1f} ms "
          f"({len(decisions)} policy decisions, {applies} manifest "
          "applies, bit-identical to the pinned run)")
    return entry


def lm_cache_bench(*, smoke: bool = False, seed: int = 0) -> list[dict]:
    """The ACAM semantic cache in front of LM decode, swept over hit rate.

    One ``serving_lm_decode_only`` baseline row (the bare continuous-
    batching `Engine` over the identical prompt set) plus one
    ``serving_lm_cache_h{0,50,90}`` row per target hit rate: the bank is
    warmed with a fixed prompt pool, then a measured window of R requests
    containing EXACTLY round(R*h) Zipf-weighted repeats (hits) and
    R - round(R*h) fresh prompts (decode misses) is served through
    `repro.serve.semantic_cache.SemanticCacheService`.

    Honesty note on the means: with per-miss decode cost D and per-hit
    cost o << D, mean-vs-decode-only improvements are amortisation-bounded
    by 1/(1-h) — ~10x at h=0.9 no matter how cheap the hit path is. The
    rows therefore record BOTH the mean ratios (``mean_speedup``,
    ``mean_energy_ratio``, ceiling 1/(1-h)) and the hit-path ratios
    (``hit_path_speedup``, ``hit_path_energy_ratio`` — the paper's
    E_backend-vs-frontend asymmetry, Eq. 14 nJ against per-token decode
    energy, orders of magnitude). Both engines run the SMOKE arch in
    interpret mode, which deflates the decode side of every latency
    ratio by orders of magnitude — treat ``hit_path_speedup`` as a hard
    lower bound; the energy ratios are modelled and arch-scaled, so
    they transfer."""
    import time as time_mod

    import jax

    from repro import configs
    from repro.models import lm as lm_mod
    from repro.serve import spec as spec_lib
    from repro.serve.engine import Engine, Request
    from repro.serve.semantic_cache import (PromptRequest,
                                            SemanticCacheService)

    arch = "tinyllama-1.1b"
    requests = 20 if smoke else 60
    pool_size, plen, max_new, slots = 8, 12, 8, 16
    cfg = configs.get(arch, smoke=True)
    params = lm_mod.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.RandomState(seed)
    pool = [rng.randint(0, cfg.vocab, size=plen).astype(np.int32)
            for _ in range(pool_size)]
    fresh_pool = [rng.randint(0, cfg.vocab, size=plen).astype(np.int32)
                  for _ in range(requests)]
    zipf = 1.0 / np.arange(1, pool_size + 1) ** 1.2
    zipf /= zipf.sum()

    def measured_trace(h: float) -> tuple[list[np.ndarray], int]:
        n_hit = int(round(requests * h))
        prompts = [pool[i] for i in rng.choice(pool_size, size=n_hit,
                                               p=zipf)]
        prompts += fresh_pool[:requests - n_hit]
        order = np.random.RandomState(seed + 1).permutation(requests)
        return [prompts[i] for i in order], n_hit

    # decode-only baseline: the bare engine over a representative window.
    # The warmup pass MUST be full-size: with > batch_size queued
    # requests, continuous batching joins prefill at padded lengths, and
    # those shapes compile the first time they appear — a group-of-4
    # warmup alone leaves ~seconds of compilation inside the timed pass.
    base_eng = Engine(cfg, params, batch_size=4, max_len=64, seed=seed)
    base_prompts, _ = measured_trace(0.0)
    reqs = [Request(prompt=p, max_new_tokens=max_new)
            for p in base_prompts]
    base_eng.generate(reqs)  # compile warmup, join shapes included
    t0 = time_mod.perf_counter()
    base_eng.generate([Request(prompt=p, max_new_tokens=max_new)
                       for p in base_prompts])
    base_us = (time_mod.perf_counter() - t0) * 1e6 / requests
    from repro.core.energy import lm_decode_energy

    base_nj = lm_decode_energy(cfg.active_param_count(),
                               plen + max_new) * 1e9
    entries = [{
        "tenants": 1, "slots": 4, "requests": requests, "classes": 0,
        "matching_backend": "default", "bank_sharding": 1,
        "arch": cfg.name, "lm_baseline": True,
        "us_per_request": round(base_us, 1),
        "decode_energy_nj": round(base_nj, 3),
        "requests_per_s": round(1e6 / base_us, 2),
        "latency_p50_ms": round(base_us / 1e3, 3),
        "latency_p99_ms": round(base_us / 1e3, 3),
        "escalation_rate": 1.0, "nj_per_request": round(base_nj, 3),
        "occupancy": 0.0, "classify_dispatches": 0,
    }]
    print(f"lm decode-only baseline: {base_us:.0f} us/request, "
          f"{base_nj:.1f} nJ/request modelled")

    eng = Engine(cfg, params, batch_size=4, max_len=64, seed=seed)
    for h in (0.0, 0.5, 0.9):
        spec = spec_lib.ServiceSpec(
            registry=spec_lib.RegistrySpec(num_features=NUM_FEATURES),
            scheduler=spec_lib.SchedulerSpec(slots=slots),
            cascade=spec_lib.CascadeSpec(backend="lm", tau=8.0,
                                         tau_units="count",
                                         max_queue=4096),
            router=spec_lib.RouterSpec(
                max_templates=pool_size + requests + slots,
                response_capacity=4096),
            mesh=spec_lib.MeshSpec(install=False))
        svc = SemanticCacheService.from_spec(spec, engine=eng)
        svc.add_tenant("edge-0")
        # warm: admit the pool one-by-one, then one slots-wide all-miss
        # burst so the worst-case escalation join shapes are compiled
        # before the measured window (same trap as the baseline above)
        for p in pool:
            svc.serve_prompts([PromptRequest("edge-0", p,
                                             max_new_tokens=max_new)])
        warm = [rng.randint(0, cfg.vocab, size=plen).astype(np.int32)
                for _ in range(slots)]
        svc.serve_prompts(PromptRequest("edge-0", p,
                                        max_new_tokens=max_new)
                          for p in warm)
        svc.reset_metrics()
        prompts, n_hit = measured_trace(h)
        out = []
        t0 = time_mod.perf_counter()
        for i in range(0, requests, slots):
            out.extend(svc.serve_prompts(
                PromptRequest("edge-0", p, max_new_tokens=max_new)
                for p in prompts[i:i + slots]))
        us = (time_mod.perf_counter() - t0) * 1e6 / requests
        hits = [r for r in out if r.cache_hit]
        assert len(hits) == n_hit, (len(hits), n_hit)  # exact hit rate
        m = svc.metrics()
        # hit-path cost, isolated: an all-hit probe burst AFTER the
        # window (in-window hit latencies are tick latencies — they
        # include the co-scheduled misses' decode time, which is the
        # amortisation story, not the hit-path story)
        probe = [pool[i % pool_size] for i in range(slots)]
        t0 = time_mod.perf_counter()
        probed = svc.serve_prompts(
            PromptRequest("edge-0", p, max_new_tokens=max_new)
            for p in probe)
        hit_us = (time_mod.perf_counter() - t0) * 1e6 / slots
        assert all(r.cache_hit for r in probed), "probe burst must hit"
        hit_nj = float(np.median([r.energy_j for r in probed])) * 1e9
        entry = {
            "tenants": 1, "slots": slots, "requests": requests,
            "classes": 0, "matching_backend": "default",
            "bank_sharding": 1, "arch": cfg.name,
            "hit_rate": h,
            "mean_speedup": round(base_us / us, 2),
            "mean_energy_ratio": round(base_nj / m["nj_per_request"], 2)
            if m["nj_per_request"] else None,
            "hit_path_speedup": round(base_us / hit_us, 1),
            "hit_path_energy_ratio": round(base_nj / hit_nj, 1),
            "hit_path_us": round(hit_us, 1),
            "hit_path_nj": round(hit_nj, 4),
            "decode_us_per_request": round(base_us, 1),
            "decode_energy_nj": round(base_nj, 3),
            "requests_per_s": m["requests_per_s"],
            "latency_p50_ms": m["latency_p50_ms"],
            "latency_p99_ms": m["latency_p99_ms"],
            "escalation_rate": m["escalation_rate"],
            "nj_per_request": m["nj_per_request"],
            "occupancy": m["occupancy"],
            "classify_dispatches": m["classify_dispatches"],
        }
        assert m["classify_dispatches"] == m["ticks"], m  # ONE per tick
        entries.append(entry)
        print(f"lm cache h={h:.1f}: {us:.0f} us/request "
              f"(mean x{entry['mean_speedup']}, "
              f"bound {1 / (1 - h):.0f}x), "
              f"{m['nj_per_request']:.1f} nJ/request; hit path "
              f"x{entry['hit_path_speedup']} latency, "
              f"x{entry['hit_path_energy_ratio']} energy")
    return entries


def sweep(*, smoke: bool = False, seed: int = 0) -> list[dict]:
    tenant_grid = SMOKE_TENANTS if smoke else TENANT_SWEEP
    slot_grid = SMOKE_SLOTS if smoke else SLOT_SWEEP

    entries = []
    for tenants in tenant_grid:
        for slots in slot_grid:
            requests = (2 * max(slots, 32) if smoke
                        else max(4 * slots, 128))
            entries.append(bench_service(tenants, slots, requests=requests,
                                         seed=seed))
            _report(entries[-1])
    # one device-physics row: the same service stack through the RRAM-CMOS
    # behavioural models (repro.match "device" backend), tracking how much
    # hardware-faithful simulation costs relative to the kernel path
    tenants, slots = tenant_grid[-1], max(slot_grid)
    entries.append(bench_service(tenants, slots,
                                 requests=2 * max(slots, 32) if smoke
                                 else max(4 * slots, 128),
                                 seed=seed, backend="device"))
    _report(entries[-1])
    # bank-scaling rows: replicated vs sharded super-bank (the crossover)
    entries.extend(bank_scaling_sweep(smoke=smoke, seed=seed))
    # live-reshard row: spec-built service, 1 -> 2 shards mid-stream
    reshard = reshard_bench(seed=seed)
    if reshard is not None:
        entries.append(reshard)
    # resilience rows: p99-under-burst + shed rate, and kill/restore
    # recovery time (benchmarks/traces.py chaos harness), both re-derived
    # from the flight recorder's event log
    entries.append(burst_bench(smoke=smoke, seed=seed))
    entries.append(chaos_bench(smoke=smoke, seed=seed))
    # self-driving fleet row: autopilot over a churn trace, flip-vs-drained
    pilot_row = autopilot_bench(smoke=smoke, seed=seed)
    if pilot_row is not None:
        entries.append(pilot_row)
    # telemetry tax: sinks-off vs full recorder on one identical stream
    entries.append(telemetry_overhead_bench(smoke=smoke, seed=seed))
    # serve fusion win: composed tick vs the resident mega-kernel
    entries.append(megakernel_bench(smoke=smoke, seed=seed))
    # ACAM-as-semantic-cache in front of LM decode: hit-rate sweep +
    # decode-only baseline (hit-path AND amortisation-bounded mean ratios)
    entries.extend(lm_cache_bench(smoke=smoke, seed=seed))
    return entries


def write_bench_json(entries: list[dict],
                     path: str = "BENCH_serving.json") -> None:
    from repro.kernels import tuning

    payload = {
        "backend": tuning.backend(),
        "interpret": tuning.interpret_mode(),
        "entries": entries,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def run() -> list[dict]:
    """benchmarks/run.py harness contract."""
    from repro.distributed import forcemesh

    # phase 1 of REPRO_FORCE_MESH must precede jax backend init; this
    # module leaves jax untouched until bench_service, so this is in time
    forcemesh.apply_xla_flags()
    fast = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
    entries = sweep(smoke=fast)
    write_bench_json(entries)
    return [{"name": _row_name(e), "us_per_call":
             round(1e6 / e["requests_per_s"], 2)
             if e["requests_per_s"] else 0.0,
             "derived": _row_derived(e)} for e in entries]


def _row_name(e: dict) -> str:
    if e.get("lm_baseline"):
        return "serving_lm_decode_only"
    if "hit_rate" in e:
        return f"serving_lm_cache_h{int(round(e['hit_rate'] * 100))}"
    if "megakernel_speedup_pct" in e:
        return "serving_megakernel"
    if "telemetry_overhead_pct" in e:
        return "serving_telemetry_overhead"
    if "flip_downtime_ms" in e:
        return "serving_autopilot"
    if "reshard_downtime_ms" in e:
        return f"serving_reshard_1to{e['bank_sharding']}"
    if e.get("trace") == "chaos":
        return "serving_chaos_recovery"
    if e.get("trace") == "burst":
        return f"serving_burst_t{e['tenants']}_s{e['slots']}"
    return (f"serving_t{e['tenants']}_c{e['classes']}_s{e['slots']}"
            + ("" if e["bank_sharding"] == 1
               else f"_shard{e['bank_sharding']}")
            + ("" if e["matching_backend"] == "default"
               else f"_{e['matching_backend']}"))


def _row_derived(e: dict) -> str:
    if e.get("lm_baseline"):
        return (f"{e['us_per_request']}us/req,"
                f"{e['decode_energy_nj']}nJ/req,decode-only")
    if "hit_rate" in e:
        return (f"h={e['hit_rate']},mean_x{e['mean_speedup']},"
                + (f"hitpath_x{e['hit_path_speedup']}us/"
                   f"x{e['hit_path_energy_ratio']}nJ"
                   if e["hit_path_speedup"] else "no-hits")
                + f",{e['nj_per_request']:.1f}nJ/req")
    if "megakernel_speedup_pct" in e:
        return (f"speedup={e['megakernel_speedup_pct']}%,"
                f"compose={e['compose_us_per_request']}us,"
                f"mega={e['mega_us_per_request']}us")
    if "telemetry_overhead_pct" in e:
        return (f"overhead={e['telemetry_overhead_pct']}%,"
                f"base={e['base_us_per_request']}us,"
                f"tel={e['telemetry_us_per_request']}us")
    if "flip_downtime_ms" in e:
        return (f"flip={e['flip_downtime_ms']}ms,"
                f"drained={e['drained_downtime_ms']}ms,"
                f"flips={e['policy_flips']},"
                f"shards={e['bank_sharding']}")
    if "reshard_downtime_ms" in e:
        return (f"downtime={e['reshard_downtime_ms']}ms,"
                f"moved={e['tenants_moved']},"
                f"{e['requests_per_s']:.0f}req/s")
    if e.get("trace") == "chaos":
        return (f"recovery={e['recovery_ms']}ms,"
                f"lost={e['lost_in_flight']},"
                f"{e['requests_per_s']:.0f}req/s")
    if e.get("trace") == "burst":
        return (f"p99_burst={e['p99_burst_ms']}ms,"
                f"shed={e['shed_rate']:.3f},"
                f"{e['requests_per_s']:.0f}req/s")
    return (f"{e['requests_per_s']:.0f}req/s,"
            f"esc={e['escalation_rate']:.3f},"
            f"{e['nj_per_request']:.2f}nJ/req")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small tenant/slot grid")
    ap.add_argument("--reshard", action="store_true",
                    help="run ONLY the live-reshard smoke: boot the "
                         "spec-built service at bank_shards=1 under "
                         "REPRO_FORCE_MESH, reconfigure to 2 mid-stream, "
                         "assert bit-identity + one sharded dispatch per "
                         "tick, report drain->resume downtime")
    ap.add_argument("--chaos", action="store_true",
                    help="run ONLY the chaos smoke: replay a bursty trace, "
                         "kill the service mid-stream, restore from its "
                         "snapshot, assert bit-identity vs a clean build, "
                         "and append the recovery-time row to "
                         "BENCH_serving.json")
    ap.add_argument("--megakernel", action="store_true",
                    help="run ONLY the serve mega-kernel A/B: interleaved "
                         "serve_fusion=mega vs =compose passes over the "
                         "same request stream (bit-identical signatures "
                         "asserted), then append/replace the "
                         "serving_megakernel row in BENCH_serving.json")
    ap.add_argument("--lm-cache", action="store_true",
                    help="run ONLY the ACAM-semantic-cache-vs-LM-decode "
                         "sweep: decode-only baseline plus exact hit "
                         "rates {0, 0.5, 0.9}, then append/replace the "
                         "serving_lm_* rows in BENCH_serving.json")
    ap.add_argument("--autopilot", action="store_true",
                    help="run ONLY the self-driving fleet smoke: bursty "
                         "Zipf churn trace with the repro.fleet autopilot "
                         "on under REPRO_FORCE_MESH — asserts at least one "
                         "policy-initiated double-buffered reshard, "
                         "bit-identity vs a pinned-spec run, and flip "
                         "downtime strictly below the drained reshard — "
                         "then append the serving_autopilot row to "
                         "BENCH_serving.json")
    ap.add_argument("--telemetry-dir", default=None, metavar="DIR",
                    help="with --chaos or --autopilot: keep the flight "
                         "recorder's events.jsonl + metrics.prom in DIR so "
                         "the CI smoke jobs can validate them with "
                         "`python -m repro.obs.export`")
    args = ap.parse_args()
    if args.reshard or args.chaos or args.autopilot:
        from repro.distributed import forcemesh

        forcemesh.apply_xla_flags()
    if args.reshard:
        entry = reshard_bench()
        if entry is None:
            raise SystemExit("--reshard needs REPRO_FORCE_MESH=DxM")
        return
    if args.chaos:
        entry = chaos_bench(smoke=True, telemetry_dir=args.telemetry_dir)
        assert entry["recovery_ms"] is not None, "service never recovered"
        path = "BENCH_serving.json"
        if os.path.exists(path):
            with open(path) as f:
                payload = json.load(f)
            payload["entries"] = [e for e in payload["entries"]
                                  if e.get("trace") != "chaos"] + [entry]
            with open(path, "w") as f:
                json.dump(payload, f, indent=1)
        else:
            write_bench_json([entry], path)
        print("appended chaos recovery row to BENCH_serving.json")
        return
    if args.autopilot:
        entry = autopilot_bench(smoke=True,
                                telemetry_dir=args.telemetry_dir)
        if entry is None:
            raise SystemExit("--autopilot needs REPRO_FORCE_MESH=DxM")
        path = "BENCH_serving.json"
        if os.path.exists(path):
            with open(path) as f:
                payload = json.load(f)
            payload["entries"] = [e for e in payload["entries"]
                                  if "flip_downtime_ms" not in e] + [entry]
            with open(path, "w") as f:
                json.dump(payload, f, indent=1)
        else:
            write_bench_json([entry], path)
        print("appended serving_autopilot row to BENCH_serving.json")
        return
    if args.lm_cache:
        rows = lm_cache_bench(smoke=args.smoke)
        path = "BENCH_serving.json"
        if os.path.exists(path):
            with open(path) as f:
                payload = json.load(f)
            payload["entries"] = [
                e for e in payload["entries"]
                if "hit_rate" not in e and not e.get("lm_baseline")] + rows
            with open(path, "w") as f:
                json.dump(payload, f, indent=1)
        else:
            write_bench_json(rows, path)
        print("appended lm semantic-cache rows to BENCH_serving.json")
        return
    if args.megakernel:
        entry = megakernel_bench(smoke=args.smoke)
        path = "BENCH_serving.json"
        if os.path.exists(path):
            with open(path) as f:
                payload = json.load(f)
            payload["entries"] = [
                e for e in payload["entries"]
                if "megakernel_speedup_pct" not in e] + [entry]
            with open(path, "w") as f:
                json.dump(payload, f, indent=1)
        else:
            write_bench_json([entry], path)
        print("appended serve mega-kernel row to BENCH_serving.json")
        return
    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
    for r in run():
        print(r)
    print("wrote BENCH_serving.json")


if __name__ == "__main__":
    main()
