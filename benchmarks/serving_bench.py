"""Serving-tier benchmark: the multi-tenant ACAM service under load.

Sweeps tenant count x scheduler micro-batch size and measures the service
end to end — admission, cross-tenant micro-batching (one fused classify
dispatch per tick), the confidence cascade, and paper §V-D energy
attribution — emitting ``BENCH_serving.json`` so the serving trajectory is
tracked PR over PR alongside ``BENCH_kernels.json``.

On this CPU container the fused kernels run in Pallas interpret mode, so
requests/s is a correctness-path number, not a TPU number; the JSON records
``backend``/``interpret`` to keep runs distinguishable. Escalation rate and
nJ/request are backend-independent.

BENCH_serving.json schema::

    {"backend": "cpu" | "tpu",
     "interpret": bool,
     "entries": [
       {"tenants": 8, "slots": 256, "requests": 1024,
        "classes": 10,                # classes per synthetic tenant
        "matching_backend": "default",  # or the pinned engine backend
                                        # ("device" = RRAM-physics row)
        "bank_sharding": 1,           # super-bank class-row shards (model
                                      # axis size; 1 = replicated bank)
        "requests_per_s": ...,        # completed / service busy time
        "latency_p50_ms": ..., "latency_p99_ms": ...,
        "escalation_rate": ...,       # cascade escalations / requests
        "nj_per_request": ...,        # E_backend (+ E_frontend if escalated)
        "occupancy": ...,             # mean batch fill fraction
        "classify_dispatches": ...}]}

The **bank-scaling sweep** (`bank_scaling_sweep`) grows tenants x classes
and, when ``REPRO_FORCE_MESH=DxM`` provides a forced host mesh, measures
every point replicated AND bank-sharded — the `bank_sharding` field is how
BENCH json tracks the replicated-vs-sharded crossover as the super-bank
outgrows one device. (On this CPU container both run through Pallas
interpret, so the sharded rows are a correctness-path number; the
crossover itself is a TPU measurement.)

``--smoke`` restricts the sweep for CI. `run()` keeps the harness contract
used by benchmarks/run.py: a list of ``{"name", "us_per_call", "derived"}``
rows.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

TENANT_SWEEP = (1, 8, 64)
SLOT_SWEEP = (1, 256)
SMOKE_TENANTS = (1, 8)
SMOKE_SLOTS = (1, 64)
NUM_FEATURES = 64
NUM_CLASSES = 10


def bench_service(tenants: int, slots: int, *, requests: int | None = None,
                  seed: int = 0, backend: str | None = None,
                  classes: int = NUM_CLASSES) -> dict:
    """Serve a mixed-tenant burst through a fresh service; return metrics.

    ``backend`` pins the scheduler's `repro.match` engine backend;
    margin_tau stays in match-count units — the service converts to the
    device backend's matchline-fraction units itself. The service infers
    ``bank_sharding`` from whatever mesh is installed when this runs
    (`bank_scaling_sweep` toggles it).
    """
    from repro.serve import acam_service as svc_lib

    requests = requests or max(4 * slots, 128)
    svc = svc_lib.ACAMService(
        NUM_FEATURES,
        config=svc_lib.ServiceConfig(slots=slots,
                                     max_queue=max(requests, 4096)),
        backend=backend)
    protos = []
    for t in range(tenants):
        bank, head, p = svc_lib.make_synthetic_tenant(
            seed * 1000 + t, num_classes=classes,
            num_features=NUM_FEATURES)
        svc.register_tenant(f"t{t}", bank, head=head)
        protos.append(p)

    rng = np.random.RandomState(seed)
    tenant_of = rng.randint(0, tenants, size=requests)
    reqs = []
    for i, t in enumerate(tenant_of):
        feats, _ = svc_lib.sample_tenant_queries(seed + i, protos[t], 1,
                                                 noise=0.8)
        reqs.append(svc_lib.ClassifyRequest(f"t{t}", feats[0]))

    # warmup tick compiles the fused dispatch so requests/s measures the
    # steady state, matching how a long-lived service behaves
    svc.serve(reqs[:1])
    svc.reset_metrics()
    responses = svc.serve(reqs)
    assert len(responses) == requests
    m = svc.metrics()
    return {
        "tenants": tenants,
        "slots": slots,
        "requests": requests,
        "classes": classes,
        "matching_backend": backend or "default",
        "bank_sharding": svc.registry.bank_shards,
        "requests_per_s": m["requests_per_s"],
        "latency_p50_ms": m["latency_p50_ms"],
        "latency_p99_ms": m["latency_p99_ms"],
        "escalation_rate": m["escalation_rate"],
        "nj_per_request": m["nj_per_request"],
        "occupancy": m["occupancy"],
        "classify_dispatches": m["classify_dispatches"],
    }


def _report(e):
    print(f"tenants={e['tenants']:3d} classes={e['classes']:3d} "
          f"slots={e['slots']:4d} shards={e['bank_sharding']} "
          f"backend={e['matching_backend']:9s}: "
          f"{e['requests_per_s']:9.1f} req/s, "
          f"escalation {e['escalation_rate']:.3f}, "
          f"{e['nj_per_request']:.2f} nJ/req, "
          f"occupancy {e['occupancy']:.2f}")


def bank_scaling_sweep(*, smoke: bool = False, seed: int = 0) -> list[dict]:
    """Grow the super-bank (tenants x classes) replicated vs bank-sharded.

    The sharded points need a model mesh axis: when ``REPRO_FORCE_MESH``
    provides forced host devices the sweep installs the mesh around each
    sharded measurement (`repro.distributed.forcemesh`); without it only
    the replicated rows are emitted.
    """
    from repro.distributed import context, forcemesh

    grid = ((4, 16), (8, 32)) if smoke else ((8, 16), (32, 32), (64, 48))
    slots = min(SLOT_SWEEP[-1], 64)
    spec = forcemesh.env_spec()
    entries = []
    for tenants, classes in grid:
        requests = 2 * slots if smoke else 4 * slots
        context.clear()
        entries.append(bench_service(tenants, slots, requests=requests,
                                     seed=seed, classes=classes))
        _report(entries[-1])
        if spec is None:
            continue
        try:
            forcemesh.install(spec)
        except RuntimeError as e:
            print(f"skipping sharded rows: {e}")
            spec = None
            continue
        entries.append(bench_service(tenants, slots, requests=requests,
                                     seed=seed, classes=classes))
        _report(entries[-1])
        context.clear()
    return entries


def sweep(*, smoke: bool = False, seed: int = 0) -> list[dict]:
    tenant_grid = SMOKE_TENANTS if smoke else TENANT_SWEEP
    slot_grid = SMOKE_SLOTS if smoke else SLOT_SWEEP

    entries = []
    for tenants in tenant_grid:
        for slots in slot_grid:
            requests = (2 * max(slots, 32) if smoke
                        else max(4 * slots, 128))
            entries.append(bench_service(tenants, slots, requests=requests,
                                         seed=seed))
            _report(entries[-1])
    # one device-physics row: the same service stack through the RRAM-CMOS
    # behavioural models (repro.match "device" backend), tracking how much
    # hardware-faithful simulation costs relative to the kernel path
    tenants, slots = tenant_grid[-1], max(slot_grid)
    entries.append(bench_service(tenants, slots,
                                 requests=2 * max(slots, 32) if smoke
                                 else max(4 * slots, 128),
                                 seed=seed, backend="device"))
    _report(entries[-1])
    # bank-scaling rows: replicated vs sharded super-bank (the crossover)
    entries.extend(bank_scaling_sweep(smoke=smoke, seed=seed))
    return entries


def write_bench_json(entries: list[dict],
                     path: str = "BENCH_serving.json") -> None:
    from repro.kernels import tuning

    payload = {
        "backend": tuning.backend(),
        "interpret": tuning.interpret_mode(),
        "entries": entries,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def run() -> list[dict]:
    """benchmarks/run.py harness contract."""
    from repro.distributed import forcemesh

    # phase 1 of REPRO_FORCE_MESH must precede jax backend init; this
    # module leaves jax untouched until bench_service, so this is in time
    forcemesh.apply_xla_flags()
    fast = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
    entries = sweep(smoke=fast)
    write_bench_json(entries)
    return [{
        "name": f"serving_t{e['tenants']}_c{e['classes']}_s{e['slots']}"
        + ("" if e["bank_sharding"] == 1 else f"_shard{e['bank_sharding']}")
        + ("" if e["matching_backend"] == "default"
           else f"_{e['matching_backend']}"),
        "us_per_call": round(1e6 / e["requests_per_s"], 2)
        if e["requests_per_s"] else 0.0,
        "derived": (f"{e['requests_per_s']:.0f}req/s,"
                    f"esc={e['escalation_rate']:.3f},"
                    f"{e['nj_per_request']:.2f}nJ/req"),
    } for e in entries]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small tenant/slot grid")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
    for r in run():
        print(r)
    print("wrote BENCH_serving.json")


if __name__ == "__main__":
    main()
