"""Replayable load + chaos traces for the serving tier.

A *trace* is a deterministic op list — submits, ticks, tenant churn —
generated from a seeded `TraceConfig`: bursty arrivals (alternating
burst/calm phases) over a Zipf-skewed tenant popularity distribution, the
shape real multi-tenant edge fleets see. The same config always yields the
same trace, so a run is replayable bit-for-bit: the chaos harness replays
one trace twice (once clean, once with a kill or a mesh shrink injected)
and compares.

`replay` drives a `HybridService` through a trace and returns the numbers
the resilience rows in ``BENCH_serving.json`` track: p99 latency split by
burst/calm phase, shed rate, and — when a `ChaosPlan` injects a mid-stream
kill — the snapshot-restore recovery time. Chaos events are positioned by
*tick index*, so they land at the same point of the trace every run.

Used by `benchmarks/serving_bench.py` (burst + chaos rows, ``--chaos``)
and `tests/test_resilience.py`.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Seeded generator config — equal configs generate equal traces."""

    seed: int = 0
    tenants: int = 8
    classes: int = 10
    num_features: int = 64
    requests: int = 512  # total submits across all phases
    zipf_a: float = 1.2  # tenant popularity skew (larger = more skewed)
    burst: int = 96  # submits per burst phase
    calm: int = 4  # submits per calm phase
    phase_ticks: int = 4  # ticks after each phase's submits
    churn_every: int = 0  # evict+re-register a cold tenant every k-th
    #                       phase (0: no churn)
    query_noise: float = 0.8  # feature noise (drives the escalation rate)


@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """Failures to inject while replaying, positioned by tick index."""

    ckpt: object = None  # Checkpointer backing kill/restore
    snapshot_every: int = 8  # snapshot cadence in ticks (0: never)
    kill_at_tick: int | None = None  # SIGKILL-equivalent: drop the service
    #                                  object, restore from the checkpoint
    lose_devices_at: int | None = None  # simulate device loss at this tick
    lose: tuple[int, ...] = (1,)  # which device indices fail
    heal_at_tick: int | None = None  # restore_devices at this tick


def zipf_weights(cfg: TraceConfig) -> np.ndarray:
    """Tenant popularity ∝ 1/(rank+1)^a over a seeded rank shuffle (which
    tenant is hot differs per seed; the skew shape does not)."""
    rng = np.random.RandomState(cfg.seed ^ 0x5EED)
    ranks = rng.permutation(cfg.tenants)
    w = 1.0 / np.power(ranks + 1.0, cfg.zipf_a)
    return w / w.sum()


def make_trace(cfg: TraceConfig) -> list[tuple]:
    """The deterministic op list. Ops:

    ``("submit", tenant_idx, qseed, phase)`` — one request, ``phase`` in
    {"burst", "calm"}; ``("tick", phase)``; ``("evict", tenant_idx)`` /
    ``("register", tenant_idx)`` — the churn pair. Ends with drain ticks.
    """
    rng = np.random.RandomState(cfg.seed)
    weights = zipf_weights(cfg)
    coldest = int(np.argmin(weights))
    ops: list[tuple] = []
    submitted = 0
    phase_i = 0
    while submitted < cfg.requests:
        phase = "burst" if phase_i % 2 == 0 else "calm"
        n = min(cfg.burst if phase == "burst" else cfg.calm,
                cfg.requests - submitted)
        for _ in range(n):
            t = int(rng.choice(cfg.tenants, p=weights))
            ops.append(("submit", t, cfg.seed * 100_003 + submitted, phase))
            submitted += 1
        ops.extend([("tick", phase)] * cfg.phase_ticks)
        phase_i += 1
        if cfg.churn_every and phase_i % cfg.churn_every == 0:
            # churn the coldest tenant: its queued requests (if any) resolve
            # against the re-registered placement at tick time
            ops.append(("evict", coldest))
            ops.append(("register", coldest))
    ops.extend([("tick", "drain")] * 64)  # bounded drain tail
    return ops


class TenantPool:
    """Deterministic synthetic tenants + per-submit queries for a trace.

    Banks, heads and prototypes come from `make_synthetic_tenant` keyed on
    the trace seed, so a restarted process regenerates the exact same
    tenants — which is what lets the chaos harness compare results across
    a kill/restore.
    """

    def __init__(self, cfg: TraceConfig):
        from repro.serve import acam_service as svc_lib

        self.cfg = cfg
        self.banks, self.heads, self.protos = [], [], []
        for t in range(cfg.tenants):
            bank, head, p = svc_lib.make_synthetic_tenant(
                cfg.seed * 1000 + t, num_classes=cfg.classes,
                num_features=cfg.num_features)
            self.banks.append(bank)
            self.heads.append(head)
            self.protos.append(p)

    def tenant_id(self, t: int) -> str:
        return f"t{t}"

    def register(self, svc, t: int) -> None:
        svc.register_tenant(self.tenant_id(t), self.banks[t],
                            head=self.heads[t])

    def register_all(self, svc) -> None:
        for t in range(self.cfg.tenants):
            self.register(svc, t)

    def request(self, t: int, qseed: int):
        from repro.serve import acam_service as svc_lib

        feats, _ = svc_lib.sample_tenant_queries(
            qseed, self.protos[t], 1, noise=self.cfg.query_noise)
        return svc_lib.ClassifyRequest(self.tenant_id(t), feats[0])


def replay(svc, trace: list[tuple], pool: TenantPool, *,
           chaos: ChaosPlan | None = None):
    """Drive ``svc`` through ``trace``, injecting ``chaos`` if given.

    Returns ``(svc, stats)`` — the service comes BACK because a chaos kill
    replaces it (the restored incarnation finishes the trace). ``stats``
    carries the resilience numbers: phase-split latencies, responses by
    disposition, and recovery/downtime timings for injected failures.
    """
    from repro.serve.acam_service import AdmissionError

    lat = {"burst": [], "calm": [], "drain": []}
    stats = {"submitted": 0, "rejected": 0, "completed": 0, "errors": 0,
             "shed": 0, "escalated": 0, "recovery_ms": None,
             "lost_in_flight": 0, "device_loss_downtime_ms": None,
             "killed": False}
    ticks = 0
    for op in trace:
        kind = op[0]
        if kind == "submit":
            _, t, qseed, _phase = op
            try:
                svc.submit(pool.request(t, qseed))
                stats["submitted"] += 1
            except AdmissionError:
                stats["rejected"] += 1
        elif kind == "evict":
            tid = pool.tenant_id(op[1])
            if tid in svc.registry:
                svc.evict_tenant(tid)
        elif kind == "register":
            if pool.tenant_id(op[1]) not in svc.registry:
                pool.register(svc, op[1])
        elif kind == "tick":
            for r in svc.step():
                stats["completed"] += 1
                stats["errors"] += r.error is not None
                stats["shed"] += r.shed
                stats["escalated"] += r.escalated
                if r.error is None:
                    lat[op[1]].append(r.latency_s)
            ticks += 1
            if chaos is not None:
                svc = _inject(svc, chaos, ticks, stats)
    for phase in ("burst", "calm"):
        key = f"p99_{phase}_ms"
        stats[key] = (round(float(np.percentile(lat[phase], 99)) * 1e3, 3)
                      if lat[phase] else None)
    return svc, stats


def _inject(svc, chaos: ChaosPlan, ticks: int, stats: dict):
    """Apply the chaos plan's events scheduled for tick ``ticks``."""
    from repro.serve.control import HybridService

    if chaos.ckpt is not None and chaos.snapshot_every \
            and ticks % chaos.snapshot_every == 0:
        svc.snapshot(chaos.ckpt)
    if ticks == chaos.kill_at_tick:
        if chaos.ckpt is None:
            raise ValueError("ChaosPlan.kill_at_tick needs a ckpt")
        if chaos.ckpt.latest_step() is None:
            svc.snapshot(chaos.ckpt)  # never kill before first durability
        # the kill: in-flight queue dies with the process; durable state
        # survives. `tests/test_resilience.py` does this across a real
        # SIGKILL'd subprocess; here the dropped object is the same deal.
        # The loss is read from the public health() view (and the bench
        # re-derives it from the JSONL event log — the dead incarnation's
        # last "tick" line carries the same queue depth).
        stats["lost_in_flight"] = svc.health()["queue_depth"]
        stats["killed"] = True
        del svc
        t0 = time.perf_counter()
        svc, _report = HybridService.restore(chaos.ckpt)
        # warm the restored service's dispatch: recovery means SERVING again
        stats["recovery_ms"] = round((time.perf_counter() - t0) * 1e3, 3)
    if ticks == chaos.lose_devices_at:
        report = svc.handle_device_loss(chaos.lose)
        stats["device_loss_downtime_ms"] = round(report.downtime_s * 1e3, 3)
        stats["post_loss_bank_shards"] = svc.registry.bank_shards
    if ticks == chaos.heal_at_tick:
        svc.restore_devices()
    return svc
