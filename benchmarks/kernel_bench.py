"""Kernel micro-benchmarks: ref-vs-kernel comparison harness.

Times the compiled jnp reference paths against the Pallas kernel paths at
deployment shapes (B in {1, 256, 4096}; M=10 templates, N=784 features) and
emits ``BENCH_kernels.json`` so the perf trajectory is tracked PR over PR.

On this CPU container the Pallas kernels execute in interpret mode (lowered
to XLA through the pallas interpreter — a correctness path, not a TPU
number), so CPU "speedup" mostly measures interpreter overhead; the JSON
records ``backend``/``interpret`` so TPU runs are distinguishable. The jnp
reference wall-times remain real regression signals for the XLA fallbacks.

BENCH_kernels.json schema::

    {"backend": "cpu" | "tpu",
     "interpret": bool,            # kernels ran via the pallas interpreter
     "entries": [
       {"kernel": "acam_match",    # | acam_similarity | *_classify_fused
                                   # | acam_device_classify (RRAM physics)
                                   # | acam_match_serve /
                                   #   acam_similarity_serve (the resident
                                   #   serving mega-kernel; ref_us = the
                                   #   pre-megakernel compose path, so
                                   #   speedup IS the fusion win)
                                   # | acam_similarity_classify_chunked
                                   #   (big-bank single-dispatch similarity;
                                   #   ref_us = jnp oracle)
                                   # | acam_match_classify_sharded
                                   #   (bank rows sharded over the model
                                   #   axis; ref_us = replicated engine,
                                   #   kernel_us = sharded engine, extra
                                   #   "bank_sharding" + "reduce" fields —
                                   #   the cross-shard reduce strategy the
                                   #   plan selected; rows appear only
                                   #   under REPRO_FORCE_MESH)
        "b": 256, "m": 10, "n": 784,
        "ref_us": 123.4,           # jnp reference, us/call
        "kernel_us": 456.7,        # timed engine backend (pallas kernels,
                                   # or the device-physics model), us/call
        "speedup": 0.27,           # ref_us / kernel_us
        "ref_cell_matches_per_us": ...,    # b*m*n / us
        "kernel_cell_matches_per_us": ...}]}

The raw ``acam_match``/``acam_similarity`` rows time the two-stage Pallas
kernels directly against their jnp oracles (kernel micro-benchmarks); the
``*_classify*`` rows go through `repro.match.MatchEngine` — the exact path
production callers execute.

``--tune`` grid-searches kernel block sizes first (repro.kernels.tuning —
the winners persist to the v2 JSON cache keyed by
``kernel|platform[+interp]|shape|dtype``, so interpreted and compiled
timings never cross-contaminate); ``--smoke`` restricts to B in {1, 256}
for CI.

`run()` keeps the harness contract used by benchmarks/run.py: a list of
``{"name", "us_per_call", "derived"}`` rows.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.distributed import forcemesh  # imports no jax

# REPRO_FORCE_MESH phase 1 (forced host devices) must land in XLA_FLAGS
# before jax initialises its CPU backend — i.e. before the import below
forcemesh.apply_xla_flags()

import jax
import jax.numpy as jnp

BENCH_SHAPES = (1, 256, 4096)  # batch sizes; the paper bank is M=10, N=784
SMOKE_SHAPES = (1, 256)
M, N = 10, 784


def _time(fn, *args, iters=20, reps=3) -> float:
    """us/call: best of `reps` timed loops (min suppresses the scheduler
    noise of this shared CPU container, the standard repeat-min protocol)."""
    out = fn(*args)  # single warmup call; reuse its result
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
            (out[0] if isinstance(out, tuple) else out).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best / iters * 1e6  # us


def _compare_entry(kernel: str, b: int, m: int, n: int, ref_us: float,
                   kernel_us: float) -> dict:
    cells = b * m * n
    return {
        "kernel": kernel, "b": b, "m": m, "n": n,
        "ref_us": round(ref_us, 2), "kernel_us": round(kernel_us, 2),
        "speedup": round(ref_us / kernel_us, 4),
        "ref_cell_matches_per_us": round(cells / ref_us, 1),
        "kernel_cell_matches_per_us": round(cells / kernel_us, 1),
    }


def compare_kernels(batches=BENCH_SHAPES, *, iters=10) -> list[dict]:
    """Ref-vs-kernel timing entries for both ACAM kernels + the fused path."""
    from repro.core import templates as T
    from repro.kernels.acam_match import ops as match_ops
    from repro.kernels.acam_match.ref import acam_match_ref
    from repro.kernels.acam_similarity import ops as sim_ops
    from repro.kernels.acam_similarity.ref import acam_similarity_ref

    key = jax.random.PRNGKey(0)
    thr = jnp.zeros((N,))
    tmpl = (jax.random.uniform(key, (M, N)) > 0.5).astype(jnp.float32)
    lo = jnp.zeros((M, N))
    hi = (jax.random.uniform(jax.random.fold_in(key, 1), (M, N)) > 0.3
          ).astype(jnp.float32)
    bank = T.TemplateBank(
        templates=tmpl[:, None, :], lower=lo[:, None, :], upper=hi[:, None, :],
        valid=jnp.ones((M, 1), bool), thresholds=thr)

    entries = []
    for b in batches:
        f = jax.random.normal(jax.random.fold_in(key, b), (b, N))
        it = max(3, iters // 4) if b >= 4096 else iters

        # kernel paths timed under jit, as deployed (hybrid._fused_forward
        # traces the dispatch into one graph; block lookup is trace-time)
        ref_us = _time(jax.jit(acam_match_ref), f, thr, tmpl, iters=it)
        ker_us = _time(jax.jit(lambda x: match_ops.match_scores(x, thr, tmpl)),
                       f, iters=it)
        entries.append(_compare_entry("acam_match", b, M, N, ref_us, ker_us))

        ref_us = _time(jax.jit(acam_similarity_ref), f, lo, hi, iters=it)
        ker_us = _time(jax.jit(lambda x: sim_ops.similarity_scores(x, lo, hi)),
                       f, iters=it)
        entries.append(_compare_entry("acam_similarity", b, M, N, ref_us,
                                      ker_us))

        # end-to-end classify through the engine layer (what production
        # callers execute): reference vs kernel (fused binarize->match->WTA)
        # vs the RRAM-device-physics backend
        from repro import match

        eng_ref = match.engine_for(backend="reference")
        eng_ker = match.engine_for(backend="kernel")
        eng_dev = match.engine_for(backend="device")

        ref_us = _time(jax.jit(lambda feats: eng_ref.classify_features(
            feats, bank)), f, iters=it)
        ker_us = _time(jax.jit(lambda feats: eng_ker.classify_features(
            feats, bank)), f, iters=it)
        entries.append(_compare_entry("acam_match_classify_fused", b, M, N,
                                      ref_us, ker_us))

        dev_us = _time(jax.jit(lambda feats: eng_dev.classify_features(
            feats, bank)), f, iters=it)
        entries.append(_compare_entry("acam_device_classify", b, M, N,
                                      ref_us, dev_us))
    return entries


def serve_entries(batches=BENCH_SHAPES, *, iters: int = 10) -> list[dict]:
    """Mega-kernel vs compose rows for the multi-tenant serve path.

    Times `MatchEngine.classify_serve` (the scheduler tick's dispatch) with
    ``serve_fusion="mega"`` (ONE resident pallas_call) against
    ``serve_fusion="compose"`` (jnp gather/shift + fused margins kernel +
    jnp tau compare) — same kernel backend both sides, so the speedup
    column IS the fusion win. Plus the big-bank chunked-similarity row
    against its jnp oracle (the coverage the similarity method gained)."""
    from repro import match
    from repro.core import templates as T

    key = jax.random.PRNGKey(2)
    n_slots = 8
    tmpl = (jax.random.uniform(key, (M, 1, N)) > 0.5).astype(jnp.float32)
    bank = T.TemplateBank(
        templates=tmpl, lower=jnp.zeros_like(tmpl),
        upper=(jax.random.uniform(jax.random.fold_in(key, 1), (M, 1, N))
               > 0.3).astype(jnp.float32),
        valid=jnp.ones((M, 1), bool), thresholds=jnp.zeros((N,)))
    thr_table = jax.random.normal(jax.random.fold_in(key, 2),
                                  (n_slots, N)) * 0.1

    entries = []
    for b in batches:
        f = jax.random.normal(jax.random.fold_in(key, b), (b, N))
        slot = jnp.asarray(
            jax.random.randint(jax.random.fold_in(key, b + 1), (b,), 0,
                               n_slots), jnp.int32)
        tau = jnp.full((b,), 2.0, jnp.float32)
        it = max(3, iters // 4) if b >= 4096 else iters
        for method, name in (("feature_count", "acam_match_serve"),
                             ("similarity", "acam_similarity_serve")):
            mega = match.engine_from_config(match.EngineConfig(
                method=method, backend="kernel", serve_fusion="mega"))
            comp = match.engine_from_config(match.EngineConfig(
                method=method, backend="kernel", serve_fusion="compose"))
            comp_us = _time(jax.jit(lambda x, s, t, e=comp: e.classify_serve(
                x, thr_table, s, bank, tau=t)), f, slot, tau, iters=it)
            mega_us = _time(jax.jit(lambda x, s, t, e=mega: e.classify_serve(
                x, thr_table, s, bank, tau=t)), f, slot, tau, iters=it)
            entries.append(_compare_entry(name, b, M, N, comp_us, mega_us))

    # big-bank chunked similarity: C=1100, K=2 exceeds the fused budget
    c_big, k_big = 1100, 2
    big = (jax.random.uniform(jax.random.fold_in(key, 9),
                              (c_big, k_big, N)) > 0.5).astype(jnp.float32)
    big_bank = T.TemplateBank(
        templates=big, lower=jnp.zeros_like(big), upper=jnp.ones_like(big),
        valid=jnp.ones((c_big, k_big), bool), thresholds=jnp.zeros((N,)))
    eng_ref = match.engine_from_config(match.EngineConfig(
        method="similarity", backend="reference"))
    eng_ker = match.engine_from_config(match.EngineConfig(
        method="similarity", backend="kernel"))
    for b in batches[:2]:  # the big bank at B=4096 is a minutes-long cell
        f = jax.random.normal(jax.random.fold_in(key, 20 + b), (b, N))
        ref_us = _time(jax.jit(lambda x: eng_ref.classify_features_margin(
            x, big_bank)), f, iters=max(3, iters // 2))
        ker_us = _time(jax.jit(lambda x: eng_ker.classify_features_margin(
            x, big_bank)), f, iters=max(3, iters // 2))
        entries.append(_compare_entry("acam_similarity_classify_chunked", b,
                                      c_big * k_big, N, ref_us, ker_us))
    return entries


def sharded_classify_entries(batches=BENCH_SHAPES, *, classes: int = 512,
                             iters: int = 10) -> list[dict]:
    """Replicated-vs-bank-sharded classify rows (the model-axis story).

    Times `MatchEngine.classify_features` over a ``classes``-row bank with
    the forced ``REPRO_FORCE_MESH`` mesh installed (super-bank class rows
    sharded over "model", batch over "data") against the same engine
    replicated. Emits nothing when no forced mesh is available. On CPU both
    sides run Pallas-interpret, so these rows track the *dispatch
    structure* cost; the replicated-vs-sharded crossover is a TPU number.
    """
    from repro import match
    from repro.core import templates as T
    from repro.distributed import context

    spec = forcemesh.env_spec()
    if spec is None:
        return []
    try:
        mesh = forcemesh.install(spec)
    except RuntimeError as e:
        print(f"skipping sharded-classify rows: {e}")
        return []
    # record what the engine will actually do, not the mesh shape: a model
    # axis that doesn't divide `classes` runs bank-replicated
    plan, _ = match.plan_for(batch=batches[0], num_classes=classes)
    shards = plan.bank_shards
    if shards == 1:
        print(f"skipping sharded-classify rows: {classes} classes do not "
              f"shard over the {dict(mesh.shape)} mesh")
        context.clear()
        return []

    key = jax.random.PRNGKey(1)
    tmpl = (jax.random.uniform(key, (classes, 1, N)) > 0.5
            ).astype(jnp.float32)
    bank = T.TemplateBank(
        templates=tmpl, lower=jnp.zeros_like(tmpl),
        upper=jnp.ones_like(tmpl), valid=jnp.ones((classes, 1), bool),
        thresholds=jnp.zeros((N,)))
    eng = match.engine_for(backend="kernel")

    entries = []
    for b in batches:
        f = jax.random.normal(jax.random.fold_in(key, b), (b, N))
        it = max(3, iters // 4) if b >= 4096 else iters
        context.set_mesh_axes("data", "model", mesh)
        sharded_us = _time(jax.jit(
            lambda x: eng.classify_features(x, bank)), f, iters=it)
        context.clear()
        rep_us = _time(jax.jit(
            lambda x: eng.classify_features(x, bank)), f, iters=it)
        e = _compare_entry("acam_match_classify_sharded", b, classes, N,
                           rep_us, sharded_us)
        e["bank_sharding"] = shards
        e["reduce"] = plan.reduce  # cross-shard strategy the plan selected
        entries.append(e)
    context.clear()
    return entries


def write_bench_json(entries: list[dict],
                     path: str = "BENCH_kernels.json") -> None:
    from repro.kernels import tuning

    payload = {
        "backend": tuning.backend(),
        # same predicate the ops wrappers use to enable interpret mode, so
        # the flag always reflects how the kernels actually executed
        "interpret": tuning.interpret_mode(),
        "entries": entries,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def run() -> list[dict]:
    fast = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
    rows = []
    key = jax.random.PRNGKey(0)

    shapes = SMOKE_SHAPES if fast else BENCH_SHAPES
    entries = compare_kernels(shapes)
    entries += serve_entries(shapes)
    entries += sharded_classify_entries(shapes)  # no-op without forced mesh
    write_bench_json(entries)
    for e in entries:
        rows.append({
            "name": f"{e['kernel']}_b{e['b']}",
            "us_per_call": e["kernel_us"],
            "derived": (f"ref={e['ref_us']:.0f}us,speedup={e['speedup']:.2f},"
                        f"{e['kernel_cell_matches_per_us']:.0f} cell-matches/us"),
        })

    from repro.kernels.kd_loss.ref import kd_loss_ref
    zs = jax.random.normal(key, (64, 32000))
    zt = jax.random.normal(key, (64, 32000))
    y = jnp.zeros((64,), jnp.int32)
    us = _time(jax.jit(lambda a, b, c: jnp.mean(kd_loss_ref(a, b, c))), zs, zt, y)
    rows.append({"name": "kd_loss_ref_64x32k", "us_per_call": us,
                 "derived": f"{64*32000*4/us/1e3:.1f} MB/ms logits"})

    from repro.models.layers import chunked_attention
    qq = jax.random.normal(key, (1, 1024, 8, 64), jnp.bfloat16)
    kk = jax.random.normal(key, (1, 1024, 2, 64), jnp.bfloat16)
    vv = jax.random.normal(key, (1, 1024, 2, 64), jnp.bfloat16)
    us = _time(jax.jit(lambda a, b, c: chunked_attention(a, b, c, causal=True)),
               qq, kk, vv)
    flops = 2 * 2 * 1024 * 1024 * 8 * 64
    rows.append({"name": "chunked_attention_1k", "us_per_call": us,
                 "derived": f"{flops/us/1e3:.1f} MFLOP/ms"})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tune", action="store_true",
                    help="autotune kernel blocks before benchmarking")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: B in {1, 256} only")
    args = ap.parse_args()

    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
    if args.tune:
        from repro.kernels import tuning
        for k, blk in tuning.autotune_acam(
                shapes=[(b, M, N) for b in
                        (SMOKE_SHAPES if args.smoke else BENCH_SHAPES)]).items():
            print(f"tuned {k} -> {blk}")

    for r in run():
        print(r)
    print("wrote BENCH_kernels.json")


if __name__ == "__main__":
    main()
