"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python
emulation — not a performance number), so the wall-times reported here are
for the *compiled jnp reference paths* at deployment shapes; they give the
CSV a concrete us_per_call column and catch performance regressions of the
XLA fallbacks. TPU timings come from the roofline analysis instead
(EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=20) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)

    # acam matching at the paper's deployment shape (B=1 is the edge case;
    # B=256 the calibration batch)
    from repro.kernels.acam_match.ref import acam_match_ref
    for b in (1, 256):
        f = jax.random.normal(key, (b, 784))
        thr = jnp.zeros((784,))
        t = (jax.random.uniform(key, (10, 784)) > 0.5).astype(jnp.float32)
        us = _time(jax.jit(acam_match_ref), f, thr, t)
        rows.append({"name": f"acam_match_ref_b{b}", "us_per_call": us,
                     "derived": f"{b*10*784/us:.0f} cell-matches/us"})

    from repro.kernels.acam_similarity.ref import acam_similarity_ref
    q = jax.random.uniform(key, (256, 784))
    lo = jnp.zeros((10, 784)); hi = jnp.ones((10, 784))
    us = _time(jax.jit(acam_similarity_ref), q, lo, hi)
    rows.append({"name": "acam_similarity_ref_b256", "us_per_call": us,
                 "derived": f"{256*10*784/us:.0f} cell-ops/us"})

    from repro.kernels.kd_loss.ref import kd_loss_ref
    zs = jax.random.normal(key, (64, 32000))
    zt = jax.random.normal(key, (64, 32000))
    y = jnp.zeros((64,), jnp.int32)
    us = _time(jax.jit(lambda a, b, c: jnp.mean(kd_loss_ref(a, b, c))), zs, zt, y)
    rows.append({"name": "kd_loss_ref_64x32k", "us_per_call": us,
                 "derived": f"{64*32000*4/us/1e3:.1f} MB/ms logits"})

    from repro.models.layers import chunked_attention
    qq = jax.random.normal(key, (1, 1024, 8, 64), jnp.bfloat16)
    kk = jax.random.normal(key, (1, 1024, 2, 64), jnp.bfloat16)
    vv = jax.random.normal(key, (1, 1024, 2, 64), jnp.bfloat16)
    us = _time(jax.jit(lambda a, b, c: chunked_attention(a, b, c, causal=True)),
               qq, kk, vv)
    flops = 2 * 2 * 1024 * 1024 * 8 * 64
    rows.append({"name": "chunked_attention_1k", "us_per_call": us,
                 "derived": f"{flops/us/1e3:.1f} MFLOP/ms"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
