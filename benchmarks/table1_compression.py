"""Paper Table I: teacher vs student compression pipeline.

Columns: accuracy / F1 / precision / recall / parameters / MAC operations /
compression ratio — for teacher (colour + greyscale), unoptimised student,
and the optimised (KD + prune + QAT) student. Parameter and MAC counts are
analytic (Eq. 13) and therefore match the paper's methodology exactly;
accuracies are on the synthetic dataset (DESIGN.md §2).
"""
from __future__ import annotations

import functools

from benchmarks import common
from repro.models import cnn
from repro.train import cnn_trainer as T


def run() -> list[dict]:
    d = common.data()
    m = common.models()
    rows = []

    teacher_macs_c = cnn.teacher_macs(common.TEACHER_CFG_COLOR)
    teacher_params_c = cnn.count_params(m["teacher_color"])
    tl_c = functools.partial(
        lambda p, x, cfg: cnn.teacher_logits(p, x, cfg), cfg=common.TEACHER_CFG_COLOR)
    met = T.metrics(tl_c, m["teacher_color"], *d["color_te"])
    rows.append(dict(model="teacher_colour", **met, params=teacher_params_c,
                     macs=teacher_macs_c, compression="1:1"))

    teacher_macs_g = cnn.teacher_macs(common.TEACHER_CFG_GRAY)
    tl_g = functools.partial(
        lambda p, x, cfg: cnn.teacher_logits(p, x, cfg), cfg=common.TEACHER_CFG_GRAY)
    met = T.metrics(tl_g, m["teacher_gray"], *d["gray_te"])
    rows.append(dict(model="teacher_greyscale", **met,
                     params=cnn.count_params(m["teacher_gray"]),
                     macs=teacher_macs_g,
                     compression=f"{teacher_macs_c/teacher_macs_g:.2f}:1"))

    s_macs = cnn.student_macs()["total"]
    s_params = cnn.count_params(m["student_base"])
    sl = functools.partial(cnn.student_logits, train=False)
    met = T.metrics(sl, m["student_base"], *d["gray_te"])
    rows.append(dict(model="student_base", **met, params=s_params, macs=s_macs,
                     compression=f"{teacher_macs_c/s_macs:.0f}:1"))

    met = T.metrics(sl, m["student_opt"], *d["gray_te"])
    eff_macs = int(s_macs * 0.2)  # 80% sparsity skips pruned-weight MACs
    rows.append(dict(model="student_optimised", **met, params=s_params,
                     macs=eff_macs,
                     compression=f"{teacher_macs_c/eff_macs:.0f}:1"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
