"""Quickstart: the full hybrid edge classifier, end to end.

Trains the paper's tinyML student CNN (Fig. 5) on the synthetic CIFAR-10
substitute, distils templates, programs the ACAM back-end, and reports the
accuracy/energy trade-off of §V. Runs on CPU in a few minutes.

    PYTHONPATH=src python examples/quickstart.py [--fast]
"""
import argparse
import functools

import jax
import jax.numpy as jnp

from repro import match
from repro.core import acam, energy, hybrid
from repro.data import synthetic
from repro.models import cnn
from repro.train import cnn_trainer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--n-per-class", type=int, default=None)
    ap.add_argument("--mc", type=int, default=0, metavar="N",
                    help="Monte-Carlo sweep: N independent sigma_program "
                         "draws (engine.sweep_program_noise) -> confidence "
                         "interval on noisy-hardware accuracy")
    args = ap.parse_args()
    n = args.n_per_class or (120 if args.fast else 400)
    epochs = 2 if args.fast else 4

    print("== data: synthetic CIFAR-10 (greyscale, normalised; paper §IV-A)")
    tr = synthetic.load("train", n_per_class=n, seed=0)
    te = synthetic.load("test", n_per_class=max(n // 4, 50), seed=0)
    gtr = synthetic.normalize(synthetic.to_grayscale(tr.images))
    gte = synthetic.normalize(synthetic.to_grayscale(te.images))

    print("== front-end: student CNN (conv 32-128-256-16 -> 784 features)")
    cfg = T.TrainConfig(epochs=epochs, batch_size=128)
    params, _ = T.train_student(gtr, tr.labels, cfg=cfg)
    logits_fn = functools.partial(cnn.student_logits, train=False)
    acc_soft = T.evaluate(logits_fn, params, gte, te.labels)
    print(f"   softmax-head accuracy: {acc_soft:.4f}")

    print("== back-end: binary templates -> TXL-ACAM pattern matching")
    feature_fn = lambda p, x: cnn.student_features(p, x)[0]
    head = hybrid.fit_acam_head(feature_fn, params, gtr, tr.labels, 10, k=1)
    clf = hybrid.HybridClassifier(params, jax.jit(feature_fn), head)
    acc_acam = clf.accuracy(gte, te.labels)
    print(f"   ACAM feature-count accuracy: {acc_acam:.4f} "
          f"(drop {acc_soft - acc_acam:+.4f} vs softmax — paper saw -11%)")

    print("== device physics: the same head through the RRAM-CMOS models")
    feats_te = jax.jit(feature_fn)(params, gte)

    def device_acc(sigma):
        eng = match.engine_for(
            backend="device",
            device=acam.ACAMConfig(sigma_program=sigma), seed=7)
        pred, _ = eng.classify_features(feats_te, head.bank)
        return float(jnp.mean(pred == te.labels))

    acc_dev = device_acc(0.0)
    acc_noisy = device_acc(0.10)
    print(f"   ideal array (sigma=0)      : {acc_dev:.4f} "
          f"(matches the window model exactly)")
    print(f"   noisy RRAM (sigma=0.10)    : {acc_noisy:.4f} "
          f"(programming variability, §III)")

    if args.mc > 0:
        # one programmed array is a single sample of the write-noise
        # process; the vmapped sweep turns it into a confidence interval
        for sigma in (0.05, 0.10, 0.20):
            eng = match.engine_for(
                backend="device",
                device=acam.ACAMConfig(sigma_program=sigma), seed=7)
            preds, _ = eng.sweep_program_noise(feats_te, head.bank, args.mc)
            accs = jnp.mean(preds == te.labels[None, :], axis=1)
            print(f"   MC x{args.mc} sigma={sigma:.2f}      : "
                  f"{float(jnp.mean(accs)):.4f} +/- "
                  f"{float(jnp.std(accs)):.4f} "
                  f"(min {float(jnp.min(accs)):.4f}, "
                  f"max {float(jnp.max(accs)):.4f})")
        # tiled deployments program one physical array PER bank shard
        # (device_noise="per_shard": array s keyed fold_in(seed, s)) — a
        # distinct noise layout from the single-array draw above
        eng = match.engine_for(
            backend="device", device=acam.ACAMConfig(sigma_program=0.10),
            seed=7, device_noise="per_shard")
        preds, _ = eng.sweep_program_noise(feats_te, head.bank, args.mc,
                                           bank_shards=2)
        accs = jnp.mean(preds == te.labels[None, :], axis=1)
        print(f"   MC x{args.mc} sigma=0.10 x2arr: "
              f"{float(jnp.mean(accs)):.4f} +/- {float(jnp.std(accs)):.4f} "
              f"(per-shard programming keys, 2 arrays)")

    print("== serving: the same head behind the declarative front door")
    # ONE ServiceSpec stands up the whole serving stack (registry ->
    # scheduler -> cascade); the spec is JSON-round-trippable, so this
    # exact configuration can ship as a file (launch/serve --spec).
    import numpy as np

    from repro.match.config import EngineConfig
    from repro.serve import acam_service as svc_lib
    from repro.serve import spec as spec_lib
    from repro.serve.control import HybridService

    spec = spec_lib.ServiceSpec(
        registry=spec_lib.RegistrySpec(num_features=head.bank.num_features),
        engine=EngineConfig(backend=match.default_backend(), margin=True),
        mesh=spec_lib.MeshSpec(bank_shards=1, install=False),
        scheduler=spec_lib.SchedulerSpec(slots=64),
        cascade=spec_lib.CascadeSpec(tau=8.0, tau_units="count"),
    )
    assert spec_lib.ServiceSpec.from_json(spec.to_json()) == spec
    svc = HybridService.from_spec(spec)
    dense = params["head"]
    svc.register_tenant("wearable-0", head.bank,
                        head=(np.asarray(dense["w"]),
                              np.asarray(dense["b"])))
    responses = svc.serve([
        svc_lib.ClassifyRequest("wearable-0", f) for f in np.asarray(feats_te)])
    m = svc.metrics()
    acc_svc = float(np.mean([r.pred == y
                             for r, y in zip(responses, te.labels)]))
    print(f"   cascade accuracy {acc_svc:.4f} over {m['completed']} requests "
          f"({m['classify_dispatches']} fused dispatches, escalation rate "
          f"{m['escalation_rate']:.3f}, {m['nj_per_request']:.2f} nJ/req)")

    print("== telemetry: the flight recorder behind metrics()")
    # every number above was a view over `svc.obs` (repro.obs): the
    # latency quantiles are exact-from-buckets reads of ONE histogram
    # (the shed check reads the identical value), the energy ledger is
    # bit-exact with the per-response sum, and span conservation
    # (started == finished + in-flight) is a structural property.
    fleet = svc.obs.ledger.fleet()
    spans = svc.obs.spans.conservation()
    assert fleet["total_nj"] == sum(r.energy_j for r in responses) * 1e9
    assert spans["started"] == spans["finished"] + spans["in_flight"]
    print(f"   energy ledger: {fleet['total_nj']:.1f} nJ over "
          f"{fleet['requests']} requests (backend share "
          f"{fleet['backend_share']:.3f}; bit-exact with per-response sum)")
    print(f"   latency p50/p99: {m['latency_p50_ms']:.3f}/"
          f"{m['latency_p99_ms']:.3f} ms (exact from histogram buckets)")
    print(f"   spans: {spans['started']} started == {spans['finished']} "
          f"finished + {spans['in_flight']} in-flight "
          f"(dispositions {spans['by_disposition']})")

    print("== energy (paper §V-D arithmetic)")
    nums = energy.paper_numbers()
    print(f"   back-end  : {nums['backend_nj']:.2f} nJ / inference (Eq. 14)")
    print(f"   front-end : {nums['frontend_nj']:.2f} nJ / inference")
    print(f"   teacher   : {nums['teacher_uj']:.2f} uJ / inference")
    print(f"   reduction : {nums['reduction_x']:.0f}x")
    print(f"   this head : {head.energy_per_inference()*1e9:.2f} nJ")


if __name__ == "__main__":
    main()
