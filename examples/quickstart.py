"""Quickstart: the full hybrid edge classifier, end to end.

Trains the paper's tinyML student CNN (Fig. 5) on the synthetic CIFAR-10
substitute, distils templates, programs the ACAM back-end, and reports the
accuracy/energy trade-off of §V. Runs on CPU in a few minutes.

    PYTHONPATH=src python examples/quickstart.py [--fast]
"""
import argparse
import functools

import jax
import jax.numpy as jnp

from repro import match
from repro.core import acam, energy, hybrid
from repro.data import synthetic
from repro.models import cnn
from repro.train import cnn_trainer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--n-per-class", type=int, default=None)
    ap.add_argument("--mc", type=int, default=0, metavar="N",
                    help="Monte-Carlo sweep: N independent sigma_program "
                         "draws (engine.sweep_program_noise) -> confidence "
                         "interval on noisy-hardware accuracy")
    args = ap.parse_args()
    n = args.n_per_class or (120 if args.fast else 400)
    epochs = 2 if args.fast else 4

    print("== data: synthetic CIFAR-10 (greyscale, normalised; paper §IV-A)")
    tr = synthetic.load("train", n_per_class=n, seed=0)
    te = synthetic.load("test", n_per_class=max(n // 4, 50), seed=0)
    gtr = synthetic.normalize(synthetic.to_grayscale(tr.images))
    gte = synthetic.normalize(synthetic.to_grayscale(te.images))

    print("== front-end: student CNN (conv 32-128-256-16 -> 784 features)")
    cfg = T.TrainConfig(epochs=epochs, batch_size=128)
    params, _ = T.train_student(gtr, tr.labels, cfg=cfg)
    logits_fn = functools.partial(cnn.student_logits, train=False)
    acc_soft = T.evaluate(logits_fn, params, gte, te.labels)
    print(f"   softmax-head accuracy: {acc_soft:.4f}")

    print("== back-end: binary templates -> TXL-ACAM pattern matching")
    feature_fn = lambda p, x: cnn.student_features(p, x)[0]
    head = hybrid.fit_acam_head(feature_fn, params, gtr, tr.labels, 10, k=1)
    clf = hybrid.HybridClassifier(params, jax.jit(feature_fn), head)
    acc_acam = clf.accuracy(gte, te.labels)
    print(f"   ACAM feature-count accuracy: {acc_acam:.4f} "
          f"(drop {acc_soft - acc_acam:+.4f} vs softmax — paper saw -11%)")

    print("== device physics: the same head through the RRAM-CMOS models")
    feats_te = jax.jit(feature_fn)(params, gte)

    def device_acc(sigma):
        eng = match.engine_for(
            backend="device",
            device=acam.ACAMConfig(sigma_program=sigma), seed=7)
        pred, _ = eng.classify_features(feats_te, head.bank)
        return float(jnp.mean(pred == te.labels))

    acc_dev = device_acc(0.0)
    acc_noisy = device_acc(0.10)
    print(f"   ideal array (sigma=0)      : {acc_dev:.4f} "
          f"(matches the window model exactly)")
    print(f"   noisy RRAM (sigma=0.10)    : {acc_noisy:.4f} "
          f"(programming variability, §III)")

    if args.mc > 0:
        # one programmed array is a single sample of the write-noise
        # process; the vmapped sweep turns it into a confidence interval
        for sigma in (0.05, 0.10, 0.20):
            eng = match.engine_for(
                backend="device",
                device=acam.ACAMConfig(sigma_program=sigma), seed=7)
            preds, _ = eng.sweep_program_noise(feats_te, head.bank, args.mc)
            accs = jnp.mean(preds == te.labels[None, :], axis=1)
            print(f"   MC x{args.mc} sigma={sigma:.2f}      : "
                  f"{float(jnp.mean(accs)):.4f} +/- "
                  f"{float(jnp.std(accs)):.4f} "
                  f"(min {float(jnp.min(accs)):.4f}, "
                  f"max {float(jnp.max(accs)):.4f})")

    print("== energy (paper §V-D arithmetic)")
    nums = energy.paper_numbers()
    print(f"   back-end  : {nums['backend_nj']:.2f} nJ / inference (Eq. 14)")
    print(f"   front-end : {nums['frontend_nj']:.2f} nJ / inference")
    print(f"   teacher   : {nums['teacher_uj']:.2f} uJ / inference")
    print(f"   reduction : {nums['reduction_x']:.0f}x")
    print(f"   this head : {head.energy_per_inference()*1e9:.2f} nJ")


if __name__ == "__main__":
    main()
