"""Two engines, one front door: the ACAM tier as an LM semantic cache.

Routes a Zipf-repeat prompt trace through
`repro.serve.semantic_cache.SemanticCacheService`:

    prompt -> hashing featurizer -> ONE fused ACAM match dispatch per tick
        confident hit  -> response store (Eq. 14 nJ-scale energy)
        miss           -> `serve.Engine` continuous-batching decode,
                          admitted back as a template (cache churn)

then demonstrates the durability story: snapshot, restore WITHOUT the
engine, and serve the same hits bit-identically from the restored
response store alone.

The asserts at the bottom are the contract the CI `lm-cache-smoke` job
pins: one fused match dispatch per tick, cache counters conserve
(hits + misses == error-free routed responses), every hit replays the
exact tokens decode produced when its template was admitted, and the
mean energy per request collapses once the cache is warm.

    PYTHONPATH=src python examples/lm_semantic_cache.py
    PYTHONPATH=src python examples/lm_semantic_cache.py --requests 48 \
        --unique 6 --temperature 0.7
"""
import argparse
import tempfile
import time

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--unique", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro import configs
    from repro.models import lm
    from repro.serve import spec as spec_lib
    from repro.serve.engine import Engine
    from repro.serve.semantic_cache import (PromptRequest,
                                            SemanticCacheService,
                                            synthetic_prompt_trace)

    cfg = configs.get(args.arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(cfg, params, batch_size=4, max_len=64,
                 temperature=args.temperature, seed=args.seed)

    spec = spec_lib.ServiceSpec(
        registry=spec_lib.RegistrySpec(num_features=64),
        scheduler=spec_lib.SchedulerSpec(slots=args.slots),
        cascade=spec_lib.CascadeSpec(backend="lm", tau=8.0,
                                     tau_units="count"),
        router=spec_lib.RouterSpec(max_templates=args.unique),
        mesh=spec_lib.MeshSpec(install=False))
    svc = SemanticCacheService.from_spec(spec, engine=eng)
    svc.add_tenant("edge-0")

    trace = synthetic_prompt_trace(args.seed, vocab=cfg.vocab,
                                   n_unique=args.unique,
                                   n_requests=args.requests)
    t0 = time.time()
    out = svc.serve_prompts(PromptRequest("edge-0", p,
                                          max_new_tokens=args.max_new)
                            for p in trace)
    dt = time.time() - t0

    m = svc.metrics()
    ev = svc.obs.cache_events
    hits = [r for r in out if r.cache_hit]
    misses = [r for r in out if not r.cache_hit and r.error is None]
    print(f"{cfg.name} behind the ACAM semantic cache:")
    print(f"  {len(out)} requests ({args.unique} unique prompts), "
          f"{len(hits)} hits / {len(misses)} decode misses in {dt:.2f}s")
    print(f"  match stage: {m['classify_dispatches']} fused dispatches "
          f"over {m['ticks']} ticks (one per tick)")
    hit_j = max((r.energy_j for r in hits), default=0.0)
    miss_j = min((r.energy_j for r in misses), default=0.0)
    print(f"  energy: hit path {hit_j * 1e9:.3f} nJ vs decode miss "
          f"{miss_j * 1e9:.1f} nJ; mean {m['nj_per_request']:.1f} "
          "nJ/request")

    # CI contract ---------------------------------------------------------
    assert m["classify_dispatches"] == m["ticks"], \
        "match stage must stay ONE fused dispatch per tick"
    served = sum(r.error is None for r in out)
    assert ev.value(event="hit") + ev.value(event="miss") == served, \
        "cache counters must conserve: hits + misses == served"
    decoded = {r.template_id: r.tokens for r in misses}
    for r in hits:
        assert r.tokens == decoded[r.template_id], \
            "a hit must replay the exact tokens decode produced"
    assert len(hits) > 0 and miss_j > 100 * hit_j, \
        "hit-path energy must be orders below decode"
    ledger = svc.obs.ledger.fleet_j()
    assert abs(sum(r.energy_j for r in out) - ledger) < 1e-15, \
        "per-response energy must sum bit-exactly to the fleet ledger"

    # durability: restore WITHOUT an engine, serve the same hits ----------
    from repro.checkpoint.checkpointer import Checkpointer

    with tempfile.TemporaryDirectory() as d:
        svc.snapshot(Checkpointer(d))
        svc2, report = SemanticCacheService.restore(Checkpointer(d))
        replay = svc2.serve_prompts(
            PromptRequest("edge-0", p, max_new_tokens=args.max_new)
            for p in trace[:args.unique])
        assert all(r.cache_hit for r in replay), \
            "restored response store must serve hits with NO engine"
        for r in replay:
            assert r.tokens == decoded[r.template_id]
    print(f"  restore: step {report.step} adopted {report.tenants} "
          f"tenant(s); {len(replay)} hits served engine-less, "
          "bit-identical")
    print("OK")


if __name__ == "__main__":
    main()
