"""The paper's technique at zoo scale: an ACAM template-matching head on the
HuBERT encoder (504 masked-prediction units — ACAM-scale classification).

Demonstrates DESIGN.md §5: KD/prune/quant apply to every assigned arch; the
ACAM *head* applies wherever the final stage is a small-cardinality
classifier. Here we train the (smoke-size) encoder briefly on a synthetic
frame-labelling task, then swap the 504-way dense head for binary template
matching and compare accuracy + per-frame energy.

    PYTHONPATH=src python examples/acam_head_for_hubert.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import hybrid, templates
from repro.models import lm
from repro.optim import optimizers as optim


def main():
    cfg = configs.get("hubert-xlarge", smoke=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    opt = optim.adamw(3e-3)
    opt_state = opt.init(params)

    # synthetic frame-classification task: class = f(embedding direction)
    n_classes = 16  # reduced codebook for the smoke config
    proto = jax.random.normal(jax.random.fold_in(key, 9),
                              (n_classes, cfg.d_model))

    def batch(step, b=8, s=32):
        k = jax.random.fold_in(key, step)
        x = jax.random.normal(k, (b, s, cfg.d_model), jnp.bfloat16)
        y = jnp.argmax(jnp.einsum("bsd,cd->bsc", x.astype(jnp.float32), proto),
                       axis=-1)
        return {"inputs": x, "labels": y}

    @jax.jit
    def step(params, opt_state, b):
        loss, g = jax.value_and_grad(lambda p: lm.loss_fn(p, cfg, b))(params)
        params, opt_state = opt.update(g, opt_state, params)
        return params, opt_state, loss

    for i in range(60):
        params, opt_state, loss = step(params, opt_state, batch(i))
    print(f"encoder trained: final loss {float(loss):.3f}")

    # features = pre-head hidden states; labels = frame classes
    def feature_fn(p, x):
        logits, _ = lm.forward(p, cfg, x)
        return logits.reshape(-1, cfg.vocab)  # use logits as the feature map

    test = batch(999, b=16)
    feats = feature_fn(params, test["inputs"])
    y = test["labels"].reshape(-1)

    acc_dense = float(jnp.mean(jnp.argmax(feats, -1) == y))

    cal = batch(123, b=32)
    cal_feats = feature_fn(params, cal["inputs"])
    bank = templates.generate_templates(
        cal_feats, cal["labels"].reshape(-1), n_classes, k=1)
    head = hybrid.ACAMHead(bank=bank)
    pred, _ = head(feats)
    acc_acam = float(jnp.mean(pred == y))

    print(f"dense-head frame accuracy : {acc_dense:.4f}")
    print(f"ACAM-head frame accuracy  : {acc_acam:.4f}")
    print(f"ACAM energy per frame     : {head.energy_per_inference()*1e9:.3f} nJ "
          f"({n_classes} templates x {bank.num_features} cells x 185 fJ)")


if __name__ == "__main__":
    main()
