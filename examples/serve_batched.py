"""Batched serving demo: LM decode engine AND the hybrid ACAM classifier.

Two workloads behind one CLI:

  lm    (default) — admits a ragged set of token requests, batches them,
        prefills the KV cache and decodes with greedy/temperature sampling —
        the smoke-scale version of the serving path the decode_32k /
        long_500k dry-run cells lower at production scale.

  acam  — serves image-classification requests through ONE end-to-end jitted
        fused path: CNN front-end features -> fused binarize->match->WTA
        Pallas kernel (`matching.classify_features` via
        `hybrid.HybridClassifier.predict`). No per-request Python between
        the feature map and the class decision; ragged request queues are
        batched to a fixed slot count exactly like the LM engine.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-2.7b
    PYTHONPATH=src python examples/serve_batched.py --workload acam
"""
import argparse
import time

import jax
import numpy as np


def run_lm(args) -> None:
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine, Request

    cfg = configs.get(args.arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_size=4, max_len=128,
                 temperature=args.temperature)

    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=rng.randint(4, 24)),
                    max_new_tokens=args.max_new) for _ in range(args.requests)]
    t0 = time.time()
    eng.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"arch={cfg.name}: {len(reqs)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s, CPU smoke scale)")
    for i, r in enumerate(reqs):
        print(f"  req{i} prompt[{len(r.prompt)}] -> {r.out}")


def run_acam(args) -> None:
    from repro.core import hybrid
    from repro.data import synthetic
    from repro.models import cnn
    from repro.train import cnn_trainer as T

    n = 80 if args.fast else 200
    tr = synthetic.load("train", n_per_class=n, seed=0)
    gtr = synthetic.normalize(synthetic.to_grayscale(tr.images))
    cfg = T.TrainConfig(epochs=1 if args.fast else 2, batch_size=128)
    params, _ = T.train_student(gtr, tr.labels, cfg=cfg)
    feature_fn = jax.jit(lambda p, x: cnn.student_features(p, x)[0])
    head = hybrid.fit_acam_head(lambda p, x: cnn.student_features(p, x)[0],
                                params, gtr, tr.labels, 10, k=1)
    clf = hybrid.HybridClassifier(params, feature_fn, head)

    # ragged request queue -> fixed serving slots (continuous batching à la
    # the LM engine: pad the tail batch instead of recompiling its shape)
    te = synthetic.load("test", n_per_class=max(n // 4, 25), seed=1)
    gte = synthetic.normalize(synthetic.to_grayscale(te.images))
    rng = np.random.RandomState(0)
    order = rng.permutation(len(te.labels))
    slots = args.batch_size
    served, correct = 0, 0
    t_first = None
    t0 = time.time()
    for i in range(0, len(order), slots):
        idx = order[i:i + slots]
        batch = gte[idx]
        if len(idx) < slots:  # pad the ragged tail to the jitted slot shape
            pad = np.zeros((slots - len(idx),) + batch.shape[1:], batch.dtype)
            batch = np.concatenate([batch, pad], axis=0)
        pred = np.asarray(clf.predict(batch))[:len(idx)]
        if t_first is None:
            t_first = time.time() - t0
        served += len(idx)
        correct += int((pred == te.labels[idx]).sum())
    dt = time.time() - t0
    print(f"acam workload: {served} classifications in {dt:.2f}s "
          f"({served/dt:.0f} img/s incl. jit; first-batch {t_first:.2f}s), "
          f"accuracy {correct/served:.4f}")
    print(f"  backend energy {head.energy_per_inference()*1e9:.2f} nJ/inference"
          f" (paper Eq. 14)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "acam"), default="lm")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    (run_acam if args.workload == "acam" else run_lm)(args)


if __name__ == "__main__":
    main()
