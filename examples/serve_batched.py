"""Batched serving demo: LM decode engine AND the multi-tenant ACAM service.

Two workloads behind one CLI:

  lm    (default) — admits a ragged set of token requests, batches them,
        prefills the KV cache and decodes with greedy/temperature sampling —
        the smoke-scale version of the serving path the decode_32k /
        long_500k dry-run cells lower at production scale. Reports batch
        fill and decode-slot utilisation, not just wall-clock totals.

  acam  — trains the paper's CNN front-end, fits its ACAM template bank,
        registers it as a tenant of `repro.serve.acam_service.ACAMService`
        (optionally alongside extra synthetic tenants via --tenants), and
        classifies the test set through the service: micro-batched
        cross-tenant scheduling, ONE fused binarize->match->WTA Pallas
        dispatch per tick, confidence-cascade escalation to the CNN's dense
        head, and paper §V-D per-request energy attribution. Reports the
        scheduler's batch-fill/occupancy stats so the coalescing quality is
        observable.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-2.7b
    PYTHONPATH=src python examples/serve_batched.py --workload acam --fast
"""
import argparse
import time

import jax
import numpy as np


def run_lm(args) -> None:
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine, Request

    cfg = configs.get(args.arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    slots = 4
    eng = Engine(cfg, params, batch_size=slots, max_len=128,
                 temperature=args.temperature)

    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=rng.randint(4, 24)),
                    max_new_tokens=args.max_new) for _ in range(args.requests)]
    t0 = time.time()
    eng.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    # batch-fill/occupancy: how full each greedy batch was, and what share
    # of decode slot-steps produced a token (finished sequences idle their
    # slot until the batch drains — the stat the continuous ACAM scheduler
    # improves on)
    n_batches = -(-len(reqs) // slots)
    fill = len(reqs) / (n_batches * slots)
    slot_steps = sum(
        slots * max(len(r.out) for r in reqs[i:i + slots])
        for i in range(0, len(reqs), slots))
    util = total / slot_steps
    print(f"arch={cfg.name}: {len(reqs)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s, CPU smoke scale)")
    print(f"  batch fill {fill:.2f} ({n_batches} batches x {slots} slots), "
          f"decode slot utilisation {util:.2f}")
    for i, r in enumerate(reqs):
        print(f"  req{i} prompt[{len(r.prompt)}] -> {r.out}")


def run_acam(args) -> None:
    from repro import match
    from repro.core import hybrid
    from repro.data import synthetic
    from repro.match.config import EngineConfig
    from repro.models import cnn
    from repro.serve import acam_service as svc_lib
    from repro.serve import spec as spec_lib
    from repro.serve.control import HybridService
    from repro.train import cnn_trainer as T

    n = 80 if args.fast else 200
    tr = synthetic.load("train", n_per_class=n, seed=0)
    gtr = synthetic.normalize(synthetic.to_grayscale(tr.images))
    cfg = T.TrainConfig(epochs=1 if args.fast else 2, batch_size=128)
    params, _ = T.train_student(gtr, tr.labels, cfg=cfg)
    feature_fn = jax.jit(lambda p, x: cnn.student_features(p, x)[0])
    head = hybrid.fit_acam_head(lambda p, x: cnn.student_features(p, x)[0],
                                params, gtr, tr.labels, 10, k=1)

    # ONE declarative ServiceSpec is the whole front door: engine backend
    # (--backend; device = RRAM physics), tick size, cascade tau with
    # EXPLICIT units ("count" — the service converts to matchline fractions
    # itself when the backend senses them). The trained hybrid classifier
    # becomes tenant 0; its dense softmax head is the escalation target.
    # --tenants adds synthetic co-tenants so the scheduler coalesces.
    spec = spec_lib.ServiceSpec(
        registry=spec_lib.RegistrySpec(
            num_features=head.bank.num_features),
        engine=EngineConfig(backend=args.backend or match.default_backend(),
                            margin=True),
        mesh=spec_lib.MeshSpec(bank_shards=1, install=False),
        scheduler=spec_lib.SchedulerSpec(slots=args.batch_size),
        cascade=spec_lib.CascadeSpec(tau=args.margin_tau,
                                     tau_units="count"),
    )
    svc = HybridService.from_spec(spec)
    dense = params["head"]
    svc.register_tenant("wearable-0", head.bank,
                        head=(np.asarray(dense["w"]), np.asarray(dense["b"])))
    protos = {}
    for t in range(1, args.tenants):
        bank, thead, p = svc_lib.make_synthetic_tenant(
            1000 + t, num_classes=10, num_features=head.bank.num_features)
        svc.register_tenant(f"synthetic-{t}", bank, head=thead)
        protos[f"synthetic-{t}"] = p

    # front-end feature extraction stays a batched jitted pass; the service
    # serves the (feature-map -> class) back-end per request
    te = synthetic.load("test", n_per_class=max(n // 4, 25), seed=1)
    gte = synthetic.normalize(synthetic.to_grayscale(te.images))
    feats = np.asarray(feature_fn(params, gte))

    rng = np.random.RandomState(0)
    reqs, truth = [], []
    for i in rng.permutation(len(te.labels)):
        reqs.append(svc_lib.ClassifyRequest("wearable-0", feats[i]))
        truth.append(int(te.labels[i]))
    for tid, p in protos.items():
        qf, qy = svc_lib.sample_tenant_queries(11, p, len(te.labels) // 4)
        for i in range(len(qy)):
            reqs.append(svc_lib.ClassifyRequest(tid, qf[i]))
            truth.append(int(qy[i]))
    if args.tenants > 1:  # interleave so micro-batches mix tenants
        order = rng.permutation(len(reqs))
        reqs = [reqs[i] for i in order]
        truth = [truth[i] for i in order]

    t0 = time.time()
    responses = svc.serve(reqs)
    dt = time.time() - t0
    m = svc.metrics()
    correct = sum(r.pred == y for r, y in zip(responses, truth))
    print(f"acam workload: {m['completed']} classifications over "
          f"{max(args.tenants, 1)} tenants in {dt:.2f}s "
          f"({m['completed']/dt:.0f} req/s incl. jit), "
          f"accuracy {correct/len(responses):.4f}")
    print(f"  scheduler: {m['classify_dispatches']} fused dispatches, "
          f"occupancy {m['occupancy']:.2f} "
          f"(fill {m['min_fill']}..{m['max_fill']} of {m['slots']} slots)")
    print(f"  cascade: escalation rate {m['escalation_rate']:.3f}, "
          f"{m['nj_per_request']:.2f} nJ/request (accepted-at-ACAM backend "
          f"energy {head.energy_per_inference()*1e9:.2f} nJ, paper Eq. 14)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "acam"), default="lm")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--tenants", type=int, default=3,
                    help="acam: total tenants (1 trained + N-1 synthetic)")
    ap.add_argument("--margin-tau", type=float, default=8.0,
                    help="acam: cascade accept threshold (match counts)")
    ap.add_argument("--backend", default=None,
                    choices=("auto", "kernel", "reference", "device"),
                    help="acam: repro.match engine backend "
                         "(device = RRAM-CMOS physics models)")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    (run_acam if args.workload == "acam" else run_lm)(args)


if __name__ == "__main__":
    main()
