"""Batched serving demo: the decode engine over a zoo model.

Admits a ragged set of requests, batches them, prefILLS the KV cache and
decodes with greedy/temperature sampling — the smoke-scale version of the
serving path that the decode_32k / long_500k dry-run cells lower at
production scale.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-2.7b
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_size=4, max_len=128,
                 temperature=args.temperature)

    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=rng.randint(4, 24)),
                    max_new_tokens=args.max_new) for _ in range(args.requests)]
    t0 = time.time()
    eng.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"arch={cfg.name}: {len(reqs)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s, CPU smoke scale)")
    for i, r in enumerate(reqs):
        print(f"  req{i} prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
