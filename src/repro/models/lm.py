"""Unified LM for the 10 assigned architectures.

One config dataclass + one functional model covering: dense GQA transformers
(qwen/llama family, optional QKV bias + qk-norm), MoE (phi3.5 softmax top-2 /
deepseek-v3 sigmoid top-8 + shared expert + MLA), VLM backbones (M-RoPE,
embedding inputs), hybrid attn+SSM (hymba), encoder-only (hubert), and pure
SSM (mamba2 SSD).

Layers are homogeneous and stacked on a leading axis so the model lowers as a
single `lax.scan` (+ `jax.checkpoint` remat) — compile time and HLO size stay
bounded at 61-80 layer full configs on a 512-device mesh.

Entry points:
    init_params(key, cfg)                 -> params pytree
    forward(params, cfg, tokens/embeds)   -> logits               (train path)
    loss_fn(params, cfg, batch)           -> scalar loss
    prefill(params, cfg, inputs)          -> (logits, cache)      (serve)
    decode_step(params, cfg, token, cache)-> (logits, new cache)  (serve)
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed import context as mesh_ctx
from repro.models import layers as L

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True
    sliding_window: int | None = None  # hymba long-context attention
    rope: str = "standard"  # standard | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    # inputs
    input_mode: str = "tokens"  # tokens | embeds (vlm/audio stubs)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    router_type: str = "softmax"
    capacity_factor: float = 1.25
    # MLA (deepseek)
    mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # SSM
    ssm: bool = False  # attention-free (mamba2)
    hybrid: bool = False  # parallel attn + ssm heads (hymba)
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # numerics / misc
    dtype: Any = jnp.bfloat16
    remat: bool = True
    q_chunk: int = 512
    # performance options (§Perf hillclimb; baseline keeps defaults)
    precompute_rope: bool = False  # hoist cos/sin tables out of the scan
    moe_impl: str = "gspmd"  # "gspmd" | "shard_map" (manual EP collectives)
    #: pad attention heads to a multiple of the TP degree (mesh-alignment
    #: codesign, §Perf cell B): non-dividing head counts make every
    #: (B,S,H*hd)->(B,S,H,hd) reshape pay a resharding collective-permute
    #: (measured -56% layer collectives on qwen2.5-14b at +6.6% FLOPs).
    #: kv heads are duplicated, dead q slots zero-initialised; exact
    #: equivalence at init (see pad geometry in _pad_geom).
    head_pad_multiple: int = 0

    @property
    def uses_attention(self) -> bool:
        return not self.ssm

    @property
    def ssm_spec(self) -> L.SSMSpec:
        d_inner = self.ssm_expand * self.d_model if self.ssm else self.d_model
        return L.SSMSpec(
            d_inner=d_inner,
            n_heads=d_inner // self.ssm_headdim,
            head_dim=self.ssm_headdim,
            d_state=self.ssm_state,
            chunk=self.ssm_chunk,
        )

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS and reporting)."""
        d, hd = self.d_model, self.head_dim
        n = 0
        if self.input_mode == "tokens":
            n += self.vocab * d
        n += self.vocab * d  # unembed
        per = 0
        if self.mla:
            per += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.qk_rope_dim)
            per += d * (self.kv_lora_rank + self.qk_rope_dim)
            per += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            per += self.n_heads * self.v_head_dim * d
        elif self.ssm:
            spec = self.ssm_spec
            per += d * (2 * spec.d_inner + 2 * spec.d_state + spec.n_heads)
            per += spec.d_inner * d
        else:
            per += d * self.n_heads * hd  # q
            per += 2 * d * self.n_kv_heads * hd  # k, v
            per += self.n_heads * hd * d  # o
            if self.hybrid:
                spec = self.ssm_spec
                per += d * (2 * spec.d_inner + 2 * spec.d_state + spec.n_heads)
                per += spec.d_inner * d
        if self.n_experts > 0:
            per += d * self.n_experts  # router
            per += self.n_experts * 3 * d * self.d_ff_expert
            per += self.n_shared_experts * 3 * d * self.d_ff_expert
        elif self.d_ff > 0:
            per += 3 * d * self.d_ff
        return n + self.n_layers * per

    def active_param_count(self) -> int:
        """Activated params per token (MoE top-k), for 6*N_active*D."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        per_moe_total = self.n_experts * 3 * d * self.d_ff_expert
        per_moe_active = (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff_expert
        return self.param_count() - self.n_layers * (per_moe_total - per_moe_active)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _pad_geom(cfg: ArchConfig):
    """Mesh-aligned head geometry (hp, kvp, dup, gp) or None.

    kvp = pad kv heads to `m` via duplication (requires KV | m, or g == 1
    where plain dead-head padding works); q heads pad to hp = kvp * gp with
    gp = ceil(g / dup). Padded q slot s belongs to padded group s // gp,
    whose kv source is (s // gp) // dup-th original group... concretely:
    orig q head of padded slot s = g*( (s//gp)//dup ) + ((s//gp)%dup)*gp + s%gp,
    valid when the per-group offset < g.
    """
    m = cfg.head_pad_multiple
    if m <= 0:
        return None
    H, KV = cfg.n_heads, cfg.n_kv_heads
    g = H // KV
    if KV % m == 0 and H % m == 0:
        return None  # already aligned
    if g == 1:
        hp = -(-H // m) * m
        return (hp, hp, 1, 1)
    if m % KV != 0:
        return None  # unsupported geometry (e.g. hymba kv=5)
    kvp = m
    dup = kvp // KV
    gp = -(-g // dup)
    return (kvp * gp, kvp, dup, gp)


def _q_head_map(cfg: ArchConfig):
    """(orig_index, valid_mask) arrays of length hp for the padded q layout."""
    import numpy as np
    geom = _pad_geom(cfg)
    hp, kvp, dup, gp = geom
    H, KV = cfg.n_heads, cfg.n_kv_heads
    g = H // KV if KV else 1
    idx, valid = [], []
    for s_ in range(hp):
        grp, t = s_ // gp, s_ % gp
        if dup == 1:  # MHA dead-head padding
            o = s_
            ok = o < H
        else:
            o = g * (grp // dup) + (grp % dup) * gp + t
            ok = ((grp % dup) * gp + t) < g and (grp // dup) < KV
        idx.append(o if ok else 0)
        valid.append(ok)
    return np.asarray(idx), np.asarray(valid)


def _init_attn(key: Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 6)
    d, hd = cfg.d_model, cfg.head_dim
    geom = _pad_geom(cfg)
    if geom is None:
        H, KV = cfg.n_heads, cfg.n_kv_heads
        p = {
            "q": L.linear_init(ks[0], d, H * hd, bias=cfg.qkv_bias, dtype=cfg.dtype),
            "k": L.linear_init(ks[1], d, KV * hd, bias=cfg.qkv_bias, dtype=cfg.dtype),
            "v": L.linear_init(ks[2], d, KV * hd, bias=cfg.qkv_bias, dtype=cfg.dtype),
            "o": L.linear_init(ks[3], H * hd, d, dtype=cfg.dtype),
        }
    else:
        hp, kvp, dup, gp = geom
        base_q = L.linear_init(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias,
                               dtype=cfg.dtype)
        base_k = L.linear_init(ks[1], d, cfg.n_kv_heads * hd,
                               bias=cfg.qkv_bias, dtype=cfg.dtype)
        base_v = L.linear_init(ks[2], d, cfg.n_kv_heads * hd,
                               bias=cfg.qkv_bias, dtype=cfg.dtype)
        base_o = L.linear_init(ks[3], cfg.n_heads * hd, d, dtype=cfg.dtype)
        p = {"q": pad_q(base_q, cfg, axis=1), "k": pad_kv(base_k, cfg, axis=1),
             "v": pad_kv(base_v, cfg, axis=1), "o": pad_q(base_o, cfg, axis=0)}
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(hd)
        p["k_norm"] = L.rmsnorm_init(hd)
    return p


def pad_q(pp: dict, cfg: ArchConfig, axis: int) -> dict:
    """Re-lay a q-side weight into the padded head layout (dead slots = 0)."""
    idx, valid = _q_head_map(cfg)
    hd = cfg.head_dim
    out = {}
    for k_, w in pp.items():
        if axis == 1 and k_ == "w":  # (d, H*hd) -> (d, hp*hd)
            wh = w.reshape(w.shape[0], cfg.n_heads, hd)
            padded = wh[:, idx, :] * jnp.asarray(valid, w.dtype)[None, :, None]
            out[k_] = padded.reshape(w.shape[0], -1)
        elif axis == 0 and k_ == "w":  # (H*hd, d) -> (hp*hd, d)
            wh = w.reshape(cfg.n_heads, hd, w.shape[-1])
            padded = wh[idx] * jnp.asarray(valid, w.dtype)[:, None, None]
            out[k_] = padded.reshape(-1, w.shape[-1])
        elif k_ == "b":  # (H*hd,) bias
            bh = w.reshape(cfg.n_heads, hd)
            out[k_] = (bh[idx] * jnp.asarray(valid, w.dtype)[:, None]).reshape(-1)
        else:
            out[k_] = w
    return out


def pad_kv(pp: dict, cfg: ArchConfig, axis: int) -> dict:
    """Duplicate kv-head weight columns into the padded layout."""
    import numpy as np
    hp, kvp, dup, gp = _pad_geom(cfg)
    KV = cfg.n_kv_heads
    idx = np.minimum(np.arange(kvp) // max(dup, 1), KV - 1)
    valid = np.arange(kvp) < KV * max(dup, 1) if dup > 1 else np.arange(kvp) < KV
    hd = cfg.head_dim
    out = {}
    for k_, w in pp.items():
        if k_ == "w":  # (d, KV*hd) -> (d, kvp*hd)
            wh = w.reshape(w.shape[0], KV, hd)
            padded = wh[:, idx, :] * jnp.asarray(valid, w.dtype)[None, :, None]
            out[k_] = padded.reshape(w.shape[0], -1)
        elif k_ == "b":
            bh = w.reshape(KV, hd)
            out[k_] = (bh[idx] * jnp.asarray(valid, w.dtype)[:, None]).reshape(-1)
        else:
            out[k_] = w
    return out


def padded_heads(cfg: ArchConfig) -> tuple[int, int]:
    geom = _pad_geom(cfg)
    if geom is None:
        return cfg.n_heads, cfg.n_kv_heads
    return geom[0], geom[1]


def _init_mla(key: Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "q_a": L.linear_init(ks[0], d, cfg.q_lora_rank, dtype=cfg.dtype),
        "q_a_norm": L.rmsnorm_init(cfg.q_lora_rank),
        "q_b": L.linear_init(ks[1], cfg.q_lora_rank, cfg.n_heads * qk_dim, dtype=cfg.dtype),
        "kv_a": L.linear_init(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dtype=cfg.dtype),
        "kv_a_norm": L.rmsnorm_init(cfg.kv_lora_rank),
        "kv_b": L.linear_init(
            ks[3], cfg.kv_lora_rank,
            cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim), dtype=cfg.dtype),
        "o": L.linear_init(ks[4], cfg.n_heads * cfg.v_head_dim, d, dtype=cfg.dtype),
    }


def _init_layer(key: Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 8)
    p: dict = {"ln1": L.rmsnorm_init(cfg.d_model)}
    if cfg.ssm:
        p["ssd"] = L.ssd_init(ks[0], cfg.d_model, cfg.ssm_spec, dtype=cfg.dtype)
        return p  # mamba2: pure SSM stack, no separate MLP
    if cfg.mla:
        p["attn"] = _init_mla(ks[0], cfg)
    else:
        p["attn"] = _init_attn(ks[0], cfg)
    if cfg.hybrid:
        p["ssd"] = L.ssd_init(ks[1], cfg.d_model, cfg.ssm_spec, dtype=cfg.dtype)
        p["attn_out_norm"] = L.rmsnorm_init(cfg.d_model)
        p["ssm_out_norm"] = L.rmsnorm_init(cfg.d_model)
    p["ln2"] = L.rmsnorm_init(cfg.d_model)
    if cfg.n_experts > 0:
        p["moe"] = L.moe_init(
            ks[2], cfg.d_model, cfg.d_ff_expert, cfg.n_experts,
            cfg.n_shared_experts, cfg.d_ff_expert, dtype=cfg.dtype)
    else:
        p["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype=cfg.dtype)
    return p


def init_params(key: Array, cfg: ArchConfig) -> PyTree:
    k_emb, k_un, k_layers, k_norm = jax.random.split(key, 4)
    p: dict = {}
    if cfg.input_mode == "tokens":
        p["embed"] = (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model))
                      * 0.02).astype(cfg.dtype)
    p["unembed"] = L.linear_init(k_un, cfg.d_model, cfg.vocab, dtype=cfg.dtype)
    p["final_norm"] = L.rmsnorm_init(cfg.d_model)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    p["layers"] = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    return p


# ---------------------------------------------------------------------------
# layer application (shared by train/prefill/decode)
# ---------------------------------------------------------------------------

def _rope(cfg: ArchConfig, x: Array, positions: Array, tables=None) -> Array:
    if cfg.rope == "none":
        return x
    if cfg.rope == "mrope":
        return L.apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return L.apply_rope(x, positions, cfg.rope_theta, tables=tables)


def _rope_tables(cfg: ArchConfig, positions: Array):
    """Step-level rope tables (hillclimb: scan-invariant, built once)."""
    if not cfg.precompute_rope or cfg.rope != "standard":
        return None
    d = cfg.qk_rope_dim if cfg.mla else cfg.head_dim
    return L.rope_tables(positions, d, cfg.rope_theta)


def _attn_qkv(p: dict, cfg: ArchConfig, h: Array, positions: Array,
              tables=None):
    b, s, _ = h.shape
    H, KV = padded_heads(cfg)
    q = L.linear(p["q"], h).reshape(b, s, H, cfg.head_dim)
    k = L.linear(p["k"], h).reshape(b, s, KV, cfg.head_dim)
    v = L.linear(p["v"], h).reshape(b, s, KV, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q)
        k = L.rmsnorm(p["k_norm"], k)
    q = _rope(cfg, q, positions, tables)
    k = _rope(cfg, k, positions, tables)
    return q, k, v


def _mla_q(p: dict, cfg: ArchConfig, h: Array, positions: Array, tables=None):
    b, s, _ = h.shape
    qa = L.rmsnorm(p["q_a_norm"], L.linear(p["q_a"], h))
    q = L.linear(p["q_b"], qa).reshape(
        b, s, cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = _rope(cfg, q_rope, positions, tables)
    return q_nope, q_rope


def _mla_kv_compressed(p: dict, cfg: ArchConfig, h: Array, positions: Array,
                       tables=None):
    ckv_rope = L.linear(p["kv_a"], h)
    c_kv, k_rope = jnp.split(ckv_rope, [cfg.kv_lora_rank], axis=-1)
    c_kv = L.rmsnorm(p["kv_a_norm"], c_kv)
    k_rope = _rope(cfg, k_rope[:, :, None, :], positions, tables)[:, :, 0]
    return c_kv, k_rope


def _mla_train_attention(p: dict, cfg: ArchConfig, h: Array, positions: Array,
                         q_chunk: int, tables=None) -> Array:
    """Expanded MLA attention (training path)."""
    b, s, _ = h.shape
    q_nope, q_rope = _mla_q(p, cfg, h, positions, tables)
    c_kv, k_rope = _mla_kv_compressed(p, cfg, h, positions, tables)
    kv = L.linear(p["kv_b"], c_kv).reshape(
        b, s, cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, cfg.n_heads, cfg.qk_rope_dim))], axis=-1)
    out = L.chunked_attention(q, k, v, causal=cfg.causal, q_chunk=q_chunk)
    return L.linear(p["o"], out.reshape(b, s, -1))


def _layer_train(p: dict, cfg: ArchConfig, h: Array, positions: Array,
                 rope_tabs=None):
    """One layer, full-sequence path. Returns (h, aux_loss)."""
    p = mesh_ctx.constrain_layer(p)  # ZeRO-3 gather-at-use (no-op unsharded)
    aux = jnp.zeros((), jnp.float32)
    x = L.rmsnorm(p["ln1"], h)
    if cfg.ssm:
        y, _ = L.ssd_block(p["ssd"], x, cfg.ssm_spec)
        return h + y, aux
    if cfg.mla:
        att = _mla_train_attention(p["attn"], cfg, x, positions, cfg.q_chunk,
                                   rope_tabs)
    else:
        q, k, v = _attn_qkv(p["attn"], cfg, x, positions, rope_tabs)
        out = L.chunked_attention(
            q, k, v, causal=cfg.causal, q_chunk=cfg.q_chunk,
            window=cfg.sliding_window)
        att = L.linear(p["attn"]["o"], out.reshape(*x.shape[:2], -1))
    if cfg.hybrid:
        ssm_y, _ = L.ssd_block(p["ssd"], x, cfg.ssm_spec)
        att = 0.5 * (L.rmsnorm(p["attn_out_norm"], att)
                     + L.rmsnorm(p["ssm_out_norm"], ssm_y))
    h = h + att
    x2 = L.rmsnorm(p["ln2"], h)
    if cfg.n_experts > 0:
        moe_fn = L.moe_shardmap if cfg.moe_impl == "shard_map" else L.moe
        y, aux = moe_fn(p["moe"], x2, top_k=cfg.top_k,
                        router_type=cfg.router_type,
                        capacity_factor=cfg.capacity_factor)
    else:
        y = L.mlp(p["mlp"], x2)
    return h + y, aux


# ---------------------------------------------------------------------------
# forward / loss (training)
# ---------------------------------------------------------------------------

def _embed_in(params: PyTree, cfg: ArchConfig, inputs: Array) -> Array:
    if cfg.input_mode == "tokens":
        return jnp.take(params["embed"], inputs, axis=0)
    return inputs.astype(cfg.dtype)


def _default_positions(cfg: ArchConfig, b: int, s: int, offset=0) -> Array:
    pos = offset + jnp.arange(s, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def _head_params(params: PyTree) -> dict:
    sub = {k: params[k] for k in ("embed", "unembed", "final_norm") if k in params}
    return mesh_ctx.constrain_head(sub)


def forward(params: PyTree, cfg: ArchConfig, inputs: Array,
            positions: Array | None = None) -> tuple[Array, Array]:
    """Full-sequence forward. inputs: tokens (B,S) int32 or embeds (B,S,d).
    Returns (logits (B,S,V), aux_loss)."""
    head_p = _head_params(params)
    h = _embed_in(head_p, cfg, inputs)
    b, s = h.shape[:2]
    if positions is None:
        positions = _default_positions(cfg, b, s)
    rope_tabs = _rope_tables(cfg, positions)

    def body(carry, layer_p):
        hh, aux = carry
        hh, a = _layer_train(layer_p, cfg, hh, positions, rope_tabs)
        return (hh, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (h, aux), _ = lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["layers"])
    h = L.rmsnorm(head_p["final_norm"], h)
    logits = L.linear(head_p["unembed"], h)
    return logits, aux


def sharded_ce(logits: Array, labels: Array) -> Array:
    """CE that stays sharded when the vocab axis is model-sharded.

    `take_along_axis` on a V-sharded tensor makes GSPMD all-gather the full
    (B,S,V) logits; the one-hot contraction below keeps every op V-sharded
    (partial sums + a tiny (B,S) all-reduce).
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    one_hot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    true_logit = jnp.einsum("...v,...v->...", shifted, one_hot)
    return lse - true_logit  # (B, S)


def loss_fn(params: PyTree, cfg: ArchConfig, batch: dict) -> Array:
    """Causal-LM CE (decoder) / frame-classification CE (encoder)."""
    logits, aux = forward(params, cfg, batch["inputs"],
                          batch.get("positions"))
    labels = batch["labels"]
    if cfg.causal:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    nll = sharded_ce(logits, labels)
    mask = labels >= 0
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss + aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with per-arch caches
# ---------------------------------------------------------------------------

class Cache(NamedTuple):
    """Unified cache; unused fields are None per family.

    k/v:       (L, B, Smax, KV, hd)        attention KV
    c_kv:      (L, B, Smax, kv_lora)       MLA compressed KV
    k_rope:    (L, B, Smax, rope_dim)      MLA shared rope key
    conv:      (L, B, K-1, conv_dim)       SSM conv state
    ssm:       (L, B, H, P, N)             SSM state
    length:    ()  int32                   tokens already in cache
    """

    k: Array | None
    v: Array | None
    c_kv: Array | None
    k_rope: Array | None
    conv: Array | None
    ssm: Array | None
    length: Array


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> Cache:
    dt = dtype or cfg.dtype
    Lc, B = cfg.n_layers, batch
    k = v = c_kv = k_rope = conv = ssm = None
    if cfg.ssm or cfg.hybrid:
        spec = cfg.ssm_spec
        conv_dim = spec.d_inner + 2 * spec.d_state
        conv = jnp.zeros((Lc, B, 3, conv_dim), dt)
        ssm = jnp.zeros((Lc, B, spec.n_heads, spec.head_dim, spec.d_state), jnp.float32)
    if cfg.mla:
        c_kv = jnp.zeros((Lc, B, max_len, cfg.kv_lora_rank), dt)
        k_rope = jnp.zeros((Lc, B, max_len, cfg.qk_rope_dim), dt)
    elif cfg.uses_attention:
        attn_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        kvp = padded_heads(cfg)[1]
        k = jnp.zeros((Lc, B, attn_len, kvp, cfg.head_dim), dt)
        v = jnp.zeros((Lc, B, attn_len, kvp, cfg.head_dim), dt)
    return Cache(k, v, c_kv, k_rope, conv, ssm, jnp.zeros((), jnp.int32))


def _layer_decode(p: dict, cfg: ArchConfig, h: Array, cache_l: dict,
                  length: Array) -> tuple[Array, dict]:
    """One layer, single-token decode. h: (B, 1, d). cache_l holds this
    layer's cache slices; returns (h, updated slices)."""
    p = mesh_ctx.constrain_layer(p)  # ZeRO-3 gather-at-use
    b = h.shape[0]
    new = dict(cache_l)
    positions = _default_positions(cfg, b, 1, offset=length)
    x = L.rmsnorm(p["ln1"], h)

    if cfg.ssm:
        y, st = L.ssd_block(p["ssd"], x, cfg.ssm_spec,
                            state={"conv": cache_l["conv"], "ssm": cache_l["ssm"]})
        new["conv"], new["ssm"] = st["conv"], st["ssm"]
        return h + y, new

    if cfg.mla:
        pa = p["attn"]
        q_nope, q_rope = _mla_q(pa, cfg, x, positions)  # (B,1,H,*)
        c_kv_new, k_rope_new = _mla_kv_compressed(pa, cfg, x, positions)
        slot = cache_l["c_kv"].shape[1]
        idx = length % slot
        c_kv = lax.dynamic_update_slice(cache_l["c_kv"], c_kv_new, (0, idx, 0))
        k_rope = lax.dynamic_update_slice(cache_l["k_rope"], k_rope_new, (0, idx, 0))
        new["c_kv"], new["k_rope"] = c_kv, k_rope
        # weight absorption: score in compressed space
        wkv = pa["kv_b"]["w"].reshape(
            cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim)
        w_uk = wkv[:, :, : cfg.qk_nope_dim]  # (R, H, dk)
        w_uv = wkv[:, :, cfg.qk_nope_dim:]  # (R, H, dv)
        q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                           w_uk.astype(jnp.float32))  # (B,H,R)
        scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
        s1 = jnp.einsum("bhr,bsr->bhs", q_eff, c_kv.astype(jnp.float32))
        s2 = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                        k_rope.astype(jnp.float32))
        logits = (s1 + s2) * scale
        mask = jnp.arange(slot)[None, :] <= idx
        logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
        att = jax.nn.softmax(logits, axis=-1)  # (B,H,S)
        out_c = jnp.einsum("bhs,bsr->bhr", att, c_kv.astype(jnp.float32))
        out = jnp.einsum("bhr,rhd->bhd", out_c, w_uv.astype(jnp.float32))
        att_out = L.linear(pa["o"], out.reshape(b, 1, -1).astype(cfg.dtype))
    else:
        q, k, v = _attn_qkv(p["attn"], cfg, x, positions)
        smax = cache_l["k"].shape[1]
        if cfg.sliding_window:
            idx = length % smax  # ring buffer for sliding window
        else:
            idx = length
        kc = lax.dynamic_update_slice(cache_l["k"], k, (0, idx, 0, 0))
        vc = lax.dynamic_update_slice(cache_l["v"], v, (0, idx, 0, 0))
        new["k"], new["v"] = kc, vc
        if cfg.sliding_window:
            # ring buffer: all slots valid once full
            eff_len = jnp.minimum(length + 1, smax)
            out = L.decode_attention(q, kc, vc, eff_len)
        else:
            out = L.decode_attention(q, kc, vc, length + 1)
        att_out = L.linear(p["attn"]["o"], out.reshape(b, 1, -1))

    if cfg.hybrid:
        ssm_y, st = L.ssd_block(p["ssd"], x, cfg.ssm_spec,
                                state={"conv": cache_l["conv"], "ssm": cache_l["ssm"]})
        new["conv"], new["ssm"] = st["conv"], st["ssm"]
        att_out = 0.5 * (L.rmsnorm(p["attn_out_norm"], att_out)
                         + L.rmsnorm(p["ssm_out_norm"], ssm_y))
    h = h + att_out
    x2 = L.rmsnorm(p["ln2"], h)
    if cfg.n_experts > 0:
        y, _ = L.moe(p["moe"], x2, top_k=cfg.top_k, router_type=cfg.router_type,
                     capacity_factor=cfg.capacity_factor)
    else:
        y = L.mlp(p["mlp"], x2)
    return h + y, new


def _cache_layer_fields(cfg: ArchConfig) -> list[str]:
    fields = []
    if cfg.mla:
        fields += ["c_kv", "k_rope"]
    elif cfg.uses_attention:
        fields += ["k", "v"]
    if cfg.ssm or cfg.hybrid:
        fields += ["conv", "ssm"]
    return fields


def decode_step(params: PyTree, cfg: ArchConfig, tokens: Array,
                cache: Cache) -> tuple[Array, Cache]:
    """One decode step. tokens: (B, 1) int32 (or (B,1,d) embeds).
    Returns (logits (B, 1, V), updated cache)."""
    head_p = _head_params(params)
    h = _embed_in(head_p, cfg, tokens)
    fields = _cache_layer_fields(cfg)
    xs = (params["layers"], {f: getattr(cache, f) for f in fields})

    def body(h, x):
        layer_p, cache_l = x
        h, new = _layer_decode(layer_p, cfg, h, cache_l, cache.length)
        return h, new

    h, new_layers = lax.scan(body, h, xs)
    h = L.rmsnorm(head_p["final_norm"], h)
    logits = L.linear(head_p["unembed"], h)
    updates = {f: new_layers[f] for f in fields}
    return logits, cache._replace(length=cache.length + 1, **updates)


def prefill(params: PyTree, cfg: ArchConfig, inputs: Array,
            positions: Array | None = None,
            max_len: int | None = None) -> tuple[Array, Cache]:
    """Process a prompt, building the serving cache.

    Returns (last-position logits (B, V), cache ready for decode_step).
    Encoder-only configs return full logits and no cache.
    """
    head_p = _head_params(params)
    h = _embed_in(head_p, cfg, inputs)
    b, s = h.shape[:2]
    if positions is None:
        positions = _default_positions(cfg, b, s)
    max_len = max_len or s
    fields = _cache_layer_fields(cfg)
    rope_tabs = _rope_tables(cfg, positions)

    def body(carry, layer_p):
        hh = carry
        out = _layer_prefill(layer_p, cfg, hh, positions, max_len, rope_tabs)
        hh, cache_l = out
        return hh, cache_l

    if cfg.remat:
        body = jax.checkpoint(body)
    h, cache_layers = lax.scan(body, h, params["layers"])
    h = L.rmsnorm(head_p["final_norm"], h)
    logits = L.linear(head_p["unembed"], h[:, -1])
    if not fields:
        return logits, init_cache(cfg, b, 1)
    cache = init_cache(cfg, b, max_len)
    cache = cache._replace(
        length=jnp.asarray(s, jnp.int32),
        **{f: cache_layers[f] for f in fields})
    return logits, cache


def _layer_prefill(p: dict, cfg: ArchConfig, h: Array, positions: Array,
                   max_len: int, rope_tabs=None):
    """Layer forward that also emits this layer's cache tensors."""
    p = mesh_ctx.constrain_layer(p)  # ZeRO-3 gather-at-use
    cache_l: dict = {}
    x = L.rmsnorm(p["ln1"], h)
    aux = None
    if cfg.ssm:
        y, st = L.ssd_block(p["ssd"], x, cfg.ssm_spec)
        cache_l["conv"], cache_l["ssm"] = st["conv"], st["ssm"]
        return h + y, cache_l
    if cfg.mla:
        att = _mla_train_attention(p["attn"], cfg, x, positions, cfg.q_chunk,
                                   rope_tabs)
        c_kv, k_rope = _mla_kv_compressed(p["attn"], cfg, x, positions,
                                          rope_tabs)
        cache_l["c_kv"] = _pad_to(c_kv, max_len, axis=1)
        cache_l["k_rope"] = _pad_to(k_rope, max_len, axis=1)
    else:
        q, k, v = _attn_qkv(p["attn"], cfg, x, positions, rope_tabs)
        out = L.chunked_attention(q, k, v, causal=cfg.causal, q_chunk=cfg.q_chunk,
                                  window=cfg.sliding_window)
        att = L.linear(p["attn"]["o"], out.reshape(*x.shape[:2], -1))
        if cfg.sliding_window:
            # ring buffer with slot(p) = p % w: take the last w keys and roll
            # them so each absolute position lands on its ring slot.
            w = min(cfg.sliding_window, max_len)
            s = k.shape[1]
            if s >= w:
                cache_l["k"] = jnp.roll(k[:, -w:], s % w, axis=1)
                cache_l["v"] = jnp.roll(v[:, -w:], s % w, axis=1)
            else:
                cache_l["k"] = _pad_to(k, w, axis=1)
                cache_l["v"] = _pad_to(v, w, axis=1)
        else:
            cache_l["k"] = _pad_to(k, max_len, axis=1)
            cache_l["v"] = _pad_to(v, max_len, axis=1)
    if cfg.hybrid:
        ssm_y, st = L.ssd_block(p["ssd"], x, cfg.ssm_spec)
        cache_l["conv"], cache_l["ssm"] = st["conv"], st["ssm"]
        att = 0.5 * (L.rmsnorm(p["attn_out_norm"], att)
                     + L.rmsnorm(p["ssm_out_norm"], ssm_y))
    h = h + att
    x2 = L.rmsnorm(p["ln2"], h)
    if cfg.n_experts > 0:
        moe_fn = L.moe_shardmap if cfg.moe_impl == "shard_map" else L.moe
        y, _ = moe_fn(p["moe"], x2, top_k=cfg.top_k,
                      router_type=cfg.router_type,
                      capacity_factor=cfg.capacity_factor)
    else:
        y = L.mlp(p["mlp"], x2)
    return h + y, cache_l


def _pad_to(x: Array, n: int, axis: int) -> Array:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)
