"""Shared neural layers for the LM zoo — pure functional JAX.

Covers every feature the 10 assigned architectures need:
  - RMSNorm / LayerNorm, per-head qk-norm (qwen3)
  - RoPE (standard) and M-RoPE (qwen2-vl 3-section rotary)
  - GQA attention with optional QKV bias, chunked (flash-style, O(S) memory)
    softmax so 32k prefill lowers without (B,H,S,S) temporaries
  - sliding-window masking (hymba long-context)
  - SwiGLU MLP
  - MoE with sort-based capacity dispatch (top-k, optional shared expert,
    softmax or sigmoid router, load-balance aux loss) — scales to 256 experts
  - MLA (deepseek multi-head latent attention), train (expanded) and decode
    (weight-absorbed, compressed cache) paths
  - Mamba2 SSD (chunked state-space duality scan) + single-step decode
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"].astype(x.dtype)


def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: dict, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_tables(positions: Array, head_dim: int, theta: float) -> tuple[Array, Array]:
    """Precompute (cos, sin) of shape (B, S, D/2) once per step so the layer
    scan does not rebuild them per layer (a §Perf hillclimb: per-layer table
    construction showed up as collective-permutes + f32 gathers in the HLO)."""
    freqs = rope_freqs(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: Array, positions: Array, theta: float = 10000.0,
               tables: tuple[Array, Array] | None = None) -> Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    if tables is None:
        tables = rope_tables(positions, d, theta)
    cos = tables[0][:, :, None, :]
    sin = tables[1][:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: Array, positions: Array, theta: float, sections: tuple[int, int, int]
) -> Array:
    """M-RoPE (qwen2-vl): positions (3, B, S) = (temporal, height, width);
    the D/2 frequency slots are split into 3 sections, each driven by its
    own position stream. sections sums to D/2."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    # section id per frequency slot
    sec = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (D/2,)
    # gather per-slot positions: (B, S, D/2)
    pos_bsd = jnp.moveaxis(positions, 0, -1).astype(jnp.float32)  # (B, S, 3)
    slot_pos = jnp.take(pos_bsd, sec, axis=-1)  # (B, S, D/2)
    angles = slot_pos * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense / attention
# ---------------------------------------------------------------------------

def linear_init(key: Array, din: int, dout: int, *, bias: bool = False,
                dtype=jnp.bfloat16) -> dict:
    p = {"w": (jax.random.normal(key, (din, dout)) * (din ** -0.5)).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((dout,), dtype)
    return p


def linear(p: dict, x: Array) -> Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def chunked_attention(
    q: Array, k: Array, v: Array, *, causal: bool, q_chunk: int = 512,
    window: int | None = None, q_offset: Array | int = 0,
) -> Array:
    """Flash-style attention with O(S_q/chunk) temporaries (pure jnp).

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H = KV * G. GQA kv-heads are
    expanded (repeated) to full heads so the head axis shards cleanly over
    the TP mesh axis even when KV < mesh "model" size — the activation-side
    analogue of "replicate KV heads across TP groups". Each q-chunk attends
    to all of k under the mask; `jax.checkpoint` on the chunk body keeps the
    (B, H, q_chunk, Sk) logits out of saved residuals (so the lax.map
    backward recomputes them chunk-by-chunk instead of stacking all chunks).

    `window` adds sliding-window masking; q_offset positions q within the kv
    stream. On real TPU the Pallas flash kernel
    (repro.kernels.flash_attention) replaces this XLA fallback.
    """
    from repro.distributed import context as mesh_ctx

    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    dv = v.shape[-1]  # may differ from d (MLA: qk-dim 192, v-dim 128)
    g = h // kv
    scale = d ** -0.5
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = mesh_ctx.constrain(q, "dp", None, "model", None)
    k = mesh_ctx.constrain(k, "dp", None, "model", None)
    v = mesh_ctx.constrain(v, "dp", None, "model", None)
    nq = -(-sq // q_chunk)
    pad = nq * q_chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, d), 1, 0)

    kpos = jnp.arange(sk)

    def one_chunk(ci, qi):
        # qi: (b, q_chunk, h, d). bf16 operands + f32 accumulation
        # (preferred_element_type) = MXU semantics, no materialised f32
        # operand copies.
        logits = jnp.einsum("bqhd,bshd->bhqs", qi, k,
                            preferred_element_type=jnp.float32) * scale
        qpos = q_offset + ci * q_chunk + jnp.arange(q_chunk)
        mask = jnp.ones((q_chunk, sk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
        att = jax.nn.softmax(logits, axis=-1)
        # fully-masked rows (padding) produce nan-free zeros:
        att = jnp.where(jnp.any(mask, axis=-1)[None, None, :, None], att, 0.0)
        out = jnp.einsum("bhqs,bshd->bqhd", att.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.astype(v.dtype)

    if nq == 1:
        # single chunk: no loop — also the path used by the dry-run layer
        # probes (q_chunk=seq) so XLA cost analysis sees the attention FLOPs
        # outside any while body.
        out = one_chunk(0, qc[0])[None]
    else:
        body = jax.checkpoint(lambda args: one_chunk(*args))
        out = lax.map(body, (jnp.arange(nq), qc))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * q_chunk, h, dv)
    return out[:, :sq]


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cur_len: Array) -> Array:
    """Single-token attention against a (B, Smax, KV, D) cache.

    q: (B, 1, H, D); cur_len: scalar int32 — only slots < cur_len attended
    (ring-buffer callers pass the buffer fill level).
    """
    b, _, h, d = q.shape
    _, smax, kv, _ = k_cache.shape
    g = h // kv
    qr = q.reshape(b, kv, g, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    mask = jnp.arange(smax) < cur_len  # (smax,)
    logits = jnp.where(mask[None, None, None, :], logits, -jnp.inf)
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", att.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key: Array, d: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_init(k1, d, d_ff, dtype=dtype),
        "up": linear_init(k2, d, d_ff, dtype=dtype),
        "down": linear_init(k3, d_ff, d, dtype=dtype),
    }


def mlp(p: dict, x: Array) -> Array:
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


# ---------------------------------------------------------------------------
# Mixture of Experts — sort-based capacity dispatch
# ---------------------------------------------------------------------------

def moe_init(
    key: Array, d: int, d_ff_expert: int, n_experts: int, n_shared: int,
    d_ff_shared: int, dtype=jnp.bfloat16,
) -> dict:
    ks = jax.random.split(key, 5)
    scale_in = d ** -0.5
    scale_out = d_ff_expert ** -0.5
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, n_experts)) * scale_in
                          ).astype(jnp.float32)},
        "gate": (jax.random.normal(ks[1], (n_experts, d, d_ff_expert)) * scale_in).astype(dtype),
        "up": (jax.random.normal(ks[2], (n_experts, d, d_ff_expert)) * scale_in).astype(dtype),
        "down": (jax.random.normal(ks[3], (n_experts, d_ff_expert, d)) * scale_out).astype(dtype),
    }
    if n_shared > 0:
        p["shared"] = mlp_init(ks[4], d, d_ff_shared * n_shared, dtype=dtype)
    return p


def moe(
    p: dict, x: Array, *, top_k: int, router_type: str = "softmax",
    capacity_factor: float = 1.25, aux_coeff: float = 0.01,
) -> tuple[Array, Array]:
    """MoE layer. x: (B, S, d) -> (y, aux_loss).

    Dispatch: flatten tokens, top-k route, sort (token,k) slots by expert id,
    pack into a static (E, C, d) capacity buffer, batched per-expert matmuls,
    weighted scatter back. Slots beyond capacity are dropped (standard
    capacity-factor semantics). FLOPs = T*K*d*d_ff*3*2 — no all-expert
    overcompute; memory = O(E*C*d) — no (B,S,E,C) one-hot.
    """
    b, s, d = x.shape
    e = p["gate"].shape[0]
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)  # (T, E)
    if router_type == "softmax":
        scores = jax.nn.softmax(logits, axis=-1)
        w, ids = lax.top_k(scores, top_k)  # (T, K)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    elif router_type == "sigmoid":  # deepseek-v3 style
        scores = jax.nn.sigmoid(logits)
        w, ids = lax.top_k(scores, top_k)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    else:
        raise ValueError(router_type)

    # load-balance aux loss (fraction-dispatched x mean-router-prob)
    probs = jax.nn.softmax(logits, axis=-1)
    dispatch_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=1), axis=0)
    aux = aux_coeff * e * jnp.sum(dispatch_frac * jnp.mean(probs, axis=0))

    # ---- sort-based dispatch ----
    # small token counts (decode steps, smoke tests): capacity = t makes
    # dropping impossible (a token contributes each expert at most once), so
    # serving is exact. At training scale the usual capacity-factor applies.
    if t <= 4096:
        cap = t
    else:
        cap = max(int(-(-t * top_k // e) * capacity_factor), top_k)
    flat_ids = ids.reshape(-1)  # (T*K,)
    sort_idx = jnp.argsort(flat_ids)  # stable
    sorted_ids = flat_ids[sort_idx]
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(e))  # (E,)
    pos_in_seg = jnp.arange(t * top_k) - seg_start[sorted_ids]
    keep = pos_in_seg < cap
    token_of_slot = sort_idx // top_k  # (T*K,) source token per sorted slot

    # pack tokens -> (E, C, d); keep the buffer sharded E->model (expert
    # parallelism), C->data under a mesh (repro.distributed.context)
    from repro.distributed import context as mesh_ctx

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[sorted_ids, jnp.where(keep, pos_in_seg, cap - 1)].add(
        jnp.where(keep[:, None], xf[token_of_slot], 0).astype(x.dtype),
        mode="drop",
    )
    buf = mesh_ctx.constrain(buf, "model", "dp", None)

    # batched expert FFN: (E, C, d) x (E, d, f)
    h_gate = jnp.einsum("ecd,edf->ecf", buf, p["gate"])
    h_up = jnp.einsum("ecd,edf->ecf", buf, p["up"])
    h = jax.nn.silu(h_gate) * h_up
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down"])  # (E, C, d)
    out_buf = mesh_ctx.constrain(out_buf, "model", "dp", None)

    # weighted scatter back to tokens
    flat_w = w.reshape(-1)[sort_idx]  # (T*K,) aligned with slots
    gathered = out_buf[sorted_ids, jnp.clip(pos_in_seg, 0, cap - 1)]  # (T*K, d)
    contrib = jnp.where(keep[:, None], gathered * flat_w[:, None].astype(x.dtype), 0)
    y = jnp.zeros((t, d), x.dtype).at[token_of_slot].add(contrib)

    if "shared" in p:
        y = y + mlp(p["shared"], xf)
    return y.reshape(b, s, d), aux


def moe_shardmap(
    p: dict, x: Array, *, top_k: int, router_type: str = "softmax",
    capacity_factor: float = 1.25, aux_coeff: float = 0.01,
) -> tuple[Array, Array]:
    """Expert-parallel MoE with manual collectives (jax.shard_map).

    §Perf hillclimb for the MoE cells: the auto-GSPMD path lowers the
    capacity-buffer scatter-adds as replicated-compute + full-buffer
    all-reduce (measured 725 GB/layer on deepseek-v3 train_4k). Here the key
    observation is that under tensor parallelism the activations are already
    replicated across the "model" axis, so *dispatch needs no communication
    at all*: every model-rank routes and packs the same (dp-local) tokens,
    computes only its own experts' slice, and the combine is one bf16 psum
    of (T_local, d) over the model axis (~0.9 GB/layer at deepseek scale —
    a ~300x cut). Router + shared expert + aux loss stay in auto-GSPMD land
    (small, and keeps their gradients trivially correct).
    """
    from repro.distributed import context as mesh_ctx

    ax = mesh_ctx.get()
    mesh = mesh_ctx.get_mesh()
    if ax is None or mesh is None:
        return moe(p, x, top_k=top_k, router_type=router_type,
                   capacity_factor=capacity_factor, aux_coeff=aux_coeff)
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e = p["gate"].shape[0]
    t = b * s
    xf = x.reshape(t, d)

    # --- routing (auto land, replicated router weights) ---
    logits = (xf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    if router_type == "softmax":
        scores = jax.nn.softmax(logits, axis=-1)
    elif router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        raise ValueError(router_type)
    w, ids = lax.top_k(scores, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

    probs = jax.nn.softmax(logits, axis=-1)
    dispatch_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=1), axis=0)
    aux = aux_coeff * e * jnp.sum(dispatch_frac * jnp.mean(probs, axis=0))

    # --- static geometry ---
    dp_axes = ax.dp if isinstance(ax.dp, tuple) else (ax.dp,)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    mp_size = mesh.shape[ax.model]
    e_loc = e // mp_size
    t_loc = max(t // dp_size, 1)
    if t_loc <= 4096:
        cap = t_loc  # exact small-batch semantics (see moe())
    else:
        cap = max(int(-(-t_loc * top_k // e) * capacity_factor), top_k)

    def block(x_blk, ids_blk, w_blk, gate, up, down):
        # x_blk (t_loc, d); ids/w (t_loc, K); gate/up (e_loc, d, f)
        j = lax.axis_index(ax.model)
        flat_ids = ids_blk.reshape(-1)
        sort_idx = jnp.argsort(flat_ids)
        sorted_ids = flat_ids[sort_idx]
        seg_start = jnp.searchsorted(sorted_ids, jnp.arange(e))
        pos = jnp.arange(sorted_ids.shape[0]) - seg_start[sorted_ids]
        local = (sorted_ids >= j * e_loc) & (sorted_ids < (j + 1) * e_loc)
        keep = local & (pos < cap)
        tok = sort_idx // top_k
        le = jnp.where(local, sorted_ids - j * e_loc, 0)

        buf = jnp.zeros((e_loc, cap, x_blk.shape[-1]), x_blk.dtype)
        buf = buf.at[le, jnp.where(keep, pos, cap - 1)].add(
            jnp.where(keep[:, None], x_blk[tok], 0).astype(x_blk.dtype),
            mode="drop")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate)) * jnp.einsum(
            "ecd,edf->ecf", buf, up)
        out_buf = jnp.einsum("ecf,efd->ecd", h, down)

        fw = w_blk.reshape(-1)[sort_idx]
        gathered = out_buf[le, jnp.clip(pos, 0, cap - 1)]
        contrib = jnp.where(keep[:, None],
                            gathered * fw[:, None].astype(x_blk.dtype), 0)
        y_loc = jnp.zeros_like(x_blk).at[tok].add(contrib)
        return lax.psum(y_loc, ax.model)

    dp = ax.dp
    y = jax.shard_map(
        block, mesh=mesh,
        in_specs=(P(dp, None), P(dp, None), P(dp, None),
                  P(ax.model, None, None), P(ax.model, None, None),
                  P(ax.model, None, None)),
        out_specs=P(dp, None),
    )(xf, ids, w, p["gate"], p["up"], p["down"])

    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x)
    return y, aux


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality, chunked)
# ---------------------------------------------------------------------------

class SSMSpec(NamedTuple):
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int
    conv_kernel: int = 4
    chunk: int = 256


def ssd_init(key: Array, d: int, spec: SSMSpec, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 4)
    din, h = spec.d_inner, spec.n_heads
    # in_proj -> [z, x, B, C, dt]
    d_proj = 2 * din + 2 * spec.d_state + h
    return {
        "in_proj": linear_init(ks[0], d, d_proj, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.conv_kernel,
                    din + 2 * spec.d_state)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_norm": rmsnorm_init(din),
        "out_proj": linear_init(ks[3], din, d, dtype=dtype),
    }


def _causal_conv(x: Array, w: Array, state: Array | None = None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C). Returns (y, new_state)
    where state carries the trailing K-1 inputs for decode."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros_like(x[:, :0])
    return y, new_state


def _segsum(a: Array) -> Array:
    """Stable 'segment sum': out[..., i, j] = sum_{k=j+1..i} a[..., k],
    -inf for j > i. a: (..., L)."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{j+1..i} when i>=j
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(
    xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array, spec: SSMSpec,
    init_state: Array | None = None,
) -> tuple[Array, Array]:
    """Chunked SSD (Mamba2 alg. 1 dual form).

    xh: (B, S, H, P) head inputs; dt: (B, S, H) positive step sizes;
    A: (H,) negative decay rates; Bm, Cm: (B, S, N) (single group).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    L = spec.chunk
    nc = -(-s // L)
    pad = nc * L - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    # chunked views
    xc = xh.reshape(b, nc, L, h, p)
    dtc = dt.reshape(b, nc, L, h)
    Bc = Bm.reshape(b, nc, L, n)
    Cc = Cm.reshape(b, nc, L, n)

    a = (dtc * A[None, None, None, :]).astype(jnp.float32)  # (b,nc,L,h) negative
    a_hp = jnp.moveaxis(a, -1, -2)  # (b, nc, h, L)
    a_cum = jnp.cumsum(a_hp, axis=-1)

    xdt = xc * dtc[..., None]  # weight inputs by dt

    # 1) intra-chunk (diagonal) term. bf16 operands + f32 accumulation:
    # the decay/score matrices stay f32 (exp output), the big tensors feed
    # the MXU in bf16 (§Perf cell D).
    Lmat = jnp.exp(_segsum(a_hp))  # (b,nc,h,L,L)
    Lmat = jnp.where(jnp.isfinite(Lmat), Lmat, 0.0)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc,
                        preferred_element_type=jnp.float32)  # (b,nc,L,L)
    xdt_b = xdt.astype(xh.dtype)
    y_diag = jnp.einsum("bchlm,bclm,bcmhp->bclhp",
                        Lmat.astype(xh.dtype), scores.astype(xh.dtype),
                        xdt_b, preferred_element_type=jnp.float32)

    # 2) chunk states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (b,nc,h,L)
    states = jnp.einsum("bcln,bchl,bclhp->bchpn",
                        Bc.astype(xh.dtype), decay_states.astype(xh.dtype),
                        xdt_b, preferred_element_type=jnp.float32)

    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])  # (b,nc,h)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_fn(carry, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    states_t = jnp.moveaxis(states, 1, 0)  # (nc,b,h,p,n)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,b,h)
    final, prev_states = lax.scan(scan_fn, s0, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,h,p,n)

    # 4) inter-chunk output
    state_decay_out = jnp.exp(a_cum)  # (b,nc,h,L)
    y_off = jnp.einsum("bcln,bchl,bchpn->bclhp",
                       Cc.astype(xh.dtype), state_decay_out.astype(xh.dtype),
                       prev_states.astype(xh.dtype),
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(b, nc * L, h, p)[:, :s]
    return y.astype(xh.dtype), final


def ssd_step(
    xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array, state: Array,
) -> tuple[Array, Array]:
    """Single-token SSM update (decode path — O(1), no chunking).

    xh: (B, 1, H, P); dt: (B, 1, H); Bm, Cm: (B, 1, N); state: (B, H, P, N).
        state' = state * exp(A*dt) + (dt*x) outer B;  y = <state', C>
    """
    a = jnp.exp(dt[:, 0, :, None, None].astype(jnp.float32)
                * A[None, :, None, None])  # (B,H,1,1)
    xdt = (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32)  # (B,H,P)
    upd = jnp.einsum("bhp,bn->bhpn", xdt, Bm[:, 0].astype(jnp.float32))
    new_state = state.astype(jnp.float32) * a + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm[:, 0].astype(jnp.float32))
    return y[:, None].astype(xh.dtype), new_state


def ssd_block(p: dict, x: Array, spec: SSMSpec, *, state: PyTree | None = None,
              ) -> tuple[Array, PyTree]:
    """Full Mamba2 block. x: (B, S, d). state: None (train/prefill from zero)
    or {"conv": (B,K-1,C), "ssm": (B,H,P,N)} for decode/continuation.
    Returns (y (B,S,d), new_state)."""
    din, h, pd, n = spec.d_inner, spec.n_heads, spec.head_dim, spec.d_state
    proj = linear(p["in_proj"], x)
    z, xin, Bm, Cm, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, Bm, Cm = jnp.split(conv_out, [din, din + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) negative
    xh = xin.reshape(*xin.shape[:-1], h, pd)
    init = None if state is None else state["ssm"]
    if x.shape[1] == 1 and state is not None:
        y, final = ssd_step(xh, dt, A, Bm, Cm, init)
    else:
        y, final = ssd_scan(xh, dt, A, Bm, Cm, spec, init_state=init)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:-1], din)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y)
    return out, {"conv": new_conv, "ssm": final}
