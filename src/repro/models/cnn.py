"""CNN front-ends: the paper's student model (Fig. 5) and ResNet teacher.

Pure-JAX functional style: params are pytrees, `init_*` builds them,
`apply_*` runs them. NHWC layout.

Student (Fig. 5): conv32(3x3, valid) -> BN -> maxpool2
                  conv128(3x3, same) -> BN -> maxpool2
                  conv256(3x3, same)
                  conv16(3x3, same)   # feature-map reducer
  32x32x1 -> 30 -> 15 -> 15 -> 7 -> 7x7x256 -> 7x7x16 = 784 features,
  matching the paper's N_features = 784 (Eq. 14) exactly.
  Head: either a dense softmax classifier (baseline) or the ACAM head.

Teacher: CIFAR-style ResNet — 3 stages from `width` channels, basic blocks
(two 3x3 convs + BN + ReLU, identity/1x1 shortcuts), global average pool,
dense head (paper §IV-B).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def he_init(key: Array, shape: tuple[int, ...], fan_in: int) -> Array:
    return jax.random.normal(key, shape) * jnp.sqrt(2.0 / fan_in)


def conv_init(key: Array, kh: int, kw: int, cin: int, cout: int) -> dict:
    return {
        "w": he_init(key, (kh, kw, cin, cout), kh * kw * cin),
        "b": jnp.zeros((cout,)),
    }


def conv2d(p: dict, x: Array, *, stride: int = 1, padding: str = "SAME") -> Array:
    y = lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def bn_init(c: int) -> dict:
    return {
        "scale": jnp.ones((c,)),
        "bias": jnp.zeros((c,)),
        "mean": jnp.zeros((c,)),
        "var": jnp.ones((c,)),
    }


def batchnorm(p: dict, x: Array, *, train: bool, momentum: float = 0.9):
    """Returns (y, new_stats). In eval mode new_stats is p unchanged."""
    if train:
        mu = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new = dict(p)
        new["mean"] = momentum * p["mean"] + (1 - momentum) * mu
        new["var"] = momentum * p["var"] + (1 - momentum) * var
    else:
        mu, var, new = p["mean"], p["var"], p
    inv = lax.rsqrt(var + 1e-5)
    return (x - mu) * inv * p["scale"] + p["bias"], new


def maxpool2(x: Array) -> Array:
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def dense_init(key: Array, din: int, dout: int) -> dict:
    return {"w": he_init(key, (din, dout), din), "b": jnp.zeros((dout,))}


def dense(p: dict, x: Array) -> Array:
    return x @ p["w"] + p["b"]


# ---------------------------------------------------------------------------
# Student model (Fig. 5)
# ---------------------------------------------------------------------------

class StudentConfig(NamedTuple):
    in_channels: int = 1  # greyscale per §IV-A
    filters: tuple[int, int, int, int] = (32, 128, 256, 16)
    num_classes: int = 10

    @property
    def num_features(self) -> int:
        return 7 * 7 * self.filters[3]  # 784 at the paper's sizes


def init_student(key: Array, cfg: StudentConfig = StudentConfig()) -> PyTree:
    ks = jax.random.split(key, 5)
    f1, f2, f3, f4 = cfg.filters
    return {
        "conv1": conv_init(ks[0], 3, 3, cfg.in_channels, f1),
        "bn1": bn_init(f1),
        "conv2": conv_init(ks[1], 3, 3, f1, f2),
        "bn2": bn_init(f2),
        "conv3": conv_init(ks[2], 3, 3, f2, f3),
        "conv4": conv_init(ks[3], 3, 3, f3, f4),
        "head": dense_init(ks[4], cfg.num_features, cfg.num_classes),
    }


def student_features(
    params: PyTree, x: Array, *, train: bool = False, quantize: bool = False
) -> tuple[Array, PyTree]:
    """Front-end feature extractor -> (features (B, 784), new_bn_stats).

    quantize=True runs weights through int8 fake-quant (QAT / deployment).
    """
    from repro.core.quant import fake_quant_tree

    p = fake_quant_tree(params) if quantize else params
    h = jax.nn.relu(conv2d(p["conv1"], x, padding="VALID"))  # 32 -> 30
    h, bn1 = batchnorm(p["bn1"], h, train=train)
    h = maxpool2(h)  # 15
    h = jax.nn.relu(conv2d(p["conv2"], h))  # 15
    h, bn2 = batchnorm(p["bn2"], h, train=train)
    h = maxpool2(h)  # 7
    h = jax.nn.relu(conv2d(p["conv3"], h))  # 7x7x256
    h = jax.nn.relu(conv2d(p["conv4"], h))  # 7x7x16
    feats = h.reshape(h.shape[0], -1)  # 784
    new = dict(params)
    if train:
        new = dict(params, bn1=bn1, bn2=bn2)
    return feats, new


def student_logits(
    params: PyTree, x: Array, *, train: bool = False, quantize: bool = False
) -> tuple[Array, PyTree]:
    feats, new = student_features(params, x, train=train, quantize=quantize)
    return dense(params["head"], feats), new


def student_macs(cfg: StudentConfig = StudentConfig()) -> dict[str, int]:
    """Eq. 13 MAC counts per layer (+ the dense softmax head)."""
    f1, f2, f3, f4 = cfg.filters
    layers = {
        "conv1": 30 * 30 * 3 * 3 * cfg.in_channels * f1,
        "conv2": 15 * 15 * 3 * 3 * f1 * f2,
        "conv3": 7 * 7 * 3 * 3 * f2 * f3,
        "conv4": 7 * 7 * 3 * 3 * f3 * f4,
        "head": cfg.num_features * cfg.num_classes + cfg.num_classes,
    }
    layers["total"] = sum(layers.values())
    return layers


def count_params(params: PyTree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Teacher model (CIFAR-style ResNet, §IV-B)
# ---------------------------------------------------------------------------

class TeacherConfig(NamedTuple):
    in_channels: int = 3
    width: int = 16  # stage-1 channels; stages double
    blocks_per_stage: int = 3
    num_classes: int = 10


def _block_init(key: Array, cin: int, cout: int) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "conv1": conv_init(ks[0], 3, 3, cin, cout),
        "bn1": bn_init(cout),
        "conv2": conv_init(ks[1], 3, 3, cout, cout),
        "bn2": bn_init(cout),
    }
    if cin != cout:
        p["proj"] = conv_init(ks[2], 1, 1, cin, cout)
    return p


def init_teacher(key: Array, cfg: TeacherConfig = TeacherConfig()) -> PyTree:
    ks = jax.random.split(key, 2 + 3 * cfg.blocks_per_stage)
    params: dict = {"stem": conv_init(ks[0], 3, 3, cfg.in_channels, cfg.width),
                    "bn_stem": bn_init(cfg.width)}
    ki = 1
    cin = cfg.width
    for s in range(3):
        cout = cfg.width * (2**s)
        for b in range(cfg.blocks_per_stage):
            params[f"s{s}b{b}"] = _block_init(ks[ki], cin, cout)
            ki += 1
            cin = cout
    params["head"] = dense_init(ks[ki], cin, cfg.num_classes)
    return params


def _block_apply(p: dict, x: Array, *, stride: int, train: bool):
    h = conv2d(p["conv1"], x, stride=stride)
    h, bn1 = batchnorm(p["bn1"], h, train=train)
    h = jax.nn.relu(h)
    h = conv2d(p["conv2"], h)
    h, bn2 = batchnorm(p["bn2"], h, train=train)
    sc = x
    if "proj" in p:
        sc = conv2d(p["proj"], x, stride=stride)
    elif stride != 1:
        sc = x[:, ::stride, ::stride, :]
    out = jax.nn.relu(h + sc)
    new = dict(p, bn1=bn1, bn2=bn2) if train else p
    return out, new


def teacher_logits(
    params: PyTree, x: Array, cfg: TeacherConfig = TeacherConfig(), *, train: bool = False
) -> tuple[Array, PyTree]:
    new = dict(params)
    h = conv2d(params["stem"], x)
    h, new["bn_stem"] = batchnorm(params["bn_stem"], h, train=train)
    h = jax.nn.relu(h)
    for s in range(3):
        for b in range(cfg.blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            h, new[f"s{s}b{b}"] = _block_apply(
                params[f"s{s}b{b}"], h, stride=stride, train=train
            )
    h = jnp.mean(h, axis=(1, 2))  # global average pool
    return dense(params["head"], h), (new if train else params)


def teacher_macs(cfg: TeacherConfig = TeacherConfig()) -> int:
    """Analytic MAC count for the teacher at 32x32 input."""
    total = 32 * 32 * 9 * cfg.in_channels * cfg.width
    hw, cin = 32, cfg.width
    for s in range(3):
        cout = cfg.width * (2**s)
        for b in range(cfg.blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            hw_out = hw // stride
            total += hw_out * hw_out * 9 * cin * cout  # conv1
            total += hw_out * hw_out * 9 * cout * cout  # conv2
            if cin != cout:
                total += hw_out * hw_out * cin * cout  # proj
            hw, cin = hw_out, cout
    total += cin * cfg.num_classes
    return total
