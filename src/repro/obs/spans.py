"""Per-request span tracing: admission -> queue -> tick -> dispatch ->
cascade -> response, with wall-clock stamps and the serving tick id.

A `Span` is the request's flight record: when it was admitted, when its
tick dequeued it, how long the fused dispatch took, what the cascade
decided, and how it left the service (`disposition`). The derived views
(`queue_ms`, `service_ms`, `total_ms`) attribute a slow request to
queueing vs dispatch vs CNN escalation without guessing.

`SpanRecorder` enforces conservation: a span is opened exactly once at
admission (`start`) and removed exactly once at finalization (`finish`
pops it) — shed, deadline-expired, escalated, and errored requests all
travel the same open/close path, so finished-span count == finished
request count by construction, never by sampling luck.

Sampling (`ObsSpec.span_sample < 1.0`) is deterministic in the request
id — a Knuth-hash coin, no RNG state — so the same trace replayed twice
keeps the same spans and bit-identical serving results.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

#: terminal dispositions a request can leave the service with
DISPOSITIONS = ("ok", "escalated", "shed", "expired", "error", "rejected")

_KNUTH = 2654435761  # golden-ratio multiplicative hash constant


def sampled(request_id: int, rate: float) -> bool:
    """Deterministic per-request sampling coin: hash the id, compare the
    top 32 bits against the rate. Same id -> same verdict, every run."""
    if rate >= 1.0:
        return True
    if rate <= 0.0:
        return False
    return ((request_id * _KNUTH) & 0xFFFFFFFF) / 2**32 < rate


@dataclass
class Span:
    """One request's flight record. Times are `time.perf_counter()`
    stamps (monotonic seconds); durations derive from their deltas."""

    request_id: int
    tenant_id: str
    t_admit: float
    t_dequeue: float = 0.0       # stamped when a tick batches the request
    tick_id: int = -1            # serving tick that dispatched it (-1: none)
    dispatch_ms: float = 0.0     # fused ACAM dispatch wall time (batch-level)
    escalated: bool = False      # cascade sent it to the CNN head
    disposition: str = ""        # terminal state, one of DISPOSITIONS
    t_done: float = 0.0

    @property
    def queue_ms(self) -> float:
        """Admission -> tick pickup (0 for never-dispatched requests)."""
        if self.t_dequeue <= 0.0:
            return 0.0
        return (self.t_dequeue - self.t_admit) * 1e3

    @property
    def service_ms(self) -> float:
        """Tick pickup -> response (dispatch + cascade + escalation)."""
        if self.t_dequeue <= 0.0 or self.t_done <= 0.0:
            return 0.0
        return (self.t_done - self.t_dequeue) * 1e3

    @property
    def total_ms(self) -> float:
        if self.t_done <= 0.0:
            return 0.0
        return (self.t_done - self.t_admit) * 1e3

    def as_dict(self) -> dict:
        return {
            "request_id": self.request_id,
            "tenant_id": self.tenant_id,
            "tick_id": self.tick_id,
            "disposition": self.disposition,
            "escalated": self.escalated,
            "queue_ms": round(self.queue_ms, 4),
            "dispatch_ms": round(self.dispatch_ms, 4),
            "service_ms": round(self.service_ms, 4),
            "total_ms": round(self.total_ms, 4),
        }


@dataclass
class SpanRecorder:
    """Open/close ledger for request spans.

    `active` holds in-flight spans keyed by request id; `finish` pops —
    a request can therefore neither finish twice nor finish without
    having started, which is what makes span conservation a structural
    property rather than a test assertion.
    """

    sample_rate: float = 1.0
    keep: int = 512              # finished spans retained for inspection
    active: dict[int, Span] = field(default_factory=dict)
    finished: deque = field(default_factory=lambda: deque(maxlen=512))
    started_total: int = 0
    finished_total: int = 0
    by_disposition: dict[str, int] = field(default_factory=dict)

    def __post_init__(self):
        self.finished = deque(maxlen=self.keep)

    def start(self, request_id: int, tenant_id: str,
              t_admit: float | None = None) -> Span | None:
        """Open a span at admission. Returns None when sampled out (the
        conservation counters still tick, so accounting stays exact)."""
        self.started_total += 1
        if not sampled(request_id, self.sample_rate):
            return None
        span = Span(request_id, tenant_id,
                    time.perf_counter() if t_admit is None else t_admit)
        self.active[request_id] = span
        return span

    def dequeue(self, request_id: int, tick_id: int,
                t_dequeue: float) -> None:
        """Stamp tick pickup (batch-level: one perf_counter per tick,
        shared by every request in the batch — not one syscall each)."""
        span = self.active.get(request_id)
        if span is not None:
            span.t_dequeue = t_dequeue
            span.tick_id = tick_id

    def set_dispatch(self, request_id: int, dispatch_ms: float) -> None:
        span = self.active.get(request_id)
        if span is not None:
            span.dispatch_ms = dispatch_ms

    def finish(self, request_id: int, disposition: str,
               escalated: bool = False,
               t_done: float | None = None) -> Span | None:
        """Close a span exactly once; unknown/sampled-out ids only bump
        the conservation counters."""
        if disposition not in DISPOSITIONS:
            raise ValueError(f"unknown disposition {disposition!r}; "
                             f"expected one of {DISPOSITIONS}")
        self.finished_total += 1
        self.by_disposition[disposition] = \
            self.by_disposition.get(disposition, 0) + 1
        span = self.active.pop(request_id, None)
        if span is None:
            return None
        span.disposition = disposition
        span.escalated = escalated
        span.t_done = time.perf_counter() if t_done is None else t_done
        self.finished.append(span)
        return span

    @property
    def in_flight(self) -> int:
        return len(self.active)

    def conservation(self) -> dict:
        """started == finished + in-flight must hold at every quiescent
        point; the chaos/burst tests assert exactly this."""
        return {
            "started": self.started_total,
            "finished": self.finished_total,
            "in_flight": len(self.active),
            "by_disposition": dict(self.by_disposition),
        }
