"""MetricsRegistry: the one place the service's numbers live.

Three metric kinds, all labeled, all readable from one registry:

  * `Counter` — monotone accumulators (requests, escalations, joules).
    Cleared by `reset()` (the "after a warmup burst" contract).
  * `Gauge` — last-write-wins state (queue depth, shed mode, straggler
    strikes). Gauges describe the service *now*, so `reset()` leaves them
    alone unless the gauge opted in with ``clear_on_reset=True`` (per-run
    aggregates like min/max batch fill).
  * `Histogram` — fixed-bucket latency distributions with TWO views over
    one `observe()` stream: the cumulative counts (cleared by reset, what
    the Prometheus renderer exports) and a bounded **rolling window**
    (survives reset — it feeds the overload policy, and a metrics reset
    must never blind load shedding). Quantiles are computed exactly from
    the bucket counts (deterministic linear interpolation inside the
    containing bucket), so every consumer of "the p99" — `metrics()`,
    the shed check, `health()` — reads the identical value instead of
    running its own `np.percentile` over its own private reservoir.

Accumulation is plain-Python dict/float arithmetic (atomic under the GIL,
no locks taken on the tick path); rendering/iteration happens off the hot
path via `collect()`.
"""
from __future__ import annotations

from bisect import bisect_left
from collections import deque
from typing import Iterator, NamedTuple

#: default latency buckets (ms) — sub-tick through first-tick compile
#: stalls and pathological queueing
DEFAULT_LATENCY_BUCKETS_MS = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0,
                              50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0,
                              10000.0)

#: hard bound on label-set cardinality per metric family; a tenant-labeled
#: counter growing past this means a label leak, not a big fleet
MAX_LABEL_SETS = 1024


class Sample(NamedTuple):
    """One exported time-series point: (name, labels, value)."""

    name: str
    labels: tuple[tuple[str, str], ...]
    value: float


def _label_key(labels: dict | None) -> tuple[tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Family:
    """Shared plumbing: a named metric with per-label-set children."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._children: dict[tuple, float] = {}

    def _child(self, labels: dict | None) -> tuple:
        key = _label_key(labels)
        if key not in self._children:
            if len(self._children) >= MAX_LABEL_SETS:
                raise ValueError(
                    f"metric {self.name!r} exceeded {MAX_LABEL_SETS} label "
                    "sets — unbounded label cardinality")
            self._children[key] = 0.0
        return key

    def value(self, **labels) -> float:
        """Read one child (0.0 when the label set was never touched)."""
        return self._children.get(_label_key(labels), 0.0)

    def total(self) -> float:
        """Sum across every label set."""
        return sum(self._children.values())

    def items(self) -> Iterator[tuple[dict, float]]:
        """(labels-as-dict, value) per child — registry-backed views
        (e.g. `health()`'s per-host straggler strikes) read through this."""
        for key, v in sorted(self._children.items()):
            yield dict(key), v

    def samples(self) -> Iterator[Sample]:
        for key, v in sorted(self._children.items()):
            yield Sample(self.name, key, v)


class Counter(_Family):
    """Monotone accumulator; cleared by `MetricsRegistry.reset()`."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        self._children[self._child(labels)] += amount

    def clear(self) -> None:
        for key in self._children:
            self._children[key] = 0.0


class Gauge(_Family):
    """Last-write-wins state. Survives `reset()` unless constructed with
    ``clear_on_reset=True`` (per-run aggregates such as min/max fill)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "", *,
                 clear_on_reset: bool = False):
        super().__init__(name, help)
        self.clear_on_reset = clear_on_reset

    def set(self, value: float, **labels) -> None:
        self._children[self._child(labels)] = float(value)

    def set_min(self, value: float, **labels) -> None:
        """Running minimum; 0.0 doubles as "unset" (every observed fill
        is >= 1, so the sentinel never collides with a real minimum)."""
        key = self._child(labels)
        cur = self._children[key]
        self._children[key] = float(value) if cur == 0.0 \
            else min(cur, float(value))

    def set_max(self, value: float, **labels) -> None:
        key = self._child(labels)
        self._children[key] = max(self._children.get(key, 0.0), value)

    def clear(self) -> None:
        for key in self._children:
            self._children[key] = 0.0


class Histogram:
    """Fixed-bucket histogram with a cumulative view AND a rolling window.

    One `observe()` feeds both. The cumulative counts/sum are what the
    Prometheus renderer exports and what `reset()` clears; the rolling
    window (bounded deque of bucket indices, O(1) per observation) is the
    overload-policy view — `quantile(q)` reads it by default, so the shed
    check and `metrics()` report the IDENTICAL number, and a metrics reset
    does not blind load shedding.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS_MS,
                 window: int = 256):
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be strictly increasing, got "
                             f"{buckets}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.name = name
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)  # upper bounds
        self.counts = [0] * (len(self.buckets) + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self._window: deque[int] = deque(maxlen=window)
        self._win_counts = [0] * (len(self.buckets) + 1)

    def observe(self, value: float) -> None:
        i = bisect_left(self.buckets, value)
        self.counts[i] += 1
        self.sum += value
        self.count += 1
        if len(self._window) == self._window.maxlen:
            self._win_counts[self._window[0]] -= 1
        self._window.append(i)
        self._win_counts[i] += 1

    @property
    def window_count(self) -> int:
        return len(self._window)

    def quantile(self, q: float, *, window: bool = True) -> float:
        """Exact-from-buckets quantile: find the bucket holding the q-rank
        observation and interpolate linearly inside it. Deterministic —
        every caller reading the same counts gets the same value. Returns
        0.0 when empty. ``window=False`` reads the cumulative counts."""
        counts = self._win_counts if window else self.counts
        total = len(self._window) if window else self.count
        if total == 0:
            return 0.0
        rank = q * total
        seen = 0.0
        lo = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                lo = self.buckets[i] if i < len(self.buckets) else lo
                continue
            hi = self.buckets[i] if i < len(self.buckets) else lo
            if seen + c >= rank:
                frac = min(max((rank - seen) / c, 0.0), 1.0)
                return lo + (hi - lo) * frac
            seen += c
            lo = hi
        return lo

    def clear(self) -> None:
        """Clear the cumulative view ONLY; the rolling window survives
        (it is health state, not a counter — see module docstring)."""
        self.counts = [0] * len(self.counts)
        self.sum = 0.0
        self.count = 0

    def samples(self) -> Iterator[Sample]:
        cum = 0
        for i, ub in enumerate(self.buckets):
            cum += self.counts[i]
            yield Sample(f"{self.name}_bucket", (("le", repr(ub)),), cum)
        cum += self.counts[-1]
        yield Sample(f"{self.name}_bucket", (("le", "+Inf"),), cum)
        yield Sample(f"{self.name}_sum", (), self.sum)
        yield Sample(f"{self.name}_count", (), self.count)


class MetricsRegistry:
    """Named metric families, one namespace, one reset contract."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _register(self, name: str, factory):
        if name in self._metrics:
            existing = self._metrics[name]
            if type(existing) is not factory.cls:
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{existing.kind}")
            return existing
        m = factory()
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        fn = lambda: Counter(name, help)
        fn.cls = Counter
        return self._register(name, fn)

    def gauge(self, name: str, help: str = "", *,
              clear_on_reset: bool = False) -> Gauge:
        fn = lambda: Gauge(name, help, clear_on_reset=clear_on_reset)
        fn.cls = Gauge
        return self._register(name, fn)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS_MS,
                  window: int = 256) -> Histogram:
        fn = lambda: Histogram(name, help, buckets, window)
        fn.cls = Histogram
        return self._register(name, fn)

    def get(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def collect(self) -> Iterator[tuple[object, list[Sample]]]:
        """(family, samples) pairs in name order — the exporter's feed."""
        for name in sorted(self._metrics):
            m = self._metrics[name]
            yield m, list(m.samples())

    def reset(self) -> None:
        """The documented reset contract: counters and cumulative histogram
        counts go to zero; gauges (unless ``clear_on_reset``) and histogram
        rolling windows survive — they are live health state, and zeroing
        them would blind the overload policy mid-flight."""
        for m in self._metrics.values():
            if isinstance(m, Counter):
                m.clear()
            elif isinstance(m, Histogram):
                m.clear()
            elif isinstance(m, Gauge) and m.clear_on_reset:
                m.clear()
