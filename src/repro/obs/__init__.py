"""`repro.obs` — the serving stack's flight recorder.

Single source of truth for everything the service observes about
itself. Four pieces, one facade:

  * `MetricsRegistry` (`.registry`) — labeled counters/gauges +
    fixed-bucket latency histograms with exact-from-buckets quantiles.
  * `SpanRecorder` (`.spans`) — per-request flight records across
    admission -> queue -> tick -> dispatch -> cascade -> response.
  * `EnergyLedger` (`.energy`) — per-tenant + fleet SS V-D nJ totals
    with the E_backend / E_frontend split, bit-exact with the
    per-response attributions.
  * exporters (`.export`) — JSONL event log, Prometheus text renderer,
    and their validators (the CI telemetry-smoke contract).

`FlightRecorder` wires them together and is what the serving tier
holds: `ACAMService` keeps exactly one, the scheduler borrows it for
tick/dispatch stamps, the control plane borrows it for lifecycle
events, and `metrics()`/`health()` are thin reads over it. Ad-hoc
counters and private `np.percentile` reservoirs in the service are
gone — every consumer of "the p99" reads the one histogram here.

Reset contract (`FlightRecorder.reset`, behind
`ACAMService.reset_metrics()`):

  cleared    counters, cumulative histogram counts, the energy ledger,
             per-run fill aggregates (min/max batch fill)
  surviving  gauges (queue depth, shed mode, straggler strikes), the
             histogram's ROLLING window (the shed_p99_ms overload
             signal — a metrics reset must never blind load shedding),
             span conservation totals (started == finished + in-flight
             is a structural invariant, not a per-run statistic), the
             tick-id sequence, and the event log (append-only).
"""
from __future__ import annotations

import contextlib
import os
import time
from collections import deque

from .energy import NJ, EnergyLedger
from .export import (EVENT_SCHEMA, JsonlEventLog, read_events,
                     render_prometheus, validate_event,
                     validate_prometheus_text, write_prometheus)
from .registry import (DEFAULT_LATENCY_BUCKETS_MS, Counter, Gauge,
                       Histogram, MetricsRegistry)
from .spans import DISPOSITIONS, Span, SpanRecorder

__all__ = [
    "FlightRecorder", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "SpanRecorder", "Span", "EnergyLedger", "JsonlEventLog", "read_events",
    "render_prometheus", "validate_prometheus_text", "write_prometheus",
    "validate_event", "EVENT_SCHEMA", "DISPOSITIONS",
    "DEFAULT_LATENCY_BUCKETS_MS", "NJ",
]


class FlightRecorder:
    """The serving tier's one telemetry object.

    Built from an `ObsSpec` (`repro.serve.spec`); a default-constructed
    recorder (no spec) records in memory with no event log — telemetry
    is always *on*, the spec only controls buckets, sampling, the
    JSONL sink, and profiler annotations.
    """

    def __init__(self, obs=None):
        buckets = DEFAULT_LATENCY_BUCKETS_MS
        window = 256
        sample = 1.0
        telemetry_dir = None
        self.profile_annotations = False
        if obs is not None:
            # () in the spec means "the default bucket ladder"
            buckets = tuple(obs.latency_buckets_ms) or buckets
            window = obs.latency_window
            sample = obs.span_sample
            telemetry_dir = obs.telemetry_dir
            self.profile_annotations = obs.profile_annotations
        self.registry = MetricsRegistry()
        self.spans = SpanRecorder(sample_rate=sample)
        self.ledger = EnergyLedger()
        self.events = JsonlEventLog(
            os.path.join(telemetry_dir, "events.jsonl")
            if telemetry_dir else None)
        self.tick_seq = 0

        r = self.registry
        self.latency = r.histogram(
            "acam_request_latency_ms",
            "submit -> response wall time of error-free responses (ms)",
            buckets=buckets, window=window)
        self.submitted = r.counter(
            "acam_requests_submitted_total", "requests admitted to the queue")
        self.rejected = r.counter(
            "acam_requests_rejected_total", "requests refused at admission")
        self.responses = r.counter(
            "acam_responses_total",
            "responses by terminal disposition (ok/escalated/shed/"
            "expired/error)")
        self.energy = r.counter(
            "acam_energy_joules_total",
            "SS V-D attributed energy by stage (backend=ACAM array, "
            "frontend=CNN head) and tenant")
        self.esc_dispatches = r.counter(
            "acam_escalation_dispatches_total",
            "coalesced dense-head dispatches (one per tick with "
            "escalations)")
        self.cache_events = r.counter(
            "acam_semantic_cache_events_total",
            "semantic-cache router outcomes "
            "(event=hit/miss/insert/evict)")
        self.cache_hit_latency = r.histogram(
            "acam_cache_hit_latency_ms",
            "submit -> response wall time of semantic-cache hits (ms)",
            buckets=buckets, window=window)
        self.decode_latency = r.histogram(
            "acam_lm_decode_latency_ms",
            "submit -> response wall time of cache misses escalated to "
            "LM decode (ms)",
            buckets=buckets, window=window)
        self.load_shed_ticks = r.counter(
            "acam_load_shed_ticks_total", "ticks served in load-shed mode")
        self.busy_seconds = r.counter(
            "acam_service_busy_seconds_total",
            "wall time spent inside step()")
        self.ticks = r.counter(
            "acam_scheduler_ticks_total", "scheduler ticks that dispatched")
        self.dispatches = r.counter(
            "acam_scheduler_dispatches_total",
            "fused classify dispatches (== ticks: ONE per tick)")
        self.served = r.counter(
            "acam_scheduler_served_total", "requests served by a dispatch")
        self.filled_slots = r.counter(
            "acam_scheduler_filled_slots_total",
            "slots occupied across all dispatches (occupancy numerator)")
        self.tick_seconds = r.counter(
            "acam_scheduler_tick_seconds_total",
            "summed dispatch wall time")
        self.slow_ticks = r.counter(
            "acam_scheduler_slow_ticks_total",
            "ticks flagged by the straggler monitor")
        self.expired = r.counter(
            "acam_scheduler_expired_total",
            "requests expired past their queue deadline")
        self.queue_depth = r.gauge(
            "acam_queue_depth", "requests waiting in the scheduler queue")
        self.shed_mode = r.gauge(
            "acam_shed_mode", "1 when the next tick runs in load-shed mode")
        self.slots_gauge = r.gauge(
            "acam_scheduler_slots", "micro-batch slot count")
        self.fill_min = r.gauge(
            "acam_batch_fill_min", "smallest batch fill this run",
            clear_on_reset=True)
        self.fill_max = r.gauge(
            "acam_batch_fill_max", "largest batch fill this run",
            clear_on_reset=True)
        self.straggler_strikes = r.gauge(
            "acam_straggler_strikes",
            "consecutive slow-tick strikes per host "
            "(repro.ft.elastic.StragglerMonitor)")
        self.straggler_deadline = r.gauge(
            "acam_straggler_deadline_seconds",
            "current straggler deadline (rolling-median based)")
        self._shed_state = False
        self.last_dispatch_ms = 0.0  # most recent fused-dispatch wall time
        #: rolling per-tick batch fills (the fleet policy's saturation
        #: signal — like the latency window, it describes the service NOW
        #: and survives `reset()`)
        self.fill_window: deque = deque(maxlen=64)

    # -- admission ---------------------------------------------------------

    def record_submit(self, request_id: int, tenant_id: str,
                      t_admit: float) -> None:
        self.submitted.inc()
        self.spans.start(request_id, tenant_id, t_admit)

    def record_rejected(self) -> None:
        self.rejected.inc()

    # -- scheduler hooks ---------------------------------------------------

    def record_tick_dispatch(self, request_ids, fill: int, dt_s: float,
                             slow: bool, t_dequeue: float) -> int:
        """One fused dispatch happened: allocate the tick id, stamp every
        batched span with it (batch-level — one clock read for the whole
        tick, not one per request), and feed the scheduler counters."""
        tick_id = self.tick_seq
        self.tick_seq += 1
        dt_ms = dt_s * 1e3
        self.last_dispatch_ms = dt_ms
        for rid in request_ids:
            span = self.spans.active.get(rid)
            if span is not None:
                span.t_dequeue = t_dequeue
                span.tick_id = tick_id
                span.dispatch_ms = dt_ms
        self.ticks.inc()
        self.dispatches.inc()
        self.served.inc(fill)
        self.filled_slots.inc(fill)
        self.tick_seconds.inc(dt_s)
        self.slow_ticks.inc(int(slow))
        self.fill_min.set_min(fill)
        self.fill_max.set_max(fill)
        self.fill_window.append(fill)
        return tick_id

    def record_expired(self, n: int) -> None:
        self.expired.inc(n)

    def record_straggler(self, verdict: dict, flagged: dict) -> None:
        """StragglerMonitor -> registry: per-host strike gauges + the
        current deadline (`ft.elastic` feeds this after every heartbeat)."""
        self.straggler_deadline.set(verdict.get("deadline_s", 0.0))
        for host, strikes in flagged.items():
            self.straggler_strikes.set(strikes, host=host)

    def profile_span(self, name: str):
        """Context manager annotating the fused dispatch in `jax.profiler`
        traces (no-op unless `ObsSpec.profile_annotations`)."""
        if not self.profile_annotations:
            return contextlib.nullcontext()
        import jax

        return jax.profiler.TraceAnnotation(name)

    # -- cascade / response ------------------------------------------------

    def record_shed_tick(self) -> None:
        self.load_shed_ticks.inc()

    # -- semantic-cache router ---------------------------------------------

    def record_cache_event(self, event: str, n: int = 1) -> None:
        """One semantic-cache router outcome: "hit" (served from the
        response store), "miss" (escalated to decode), "insert" (template
        + response admitted), "evict" (template row invalidated by LRU
        pressure). Conservation: hit + miss == error-free routed
        responses; insert - evict == live templates."""
        self.cache_events.inc(n, event=event)

    def record_cache_latency(self, hit: bool, latency_s: float) -> None:
        """Feed the hit-vs-decode histogram pair: the two distributions
        whose gap IS the semantic cache's latency win."""
        h = self.cache_hit_latency if hit else self.decode_latency
        h.observe(latency_s * 1e3)

    def record_escalation_dispatch(self) -> None:
        self.esc_dispatches.inc()

    def finish_request(self, resp, backend_j: float,
                       frontend_j: float) -> None:
        """Close one request: disposition counter, latency observation
        (error-free responses only — expired/evicted latencies measure
        the queue deadline, not service), energy ledger + stage counters,
        and the span."""
        if resp.error is not None:
            disposition = "expired" if "deadline" in resp.error else "error"
        elif resp.shed:
            disposition = "shed"
        elif resp.escalated:
            disposition = "escalated"
        else:
            disposition = "ok"
        self.responses.inc(disposition=disposition)
        if resp.error is None:
            self.latency.observe(resp.latency_s * 1e3)
        self.ledger.add(resp.tenant_id, backend_j, frontend_j,
                        escalated=resp.escalated, shed=resp.shed)
        if backend_j:
            self.energy.inc(backend_j, stage="backend",
                            tenant=resp.tenant_id)
        if frontend_j:
            self.energy.inc(frontend_j, stage="frontend",
                            tenant=resp.tenant_id)
        self.spans.finish(resp.request_id, disposition,
                          escalated=resp.escalated)

    def add_busy(self, seconds: float) -> None:
        self.busy_seconds.inc(seconds)

    # -- health signals ----------------------------------------------------

    def set_queue_depth(self, depth: int) -> None:
        self.queue_depth.set(depth)

    def set_shed_mode(self, shedding: bool, *, queue_depth: int) -> None:
        """Track the overload flag; a FLIP emits a shed_on/shed_off event
        (the bench's shed-interval reconstruction reads these)."""
        self.shed_mode.set(int(shedding))
        if shedding != self._shed_state:
            self._shed_state = shedding
            self.emit("shed_on" if shedding else "shed_off",
                      queue_depth=queue_depth,
                      p99_ms=round(self.latency_quantile_ms(0.99), 4))

    def rolling_batch_fill(self) -> float:
        """Mean batch fill over the rolling tick window — the fleet
        policy's "sustained saturation" input (a single full tick never
        reads as saturation; a full WINDOW does)."""
        if not self.fill_window:
            return 0.0
        return sum(self.fill_window) / len(self.fill_window)

    def latency_quantile_ms(self, q: float) -> float:
        """THE latency quantile — `metrics()`, `health()`, and the
        shed_p99_ms overload check all call this, so they can never
        disagree (reads the rolling window; survives `reset`)."""
        return self.latency.quantile(q)

    # -- events / export ---------------------------------------------------

    def emit(self, kind: str, **payload) -> None:
        self.events.emit(kind, **payload)

    def render_prometheus(self) -> str:
        return render_prometheus(self.registry)

    def reset(self) -> None:
        """See the module docstring for the exact clear/survive split."""
        self.registry.reset()
        self.ledger.clear()

    def close(self) -> None:
        self.events.close()
