"""Per-tick energy ledger: the paper's SS V-D attribution, aggregated.

Each `ClassifyResponse` already carries its own energy attribution
(E_backend = ACAM array energy for the rows it searched; E_frontend
added when the cascade escalated to the CNN head; shed responses are
costed ACAM-only — that asymmetry IS the load-shed valve, since
E_backend = 1.45 nJ << E_frontend = 96.23 nJ per the paper). The ledger
folds those per-response joules into per-tenant and fleet-wide totals
with the backend/frontend split preserved, so "what is this fleet
spending per request" is one read instead of a sum over response
objects you had to keep around.

Bit-exactness contract: `add()` accumulates with plain float `+=` in
response order, which is the same left-fold `sum()` performs over the
response list — so `ledger.fleet_j()` equals
`sum(r.energy_j for r in responses)` EXACTLY, not approximately. The
telemetry test asserts `==`, not `pytest.approx`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

NJ = 1e9  # joules -> nanojoules


@dataclass
class _Cell:
    backend_j: float = 0.0
    frontend_j: float = 0.0
    #: accumulated as `+= (backend + frontend)` per response — the same
    #: float op chain as summing `r.energy_j` over the response list, so
    #: it stays bit-exact with that sum (NOT backend_j + frontend_j, which
    #: rounds differently)
    total_j: float = 0.0
    requests: int = 0
    escalated: int = 0
    shed: int = 0


@dataclass
class EnergyLedger:
    """Fleet + per-tenant accumulation of SS V-D energy attributions."""

    _fleet: _Cell = field(default_factory=_Cell)
    _tenants: dict[str, _Cell] = field(default_factory=dict)

    def add(self, tenant_id: str, backend_j: float, frontend_j: float,
            *, escalated: bool = False, shed: bool = False) -> None:
        """Fold one response's attribution in, fleet first then tenant,
        each with a single `+=` per component (see module docstring).
        ``backend_j + frontend_j`` here is the identical float expression
        the service used to build `ClassifyResponse.energy_j`, so the
        running `total_j` reproduces `sum(r.energy_j)` exactly."""
        cell = self._tenants.get(tenant_id)
        if cell is None:
            cell = self._tenants[tenant_id] = _Cell()
        for c in (self._fleet, cell):
            c.backend_j += backend_j
            c.frontend_j += frontend_j
            c.total_j += backend_j + frontend_j
            c.requests += 1
            c.escalated += int(escalated)
            c.shed += int(shed)

    # -- reads ----------------------------------------------------------

    def fleet_j(self) -> float:
        return self._fleet.total_j

    def backend_j(self) -> float:
        return self._fleet.backend_j

    def frontend_j(self) -> float:
        return self._fleet.frontend_j

    def tenant_j(self, tenant_id: str) -> float:
        cell = self._tenants.get(tenant_id)
        return cell.total_j if cell else 0.0

    def fleet(self) -> dict:
        """The operator's one-glance summary (nJ units, like the paper)."""
        c = self._fleet
        n = max(c.requests, 1)
        return {
            "requests": c.requests,
            "escalated": c.escalated,
            "shed": c.shed,
            "backend_nj": c.backend_j * NJ,
            "frontend_nj": c.frontend_j * NJ,
            "total_nj": c.total_j * NJ,
            "nj_per_request": c.total_j * NJ / n,
            "backend_share": (c.backend_j / c.total_j) if c.total_j else 0.0,
        }

    def per_tenant(self) -> dict[str, dict]:
        out = {}
        for tid in sorted(self._tenants):
            c = self._tenants[tid]
            n = max(c.requests, 1)
            out[tid] = {
                "requests": c.requests,
                "escalated": c.escalated,
                "shed": c.shed,
                "backend_nj": c.backend_j * NJ,
                "frontend_nj": c.frontend_j * NJ,
                "total_nj": c.total_j * NJ,
                "nj_per_request": c.total_j * NJ / n,
            }
        return out

    def clear(self) -> None:
        """Ledger totals are counters, so `reset_metrics()` clears them."""
        self._fleet = _Cell()
        self._tenants.clear()
