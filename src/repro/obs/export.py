"""Exporters: JSONL event log, Prometheus text renderer, validators.

The event log is the service's black box: one JSON object per line,
appended and flushed per line so a SIGKILL mid-stream loses at most the
line being written — the chaos harness reads recovery timing and
in-flight loss out of this file from a *different process* after the
kill, which is the whole point. Every line carries `kind`, `ts`
(unix seconds), and `seq` (monotone per-log); per-kind payload fields
are specified in `EVENT_SCHEMA` and enforced by `validate_event` (the
CI `telemetry-smoke` job runs this module as a CLI over the emitted
file).

The Prometheus renderer is the pull-side twin: `render_prometheus`
turns a `MetricsRegistry` into text exposition format, and
`validate_prometheus_text` asserts the two operator-facing invariants —
no duplicate (name, labels) series, and bounded label cardinality.
"""
from __future__ import annotations

import io
import json
import os
import time

from .registry import MAX_LABEL_SETS, Counter, Gauge, Histogram, \
    MetricsRegistry

# --------------------------------------------------------------------------
# Event schema
# --------------------------------------------------------------------------

#: required payload fields per event kind (every event also carries the
#: envelope fields `kind`, `ts`, `seq`). `validate_event` rejects unknown
#: kinds and missing fields; extra fields are allowed (forward compat).
EVENT_SCHEMA: dict[str, tuple[str, ...]] = {
    # one line per serving tick that did work (dispatched and/or resolved
    # expiries/sheds) — the stream bench rows are re-derived from
    "tick": ("tick_id", "fill", "served", "escalated", "shed", "expired",
             "dt_ms", "queue_depth", "shed_mode", "energy_j"),
    # lifecycle events, emitted by the control plane / service
    "reconfigure": ("actions", "drained", "duration_ms"),
    "reshard": ("bank_shards_from", "bank_shards_to"),
    "device_loss": ("lost", "survivors"),
    "device_heal": ("restored",),
    "snapshot": ("step", "path"),
    "restore": ("step", "resharded", "duration_ms"),
    "shed_on": ("queue_depth", "p99_ms"),
    "shed_off": ("queue_depth", "p99_ms"),
    # fleet lifecycle (repro.fleet): the autopilot's black box. Every
    # policy_decision carries the FULL frozen registry view it decided
    # from plus the action taken, so the whole autopilot run is
    # reconstructible from the log alone (replay `policy.decide` over
    # the logged views and compare actions — tests/test_fleet.py does).
    "policy_decision": ("tick", "action", "reason", "applied", "view"),
    "manifest_apply": ("added", "evicted", "updated", "retuned",
                       "duration_ms"),
    "buffer_flip": ("bank_shards_from", "bank_shards_to", "tenants_moved",
                    "flip_ms", "build_ms"),
}

_ENVELOPE = ("kind", "ts", "seq")


def validate_event(event: dict) -> None:
    """Raise ValueError unless `event` is a well-formed log line."""
    for f in _ENVELOPE:
        if f not in event:
            raise ValueError(f"event missing envelope field {f!r}: {event}")
    kind = event["kind"]
    if kind not in EVENT_SCHEMA:
        raise ValueError(f"unknown event kind {kind!r} "
                         f"(known: {sorted(EVENT_SCHEMA)})")
    missing = [f for f in EVENT_SCHEMA[kind] if f not in event]
    if missing:
        raise ValueError(f"{kind!r} event missing fields {missing}: {event}")


class JsonlEventLog:
    """Append-only JSONL sink, one flush per line (crash-durable up to
    the line in flight). `None` path -> no-op sink, zero overhead."""

    def __init__(self, path: str | os.PathLike | None):
        self.path = str(path) if path is not None else None
        self.seq = 0
        self._fh: io.TextIOWrapper | None = None
        if self.path is not None:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self._fh = open(self.path, "a", encoding="utf-8")

    @property
    def enabled(self) -> bool:
        return self._fh is not None

    def emit(self, kind: str, **payload) -> None:
        if self._fh is None:
            return
        event = {"kind": kind, "ts": round(time.time(), 6),
                 "seq": self.seq, **payload}
        validate_event(event)  # never write a line the reader would reject
        self.seq += 1
        self._fh.write(json.dumps(event, sort_keys=True) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def read_events(path: str | os.PathLike,
                kind: str | None = None) -> list[dict]:
    """Load (and validate) an event log; optionally filter by kind. A
    truncated final line (SIGKILL mid-write) is tolerated and dropped."""
    events = []
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                event = json.loads(line)
            except json.JSONDecodeError:
                # a torn final line is expected after a crash; anything
                # earlier means corruption and should fail loudly
                rest = fh.read().strip()
                if rest:
                    raise ValueError(
                        f"{path}:{lineno}: unparseable non-final line")
                break
            validate_event(event)
            if kind is None or event["kind"] == kind:
                events.append(event)
    return events


# --------------------------------------------------------------------------
# Prometheus text exposition
# --------------------------------------------------------------------------

def _fmt_labels(labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def render_prometheus(registry: MetricsRegistry) -> str:
    """Render the registry in Prometheus text exposition format."""
    lines = []
    for family, samples in registry.collect():
        if family.help:
            lines.append(f"# HELP {family.name} {family.help}")
        lines.append(f"# TYPE {family.name} {family.kind}")
        for s in samples:
            lines.append(f"{s.name}{_fmt_labels(s.labels)} "
                         f"{_fmt_value(s.value)}")
    return "\n".join(lines) + "\n"


def validate_prometheus_text(text: str,
                             max_label_sets: int = MAX_LABEL_SETS) -> dict:
    """Parse rendered exposition text and assert scraper invariants:
    every sample line parses, no duplicate (name, labels) series, and
    per-family series count stays under `max_label_sets`. Returns
    {"families": n, "series": n} on success, raises ValueError on any
    violation."""
    seen: set[tuple[str, str]] = set()
    per_family: dict[str, int] = {}
    families = 0
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            families += 1
            continue
        if line.startswith("#"):
            continue
        name_labels, _, value = line.rpartition(" ")
        if not name_labels:
            raise ValueError(f"line {lineno}: no value separator: {line!r}")
        try:
            float(value)
        except ValueError:
            raise ValueError(f"line {lineno}: bad value {value!r}") from None
        if "{" in name_labels:
            name, _, labels = name_labels.partition("{")
            if not labels.endswith("}"):
                raise ValueError(f"line {lineno}: unterminated labels")
        else:
            name, labels = name_labels, ""
        if not name or not name.replace("_", "").replace(":", "").isalnum():
            raise ValueError(f"line {lineno}: bad metric name {name!r}")
        key = (name, labels)
        if key in seen:
            raise ValueError(f"line {lineno}: duplicate series {key}")
        seen.add(key)
        base = name.rsplit("_bucket", 1)[0]
        per_family[base] = per_family.get(base, 0) + 1
        if per_family[base] > max_label_sets + 3:  # +sum/count/Inf slack
            raise ValueError(f"family {base!r} exceeds {max_label_sets} "
                             "series — label cardinality unbounded")
    return {"families": families, "series": len(seen)}


def write_prometheus(registry: MetricsRegistry,
                     path: str | os.PathLike) -> str:
    """Render + validate + atomically write a scrape file; returns the
    rendered text."""
    text = render_prometheus(registry)
    validate_prometheus_text(text)
    tmp = f"{path}.tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        fh.write(text)
    os.replace(tmp, path)
    return text


__all__ = [
    "EVENT_SCHEMA", "JsonlEventLog", "read_events", "validate_event",
    "render_prometheus", "validate_prometheus_text", "write_prometheus",
]


def _main(argv: list[str]) -> int:
    """CLI for the CI telemetry-smoke job:

        python -m repro.obs.export events.jsonl [metrics.prom]

    validates every JSONL line against EVENT_SCHEMA and, when given,
    the Prometheus scrape file against the exposition invariants."""
    if not argv:
        print("usage: python -m repro.obs.export <events.jsonl> "
              "[metrics.prom]")
        return 2
    events = read_events(argv[0])
    kinds: dict[str, int] = {}
    for e in events:
        kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
    print(f"{argv[0]}: {len(events)} events OK "
          f"({', '.join(f'{k}={v}' for k, v in sorted(kinds.items()))})")
    if len(argv) > 1:
        with open(argv[1], encoding="utf-8") as fh:
            stats = validate_prometheus_text(fh.read())
        print(f"{argv[1]}: {stats['families']} families, "
              f"{stats['series']} series OK")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(_main(sys.argv[1:]))
