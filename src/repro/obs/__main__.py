"""`python -m repro.obs <events.jsonl> [metrics.prom]` — the CI
telemetry-smoke validator (same CLI as `repro.obs.export`, without
runpy's found-in-sys.modules warning)."""
import sys

from .export import _main

if __name__ == "__main__":
    sys.exit(_main(sys.argv[1:]))
