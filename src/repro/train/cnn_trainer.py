"""Trainer for the paper's CNN pipeline: baseline CE, KD (+curriculum),
iterative pruning, and QAT — composable stages matching paper §II.

This is the *paper-scale* trainer (single device, small models). The LM-scale
distributed trainer lives in `repro.launch.train` / `repro.distributed`.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distill, prune
from repro.data import pipeline
from repro.models import cnn
from repro.optim import optimizers as optim

Array = jax.Array
PyTree = Any


class TrainConfig(NamedTuple):
    epochs: int = 5
    batch_size: int = 128
    lr: float = 1e-3
    weight_decay: float = 1e-4
    # distillation
    distill_alpha: float = 0.5
    distill_temperature: float = 4.0
    curriculum: bool = True
    curriculum_start_frac: float = 0.4
    # pruning
    prune_start_sparsity: float = 0.50
    prune_final_sparsity: float = 0.80
    prune_epochs: int = 3  # pruning ramp epochs (then final fine-tune)
    finetune_epochs: int = 2
    # quantisation
    qat: bool = False
    seed: int = 0


def merge_bn_stats(params, new_params):
    """Recursively copy updated BN running stats (mean/var) from the train
    pass back into the param tree (BN dicts may be nested inside blocks)."""
    if not isinstance(params, dict):
        return params
    out = {}
    for k, v in params.items():
        if isinstance(v, dict) and "mean" in v and "var" in v:
            out[k] = {**v, "mean": new_params[k]["mean"],
                      "var": new_params[k]["var"]}
        elif isinstance(v, dict):
            out[k] = merge_bn_stats(v, new_params[k])
        else:
            out[k] = v
    return out


def _make_step(loss_fn, optimizer, masks=None):
    @jax.jit
    def step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, *batch)
        if masks is not None:
            grads = prune.mask_gradients(grads, masks)
        grads, _ = optim.clip_by_global_norm(grads, 1.0)
        params, opt_state = optimizer.update(grads, opt_state, params)
        if masks is not None:
            params = prune.apply_masks(params, masks)
        # fold updated BN running stats back in (recursive: teacher blocks)
        params = merge_bn_stats(params, aux)
        return params, opt_state, loss

    return step


def _bn_stats(new_params):
    return new_params


def train_teacher(
    images: np.ndarray, labels: np.ndarray, cfg: cnn.TeacherConfig,
    *, epochs: int = 5, batch_size: int = 128, lr: float = 1e-3, seed: int = 0,
) -> PyTree:
    params = cnn.init_teacher(jax.random.PRNGKey(seed), cfg)
    opt = optim.adamw(lr, weight_decay=1e-4)
    opt_state = opt.init(params)

    def loss_fn(p, x, y):
        logits, newp = cnn.teacher_logits(p, x, cfg, train=True)
        return distill.cross_entropy(logits, y), _bn_stats(newp)

    step = _make_step(loss_fn, opt)
    for epoch in range(epochs):
        for batch in pipeline.batches(images, labels, batch_size, seed=seed, epoch=epoch):
            params, opt_state, loss = step(params, opt_state, batch)
    return params


def evaluate(logits_fn, params, images, labels, *, batch_size: int = 512) -> float:
    fn = jax.jit(lambda p, x: jnp.argmax(logits_fn(p, x)[0], axis=-1))
    correct = 0
    for i in range(0, len(labels), batch_size):
        pred = fn(params, images[i : i + batch_size])
        correct += int(jnp.sum(pred == labels[i : i + batch_size]))
    return correct / len(labels)


def metrics(logits_fn, params, images, labels, num_classes: int = 10,
            *, batch_size: int = 512) -> dict[str, float]:
    """Accuracy / macro F1 / precision / recall (Table I columns)."""
    preds = []
    fn = jax.jit(lambda p, x: jnp.argmax(logits_fn(p, x)[0], axis=-1))
    for i in range(0, len(labels), batch_size):
        preds.append(np.asarray(fn(params, images[i : i + batch_size])))
    pred = np.concatenate(preds)
    y = np.asarray(labels)
    acc = float((pred == y).mean())
    precs, recs, f1s = [], [], []
    for c in range(num_classes):
        tp = float(((pred == c) & (y == c)).sum())
        fp = float(((pred == c) & (y != c)).sum())
        fn_ = float(((pred != c) & (y == c)).sum())
        p_ = tp / (tp + fp) if tp + fp else 0.0
        r_ = tp / (tp + fn_) if tp + fn_ else 0.0
        precs.append(p_); recs.append(r_)
        f1s.append(2 * p_ * r_ / (p_ + r_) if p_ + r_ else 0.0)
    return {"accuracy": acc, "f1": float(np.mean(f1s)),
            "precision": float(np.mean(precs)), "recall": float(np.mean(recs))}


def train_student(
    images: np.ndarray, labels: np.ndarray,
    *, student_cfg: cnn.StudentConfig = cnn.StudentConfig(),
    teacher_logits_all: np.ndarray | None = None,
    cfg: TrainConfig = TrainConfig(),
    do_prune: bool = False,
) -> tuple[PyTree, PyTree | None]:
    """Train the student; returns (params, masks|None).

    teacher_logits_all: precomputed teacher logits for the full train set
    (enables KD + curriculum without holding the teacher in memory).
    """
    params = cnn.init_student(jax.random.PRNGKey(cfg.seed), student_cfg)
    opt = optim.adamw(cfg.lr, weight_decay=cfg.weight_decay)
    opt_state = opt.init(params)
    use_kd = teacher_logits_all is not None

    if use_kd:
        def loss_fn(p, x, y, zt):
            logits, newp = cnn.student_logits(p, x, train=True, quantize=cfg.qat)
            loss = distill.distillation_loss(
                logits, zt, y, alpha=cfg.distill_alpha,
                temperature=cfg.distill_temperature)
            return loss, _bn_stats(newp)
    else:
        def loss_fn(p, x, y):
            logits, newp = cnn.student_logits(p, x, train=True, quantize=cfg.qat)
            return distill.cross_entropy(logits, y), _bn_stats(newp)

    # curriculum ordering (Eq. 4) from teacher logits
    order = None
    if use_kd and cfg.curriculum:
        order = np.asarray(distill.curriculum_order(
            jnp.asarray(teacher_logits_all), jnp.asarray(labels)))
    pacing = distill.CurriculumSchedule(cfg.curriculum_start_frac, max(cfg.epochs - 1, 1))

    masks = None

    def run_epochs(n_epochs, params, opt_state, masks, epoch0=0):
        stp = _make_step(loss_fn, opt, masks)
        for e in range(n_epochs):
            epoch = epoch0 + e
            for xb, yb in pipeline.batches(
                images, labels, cfg.batch_size, seed=cfg.seed, epoch=epoch,
            ):
                params, opt_state, _ = stp(params, opt_state, (xb, yb))
        return params, opt_state

    # For KD, teacher logits must stay index-aligned per batch, so the KD loop
    # iterates indices directly (also what curriculum pacing needs).
    if use_kd:
        zt_all = np.asarray(teacher_logits_all)
        n = len(labels)
        idx_order = order if order is not None else np.arange(n)

        def kd_epochs(n_epochs, params, opt_state, masks, epoch0=0):
            stp = _make_step(loss_fn, opt, masks)
            for e in range(n_epochs):
                epoch = epoch0 + e
                limit = pacing.available(epoch, n) if cfg.curriculum else n
                pool = idx_order[:limit]
                rng = np.random.RandomState((cfg.seed * 9973 + epoch) & 0x7FFFFFFF)
                perm = rng.permutation(pool)
                stop = (len(perm) // cfg.batch_size) * cfg.batch_size
                for i in range(0, stop, cfg.batch_size):
                    sel = perm[i : i + cfg.batch_size]
                    params, opt_state, _ = stp(
                        params, opt_state, (images[sel], labels[sel], zt_all[sel]))
            return params, opt_state

        params, opt_state = kd_epochs(cfg.epochs, params, opt_state, None)
        if do_prune:
            for t in range(cfg.prune_epochs):
                s_t = float(prune.polynomial_sparsity(
                    t + 1, cfg.prune_epochs, cfg.prune_start_sparsity,
                    cfg.prune_final_sparsity))
                params, masks = prune.prune_tree(params, s_t)
                params, opt_state = kd_epochs(1, params, opt_state, masks,
                                              epoch0=cfg.epochs + t)
            params, opt_state = kd_epochs(
                cfg.finetune_epochs, params, opt_state, masks,
                epoch0=cfg.epochs + cfg.prune_epochs)
    else:
        params, opt_state = run_epochs(cfg.epochs, params, opt_state, None)
        if do_prune:
            for t in range(cfg.prune_epochs):
                s_t = float(prune.polynomial_sparsity(
                    t + 1, cfg.prune_epochs, cfg.prune_start_sparsity,
                    cfg.prune_final_sparsity))
                params, masks = prune.prune_tree(params, s_t)
                params, opt_state = run_epochs(1, params, opt_state, masks,
                                               epoch0=cfg.epochs + t)
            params, opt_state = run_epochs(
                cfg.finetune_epochs, params, opt_state, masks,
                epoch0=cfg.epochs + cfg.prune_epochs)

    return params, masks
