"""Batched serving engine: continuous-batching decode loop over the zoo.

`repro.serve` hosts TWO engines for the repo's two serving workloads:

  * **LM decode** (this module, `Engine`) — autoregressive generation over
    the language-model zoo. Requests (token prompts) are admitted into a
    fixed-size batch; prefill builds the KV/SSM cache, then a jitted decode
    loop samples tokens until EOS or max_new_tokens. Slot reuse gives
    continuous batching: when a sequence finishes mid-batch, the next
    queued request joins its slot — a single-row prefill left-padded to the
    batch's current length, spliced into the live cache — instead of
    waiting for the whole group to drain. Fill quality is reported
    honestly in `Engine.stats` (`BatchStats.occupancy`, joins, groups).

  * **ACAM classification** (`repro.serve.acam_service.ACAMService`, with
    `registry`/`scheduler`) — the paper's hybrid edge classifier as a
    multi-tenant service. Requests are *stateless* single-shot feature
    maps, so the unit of scheduling is a whole request: the micro-batching
    scheduler coalesces requests across tenants into fixed-slot batches and
    serves each batch with one fused binarize->match->WTA Pallas dispatch
    over the stacked template super-bank, then the confidence cascade
    escalates low-margin requests to the CNN logits head.

The two engines meet in `repro.serve.semantic_cache`: the ACAM tier fronts
this decode engine as a template router (hits answer from a response
store, misses escalate here).

Reproducibility contract: at temperature > 0 every sampled token draws
from ``fold_in(fold_in(base_key, request_rid), token_index)`` — a key that
depends only on the engine seed, the request's admission-order id and the
position of the token within that request. Batch composition (who shares
the batch, join timing, group splits) can therefore never change WHICH
random stream a request consumes. (Logits themselves remain left-pad
-length sensitive — pad tokens attend — so end-to-end token identity
additionally needs identical grouping, which single-`generate()`-call
replays provide.)

Join prefills compile once per distinct current length (the row is padded
to the live batch's length); at smoke scale this is a handful of
executables, and resident groups reuse the fixed-shape decode step.

Use this engine for token generation (`launch/serve.py --workload lm`,
`examples/serve_batched.py`); use the ACAM service for classification
traffic (`--workload acam`), and the semantic-cache router for cached LM
traffic (`--workload lm-cached`).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm

PyTree = Any


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    #: admission-order id, the per-request PRNG stream selector. Assigned
    #: by `Engine.generate` when < 0; callers may pin it to replay a
    #: specific stream (the semantic cache does not — its bit-identity
    #: comes from replaying identical admission orders).
    rid: int = -1


@dataclasses.dataclass
class BatchStats:
    """Honest batch-fill accounting for the decode loop."""

    slots: int = 0  # engine batch size
    groups: int = 0  # batched group prefills (group starts)
    joins: int = 0  # mid-batch slot admissions (prefill-on-join)
    requests: int = 0  # requests served (initial fills + joins)
    decode_steps: int = 0  # batched decode dispatches
    slot_steps: int = 0  # slot-steps that carried a live request

    @property
    def occupancy(self) -> float:
        """Live-slot fraction across decode steps (1.0 = no idle slots)."""
        if self.decode_steps == 0 or self.slots == 0:
            return 0.0
        return self.slot_steps / (self.decode_steps * self.slots)

    def as_dict(self) -> dict:
        return {
            "slots": self.slots,
            "groups": self.groups,
            "joins": self.joins,
            "requests": self.requests,
            "decode_steps": self.decode_steps,
            "slot_steps": self.slot_steps,
            "occupancy": round(self.occupancy, 4),
        }


class Engine:
    def __init__(self, cfg: lm.ArchConfig, params: PyTree, *,
                 batch_size: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        if not cfg.causal:
            raise ValueError("encoder-only architectures do not decode")
        self.cfg, self.params = cfg, params
        self.batch_size, self.max_len = batch_size, max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)  # base key; never split
        self.stats = BatchStats(slots=batch_size)
        self._rid_counter = 0

        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, cfg, t, c))
        self._prefill = jax.jit(
            lambda p, x: lm.prefill(p, cfg, x, max_len=max_len))

        temp = float(temperature)
        if temp > 0.0:

            def _sample(key, logits, rids, steps):
                def one(lg, rid, t):
                    k = jax.random.fold_in(jax.random.fold_in(key, rid), t)
                    return jax.random.categorical(k, lg / temp, axis=-1)

                return jax.vmap(one)(logits, rids, steps)
        else:

            def _sample(key, logits, rids, steps):
                del key, rids, steps
                return jnp.argmax(logits, axis=-1)

        self._sample_fn = jax.jit(_sample)

        def _join(live, new, slot):
            # splice a freshly prefilled single-row cache into batch slot
            # `slot` of the live cache: every array leaf batches at axis 1
            # (the Cache contract), `length` is the shared scalar clock —
            # both caches sit at the same length, so keep the live one
            def ins(a, b):
                if a.ndim == 0:
                    return a
                return jax.lax.dynamic_update_index_in_dim(
                    a, jnp.squeeze(b, axis=1), slot, 1)

            return jax.tree.map(ins, live, new)

        self._join_cache = jax.jit(_join)

    def _sample_slots(self, logits, slots) -> np.ndarray:
        """Sample one token per row: rid/token-index keyed, so the draw for
        request r's t-th token is identical whatever batch it rides in."""
        rids = np.array([s.rid if s is not None else 0 for s in slots],
                        np.int32)
        steps = np.array([len(s.out) if s is not None else 0 for s in slots],
                         np.int32)
        return np.array(self._sample_fn(
            self.key, logits, jnp.asarray(rids), jnp.asarray(steps)))

    @staticmethod
    def _push_token(r: Request, t: int) -> None:
        r.out.append(t)
        if len(r.out) >= r.max_new_tokens or \
                (r.eos_id is not None and t == r.eos_id):
            r.done = True

    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve all requests with continuous batching: a batched group
        prefill seeds up to `batch_size` slots, the decode loop samples
        until EOS/max_new_tokens per slot (ragged finish), and a finished
        slot admits the FIFO head of the queue mid-batch via a
        prefill-on-join (left-padded to the group's current length). The
        queue head only waits when its prompt is longer than the current
        length or the remaining room cannot fit its budget — then the
        group drains and a fresh group prefill restarts at that prompt's
        natural length."""
        for r in requests:
            if r.rid < 0:
                r.rid = self._rid_counter
                self._rid_counter += 1
        queue = deque(requests)
        while queue:
            self._serve_group(queue)
        return requests

    def _can_join(self, r: Request, cur_len: int) -> bool:
        return (len(r.prompt) <= cur_len
                and cur_len + r.max_new_tokens <= self.max_len)

    def _serve_group(self, queue: deque) -> None:
        b = self.batch_size
        group = [queue.popleft() for _ in range(min(b, len(queue)))]
        slots: list[Request | None] = group + [None] * (b - len(group))
        self.stats.groups += 1
        self.stats.requests += len(group)

        plen = max(len(r.prompt) for r in group)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(group):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        cur_len = plen
        tok = self._sample_slots(logits, slots)
        for i, r in enumerate(group):
            self._push_token(r, int(tok[i]))

        while True:
            # retire finished slots, then admit the queue head into any
            # free slot it fits (FIFO: only the head may join — skipping
            # ahead would reorder service nondeterministically)
            for i in range(b):
                if slots[i] is not None and slots[i].done:
                    slots[i] = None
                if slots[i] is None and queue \
                        and self._can_join(queue[0], cur_len):
                    nxt = queue.popleft()
                    slots[i] = nxt
                    self.stats.joins += 1
                    self.stats.requests += 1
                    row = np.zeros((1, cur_len), np.int32)
                    row[0, cur_len - len(nxt.prompt):] = nxt.prompt
                    jlogits, jcache = self._prefill(
                        self.params, jnp.asarray(row))
                    cache = self._join_cache(cache, jcache, i)
                    jtok = self._sample_slots(jlogits, [nxt])
                    tok[i] = jtok[0]
                    self._push_token(nxt, int(jtok[0]))
                    if nxt.done:  # max_new_tokens == 1 / instant EOS
                        slots[i] = None
            live = [i for i in range(b) if slots[i] is not None]
            if not live or cur_len >= self.max_len:
                break
            logits, cache = self._decode(
                self.params, jnp.asarray(tok)[:, None], cache)
            cur_len += 1
            self.stats.decode_steps += 1
            self.stats.slot_steps += len(live)
            tok = self._sample_slots(logits[:, 0], slots)
            for i in live:
                self._push_token(slots[i], int(tok[i]))
        for s in slots:  # out of room (cur_len hit max_len): truncate
            if s is not None:
                s.done = True
