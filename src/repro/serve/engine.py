"""Batched serving engine: continuous-batching decode loop over the zoo.

`repro.serve` hosts TWO engines for the repo's two serving workloads:

  * **LM decode** (this module, `Engine`) — autoregressive generation over
    the language-model zoo. Requests (token prompts) are admitted into a
    fixed-size batch; prefill builds the KV/SSM cache, then a jitted decode
    loop samples tokens until EOS or max_new_tokens. Slot reuse gives
    continuous batching: when a sequence finishes, the next queued request
    takes its slot (prefill-on-join with the ragged-length mask). State is
    *stateful per request* (the growing cache), so the unit of scheduling
    is a decode step.

  * **ACAM classification** (`repro.serve.acam_service.ACAMService`, with
    `registry`/`scheduler`) — the paper's hybrid edge classifier as a
    multi-tenant service. Requests are *stateless* single-shot feature
    maps, so the unit of scheduling is a whole request: the micro-batching
    scheduler coalesces requests across tenants into fixed-slot batches and
    serves each batch with one fused binarize->match->WTA Pallas dispatch
    over the stacked template super-bank, then the confidence cascade
    escalates low-margin requests to the CNN logits head.

Use this engine for token generation (`launch/serve.py --workload lm`,
`examples/serve_batched.py`); use the ACAM service for classification
traffic (`--workload acam`). Both run smoke configs on CPU (the examples)
and production configs under the pod mesh (dry-run proves the lowering; see
launch/serve.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm

PyTree = Any


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: lm.ArchConfig, params: PyTree, *,
                 batch_size: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        if not cfg.causal:
            raise ValueError("encoder-only architectures do not decode")
        self.cfg, self.params = cfg, params
        self.batch_size, self.max_len = batch_size, max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, cfg, t, c))
        self._prefill = jax.jit(
            lambda p, x: lm.prefill(p, cfg, x, max_len=max_len))

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, axis=-1)

    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve all requests with batched prefill + decode (greedy batching:
        groups of `batch_size`, left-padded prompts so the last prompt token
        is aligned at the batch's final position, ragged finish)."""
        for i in range(0, len(requests), self.batch_size):
            self._serve_batch(requests[i : i + self.batch_size])
        return requests

    def _serve_batch(self, batch: list[Request]) -> None:
        b = len(batch)
        plen = max(len(r.prompt) for r in batch)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(batch):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        tok = self._sample(logits)  # (b,)
        for i, r in enumerate(batch):
            r.out.append(int(tok[i]))
        steps = max(r.max_new_tokens for r in batch) - 1
        for _ in range(steps):
            logits, cache = self._decode(self.params, tok[:, None], cache)
            tok = self._sample(logits[:, 0])
            for i, r in enumerate(batch):
                if r.done or len(r.out) >= r.max_new_tokens:
                    r.done = True
                    continue
                t = int(tok[i])
                r.out.append(t)
                if r.eos_id is not None and t == r.eos_id:
                    r.done = True
            if all(r.done or len(r.out) >= r.max_new_tokens for r in batch):
                break
        for r in batch:
            r.done = True
