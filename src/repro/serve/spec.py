"""ServiceSpec: ONE declarative value object for the whole hybrid service.

Before this module, standing up the paper's cascade took five uncoordinated
surfaces — `EngineConfig`, `TemplateBankRegistry(...)`,
`MicroBatchScheduler(...)`, `ACAMService(...)` and launcher flags — plus an
order-sensitive footgun (`install_acam_mesh` had to run *before* service
construction or `bank_shards` silently resolved to 1). `ServiceSpec` folds
all of it into one hashable, JSON-round-trippable NamedTuple tree:

    spec = ServiceSpec(
        registry=RegistrySpec(num_features=64),
        engine=EngineConfig(backend="kernel"),
        mesh=MeshSpec(bank_shards=2),
        scheduler=SchedulerSpec(slots=64),
        cascade=CascadeSpec(tau=8.0, tau_units="count"),
    )
    spec.validate()                         # eager cross-field checks
    svc = HybridService.from_spec(spec)     # repro.serve.control owns
                                            # mesh -> registry -> scheduler
                                            # -> cascade build order
    svc.reconfigure(spec._replace(...))     # minimal live transition

Design rules:

  * **hashable** — every leaf is a primitive or a NamedTuple of primitives
    (EngineConfig / ACAMConfig included), so a spec can key caches and ride
    as a static jit argument exactly like `EngineConfig` does;
  * **JSON round-trippable** — ``ServiceSpec.from_json(spec.to_json()) ==
    spec`` exactly (tuples, None, nested configs), so launch flags, files
    (`--spec service.json`) and the control plane share one format;
  * **eagerly validated** — `validate()` raises on cross-field conflicts
    the old constructor pile only hit at serve time (or never): the device
    backend refusing bank shards under "global" `sigma_program` noise,
    registry capacity not divisible into the requested shards, a fraction
    tau above the matchline cap.

Tau carries **explicit units** (`CascadeSpec.tau_units`): "count" =
match-count margins (0..N, the digital feature-count backends), "fraction"
= matchline-fraction margins (0..1 — the device backend's sense outputs,
and the similarity method's Eq. 11 scores). The service converts between
the spec's units and the backend's native units itself (`tau_scale`), so
the same spec value serves every backend without callers rescaling.
"""
from __future__ import annotations

import json
from typing import NamedTuple

from repro.core.acam import ACAMConfig
from repro.match.config import EngineConfig


class MeshSpec(NamedTuple):
    """How the service's mesh is laid out (and whether the control plane
    installs it — `HybridService.from_spec` builds a
    (data = devices/bank_shards, model = bank_shards) mesh when ``install``
    is set, which is what kills the old construct-after-install footgun)."""

    bank_shards: int = 1  # super-bank class-row shards (model-axis size)
    data_axis: str = "data"
    model_axis: str = "model"
    install: bool = True  # False: run against whatever mesh is installed


class RegistrySpec(NamedTuple):
    """`TemplateBankRegistry` sizing + capacity policy."""

    num_features: int = 64
    k_max: int = 2
    class_bucket: int = 16
    initial_classes: int = 128
    initial_tenants: int = 8


class SchedulerSpec(NamedTuple):
    """`MicroBatchScheduler` knobs (the micro-batch tick size)."""

    slots: int = 64


class CascadeSpec(NamedTuple):
    """Confidence cascade + paper §V-D energy attribution + the overload
    policy. The paper's asymmetry — E_backend (ACAM) is orders of magnitude
    below E_frontend (CNN) — is what makes graceful degradation cheap: when
    the service is overloaded it keeps answering every request from the
    ACAM stage alone (load-shed mode skips the CNN escalation), instead of
    queueing into a latency collapse.

    ``deadline_ms``   per-request deadline: queued requests older than this
                      at tick time are expired with an error response
                      instead of being served uselessly late (None: off).
    ``shed_queue``    queue depth at/past which the service enters load-shed
                      mode — ticks answer from the ACAM stage alone, no
                      escalation dispatch (None: never shed on depth).
    ``shed_p99_ms``   rolling p99 latency budget; exceeding it also enters
                      load-shed mode until the recent window recovers
                      (None: never shed on latency).
    ``backend``       what the expensive escalation stage *is*: "cnn" (the
                      paper's softmax head — `frontend_macs` et al. model
                      its §V-D cost) or "lm" (a `serve.Engine` decode
                      backend behind `repro.serve.semantic_cache`; misses
                      are charged the per-token decode cost model from
                      `repro.core.energy.lm_decode_energy` instead of the
                      CNN MAC count). Load-shed mode is a "cnn"-only
                      policy: a shed LM request cannot be answered from the
                      ACAM stage alone (there is no cached response for
                      it), so validate() rejects shed knobs under "lm"."""

    tau: float = 8.0  # accept threshold, in tau_units
    tau_units: str = "count"  # "count" (0..N) | "fraction" (0..1)
    max_queue: int = 4096  # admission bound
    frontend_macs: int = 23_785_120
    frontend_sparsity: float = 0.80
    softmax_head_ops: int = 7_850
    paper_faithful: bool = True
    deadline_ms: float | None = None  # per-request queue deadline
    shed_queue: int | None = None  # load-shed on queue depth
    shed_p99_ms: float | None = None  # load-shed on rolling p99
    backend: str = "cnn"  # "cnn" (softmax head) | "lm" (decode engine)


class RouterSpec(NamedTuple):
    """Semantic-cache router policy (`repro.serve.semantic_cache`), active
    when ``cascade.backend == "lm"``. The router fronts the LM decode
    engine with a per-tenant ACAM template bank: a confident match serves
    the cached response; a miss escalates to decode and (policy-gated)
    admits its embedding + response back into the bank.

    ``enabled``            False = escalate-everything shadow mode: every
                           prompt decodes, the match stage still runs (so
                           its telemetry is comparable) but no hit is ever
                           served and no template admitted — the bit-
                           identity baseline against `serve.Engine` alone.
    ``max_templates``      cached-template rows per tenant bank (k = 1).
                           Admission past this evicts the tenant's LRU
                           template (LRU order = the response store's).
    ``response_capacity``  global bound on stored responses; evicting a
                           response invalidates its template row (invariant:
                           a valid template always has a stored response).
    ``admit_on_miss``      False = read-only bank (no template churn).
    ``hit_score``          absolute winner-score floor for serving a hit,
                           as a fraction of a perfect match (0..1], or None
                           to gate on the margin alone. The Eq. 12 margin
                           is *relative*: a one-template bank has no
                           runner-up, so its margin clamps to the window
                           cap and would always read confident — the
                           absolute floor is what keeps a half-matching
                           prompt escalating to decode.
    ``featurizer``         how prompts embed into the matcher's N-feature
                           space: "hashing" (seeded token n-gram feature
                           hashing, dependency-free) or "embedding" (mean-
                           pooled model embedding rows through a seeded
                           random projection — the backbone→ACAM-head path).
    ``featurizer_seed``    seed for the featurizer's hash mix / projection.
    """

    enabled: bool = True
    max_templates: int = 32
    response_capacity: int = 1024
    admit_on_miss: bool = True
    hit_score: float | None = 0.9
    featurizer: str = "hashing"
    featurizer_seed: int = 0


class ObsSpec(NamedTuple):
    """Telemetry knobs for the service's flight recorder (`repro.obs`).

    Telemetry is always on — the recorder is how `metrics()`/`health()`
    and the overload policy see anything at all — so this spec only
    shapes it: histogram resolution, the rolling-window length behind
    the shed_p99_ms signal, span sampling, and the optional sinks.

    ``latency_buckets_ms``  upper bounds (ms) of the request-latency
                            histogram; quantiles are exact from these
                            buckets, so resolution == bucket density.
    ``latency_window``      rolling-window length (observations) behind
                            `latency_p50/99_ms` and the shed_p99_ms
                            overload check; survives `reset_metrics()`.
    ``telemetry_dir``       when set, the service appends a JSONL event
                            log (`events.jsonl`: one line per serving
                            tick + every lifecycle event) under this
                            directory. None: no event log.
    ``span_sample``         fraction of requests carrying a full span
                            (deterministic in the request id); span
                            *conservation counters* always run.
    ``profile_annotations`` wrap the fused dispatch in a
                            `jax.profiler.TraceAnnotation` so device
                            traces show serving-tick boundaries."""

    latency_buckets_ms: tuple = ()  # () -> repro.obs default buckets
    latency_window: int = 256
    telemetry_dir: str | None = None
    span_sample: float = 1.0
    profile_annotations: bool = False


TAU_UNITS = ("count", "fraction")
CASCADE_BACKENDS = ("cnn", "lm")
FEATURIZERS = ("hashing", "embedding")


class ServiceSpec(NamedTuple):
    """The one front door: everything needed to build (and live-retarget)
    a `HybridService`, as a single hashable value."""

    registry: RegistrySpec = RegistrySpec()
    engine: EngineConfig = EngineConfig()
    mesh: MeshSpec = MeshSpec()
    scheduler: SchedulerSpec = SchedulerSpec()
    cascade: CascadeSpec = CascadeSpec()
    obs: ObsSpec = ObsSpec()
    router: RouterSpec = RouterSpec()

    # -- validation ---------------------------------------------------------

    def validate(self) -> "ServiceSpec":
        """Eager cross-field validation; returns self so call sites chain."""
        from repro.match import backend_names
        from repro.match.config import validate as validate_engine

        validate_engine(self.engine, backend_names())
        reg, mesh, sched, casc = (self.registry, self.mesh, self.scheduler,
                                  self.cascade)
        if reg.num_features < 1:
            raise ValueError(f"num_features must be >= 1, got "
                             f"{reg.num_features}")
        if reg.k_max < 1 or reg.class_bucket < 1 or reg.initial_tenants < 1:
            raise ValueError("k_max, class_bucket and initial_tenants must "
                             f"be >= 1, got {reg}")
        if mesh.bank_shards < 1:
            raise ValueError(f"bank_shards must be >= 1, got "
                             f"{mesh.bank_shards}")
        align = mesh.bank_shards * reg.class_bucket
        if reg.initial_classes < 1 or reg.initial_classes % align:
            raise ValueError(
                f"registry capacity ({reg.initial_classes} classes) must cut "
                f"into {mesh.bank_shards} shards of whole "
                f"{reg.class_bucket}-row buckets (a multiple of {align})")
        if mesh.data_axis == mesh.model_axis:
            raise ValueError(f"mesh axes must differ, got "
                             f"{mesh.data_axis!r} twice")
        if sched.slots < 1:
            raise ValueError(f"slots must be >= 1, got {sched.slots}")
        if casc.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {casc.max_queue}")
        if casc.deadline_ms is not None and casc.deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0 (or None), got "
                             f"{casc.deadline_ms}")
        if casc.shed_queue is not None and not (
                1 <= casc.shed_queue <= casc.max_queue):
            raise ValueError(
                f"shed_queue must sit inside the admission bound "
                f"[1, {casc.max_queue}], got {casc.shed_queue} (a shed "
                "threshold past max_queue can never trigger)")
        if casc.shed_p99_ms is not None and casc.shed_p99_ms <= 0:
            raise ValueError(f"shed_p99_ms must be > 0 (or None), got "
                             f"{casc.shed_p99_ms}")
        if casc.backend not in CASCADE_BACKENDS:
            raise ValueError(f"unknown cascade backend {casc.backend!r}; "
                             f"use {CASCADE_BACKENDS}")
        if casc.backend == "lm" and (casc.shed_queue is not None
                                     or casc.shed_p99_ms is not None):
            raise ValueError(
                'cascade.backend="lm" cannot load-shed: a shed request has '
                "no cached response to fall back on (shed_queue and "
                "shed_p99_ms must be None; bound load with max_queue / "
                "deadline_ms instead)")
        rtr = self.router
        if rtr.max_templates < 1:
            raise ValueError(f"router.max_templates must be >= 1, got "
                             f"{rtr.max_templates}")
        if rtr.response_capacity < rtr.max_templates:
            raise ValueError(
                f"router.response_capacity ({rtr.response_capacity}) below "
                f"max_templates ({rtr.max_templates}): a single tenant's "
                "bank could hold templates whose responses were evicted")
        if rtr.hit_score is not None and not 0.0 < rtr.hit_score <= 1.0:
            raise ValueError(f"router.hit_score must be in (0, 1] or None, "
                             f"got {rtr.hit_score}")
        if rtr.featurizer not in FEATURIZERS:
            raise ValueError(f"unknown router featurizer "
                             f"{rtr.featurizer!r}; use {FEATURIZERS}")
        if casc.tau_units not in TAU_UNITS:
            raise ValueError(f"unknown tau_units {casc.tau_units!r}; "
                             f"use {TAU_UNITS}")
        cap = (float(reg.num_features)
               if self.native_tau_units == "count" else 1.0)
        if casc.tau * self.tau_scale() > cap:
            raise ValueError(
                f"tau={casc.tau} {casc.tau_units} converts past the "
                f"served margin cap ({cap} {self.native_tau_units}); every "
                "request would escalate")
        if not 0.0 <= casc.frontend_sparsity <= 1.0:
            raise ValueError(f"frontend_sparsity must be in [0, 1], got "
                             f"{casc.frontend_sparsity}")
        obs = self.obs
        b = obs.latency_buckets_ms
        if b and (list(b) != sorted(set(b)) or b[0] <= 0):
            raise ValueError(
                f"latency_buckets_ms must be strictly increasing and "
                f"positive, got {b}")
        if obs.latency_window < 1:
            raise ValueError(f"latency_window must be >= 1, got "
                             f"{obs.latency_window}")
        if not 0.0 <= obs.span_sample <= 1.0:
            raise ValueError(f"span_sample must be in [0, 1], got "
                             f"{obs.span_sample}")
        dev = self.engine.device or ACAMConfig()
        if (self.engine.backend == "device" and mesh.bank_shards > 1
                and dev.sigma_program > 0.0
                and self.engine.device_noise != "per_shard"):
            raise ValueError(
                f"device backend with sigma_program={dev.sigma_program} "
                f"cannot shard the bank over {mesh.bank_shards} shards "
                'under device_noise="global" (one physical array draws one '
                'noise field); set engine.device_noise="per_shard" to '
                "program one array per shard")
        hash(self)  # fail fast: specs must stay usable as cache/jit keys
        return self

    # -- unit conversion ----------------------------------------------------

    @property
    def native_tau_units(self) -> str:
        """The units the served margins actually arrive in: matchline
        fractions (0..1) for the device backend and the similarity method,
        match counts (0..N) for the digital feature-count paths."""
        if self.engine.backend == "device" \
                or self.engine.method == "similarity":
            return "fraction"
        return "count"

    def tau_scale(self) -> float:
        """Multiplier taking a tau in `cascade.tau_units` to native units."""
        given, native = self.cascade.tau_units, self.native_tau_units
        if given == native:
            return 1.0
        n = float(self.registry.num_features)
        return 1.0 / n if native == "fraction" else n

    # -- JSON ---------------------------------------------------------------

    def to_dict(self) -> dict:
        d = {
            "registry": self.registry._asdict(),
            "engine": self.engine._asdict(),
            "mesh": self.mesh._asdict(),
            "scheduler": self.scheduler._asdict(),
            "cascade": self.cascade._asdict(),
            "obs": self.obs._asdict(),
            "router": self.router._asdict(),
        }
        eng = d["engine"]
        if eng["block"] is not None:
            eng["block"] = list(eng["block"])
        if eng["device"] is not None:
            eng["device"] = self.engine.device._asdict()
        d["obs"]["latency_buckets_ms"] = list(self.obs.latency_buckets_ms)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceSpec":
        eng = dict(d.get("engine", {}))
        if eng.get("block") is not None:
            eng["block"] = tuple(int(b) for b in eng["block"])
        if eng.get("device") is not None:
            eng["device"] = ACAMConfig(**eng["device"])
        obs = dict(d.get("obs", {}))
        if "latency_buckets_ms" in obs:
            obs["latency_buckets_ms"] = tuple(
                float(x) for x in obs["latency_buckets_ms"])
        return cls(
            registry=RegistrySpec(**d.get("registry", {})),
            engine=EngineConfig(**eng),
            mesh=MeshSpec(**d.get("mesh", {})),
            scheduler=SchedulerSpec(**d.get("scheduler", {})),
            cascade=CascadeSpec(**d.get("cascade", {})),
            obs=ObsSpec(**obs),
            router=RouterSpec(**d.get("router", {})),
        )

    def to_json(self, *, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ServiceSpec":
        return cls.from_dict(json.loads(text))

    @classmethod
    def from_file(cls, path: str) -> "ServiceSpec":
        with open(path) as f:
            return cls.from_json(f.read())


def aligned_classes(bank_shards: int, *, class_bucket: int = 16,
                    base: int = 128) -> int:
    """The registry's default class capacity (``base``) rounded up to cut
    into ``bank_shards`` shards of whole ``class_bucket``-row buckets —
    the one expression every spec builder uses for a default-capacity
    registry at a given shard count."""
    align = max(1, bank_shards) * class_bucket
    return -(-base // align) * align


def from_legacy(num_features: int, *, config=None, k_max: int = 2,
                class_bucket: int = 16, backend: str | None = None,
                bank_shards: int = 1) -> ServiceSpec:
    """Bridge the pre-spec `ACAMService(...)` constructor surface onto one
    `ServiceSpec` (the deprecated shims delegate here). Semantics match the
    old constructor: ``backend=None`` resolves the process default ONCE,
    taus are match-count units, capacity is silently rounded up to a shard
    multiple (the spec path validates it eagerly instead), and no mesh is
    installed (legacy callers installed their own). One deliberate fix over
    the old constructor: ``method="similarity"`` margins live in [0, 1], so
    count-unit taus are now converted (`tau_scale` = 1/N) — the old code
    only rescaled for ``backend="device"`` and would have compared a
    count-unit tau against fraction-unit margins."""
    from repro import match as match_lib
    from repro.serve.acam_service import ServiceConfig

    config = config or ServiceConfig()
    return ServiceSpec(
        registry=RegistrySpec(num_features=num_features, k_max=k_max,
                              class_bucket=class_bucket,
                              initial_classes=aligned_classes(
                                  bank_shards, class_bucket=class_bucket)),
        engine=EngineConfig(method=config.method, alpha=config.alpha,
                            backend=backend or match_lib.default_backend(),
                            margin=True),
        mesh=MeshSpec(bank_shards=bank_shards, install=False),
        scheduler=SchedulerSpec(slots=config.slots),
        cascade=CascadeSpec(tau=config.margin_tau, tau_units="count",
                            max_queue=config.max_queue,
                            frontend_macs=config.frontend_macs,
                            frontend_sparsity=config.frontend_sparsity,
                            softmax_head_ops=config.softmax_head_ops,
                            paper_faithful=config.paper_faithful),
    )
