"""The hybrid service's control plane: build-from-spec + live transitions.

`HybridService` is the front door the launchers, benchmarks and examples
construct through. It owns the two things the scattered constructor surface
never could:

**Construction order.** `HybridService.from_spec(spec)` executes the one
correct boot sequence — mesh install -> registry -> scheduler -> cascade —
so the old footgun (constructing `ACAMService` before `install_acam_mesh`
and silently getting `bank_shards=1`) cannot happen: the shard count comes
from the spec, and the spec's mesh is installed first.

**Runtime transitions.** `service.reconfigure(new_spec)` diffs the current
spec against the new one and executes the minimal live transition over a
drained scheduler:

    bank_shards change   LIVE RESHARD: drain -> `registry.reshard` re-packs
                         bucket runs to the new shard boundaries (zero
                         tenant re-registrations; slots, thresholds, head
                         tables and template rows survive) -> install the
                         new (data, model) mesh (the mesh generation
                         counter forces the scheduler's re-trace) -> the
                         next tick gathers the re-packed super-bank and
                         dispatches under the new `PartitionPlan`.
                         Predictions, margins and escalation decisions are
                         bit-identical across the transition (the engine's
                         cross-shard reduce contract). One documented
                         exception: the device backend under
                         `device_noise="per_shard"` with `sigma_program > 0`
                         — there the shard count IS the physical tiling
                         (one programmed array per shard, keyed
                         fold_in(seed, s)), so resharding re-programs the
                         arrays and legitimately re-realises the write
                         noise, exactly as re-tiling real RRAM would.
    engine change        backend/method/noise swap: the scheduler's next
                         tick dispatches under the new `EngineConfig` (a
                         fresh static jit key); taus are re-resolved into
                         the new backend's native margin units.
    scheduler change     new tick size: the scheduler is rebuilt over the
                         same registry (the queue is empty post-drain).
    cascade change       taus / energy attribution / admission bound are
                         re-derived for every registered tenant in place.

Transitions the spec cannot express live (a different feature dim, k_max
or bucket size — the banks themselves would change shape) raise
`ReconfigureError` before anything mutates.

**Durability & failover** (PR 6). `snapshot(ckpt)` / `restore(ckpt)`
persist and rebuild the full service through the atomic-rename
checkpointer (`repro.serve.snapshot`) — a killed service restarts
bit-identical, optionally onto a different mesh. `handle_device_loss`
degrades the live service onto the surviving devices (largest shard count
they can form, as an ordinary reconfigure transition); `restore_devices`
heals back to the full fleet.

The report returned by `reconfigure` carries the drained responses, the
action log, and the drain->resume wall time (`downtime_s`) — the number
`benchmarks/serving_bench.py --reshard` tracks.
"""
from __future__ import annotations

import dataclasses
import time

from repro.serve.acam_service import ACAMService, ClassifyResponse
from repro.serve.spec import MeshSpec, ServiceSpec


class ReconfigureError(ValueError):
    """The requested spec transition cannot be executed live."""


#: registry fields that shape the banks themselves — never live-mutable.
_FROZEN_REGISTRY_FIELDS = ("num_features", "k_max", "class_bucket")


@dataclasses.dataclass
class ReconfigureReport:
    """What a live transition did (and what it cost)."""

    spec: ServiceSpec  # the spec now in force
    actions: tuple[str, ...]  # human-readable transition log
    drained: list[ClassifyResponse]  # requests served during the quiesce
    downtime_s: float  # drain start -> resume wall time
    tenants_moved: int = 0  # reshard: tenants whose class offset changed


@dataclasses.dataclass
class ManifestReport:
    """What `apply_manifest` did: the tenant-set analogue of
    `ReconfigureReport` (ids per transition kind, no drain involved —
    every transition rides the hot register/update/evict paths)."""

    manifest: "object"  # the FleetManifest now in force
    added: tuple[str, ...]
    evicted: tuple[str, ...]
    updated: tuple[str, ...]
    retuned: tuple[str, ...]
    duration_s: float

    @property
    def empty(self) -> bool:
        return not (self.added or self.evicted or self.updated
                    or self.retuned)


def install_mesh(mesh: MeshSpec, devices=None):
    """Build and install the (data = devices/bank_shards, model =
    bank_shards) serving mesh described by a `MeshSpec`. Returns the mesh.

    This is the spec path's replacement for the old order-sensitive
    launcher helper: `HybridService.from_spec` calls it BEFORE any service
    tier exists, so registry placement and the engine's `PartitionPlan`
    can never disagree about the shard count.

    ``devices`` restricts the mesh to a survivor subset — the degraded
    path `handle_device_loss` takes after a simulated device failure.
    """
    from repro.distributed import context
    from repro.launch.mesh import make_serving_mesh

    built = make_serving_mesh(bank_shards=mesh.bank_shards,
                              axis_names=(mesh.data_axis, mesh.model_axis),
                              devices=devices)
    context.set_mesh_axes(mesh.data_axis, mesh.model_axis, built)
    return built


class HybridService(ACAMService):
    """`ACAMService` + the declarative lifecycle: one spec in, live
    transitions after. (The inherited legacy keyword constructor still
    works; `from_spec` is the intended front door.)"""

    @classmethod
    def from_spec(cls, spec: ServiceSpec) -> "HybridService":
        """Validate, install the spec's mesh (when it owns one), then build
        registry -> scheduler -> cascade in order."""
        spec.validate()
        svc = cls.__new__(cls)
        if spec.mesh.install:
            install_mesh(spec.mesh)
        svc._build(spec)
        return svc

    def reconfigure(self, new_spec: ServiceSpec) -> ReconfigureReport:
        """Diff specs and execute the minimal live transition (see module
        docstring). Pending requests are drained — served under the OLD
        config — before anything switches; their responses are returned in
        the report so no work is lost."""
        new_spec.validate()
        old = self.spec
        for field in _FROZEN_REGISTRY_FIELDS:
            if getattr(new_spec.registry, field) != \
                    getattr(old.registry, field):
                raise ReconfigureError(
                    f"registry.{field} cannot change live "
                    f"({getattr(old.registry, field)} -> "
                    f"{getattr(new_spec.registry, field)}): the registered "
                    "banks would change shape; build a fresh service")
        if new_spec.mesh.install:
            # fail BEFORE any mutation: a mesh the devices cannot form must
            # not strand a resharded registry behind the old mesh (after a
            # device loss, "available" means the survivors)
            ndev = len(self._avail_devices())
            if ndev % new_spec.mesh.bank_shards:
                raise ReconfigureError(
                    f"mesh.bank_shards={new_spec.mesh.bank_shards} does not "
                    f"divide the {ndev} available devices; nothing was "
                    "changed")
        if new_spec == old:
            return ReconfigureReport(spec=old, actions=(), drained=[],
                                     downtime_s=0.0)

        t0 = time.perf_counter()
        drained = self.drain()
        actions: list[str] = []
        moved = 0

        reshard = new_spec.mesh.bank_shards != old.mesh.bank_shards
        if reshard:
            moved = self.registry.reshard(new_spec.mesh.bank_shards)
            actions.append(
                f"resharded super-bank {old.mesh.bank_shards} -> "
                f"{new_spec.mesh.bank_shards} ({moved} tenant runs "
                f"re-packed, 0 re-registrations)")
            self.obs.emit("reshard",
                          bank_shards_from=old.mesh.bank_shards,
                          bank_shards_to=new_spec.mesh.bank_shards)
        if new_spec.mesh != old.mesh or reshard:
            if new_spec.mesh.install:
                install_mesh(new_spec.mesh, devices=self._devices)
                actions.append(
                    f"installed ({new_spec.mesh.data_axis}, "
                    f"{new_spec.mesh.model_axis}={new_spec.mesh.bank_shards})"
                    " mesh (generation bump -> scheduler re-trace)")

        if new_spec.engine != old.engine:
            self.scheduler.set_engine(new_spec.engine)
            actions.append(f"engine {old.engine.backend}/{old.engine.method}"
                           f" -> {new_spec.engine.backend}/"
                           f"{new_spec.engine.method}")
        if new_spec.scheduler != old.scheduler:
            from repro.serve.scheduler import MicroBatchScheduler

            stats = self.scheduler.stats  # cumulative view stays coherent
            self.scheduler = MicroBatchScheduler(
                self.registry, slots=new_spec.scheduler.slots,
                engine=new_spec.engine, monitor=self.scheduler.monitor,
                recorder=self.obs)
            self.scheduler.tau_fn = self._margin_tau_of
            stats.slots = new_spec.scheduler.slots
            self.scheduler.stats = stats
            self.obs.slots_gauge.set(new_spec.scheduler.slots)
            actions.append(f"scheduler slots {old.scheduler.slots} -> "
                           f"{new_spec.scheduler.slots}")
        if new_spec.cascade != old.cascade:
            actions.append("cascade re-derived (tau/energy/admission)")
        # always re-derive the cascade view: tau units depend on the engine
        # backend/method as much as on the cascade block itself
        self._apply_cascade(new_spec)
        self.spec = new_spec
        downtime_s = time.perf_counter() - t0
        self.obs.emit("reconfigure", actions=list(actions),
                      drained=len(drained),
                      duration_ms=round(downtime_s * 1e3, 3))
        return ReconfigureReport(spec=new_spec, actions=tuple(actions),
                                 drained=drained,
                                 downtime_s=downtime_s,
                                 tenants_moved=moved)

    # ------------------------------------------------- fleet (repro.fleet)

    def apply_manifest(self, manifest) -> ManifestReport:
        """Diff a `FleetManifest` against the one in force and execute the
        minimal tenant transitions — the tenant-set analogue of
        `reconfigure`:

            only in new        register (bank from seed/checkpoint + head)
            only in old        evict
            bank source moved  hot update in place (checkpoint-path or
                               seed/shape change forces the bank reload)
            epoch bumped       evict + re-register (forced fresh placement)
            tau-only change    retune the threshold (registry untouched)

        All transitions ride the hot paths, so bucketed shapes — and every
        jitted caller's trace cache — stay untouched in the steady state;
        a no-op manifest produces zero transitions and zero retraces.
        Per-tenant taus are converted from the MANIFEST'S declared units
        into the spec's `cascade.tau_units` before installation
        (`fleet.manifest.tau_in_units`), so one manifest serves specs in
        either unit system."""
        from repro.fleet import manifest as manifest_lib

        new = manifest.validate().normalized()
        old = getattr(self, "_manifest", None) or \
            manifest_lib.FleetManifest()
        diff = manifest_lib.diff_manifests(old, new)
        t0 = time.perf_counter()
        n = self.registry.num_features
        units = self.spec.cascade.tau_units
        by_id = new.by_id()

        def _tau(t):
            return manifest_lib.tau_in_units(t.tau, t.tau_units, units, n)

        for tid in diff.evict:
            if tid in self.registry:
                self.evict_tenant(tid)
        for tid in diff.add:
            t = by_id[tid]
            bank, head = manifest_lib.materialize(t, n)
            if tid in self.registry:  # adopting an imperatively-registered
                self.update_tenant(tid, bank, head=head,  # tenant
                                   margin_tau=_tau(t))
            else:
                self.register_tenant(tid, bank, head=head,
                                     margin_tau=_tau(t))
        for tid in diff.update:
            t = by_id[tid]
            bank, head = manifest_lib.materialize(t, n)
            self.update_tenant(tid, bank, head=head, margin_tau=_tau(t))
        for tid in diff.retune:
            self.retune_tenant(tid, margin_tau=_tau(by_id[tid]))
        self._manifest = new
        duration_s = time.perf_counter() - t0
        if not diff.empty:
            self.obs.emit("manifest_apply", added=list(diff.add),
                          evicted=list(diff.evict),
                          updated=list(diff.update),
                          retuned=list(diff.retune),
                          duration_ms=round(duration_s * 1e3, 3))
        return ManifestReport(manifest=new, added=diff.add,
                              evicted=diff.evict, updated=diff.update,
                              retuned=diff.retune, duration_s=duration_s)

    def rolling_reshard(self, new_spec: ServiceSpec, *,
                        prepared=None) -> ReconfigureReport:
        """The double-buffered reshard (`repro.fleet.reshard`): build the
        re-packed super-bank alongside the live one, then flip between
        ticks — NO drain, downtime is the flip + mesh install alone.
        Bit-identical preds/margins/escalations to the drained
        `reconfigure` path. Pass ``prepared`` (from `fleet.reshard.
        prepare`) to flip a buffer built earlier, overlapped with
        serving; without it this prepares and flips back to back."""
        from repro.fleet import reshard as reshard_lib

        if prepared is None:
            prepared = reshard_lib.prepare(self, new_spec)
        return reshard_lib.flip(self, prepared)

    def compact_registry(self) -> int:
        """Reclaim eviction debt: re-pack the super-bank into its smallest
        shard-aligned capacity (`TemplateBankRegistry.compact`). The
        fleet policy triggers this when occupancy drops below its
        threshold (`fleet.policy.should_compact`); safe live — queued
        requests resolve placements at tick time. Returns class rows
        freed."""
        return self.registry.compact()

    # ------------------------------------------------------- durability

    def snapshot(self, ckpt, step: int | None = None, *,
                 blocking: bool = True) -> int:
        """Persist the full service state (registry, placements, taus, head
        tables, spec) through the atomic-rename checkpointer. Returns the
        step written. See `repro.serve.snapshot`."""
        from repro.serve import snapshot as snapshot_lib

        step = snapshot_lib.save_snapshot(self, ckpt, step,
                                          blocking=blocking)
        self.obs.emit("snapshot", step=step, path=str(ckpt.dir))
        return step

    @classmethod
    def restore(cls, ckpt, step: int | None = None, *,
                mesh: MeshSpec | None = None):
        """Rebuild a ready-to-serve service from its latest (or a given)
        snapshot — bit-identical preds/margins/escalations, zero tenant
        re-registrations. ``mesh`` restores onto a DIFFERENT mesh (elastic
        shrink/grow across a restart). Returns ``(service,
        RestoreReport)``."""
        from repro.serve import snapshot as snapshot_lib

        svc, report = snapshot_lib.restore_service(ckpt, step, mesh=mesh,
                                                   cls=cls)
        svc.obs.emit("restore", step=report.step,
                     resharded=report.resharded,
                     duration_ms=round(report.restore_s * 1e3, 3))
        return svc, report

    # --------------------------------------------------- elastic failover

    def _avail_devices(self) -> list:
        """The devices the control plane may build meshes over: all of
        `jax.devices()` minus any reported lost."""
        import jax

        if self._devices is not None:
            return list(self._devices)
        return list(jax.devices())

    def handle_device_loss(self, lost) -> ReconfigureReport:
        """Degrade gracefully after a (simulated) device failure: drop the
        lost devices, pick the largest shard count the survivors can form,
        and reshard the live service onto them.

        ``lost`` is an iterable of device indices into the full
        `jax.devices()` list. Losses accumulate across calls (a second
        failure shrinks further); `restore_devices` heals the fleet. The
        reshard is the ordinary `reconfigure` transition — zero tenant
        re-registrations, bit-identical results after the shrink.
        """
        import jax

        all_devs = list(jax.devices())
        for i in lost:
            if not 0 <= i < len(all_devs):
                raise ReconfigureError(
                    f"device index {i} out of range (fleet has "
                    f"{len(all_devs)} devices)")
            self._lost_devices.add(int(i))
        survivors = [d for i, d in enumerate(all_devs)
                     if i not in self._lost_devices]
        if not survivors:
            raise ReconfigureError("all devices lost; nothing to serve on")
        self._devices = survivors

        # largest shard count the survivors can still form, capped at the
        # current one (device loss never widens the model axis)
        shards = min(self.spec.mesh.bank_shards, len(survivors))
        while len(survivors) % shards:
            shards -= 1
        target = self.spec._replace(
            mesh=self.spec.mesh._replace(bank_shards=shards))
        if target != self.spec:
            report = self.reconfigure(target)
        else:
            # same spec, fewer devices: the mesh itself must still shrink
            t0 = time.perf_counter()
            drained = self.drain()
            actions: tuple[str, ...] = ()
            if self.spec.mesh.install:
                install_mesh(self.spec.mesh, devices=survivors)
                actions = (f"reinstalled mesh on {len(survivors)} "
                           "surviving devices (generation bump -> "
                           "scheduler re-trace)",)
            report = ReconfigureReport(
                spec=self.spec, actions=actions, drained=drained,
                downtime_s=time.perf_counter() - t0)
        self.obs.emit("device_loss", lost=sorted(self._lost_devices),
                      survivors=len(survivors))
        return dataclasses.replace(
            report, actions=report.actions + (
                f"device loss: {len(self._lost_devices)} down, "
                f"{len(survivors)} surviving, bank_shards={shards}",))

    def restore_devices(self) -> ReconfigureReport:
        """Heal the fleet: forget recorded losses and rebuild the spec's
        mesh over the full device set (the repair-complete transition)."""
        self._lost_devices.clear()
        self._devices = None
        t0 = time.perf_counter()
        drained = self.drain()
        actions: tuple[str, ...] = ()
        if self.spec.mesh.install:
            install_mesh(self.spec.mesh)
            actions = ("restored full fleet: mesh reinstalled over all "
                       "devices",)
        self.obs.emit("device_heal", restored=len(self._avail_devices()))
        return ReconfigureReport(spec=self.spec, actions=actions,
                                 drained=drained,
                                 downtime_s=time.perf_counter() - t0)
