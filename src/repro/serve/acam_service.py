"""Multi-tenant ACAM classification service (the hybrid cascade core).

The intended front door is the spec path — ONE declarative
`repro.serve.spec.ServiceSpec` handed to
`repro.serve.control.HybridService.from_spec`, which owns construction
order (mesh -> registry -> scheduler -> cascade) and live transitions
(`reconfigure`: reshard / backend swap / tau retune). The keyword
constructor below survives as a deprecated shim that builds the same spec
(`repro.serve.spec.from_legacy`).

Turns the fused Pallas classify kernel into a service tier:

    submit -> admission (known tenant, feature dim, queue bound)
           -> micro-batching scheduler (ONE fused classify dispatch per
              tick over the registry's super-bank; `repro.serve.scheduler`)
           -> confidence cascade: the per-request Eq. 12 winner-vs-runner-up
              **margin** decides
                accept-at-ACAM   (margin >= tau): charge E_backend only
                escalate         (margin <  tau): run the tenant's CNN
                                 logits head on the same features; charge
                                 E_frontend + E_backend (paper §V-D via
                                 `repro.core.energy`)
           -> per-request `ClassifyResponse` + aggregated service metrics
              (throughput, p50/p99 latency, escalation rate, nJ/request).

Every number the service reports lives in its `repro.obs.FlightRecorder`
(`self.obs`): `metrics()` and `health()` are thin reads over its metric
registry, per-request spans travel admission -> tick -> response through
it, the SS V-D energy ledger aggregates there, and — when the spec sets
`obs.telemetry_dir` — a JSONL event log records every tick and lifecycle
event. The shed_p99_ms overload check reads the SAME histogram quantile
`metrics()` reports (one source of truth, not three reservoirs).

Escalated slots from one tick are themselves coalesced into one dense-head
dispatch (padded to power-of-two buckets so the escalation path compiles a
handful of shapes, ever). Tenants without a registered head never escalate.

`make_synthetic_tenant` / `sample_tenant_queries` build deterministic
per-tenant banks + matching nearest-centroid heads without training a CNN —
the launcher (`repro.launch.serve --workload acam`), the serving benchmark
(`benchmarks/serving_bench.py`) and the tests all share them. For a real
front-end, fit a bank with `repro.core.hybrid.fit_acam_head` and pass the
model's dense head weights (see `examples/serve_batched.py`).
"""
from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy as energy_lib
from repro.core import templates
from repro.core.templates import TemplateBank
from repro.obs import FlightRecorder
from repro.serve.registry import RegistryError, TemplateBankRegistry
from repro.serve.scheduler import MicroBatchScheduler, SlotResult, WorkItem


class AdmissionError(ValueError):
    """Request rejected at admission (unknown tenant, bad shape, overload)."""


@dataclasses.dataclass(frozen=True)
class ClassifyRequest:
    """One classification request: a tenant's raw front-end feature map."""

    tenant_id: str
    features: np.ndarray  # (N,) float32


@dataclasses.dataclass
class ClassifyResponse:
    request_id: int
    tenant_id: str
    pred: int  # tenant-local class id; -1 on error
    margin: float  # Eq. 12 confidence margin at the ACAM
    escalated: bool  # False: accepted at the ACAM back-end
    energy_j: float  # E_backend, or E_frontend + E_backend if escalated
    latency_s: float  # submit -> response wall time
    error: str | None = None  # e.g. tenant evicted while the request queued
    #: True: overload degraded this answer — the margin asked for CNN
    #: escalation but load-shed mode served the ACAM winner instead
    shed: bool = False
    #: winner's absolute per-class score in native units (0 on error).
    #: The semantic-cache router's hit_score floor reads this; plain
    #: classification traffic can ignore it.
    score: float = 0.0


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    slots: int = 64  # scheduler micro-batch size
    method: str = "feature_count"
    alpha: float = 1.0
    #: cascade accept threshold, always in match-count units (0..N). The
    #: device-physics backend senses matchline *fractions* (0..1) — the
    #: service rescales tau by 1/N automatically when constructed with
    #: backend="device", so callers never convert units themselves.
    margin_tau: float = 8.0
    max_queue: int = 4096  # admission bound
    # paper §V-D energy attribution (repro.core.energy.hybrid_report defaults)
    frontend_macs: int = 23_785_120
    frontend_sparsity: float = 0.80
    softmax_head_ops: int = 7_850
    paper_faithful: bool = True


@dataclasses.dataclass
class _TenantRuntime:
    has_head: bool  # False: cascade disabled (no escalation target)
    raw_tau: float | None  # per-tenant override in the spec's tau_units
    margin_tau: float | None  # resolved to native units; None: no head
    backend_j: float  # Eq. 14 energy of this tenant's programmed rows


def _bucket(n: int, cap: int) -> int:
    """Next power-of-two >= n, capped (escalation batch shape buckets)."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


@jax.jit
def _escalate_heads(w_table, b_table, feats, head_slot, n_classes):
    """One dense-head dispatch for all escalated slots of a tick.

    Gathers each slot's tenant head from the stacked table and masks class
    columns beyond the tenant's true class count.
    """
    w = jnp.take(w_table, head_slot, axis=0)  # (S, N, C)
    b = jnp.take(b_table, head_slot, axis=0)  # (S, C)
    logits = jnp.einsum("sn,snc->sc", feats, w) + b
    cols = jnp.arange(logits.shape[-1])[None, :]
    logits = jnp.where(cols < n_classes[:, None], logits, -jnp.inf)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


class ACAMService:
    """Request/response front for multi-tenant hybrid ACAM classification."""

    def __init__(self, num_features: int, *,
                 config: ServiceConfig = ServiceConfig(), k_max: int = 2,
                 class_bucket: int = 16, backend: str | None = None,
                 bank_shards: int | None = None):
        """DEPRECATED shim over the spec path: prefer
        `repro.serve.control.HybridService.from_spec(ServiceSpec(...))`,
        which owns mesh install order and enables live `reconfigure`. These
        keywords are bridged 1:1 through `repro.serve.spec.from_legacy`.

        ``backend`` pins the scheduler's `repro.match` engine backend
        ("reference" | "kernel" | "device" | "auto"); None resolves the
        process default ONCE, here. "device" serves every tick through the
        RRAM-CMOS physics models — margins are then matchline fractions,
        and every margin_tau (given in match-count units) is rescaled by
        1/num_features (`ServiceSpec.tau_scale`).

        ``bank_shards`` aligns the registry's tenant placement to the bank
        shards the engine's `PartitionPlan` cuts the super-bank into. None
        infers it from the installed mesh — which is the ordering footgun
        this constructor is deprecated for: with no mesh installed it
        silently resolves to 1, so it now warns. `from_spec` makes the
        shard count explicit and installs the mesh itself."""
        from repro import match as match_lib
        from repro.serve import spec as spec_lib

        if bank_shards is None:
            from repro.distributed import context

            if context.get_mesh() is None:
                warnings.warn(
                    "ACAMService(bank_shards=None) with no mesh installed: "
                    "bank_shards silently resolves to 1. If you meant to "
                    "shard the super-bank, install the serving mesh BEFORE "
                    "constructing the service — or switch to the spec path "
                    "(repro.serve.control.HybridService.from_spec), which "
                    "owns mesh install order and makes this impossible.",
                    UserWarning, stacklevel=2)
            bank_shards = match_lib.bank_shards_in_mesh()
        self._build(spec_lib.from_legacy(
            num_features, config=config, k_max=k_max,
            class_bucket=class_bucket, backend=backend,
            bank_shards=bank_shards))

    def _build(self, spec) -> None:
        """Construct every tier from a validated `ServiceSpec` in the one
        correct order: registry -> scheduler -> cascade. (The mesh, when
        the spec owns it, is installed before this runs —
        `HybridService.from_spec`.)"""
        spec.validate()
        self.spec = spec
        #: the flight recorder: metric registry + span recorder + energy
        #: ledger + event log. Lives as long as the service (reconfigure
        #: rebuilds schedulers, never this).
        self.obs = FlightRecorder(spec.obs)
        self.registry = TemplateBankRegistry(
            spec.registry.num_features, k_max=spec.registry.k_max,
            class_bucket=spec.registry.class_bucket,
            initial_classes=spec.registry.initial_classes,
            initial_tenants=spec.registry.initial_tenants,
            bank_shards=spec.mesh.bank_shards)
        self.scheduler = MicroBatchScheduler(
            self.registry, slots=spec.scheduler.slots, engine=spec.engine,
            recorder=self.obs)
        # the cascade's tau rides into the serve kernel: the scheduler asks
        # this per dispatched request and the margin < tau compare happens
        # in the fused dispatch (SlotResult.escalate), not here in python
        self.scheduler.tau_fn = self._margin_tau_of
        self.scheduler.monitor.sink = self.obs.record_straggler
        self.obs.slots_gauge.set(spec.scheduler.slots)
        #: control-plane failure state (simulated device loss): None = every
        #: jax device is healthy; else the surviving device list every mesh
        #: (re)install is built over (`HybridService.handle_device_loss`)
        self._devices = None
        self._lost_devices: set[int] = set()
        self._tenants: dict[str, _TenantRuntime] = {}
        self._head_w: np.ndarray | None = None  # (T_cap, N, C_head)
        self._head_b: np.ndarray | None = None  # (T_cap, C_head)
        self._head_cache: tuple[int, jnp.ndarray, jnp.ndarray] | None = None
        self._head_gen = 0
        self._next_id = 0
        self._apply_cascade(spec)

    def _apply_cascade(self, spec) -> None:
        """(Re)derive everything the cascade spec controls: the legacy
        `ServiceConfig` view, tau unit conversion, the §V-D front-end
        energy, and every registered tenant's resolved threshold. Called at
        build AND by the control plane's live transitions."""
        casc = spec.cascade
        self.spec = spec
        self.config = ServiceConfig(
            slots=spec.scheduler.slots, method=spec.engine.method,
            alpha=spec.engine.alpha, margin_tau=casc.tau,
            max_queue=casc.max_queue, frontend_macs=casc.frontend_macs,
            frontend_sparsity=casc.frontend_sparsity,
            softmax_head_ops=casc.softmax_head_ops,
            paper_faithful=casc.paper_faithful)
        self._tau_scale = spec.tau_scale()
        effective = int(round(casc.frontend_macs
                              * (1.0 - casc.frontend_sparsity)))
        effective -= casc.softmax_head_ops
        self._frontend_j = energy_lib.frontend_energy(
            effective, paper_faithful=casc.paper_faithful)
        for rt in self._tenants.values():
            rt.margin_tau = self._resolve_tau(rt.raw_tau) if rt.has_head \
                else None

    def _resolve_tau(self, raw: float | None) -> float:
        """Spec-units tau (per-tenant override or the cascade default) ->
        the served backend's native margin units."""
        tau = self.spec.cascade.tau if raw is None else raw
        return tau * self._tau_scale

    def _margin_tau_of(self, tenant_id: str) -> float | None:
        """The scheduler's `tau_fn`: resolved margin threshold for one
        tenant (None = no CNN head registered, never escalate)."""
        rt = self._tenants.get(tenant_id)
        return None if rt is None else rt.margin_tau

    # -- tenant lifecycle ---------------------------------------------------

    def register_tenant(self, tenant_id: str, bank: TemplateBank, *,
                        head: tuple[np.ndarray, np.ndarray] | None = None,
                        margin_tau: float | None = None) -> None:
        """Hot-register a tenant: templates into the super-bank, optional
        (W, b) CNN logits head enabling the escalation path."""
        head = self._check_head(head)  # validate BEFORE mutating the registry
        entry = self.registry.register(tenant_id, bank)
        self._install(tenant_id, entry.slot, entry.valid_rows, head,
                      margin_tau)

    def update_tenant(self, tenant_id: str, bank: TemplateBank, *,
                      head: tuple[np.ndarray, np.ndarray] | None = None,
                      margin_tau: float | None = None) -> None:
        head = self._check_head(head)
        entry = self.registry.update(tenant_id, bank)
        self._install(tenant_id, entry.slot, entry.valid_rows, head,
                      margin_tau)

    def evict_tenant(self, tenant_id: str) -> None:
        self.registry.evict(tenant_id)
        del self._tenants[tenant_id]

    def retune_tenant(self, tenant_id: str, *,
                      margin_tau: float | None) -> None:
        """Change ONLY a tenant's cascade threshold (spec tau_units; None
        reverts to the cascade default) — no registry touch, no head
        change, no retrace. The manifest path's tau-only transition."""
        rt = self._tenants[tenant_id]
        rt.raw_tau = margin_tau
        rt.margin_tau = self._resolve_tau(margin_tau) if rt.has_head \
            else None

    def _check_head(self, head):
        if head is None:
            return None
        w = np.asarray(head[0], np.float32)
        b = np.asarray(head[1], np.float32)
        if w.shape[0] != self.registry.num_features:
            raise RegistryError(
                f"head expects {w.shape[0]} features, registry serves "
                f"{self.registry.num_features}")
        if w.shape[1] != b.shape[0]:
            raise RegistryError(
                f"head shapes disagree: W {w.shape} vs b {b.shape}")
        return w, b

    def _install(self, tenant_id, slot, valid_rows, head, margin_tau):
        if head is not None:
            self._head_store(slot, head[0], head[1])
        self._tenants[tenant_id] = _TenantRuntime(
            has_head=head is not None, raw_tau=margin_tau,
            margin_tau=self._resolve_tau(margin_tau)
            if head is not None else None,
            backend_j=energy_lib.backend_energy(valid_rows,
                                                self.registry.num_features))

    def head_of(self, tenant_id: str) -> tuple[np.ndarray, np.ndarray]:
        """The tenant's (W (N, C), b (C,)) escalation head, read back from
        the stacked tables (the single source of truth the escalation
        dispatch gathers from)."""
        entry = self.registry.get(tenant_id)
        c = entry.num_classes
        if self._head_w is None or not self._tenants[tenant_id].has_head:
            raise RegistryError(f"tenant {tenant_id!r} has no head")
        return (self._head_w[entry.slot, :, :c].copy(),
                self._head_b[entry.slot, :c].copy())

    def _head_store(self, slot: int, w: np.ndarray, b: np.ndarray) -> None:
        t_cap = self.registry.capacity_tenants
        n = self.registry.num_features
        c = w.shape[1]
        c_head = c if self._head_w is None else \
            max(c, self._head_w.shape[-1])
        if (self._head_w is None or self._head_w.shape[0] < t_cap
                or self._head_w.shape[-1] < c_head):
            new_w = np.zeros((t_cap, n, c_head), np.float32)
            new_b = np.full((t_cap, c_head), -np.inf, np.float32)
            if self._head_w is not None:
                ow, ob = self._head_w, self._head_b
                new_w[:ow.shape[0], :, :ow.shape[-1]] = ow
                new_b[:ob.shape[0], :ob.shape[-1]] = ob
            self._head_w, self._head_b = new_w, new_b
        self._head_w[slot, :, :c] = w
        self._head_w[slot, :, c:] = 0.0
        self._head_b[slot, :c] = b
        self._head_b[slot, c:] = -np.inf
        self._head_gen += 1
        self._head_cache = None

    def _head_tables(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        if self._head_cache is None or self._head_cache[0] != self._head_gen:
            self._head_cache = (self._head_gen, jnp.asarray(self._head_w),
                                jnp.asarray(self._head_b))
        return self._head_cache[1], self._head_cache[2]

    # -- request path -------------------------------------------------------

    def submit(self, request: ClassifyRequest) -> int:
        """Admit one request into the scheduler queue; returns request id.
        Admission opens the request's span (`repro.obs.SpanRecorder`)."""
        if request.tenant_id not in self.registry:
            self.obs.record_rejected()
            raise AdmissionError(f"unknown tenant {request.tenant_id!r}")
        feats = np.asarray(request.features, np.float32).reshape(-1)
        if feats.shape[0] != self.registry.num_features:
            self.obs.record_rejected()
            raise AdmissionError(
                f"expected {self.registry.num_features} features, got "
                f"{feats.shape[0]}")
        if self.scheduler.qsize >= self.config.max_queue:
            self.obs.record_rejected()
            raise AdmissionError(
                f"queue full ({self.config.max_queue} pending)")
        self._next_id += 1
        t_admit = time.perf_counter()
        self.scheduler.submit(WorkItem(
            request_id=self._next_id, tenant_id=request.tenant_id,
            features=feats, submit_t=t_admit))
        self.obs.record_submit(self._next_id, request.tenant_id, t_admit)
        self.obs.set_queue_depth(self.scheduler.qsize)
        return self._next_id

    def overloaded(self) -> bool:
        """Is the service past its overload thresholds RIGHT NOW? True when
        the queue has grown to ``cascade.shed_queue`` or the rolling p99
        latency exceeds ``cascade.shed_p99_ms`` — the next tick then runs
        in load-shed mode (ACAM stage alone, no CNN escalation: the paper's
        E_backend << E_frontend asymmetry as an overload policy).

        The p99 here is `FlightRecorder.latency_quantile_ms(0.99)` — the
        IDENTICAL read `metrics()['latency_p99_ms']` reports, from the
        histogram's rolling window (bounded, so a burst's tail stops
        poisoning the estimate once the service recovers; it also survives
        `reset_metrics()`, which must never blind this check)."""
        casc = self.spec.cascade
        if casc.shed_queue is not None \
                and self.scheduler.qsize >= casc.shed_queue:
            return True
        if casc.shed_p99_ms is not None \
                and self.obs.latency.window_count >= 32 \
                and self.obs.latency_quantile_ms(0.99) > casc.shed_p99_ms:
            return True
        return False

    def step(self) -> list[ClassifyResponse]:
        """One scheduler tick + the cascade over its results.

        Resilience duties run first: requests older than the cascade's
        per-request deadline are expired with an error (serving them
        uselessly late helps nobody), and an overloaded tick degrades
        gracefully — every slot is answered from the ACAM stage alone
        (``shed=True`` where the margin asked for escalation) instead of
        queueing CNN head work behind a growing backlog."""
        t0 = time.perf_counter()
        responses: list[ClassifyResponse] = []
        casc = self.spec.cascade
        if casc.deadline_ms is not None:
            for item in self.scheduler.expire(casc.deadline_ms / 1e3):
                responses.append(ClassifyResponse(
                    request_id=item.request_id, tenant_id=item.tenant_id,
                    pred=-1, margin=0.0, escalated=False, energy_j=0.0,
                    latency_s=time.perf_counter() - item.submit_t,
                    error=f"deadline exceeded ({casc.deadline_ms} ms "
                          "in queue)"))
        n_expired = len(responses)
        shedding = self.overloaded()
        self.obs.set_shed_mode(shedding, queue_depth=self.scheduler.qsize)
        results = self.scheduler.tick()
        if not results:
            if responses:
                self._finalize_step(responses, t0, shedding, fill=0,
                                    n_expired=n_expired, dispatched=False,
                                    escalation=False)
            return responses
        if shedding:
            self.obs.record_shed_tick()
        escalate: list[SlotResult] = []
        keep: list[tuple[SlotResult, bool, bool]] = []
        for r in results:
            rt = self._tenants.get(r.item.tenant_id) if r.error is None \
                else None
            # the margin < tau compare already ran inside the serve kernel
            # (SlotResult.escalate); rt guards tenants evicted mid-flight.
            # _wants_escalation is the routing-policy hook: the base
            # cascade trusts the in-kernel bit verbatim, the semantic
            # cache adds its absolute hit_score floor on top.
            wants = rt is not None and self._wants_escalation(r)
            if wants and not shedding:
                escalate.append(r)
                keep.append((r, True, False))
            else:
                # shed: the margin asked for the CNN head but overload says
                # answer from the ACAM stage alone
                keep.append((r, False, wants))

        esc_pred: dict[int, int] = {}
        if escalate:
            esc_pred = self._run_escalation(escalate)

        now = time.perf_counter()
        fcost: dict[int, float] = {}
        for r, escalated, shed in keep:
            if r.error is not None:
                responses.append(ClassifyResponse(
                    request_id=r.item.request_id,
                    tenant_id=r.item.tenant_id, pred=-1, margin=0.0,
                    escalated=False, energy_j=0.0,
                    latency_s=now - r.item.submit_t, error=r.error))
                continue
            rt = self._tenants[r.item.tenant_id]
            pred = esc_pred[r.item.request_id] if escalated else r.pred_local
            fj = self._frontend_cost(r.item.request_id) if escalated else 0.0
            fcost[r.item.request_id] = fj
            e = rt.backend_j + fj
            responses.append(ClassifyResponse(
                request_id=r.item.request_id,
                tenant_id=r.item.tenant_id, pred=pred,
                margin=r.margin, escalated=escalated, energy_j=e,
                latency_s=now - r.item.submit_t, shed=shed,
                score=r.score))
        self._finalize_step(responses, t0, shedding, fill=len(results),
                            n_expired=n_expired, dispatched=True,
                            escalation=bool(escalate), now=now,
                            frontend=fcost)
        return responses

    def _finalize_step(self, responses: list[ClassifyResponse], t0: float,
                       shedding: bool, *, fill: int, n_expired: int,
                       dispatched: bool, escalation: bool,
                       now: float | None = None,
                       frontend: dict[int, float] | None = None) -> None:
        """Book one step into the flight recorder: close every response's
        span (disposition + latency + SS V-D energy split), bump the busy
        clock and queue gauge, and — when the event log is on — append the
        step's "tick" line. Pure accounting: preds/margins/escalations are
        already fixed by the time this runs, so telemetry can never change
        a served answer."""
        obs = self.obs
        if escalation:
            obs.record_escalation_dispatch()
        for r in responses:
            if r.error is not None:
                obs.finish_request(r, 0.0, 0.0)
            else:
                rt = self._tenants[r.tenant_id]
                if frontend is not None:
                    fj = frontend.get(r.request_id, 0.0)
                else:
                    fj = self._frontend_j if r.escalated else 0.0
                obs.finish_request(r, rt.backend_j, fj)
        now = time.perf_counter() if now is None else now
        obs.add_busy(now - t0)
        obs.set_queue_depth(self.scheduler.qsize)
        if obs.events.enabled:
            obs.emit(
                "tick",
                tick_id=obs.tick_seq - 1 if dispatched else -1,
                fill=fill,
                served=sum(r.error is None for r in responses),
                escalated=sum(r.escalated for r in responses),
                shed=sum(r.shed for r in responses),
                expired=n_expired,
                dt_ms=round(obs.last_dispatch_ms, 4) if dispatched else 0.0,
                queue_depth=self.scheduler.qsize,
                shed_mode=int(shedding),
                energy_j=sum(r.energy_j for r in responses))

    def _wants_escalation(self, r: SlotResult) -> bool:
        """Routing-policy hook: should this served slot escalate to the
        expensive backend? The base cascade trusts the in-kernel
        ``margin < tau`` bit verbatim; `repro.serve.semantic_cache`
        overrides this to stack its absolute winner-score floor on top."""
        return r.escalate

    def _frontend_cost(self, request_id: int) -> float:
        """Energy charged for ONE escalated request. The base cascade's
        CNN head costs the same §V-D figure for every request; the
        semantic cache overrides this with the request's actual per-token
        decode cost. Only consulted for escalated requests — hits are
        charged E_backend alone."""
        del request_id
        return self._frontend_j

    def _run_escalation(self, escalate: list[SlotResult]) -> dict[int, int]:
        """Coalesce a tick's escalated slots into one dense-head dispatch."""
        n = self.registry.num_features
        size = _bucket(len(escalate), self.config.slots)
        feats = np.zeros((size, n), np.float32)
        slot = np.zeros((size,), np.int32)
        ncls = np.ones((size,), np.int32)
        for i, r in enumerate(escalate):
            feats[i] = r.item.features
            slot[i] = r.entry.slot
            ncls[i] = r.entry.num_classes
        w_table, b_table = self._head_tables()
        pred = np.asarray(_escalate_heads(
            w_table, b_table, jnp.asarray(feats), jnp.asarray(slot),
            jnp.asarray(ncls)))
        return {r.item.request_id: int(pred[i])
                for i, r in enumerate(escalate)}

    def drain(self) -> list[ClassifyResponse]:
        """Run ticks until the queue empties (the control plane's quiesce
        step: every pending request is served under the CURRENT config
        before a live transition switches anything)."""
        out: list[ClassifyResponse] = []
        while self.scheduler.qsize:
            out.extend(self.step())
        return out

    def serve(self, requests: list[ClassifyRequest]) -> list[ClassifyResponse]:
        """Submit a burst and run ticks until the queue drains."""
        for req in requests:
            self.submit(req)
        return self.drain()

    def metrics(self) -> dict:
        """The service's aggregate view — every value is a read over the
        flight recorder's registry/ledger (no service-private counters,
        no reservoirs): counters for the totals, the energy ledger for
        joules, and the ONE latency histogram for p50/p99 (the same
        quantile the shed_p99_ms overload check compares against)."""
        o = self.obs
        completed = int(o.responses.total())
        done = max(completed, 1)
        escalated = int(o.responses.value(disposition="escalated"))
        shed = int(o.responses.value(disposition="shed"))
        failed = int(o.responses.value(disposition="expired")
                     + o.responses.value(disposition="error"))
        busy = o.busy_seconds.value()
        ticks = int(o.ticks.value())
        slots = self.scheduler.slots
        energy_j = o.ledger.fleet_j()
        return {
            "submitted": int(o.submitted.value()),
            "completed": completed,
            "rejected": int(o.rejected.value()),
            "failed": failed,
            "escalated": escalated,
            "escalation_rate": round(escalated / done, 4),
            "shed": shed,
            "shed_rate": round(shed / done, 4),
            "load_shed_ticks": int(o.load_shed_ticks.value()),
            "escalation_dispatches": int(o.esc_dispatches.value()),
            "requests_per_s": round(completed / busy, 2) if busy else 0.0,
            "latency_p50_ms": round(o.latency_quantile_ms(0.50), 3),
            "latency_p99_ms": round(o.latency_quantile_ms(0.99), 3),
            "energy_total_j": energy_j,
            "nj_per_request": round(energy_j / done * 1e9, 4),
            "ticks": ticks,
            "classify_dispatches": int(o.dispatches.value()),
            "served": int(o.served.value()),
            "occupancy": round(o.filled_slots.value() / (ticks * slots), 4)
            if ticks else 0.0,
            "min_fill": int(o.fill_min.value()),
            "max_fill": int(o.fill_max.value()),
            "slots": slots,
            "tick_time_s": round(o.tick_seconds.value(), 6),
            "slow_ticks": int(o.slow_ticks.value()),
            "expired": int(o.expired.value()),
        }

    def health(self) -> dict:
        """Liveness view for operators, the chaos harness AND the fleet
        controller: straggler strikes, queue depth, load-shed state — plus
        the autoscaling policy's inputs as first-class fields (per-shard
        registered rows vs capacity, the fused kernel's VMEM row budget
        and the per-shard resident row count against it, rolling batch
        fill, the exact rolling p99, and the ledger's energy split), so
        `repro.fleet.policy.view_of` never reaches into private registry
        state."""
        from repro.kernels import layout
        from repro.match.backends import MAX_FUSED_ROWS

        verdict = self.scheduler.last_verdict or {}
        stats = self.registry.stats()
        rows = self.registry.rows_per_shard
        devices = len(self._devices) if self._devices is not None \
            else len(jax.devices())
        return {
            "queue_depth": self.scheduler.qsize,
            "load_shedding": self.overloaded(),
            "slow_ticks": int(self.obs.slow_ticks.value()),
            "straggler_strikes": {
                int(labels["host"]): int(v)
                for labels, v in self.obs.straggler_strikes.items()},
            "evict_verdict": list(verdict.get("evict", ())),
            # -- fleet-controller inputs (repro.fleet.policy) --
            "tenants": stats["tenants"],
            "bank_shards": stats["bank_shards"],
            "capacity_classes": stats["capacity_classes"],
            "rows_per_shard": rows,
            "shard_rows_used": self.registry.shard_rows_used(),
            # the resident serve kernel holds k_max * padded(rows/shard)
            # template rows in VMEM; past MAX_FUSED_ROWS it falls back to
            # the class-chunked path — headroom is the policy's VMEM signal
            "fused_rows_per_shard":
                self.registry.k_max * layout.padded_classes(rows),
            "vmem_budget_rows": MAX_FUSED_ROWS,
            "rolling_batch_fill": round(self.obs.rolling_batch_fill(), 3),
            "slots": self.scheduler.slots,
            "devices": devices,
            "p99_ms": round(self.obs.latency_quantile_ms(0.99), 4),
            "energy_backend_j": self.obs.ledger.backend_j(),
            "energy_frontend_j": self.obs.ledger.frontend_j(),
        }

    def reset_metrics(self) -> None:
        """Zero the run counters (e.g. after a warmup burst). Exact
        semantics, enforced by a regression test:

        CLEARED    counters (submitted/completed/rejected/..., scheduler
                   tick counters), cumulative latency-histogram counts,
                   the energy ledger, per-run fill aggregates
                   (min/max batch fill), and the scheduler's mirror
                   `SchedulerStats`.
        SURVIVING  gauges (queue depth, shed mode, straggler strikes —
                   they describe the service NOW), the latency
                   histogram's ROLLING window (the shed_p99_ms overload
                   signal: a metrics reset must never blind load
                   shedding), span conservation totals, in-flight spans,
                   the tick-id sequence, straggler-monitor history, and
                   the append-only event log."""
        from repro.serve.scheduler import SchedulerStats

        self.obs.reset()
        self.scheduler.stats = SchedulerStats(slots=self.scheduler.slots)


# ---------------------------------------------------------------------------
# Synthetic tenants (launcher / benchmark / test fixtures)
# ---------------------------------------------------------------------------

def make_synthetic_tenant(
    seed: int, *, num_classes: int = 10, k: int = 1, num_features: int = 64,
    samples_per_class: int = 24, spread: float = 0.6,
) -> tuple[TemplateBank, tuple[np.ndarray, np.ndarray], np.ndarray]:
    """A deterministic per-tenant classifier without training a CNN.

    Draws class prototype feature maps, fits a `TemplateBank` from noisy
    samples around them (the per-device calibration of the wearable
    scenario), and pairs it with the matching nearest-centroid dense head
    ``logits_c = f . p_c - |p_c|^2 / 2`` for the escalation path.

    Returns (bank, (head_w (N, C), head_b (C,)), prototypes (C, N)).
    """
    rng = np.random.RandomState(seed)
    protos = rng.randn(num_classes, num_features).astype(np.float32)
    n = num_classes * samples_per_class
    labels = np.repeat(np.arange(num_classes, dtype=np.int32),
                       samples_per_class)
    feats = protos[labels] + spread * rng.randn(n, num_features).astype(
        np.float32)
    bank = templates.generate_templates(
        jnp.asarray(feats), jnp.asarray(labels), num_classes, k=k)
    head_w = protos.T.astype(np.float32)  # (N, C)
    head_b = (-0.5 * np.sum(protos**2, axis=1)).astype(np.float32)
    return bank, (head_w, head_b), protos


def sample_tenant_queries(
    seed: int, protos: np.ndarray, n: int, *, noise: float = 0.8,
) -> tuple[np.ndarray, np.ndarray]:
    """Draw n query feature maps around a tenant's prototypes.

    `noise` controls how many land near class boundaries (and therefore how
    often the cascade escalates). Returns (features (n, N), labels (n,)).
    """
    rng = np.random.RandomState(seed)
    num_classes, num_features = protos.shape
    labels = rng.randint(0, num_classes, size=n).astype(np.int32)
    feats = protos[labels] + noise * rng.randn(n, num_features).astype(
        np.float32)
    return feats.astype(np.float32), labels
