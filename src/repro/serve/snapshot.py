"""Durable service state: snapshot/restore for the hybrid serving tier.

A `HybridService` is a pile of registers a power cycle would erase: the
registry's super-bank and tenant placements, per-tenant thresholds and
taus, the stacked CNN escalation heads, and the `ServiceSpec` in force.
This module folds ALL of it into one pytree and pushes it through the
existing atomic-rename `repro.checkpoint.Checkpointer`, so a killed
service restarts from its last durable snapshot and serves **bit-identical
predictions, margins and escalation decisions** — the super-bank a
restored scheduler gathers is the same bytes, the taus resolve to the same
values, the heads are the same tables.

Layout: one step directory holds the numpy state as ``.npy`` leaves
(registry arrays + head tables) plus a ``meta`` leaf — the JSON metadata
(spec, tenant placements, runtimes, counters) encoded as a uint8 array so
the whole snapshot rides the checkpointer's one atomicity contract instead
of inventing a second sidecar format.

Restore builds through the spec front door and then adopts the snapshot
state wholesale — `TemplateBankRegistry.load_state` reconstructs
placements without a single `register()` call. Restoring onto a
*different* mesh is the `repro.ft.elastic.remesh_restore` idiom applied to
serving: boot mesh-less from the snapshot's spec, then hand the target
mesh to `HybridService.reconfigure`, which re-packs the super-bank to the
new shard boundaries (elastic shrink/grow as an ordinary reconfigure
transition, bit-identical by the engine's cross-shard reduce contract).

    ckpt = Checkpointer("/var/lib/acam/ckpt")
    svc.snapshot(ckpt)                      # periodic, async-capable
    ...process dies...
    svc, report = HybridService.restore(ckpt)            # same mesh
    svc, report = HybridService.restore(                 # 2 -> 1 shrink
        ckpt, mesh=MeshSpec(bank_shards=1))
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import energy as energy_lib
from repro.serve.spec import MeshSpec, ServiceSpec

_FORMAT = 1


class SnapshotError(RuntimeError):
    """No usable snapshot, or the snapshot does not fit the request."""


@dataclasses.dataclass(frozen=True)
class RestoreReport:
    """What a restore did (and what it cost — the recovery-time number the
    chaos harness tracks)."""

    step: int  # checkpoint step restored from
    spec: ServiceSpec  # spec now in force (post any remesh)
    tenants: int  # placements adopted, zero re-registrations
    restore_s: float  # load -> service ready wall time
    resharded: bool  # True: restored onto a different shard count
    actions: tuple[str, ...]  # reconfigure transition log (remesh path)


def service_state(svc) -> dict:
    """The service's full durable state as one dict pytree (host numpy
    copies only — safe to hand to the async checkpoint writer)."""
    arrays, reg_meta = svc.registry.snapshot_state()
    meta = {
        "format": _FORMAT,
        "spec": svc.spec.to_dict(),
        "registry": reg_meta,
        "tenants": {tid: {"has_head": rt.has_head, "raw_tau": rt.raw_tau}
                    for tid, rt in svc._tenants.items()},
        "next_id": svc._next_id,
        "has_heads": svc._head_w is not None,
    }
    # service-subclass hook (e.g. the semantic cache's response store +
    # template-slot occupancy): JSON-serialisable state that must ride the
    # same atomic snapshot as the registry arrays it indexes into
    extra = getattr(svc, "_extra_snapshot_state", None)
    if extra is not None:
        meta["extra"] = extra()
    tree = {"registry": arrays,
            "meta": np.frombuffer(json.dumps(meta).encode("utf-8"),
                                  dtype=np.uint8).copy()}
    if svc._head_w is not None:
        tree["head_w"] = svc._head_w.copy()
        tree["head_b"] = svc._head_b.copy()
    return tree


def save_snapshot(svc, ckpt: Checkpointer, step: int | None = None, *,
                  blocking: bool = True) -> int:
    """Persist the service through the checkpointer's atomic-rename path.

    ``step=None`` continues the directory's step sequence (a restarted
    service keeps counting from where the last incarnation stopped).
    Returns the step written (or queued, when ``blocking=False``)."""
    if step is None:
        last = ckpt.latest_step()
        step = (max(last if last is not None else -1,
                    getattr(svc, "_last_snapshot_step", -1)) + 1)
    svc._last_snapshot_step = step
    ckpt.save(step, service_state(svc), blocking=blocking)
    return step


def load_state(ckpt: Checkpointer, step: int | None = None
               ) -> tuple[int, dict, dict]:
    """Read a snapshot back: ``(step, meta, tree)``. ``step=None`` picks
    the latest complete step (the atomic-rename contract guarantees a
    published step dir is whole)."""
    if step is None:
        step = ckpt.latest_step()
        if step is None:
            raise SnapshotError(f"no complete snapshot under {ckpt.dir}")
    tree = ckpt.restore_dict(step)
    meta = json.loads(bytes(np.asarray(tree["meta"], np.uint8)).decode())
    if meta.get("format") != _FORMAT:
        raise SnapshotError(f"snapshot format {meta.get('format')!r} != "
                            f"supported {_FORMAT}")
    return step, meta, tree


def restore_service(ckpt: Checkpointer, step: int | None = None, *,
                    mesh: MeshSpec | None = None, cls=None):
    """Rebuild a ready-to-serve `HybridService` from its latest (or a
    given) snapshot. Returns ``(service, RestoreReport)``.

    ``mesh`` restores onto a DIFFERENT mesh than the one snapshotted —
    elastic shrink/grow across a restart (fewer devices after a failure,
    more after repair): the registry state is adopted at the snapshot's
    shard count first, then `reconfigure` re-packs to the target exactly
    like a live reshard would.
    """
    from repro.serve.acam_service import _TenantRuntime
    from repro.serve.control import HybridService

    t0 = time.perf_counter()
    step, meta, tree = load_state(ckpt, step)
    spec = ServiceSpec.from_dict(meta["spec"])

    # boot mesh-less so a target mesh never has to fight the snapshot's:
    # the registry state below is aligned to the SNAPSHOT shard count
    cls = cls or HybridService
    svc = cls.from_spec(spec._replace(mesh=spec.mesh._replace(install=False)))
    svc.registry.load_state(tree["registry"], meta["registry"])
    if meta["has_heads"]:
        svc._head_w = np.array(tree["head_w"], np.float32)
        svc._head_b = np.array(tree["head_b"], np.float32)
        svc._head_gen += 1
        svc._head_cache = None
    svc._next_id = int(meta["next_id"])
    svc._last_snapshot_step = step
    for tid, info in meta["tenants"].items():
        entry = svc.registry.get(tid)  # placement adopted, not re-registered
        svc._tenants[tid] = _TenantRuntime(
            has_head=info["has_head"], raw_tau=info["raw_tau"],
            margin_tau=svc._resolve_tau(info["raw_tau"])
            if info["has_head"] else None,
            backend_j=energy_lib.backend_energy(
                entry.valid_rows, svc.registry.num_features))
    # subclass hook: adopt extra state AFTER the registry + tenants exist
    # (the semantic cache rebuilds its template slots from the adopted
    # registry bytes) and BEFORE any remesh transition moves placements
    adopt = getattr(svc, "_adopt_snapshot_state", None)
    if adopt is not None:
        adopt(meta.get("extra") or {})

    # remesh_restore idiom: the target mesh (the snapshot's own, or the
    # override) is an ordinary reconfigure transition over the restored
    # state — reshard + mesh install + retrace, bit-identical results
    target = spec if mesh is None else spec._replace(mesh=mesh)
    resharded = target.mesh.bank_shards != meta["registry"]["bank_shards"]
    actions: tuple[str, ...] = ()
    if target != svc.spec:
        actions = svc.reconfigure(target).actions
    return svc, RestoreReport(
        step=step, spec=svc.spec, tenants=len(meta["tenants"]),
        restore_s=time.perf_counter() - t0, resharded=resharded,
        actions=actions)
