"""ACAM semantic cache: template routing in front of the LM decode engine.

The paper's whole thesis is an asymmetry — an analogue front stage that
answers most requests for nanojoules so the expensive backend rarely runs
(E_backend = 1.45 nJ vs 78 uJ for the teacher, SS V-D). This module applies
that asymmetry at its most extreme: the expensive backend is not a CNN
head but a whole LM prefill+decode (`repro.serve.engine.Engine`), and the
ACAM tier fronts it as a **semantic cache router**:

    prompt --featurize--> (N,) features --submit--> ACAM micro-batch tick
        ONE fused `classify_serve` dispatch over the per-tenant template
        bank (margin + escalation bit in-kernel, PR-8 mega-kernel)
    confident hit  -> answer from the bounded LRU response store
                      (charged Eq. 14 E_backend only: rows x N x 185 fJ)
    miss           -> escalate to `Engine.generate` decode; the response
                      (and its embedding) is policy-gated admitted back
                      into the bank via the registry's hot `update` —
                      template churn under load, no device-shape change
                      (the bank always spans `router.max_templates` rows)

`SemanticCacheService` subclasses `HybridService`, so the whole fleet
machinery applies unchanged: `from_spec` (with `cascade.backend="lm"`),
live `reconfigure` (including cnn<->lm backend swaps — queued work drains
under the old backend first), `snapshot`/`restore` (the response store and
template-slot occupancy ride the same atomic snapshot as the registry
arrays they index), and the flight recorder (cache hit/miss/insert/evict
counters, a hit-latency vs decode-latency histogram pair, and LM decode
rows in the bit-exact energy ledger via
`repro.core.energy.lm_decode_energy`).

Hit policy: the in-kernel Eq. 12 margin (``margin >= tau``) AND the
winner's *absolute* score against `router.hit_score` x perfect-match. The
margin alone is relative — a one-template bank has no runner-up, so its
margin clamps to the window cap and would always read confident; the
absolute floor is what keeps a half-matching prompt escalating to decode.
Cold banks (all rows invalid) serve margin 0 from the kernel and therefore
always escalate, so a fresh tenant can never fabricate a hit.

Featurizers (prompt -> the matcher's N-feature space):

  * ``hashing`` (default, dependency-free): seeded token uni+bigram
    signatures, one dense Rademacher vector per gram. Identical prompts
    map to identical vectors (exact-duplicate hits are score == N);
    near-duplicates land nearby, unrelated prompts sit at ~N/2 agreement.
  * ``embedding``: mean-pooled model embedding rows through a seeded
    Rademacher projection — the backbone->ACAM-head path of
    `examples/acam_head_for_hubert.py` applied to token prompts.

Determinism contract: with the cache disabled (`router.enabled=False`)
every prompt escalates in admission order through ONE `Engine.generate`
call per tick, so routed outputs are token-identical to `serve.Engine`
alone; with caching on, a hit serves the exact token tuple decode produced
when the template was admitted.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Iterable

import numpy as np

from repro.core import energy as energy_lib
from repro.core.templates import TemplateBank
from repro.serve import engine as engine_lib
from repro.serve.acam_service import (ClassifyRequest, ClassifyResponse,
                                      _TenantRuntime)
from repro.serve.control import HybridService
from repro.serve.scheduler import SlotResult

_MASK = (1 << 64) - 1


def _mix64(h: int, v: int) -> int:
    """One splitmix64 round — deterministic across platforms/processes."""
    h = (h ^ (v & _MASK)) & _MASK
    h = (h * 0xBF58476D1CE4E5B9) & _MASK
    h ^= h >> 27
    h = (h * 0x94D049BB133111EB) & _MASK
    h ^= h >> 31
    return h


def hashing_featurizer(num_features: int, *,
                       seed: int = 0) -> Callable[[np.ndarray], np.ndarray]:
    """Seeded token n-gram signatures over ``num_features`` buckets.

    Every unigram and bigram contributes a DENSE Rademacher (+-1) vector
    keyed by its splitmix64 hash; the prompt signature is their sum,
    binarised downstream by the zero thresholds. Dense, not sparse-probe,
    on purpose: the matcher's feature_count scoring counts agreeing 0-bits
    too, so a sparse scheme lets two short unrelated prompts agree on all
    the buckets neither touched — straight past the hit_score floor.
    Dense sums put unrelated prompts at ~N/2 agreement (binomial, far
    below the 0.9N floor) while identical prompts agree exactly. The gram
    count 2S-1 is odd, so no bucket ever sums to a 0/1-ambiguous zero."""
    base = _mix64(0x9E3779B97F4A7C15, seed)

    def featurize(tokens) -> np.ndarray:
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        v = np.zeros((num_features,), np.float32)
        grams: list[tuple[int, ...]] = [(t,) for t in toks]
        grams += list(zip(toks, toks[1:]))
        for g in grams:
            h = _mix64(base, len(g))
            for t in g:
                h = _mix64(h, t + 1)
            rng = np.random.default_rng(h)  # Philox: platform-stable
            v += rng.integers(0, 2, num_features).astype(np.float32) * 2 - 1
        return v

    return featurize


def embedding_featurizer(embed_table: np.ndarray, *, num_features: int,
                         seed: int = 0) -> Callable[[np.ndarray], np.ndarray]:
    """Mean-pool the model's own embedding rows, then a seeded Rademacher
    projection (d_model -> N): the backbone->ACAM-head idiom for prompts.
    ``embed_table`` is the LM's (vocab, d_model) embedding matrix (e.g.
    ``engine.params["embed"]``)."""
    table = np.asarray(embed_table, np.float32)
    rng = np.random.default_rng(seed)
    proj = rng.choice(np.float32([-1.0, 1.0]),
                      size=(table.shape[1], num_features))

    def featurize(tokens) -> np.ndarray:
        toks = np.asarray(tokens, np.int64).reshape(-1)
        pooled = table[toks].mean(axis=0)
        return (pooled @ proj).astype(np.float32)

    return featurize


@dataclasses.dataclass(frozen=True)
class PromptRequest:
    """One LM request as the router sees it."""

    tenant_id: str
    prompt: np.ndarray  # (S,) int32 token ids
    max_new_tokens: int = 16
    eos_id: int | None = None


@dataclasses.dataclass(frozen=True)
class RoutedResponse:
    request_id: int
    tenant_id: str
    tokens: tuple[int, ...]  # generated tokens (cached or fresh decode)
    cache_hit: bool
    template_id: int  # tenant-local bank row served / admitted; -1 none
    margin: float  # Eq. 12 margin at the match stage
    score: float  # winner's absolute match score (native units)
    energy_j: float  # E_backend (+ per-token decode energy on a miss)
    latency_s: float  # submit -> response wall time
    error: str | None = None


class ResponseStore:
    """Bounded global-LRU store of decoded responses, keyed
    ``(tenant_id, template_row)``. Eviction is reported to the service so
    the invariant *a valid template row always has a stored response*
    holds — a matched template whose response vanished would otherwise
    serve nothing."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._d: OrderedDict[tuple[str, int], tuple[int, ...]] = OrderedDict()

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: tuple[str, int]) -> tuple[int, ...] | None:
        """LRU-refreshing read."""
        toks = self._d.get(key)
        if toks is not None:
            self._d.move_to_end(key)
        return toks

    def put(self, key: tuple[str, int],
            tokens: tuple[int, ...]) -> list[tuple[str, int]]:
        """Insert/replace; returns the keys evicted by capacity pressure."""
        self._d[key] = tuple(int(t) for t in tokens)
        self._d.move_to_end(key)
        evicted = []
        while len(self._d) > self.capacity:
            evicted.append(self._d.popitem(last=False)[0])
        return evicted

    def pop(self, key: tuple[str, int]) -> None:
        self._d.pop(key, None)

    def oldest_row(self, tenant_id: str) -> int | None:
        """The tenant's least-recently-used template row (its in-bank LRU
        victim when the bank is full)."""
        for (tid, row) in self._d:
            if tid == tenant_id:
                return row
        return None

    def state(self) -> list:
        """JSON-serialisable state, oldest-first — `load_state` replays it
        in order, so the LRU order round-trips exactly."""
        return [[tid, int(row), list(toks)]
                for (tid, row), toks in self._d.items()]

    def load_state(self, entries: list) -> None:
        self._d.clear()
        for tid, row, toks in entries:
            self._d[(str(tid), int(row))] = tuple(int(t) for t in toks)


@dataclasses.dataclass
class _TemplateSlots:
    """Host mirror of one cache tenant's bank occupancy (the registry's
    packed arrays hold the same bytes; this keeps the per-row bookkeeping
    O(max_templates) without slicing the super-bank)."""

    bits: np.ndarray  # (C, N) float32 {0,1} binarised embeddings
    valid: np.ndarray  # (C,) bool


class SemanticCacheService(HybridService):
    """`HybridService` with the LM decode engine as the cascade backend.

    Build with ``cascade.backend="lm"`` and attach the expensive backend:

        spec = ServiceSpec(cascade=CascadeSpec(backend="lm", tau=8.0),
                           router=RouterSpec(max_templates=32))
        svc = SemanticCacheService.from_spec(spec, engine=engine)
        svc.add_tenant("edge-0")
        svc.submit_prompt(PromptRequest("edge-0", prompt_tokens))
        (resp,) = svc.step_routed()

    The engine (model params) is deliberately NOT serialised in snapshots;
    `restore(...)` rebuilds the router state bit-identically and the
    engine is re-attached — restored hits serve without any engine at all.
    """

    # -- construction -------------------------------------------------------

    @classmethod
    def from_spec(cls, spec, *, engine: engine_lib.Engine | None = None,
                  featurizer=None) -> "SemanticCacheService":
        svc = super().from_spec(spec)
        svc.attach_backend(engine, featurizer=featurizer)
        return svc

    def _build(self, spec) -> None:
        super()._build(spec)
        self._store = ResponseStore(spec.router.response_capacity)
        self._templates: dict[str, _TemplateSlots] = {}
        self._jobs: dict[int, PromptRequest] = {}
        self._decoded: dict[int, tuple[int, ...]] = {}
        self._decode_j: dict[int, float] = {}
        self._backend_engine: engine_lib.Engine | None = None
        self._featurize = None
        self._active_params = 0

    def attach_backend(self, engine: engine_lib.Engine | None, *,
                       featurizer=None) -> None:
        """Attach (or re-attach, after restore) the decode engine and the
        prompt featurizer. ``featurizer=None`` builds the spec's choice:
        "hashing" needs nothing; "embedding" pulls the embedding table off
        the engine's params (and therefore needs the engine)."""
        self._backend_engine = engine
        if engine is not None:
            self._active_params = engine.cfg.active_param_count()
        n = self.registry.num_features
        rtr = self.spec.router
        if featurizer is not None:
            self._featurize = featurizer
        elif rtr.featurizer == "embedding":
            # needs the embedding table: defer until an engine arrives
            # (restore boots engine-less first, then re-attaches)
            self._featurize = None if engine is None else \
                embedding_featurizer(
                    np.asarray(engine.params["embed"]), num_features=n,
                    seed=rtr.featurizer_seed)
        else:
            self._featurize = hashing_featurizer(n, seed=rtr.featurizer_seed)

    def _apply_cascade(self, spec) -> None:
        super()._apply_cascade(spec)
        # reconfigure path: capacity changes apply lazily (next put evicts
        # down); guard because the base _build calls this before the
        # router containers exist
        if hasattr(self, "_store"):
            self._store.capacity = spec.router.response_capacity

    # -- tenant lifecycle ---------------------------------------------------

    def add_tenant(self, tenant_id: str, *,
                   margin_tau: float | None = None) -> None:
        """Register a cache tenant: a `router.max_templates`-row bank
        (k = 1), every row invalid — everything escalates until the first
        admission. `has_head=True` marks the escalation path live (the
        "head" is the attached decode engine, not a (W, b) table)."""
        rtr = self.spec.router
        n = self.registry.num_features
        slots = _TemplateSlots(
            bits=np.zeros((rtr.max_templates, n), np.float32),
            valid=np.zeros((rtr.max_templates,), bool))
        entry = self.registry.register(tenant_id, self._as_bank(slots))
        self._templates[tenant_id] = slots
        self._tenants[tenant_id] = _TenantRuntime(
            has_head=True, raw_tau=margin_tau,
            margin_tau=self._resolve_tau(margin_tau),
            backend_j=energy_lib.backend_energy(entry.valid_rows, n))

    def evict_tenant(self, tenant_id: str) -> None:
        super().evict_tenant(tenant_id)
        if tenant_id in self._templates:
            del self._templates[tenant_id]
            for key in [k for k in self._store._d if k[0] == tenant_id]:
                self._store.pop(key)

    def _as_bank(self, slots: _TemplateSlots) -> TemplateBank:
        t = slots.bits[:, None, :]  # (C, 1, N) — k = 1 bit-signatures
        return TemplateBank(
            templates=t, lower=t, upper=t,
            valid=slots.valid[:, None],
            thresholds=np.zeros((slots.bits.shape[1],), np.float32))

    def _sync_bank(self, tenant_id: str) -> None:
        """Push a tenant's host template slots into the registry's packed
        arrays (hot `update`: the bank always spans max_templates rows, so
        it re-uses its allocated range — no device-shape change, the jitted
        tick stays hot) and refresh the Eq. 14 row-count energy."""
        slots = self._templates[tenant_id]
        entry = self.registry.update(tenant_id, self._as_bank(slots))
        rt = self._tenants[tenant_id]
        rt.backend_j = energy_lib.backend_energy(
            entry.valid_rows, self.registry.num_features)

    # -- request path -------------------------------------------------------

    def submit_prompt(self, req: PromptRequest) -> int:
        """Featurize + admit one LM request; returns the request id."""
        if req.tenant_id not in self._templates:
            raise ValueError(f"{req.tenant_id!r} is not a cache tenant "
                             "(add_tenant first)")
        if self._featurize is None:
            raise RuntimeError(
                'router.featurizer="embedding" derives its projection from '
                "the engine's embedding table — attach_backend(engine) "
                "first (or pass an explicit featurizer)")
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        feats = np.asarray(self._featurize(prompt), np.float32)
        rid = self.submit(ClassifyRequest(tenant_id=req.tenant_id,
                                          features=feats))
        self._jobs[rid] = dataclasses.replace(req, prompt=prompt)
        return rid

    def _score_floor(self) -> float | None:
        hs = self.spec.router.hit_score
        if hs is None:
            return None
        cap = 1.0 if self.spec.native_tau_units == "fraction" \
            else float(self.registry.num_features)
        return hs * cap

    def _wants_escalation(self, r: SlotResult) -> bool:
        if r.item.request_id not in self._jobs:
            return super()._wants_escalation(r)  # plain classify traffic
        if not self.spec.router.enabled:
            return True  # shadow mode: every prompt decodes
        if r.escalate:  # in-kernel margin < tau
            return True
        floor = self._score_floor()
        return floor is not None and r.score < floor

    def _frontend_cost(self, request_id: int) -> float:
        cost = self._decode_j.get(request_id)
        return cost if cost is not None else super()._frontend_cost(
            request_id)

    def _run_escalation(self, escalate: list[SlotResult]) -> dict[int, int]:
        """Route a tick's escalations: job-backed slots (LM prompts) decode
        through ONE `Engine.generate` call in slot order; anything else
        (classify tenants sharing the service) falls through to the base
        dense-head dispatch."""
        misses = [r for r in escalate if r.item.request_id in self._jobs]
        rest = [r for r in escalate if r.item.request_id not in self._jobs]
        out: dict[int, int] = {}
        if rest:
            out.update(super()._run_escalation(rest))
        if not misses:
            return out
        if self._backend_engine is None:
            raise RuntimeError(
                'cascade.backend="lm" escalation needs a decode engine: '
                "SemanticCacheService.from_spec(spec, engine=...) or "
                "attach_backend(engine)")
        jobs = [self._jobs[r.item.request_id] for r in misses]
        reqs = [engine_lib.Request(prompt=j.prompt,
                                   max_new_tokens=j.max_new_tokens,
                                   eos_id=j.eos_id) for j in jobs]
        self._backend_engine.generate(reqs)
        rtr = self.spec.router
        paper = self.spec.cascade.paper_faithful
        for r, job, req in zip(misses, jobs, reqs):
            rid = r.item.request_id
            tokens = tuple(int(t) for t in req.out)
            self._decoded[rid] = tokens
            self._decode_j[rid] = energy_lib.lm_decode_energy(
                self._active_params, len(job.prompt) + len(tokens),
                paper_faithful=paper)
            row = -1
            if rtr.enabled and rtr.admit_on_miss:
                row = self._admit(job.tenant_id, r.item.features, tokens)
            out[rid] = row
        return out

    def _admit(self, tenant_id: str, feats: np.ndarray,
               tokens: tuple[int, ...]) -> int:
        """Admit one miss back into the bank: pick a free row (else the
        tenant's LRU row), write the binarised embedding, store the
        response, and invalidate any template whose response the store's
        capacity pressure pushed out — atomically from the service's view
        (all before the next tick gathers the bank)."""
        slots = self._templates[tenant_id]
        bits = (feats > 0.0).astype(np.float32)
        # dedupe: a tick batches several misses of the SAME prompt (each
        # matched before any was admitted); admitting each would write
        # identical rows whose tied margin (0) escalates every later exact
        # match forever. Refresh the existing row's response instead.
        dup = np.flatnonzero(slots.valid & (slots.bits == bits).all(axis=1))
        if dup.size:
            row = int(dup[0])
            self._store.put((tenant_id, row), tokens)
            return row
        free = np.flatnonzero(~slots.valid)
        if free.size:
            row = int(free[0])
        else:
            row = self._store.oldest_row(tenant_id)
            if row is None:  # unreachable under the store invariant
                row = 0
            self.obs.record_cache_event("evict")
        slots.bits[row] = bits
        slots.valid[row] = True
        dirty = {tenant_id}
        for etid, erow in self._store.put((tenant_id, row), tokens):
            esl = self._templates.get(etid)
            if esl is not None and esl.valid[erow]:
                esl.valid[erow] = False
                esl.bits[erow] = 0.0
                dirty.add(etid)
                self.obs.record_cache_event("evict")
        for tid in dirty:
            self._sync_bank(tid)
        self.obs.record_cache_event("insert")
        return row

    # -- response assembly --------------------------------------------------

    def collect_routed(self,
                       responses: list[ClassifyResponse]
                       ) -> list[RoutedResponse]:
        """Fold classify responses back onto their prompt jobs: hits read
        the response store (LRU-refreshing), misses take the fresh decode.
        Non-prompt responses (classify traffic sharing the service) pass
        through untouched by this method — route them normally."""
        out: list[RoutedResponse] = []
        for resp in responses:
            job = self._jobs.pop(resp.request_id, None)
            if job is None:
                continue
            tokens = self._decoded.pop(resp.request_id, None)
            self._decode_j.pop(resp.request_id, None)
            base = dict(request_id=resp.request_id,
                        tenant_id=resp.tenant_id, margin=resp.margin,
                        score=resp.score, energy_j=resp.energy_j,
                        latency_s=resp.latency_s)
            if resp.error is not None:
                out.append(RoutedResponse(tokens=(), cache_hit=False,
                                          template_id=-1, error=resp.error,
                                          **base))
                continue
            if resp.escalated:
                self.obs.record_cache_event("miss")
                self.obs.record_cache_latency(False, resp.latency_s)
                out.append(RoutedResponse(tokens=tokens, cache_hit=False,
                                          template_id=resp.pred, **base))
                continue
            stored = self._store.get((resp.tenant_id, resp.pred))
            if stored is None:  # store invariant breach — answer honestly
                out.append(RoutedResponse(
                    tokens=(), cache_hit=False, template_id=resp.pred,
                    error="matched template has no stored response",
                    **base))
                continue
            self.obs.record_cache_event("hit")
            self.obs.record_cache_latency(True, resp.latency_s)
            out.append(RoutedResponse(tokens=stored, cache_hit=True,
                                      template_id=resp.pred, **base))
        return out

    def step_routed(self) -> list[RoutedResponse]:
        """One scheduler tick, returned as routed LM responses."""
        return self.collect_routed(self.step())

    def serve_prompts(self,
                      requests: Iterable[PromptRequest]
                      ) -> list[RoutedResponse]:
        """Submit a burst of prompts and run ticks until the queue drains
        (admission order == service order; the replayed-trace idiom the
        bit-identity tests assert on)."""
        for req in requests:
            self.submit_prompt(req)
        out: list[RoutedResponse] = []
        while self.scheduler.qsize:
            out.extend(self.step_routed())
        return out

    # -- durability ---------------------------------------------------------

    def _extra_snapshot_state(self) -> dict:
        """Router state riding the service snapshot: the response store in
        LRU order (token tuples are exact ints — bit-identical round-trip)
        and the cache-tenant set. Template bits/validity are NOT
        duplicated: the registry arrays in the same snapshot already hold
        those bytes, and `_adopt_snapshot_state` reads them back."""
        return {"router": {
            "store": self._store.state(),
            "tenants": sorted(self._templates),
        }}

    def _adopt_snapshot_state(self, extra: dict) -> None:
        router = (extra or {}).get("router")
        if not router:
            return
        self._store.load_state(router["store"])
        for tid in router["tenants"]:
            bank = self.registry.bank_of(tid)
            self._templates[tid] = _TemplateSlots(
                bits=np.asarray(bank.templates[:, 0], np.float32).copy(),
                valid=np.asarray(bank.valid[:, 0], bool).copy())

    @classmethod
    def restore(cls, ckpt, step: int | None = None, *, mesh=None,
                engine: engine_lib.Engine | None = None, featurizer=None):
        """`HybridService.restore` + router re-attachment. The engine is
        never serialised — pass it back in (or later via
        `attach_backend`); hits serve with no engine at all."""
        svc, report = super().restore(ckpt, step, mesh=mesh)
        svc.attach_backend(engine, featurizer=featurizer)
        return svc, report


def synthetic_prompt_trace(seed: int, *, vocab: int, n_unique: int,
                           n_requests: int, min_len: int = 8,
                           max_len: int = 16,
                           zipf_a: float = 1.2) -> list[np.ndarray]:
    """Deterministic Zipf-repeat prompt trace for benches/examples: the
    first ``n_unique`` requests are the distinct prompts (all cold
    misses), the remaining ``n_requests - n_unique`` replay them with
    Zipf(a) popularity — so a bank holding ``n_unique`` templates serves
    exactly ``1 - n_unique/n_requests`` of the trace from cache."""
    if not 1 <= n_unique <= n_requests:
        raise ValueError(f"need 1 <= n_unique <= n_requests, got "
                         f"{n_unique}/{n_requests}")
    rng = np.random.default_rng(seed)
    pool = []
    for _ in range(n_unique):
        length = int(rng.integers(min_len, max_len + 1))
        pool.append(rng.integers(0, vocab, size=length).astype(np.int32))
    weights = 1.0 / np.arange(1, n_unique + 1, dtype=np.float64) ** zipf_a
    weights /= weights.sum()
    trace = list(pool)
    repeats = rng.choice(n_unique, size=n_requests - n_unique, p=weights)
    trace += [pool[i] for i in repeats]
    return trace
