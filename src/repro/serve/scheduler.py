"""Continuous micro-batching scheduler for the multi-tenant ACAM service.

Requests from *any* tenant are coalesced into fixed-slot micro-batches and
served by ONE fused classify dispatch per tick:

    tick:  pop <= slots requests (FIFO across tenants)
           -> one `repro.match.MatchEngine.classify_serve` call over the
              registry's super-bank: the per-slot tenant threshold-row
              gather, binarisation, match, per-slot class-window Eq. 12
              decision + margin AND the cascade's ``margin < tau``
              escalation bit — on the kernel backend all of it is ONE
              resident pallas_call (`acam_*_serve`), with no jnp prologue
              or epilogue. Executed under the engine's 2D PartitionPlan
              when a mesh is installed: slots shard over the dp axes, the
              super-bank's class rows over the model axis (the registry
              aligns tenant windows to those shards), and the per-slot
              winner/margin come from the engine's cross-shard
              (max, argmax) reduce (all-gather fold or XOR-butterfly tree,
              `plan.reduce`) — bit-identical to replicated execution,
              still ONE dispatch
           -> per-slot tenant-local predictions + margins + escalate bits

The batch shape is pinned to ``slots`` (ragged tails are padded with empty
class windows, which the kernel resolves to pred 0 / margin 0 and the
scheduler drops), and the super-bank's shapes are bucketed by the registry —
so the jitted tick function compiles once and stays hot across tenant
churn. Batch-fill statistics are recorded per tick so coalescing quality is
observable (`SchedulerStats.occupancy`).

The scheduler's cascade knowledge is one number per slot: the service
installs a ``tau_fn`` (tenant id -> margin threshold, None = no head) and
each `SlotResult` comes back with the in-kernel `escalate` bit; the service
layer (`repro.serve.acam_service`) still owns the routing itself. It does own two resilience duties:
`expire()` pops requests that outlived the cascade's per-request deadline
(the FIFO prefix), and every tick's wall time heartbeats into a
`repro.ft.elastic.StragglerMonitor` — slow-tick strikes are surfaced
through `SchedulerStats.slow_ticks` / `last_verdict` so the control plane
can shed load or shrink the mesh before latency collapses.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import match as match_lib
from repro.ft.elastic import StragglerMonitor
from repro.serve.registry import TemplateBankRegistry, TenantEntry


@dataclasses.dataclass
class WorkItem:
    """One admitted classification request, as the scheduler sees it.

    Holds only the tenant *id*: the placement (`TenantEntry`) is resolved
    against the registry at tick time, so hot update/evict between submit
    and dispatch can never serve a request against a stale class window.
    """

    request_id: int
    tenant_id: str
    features: np.ndarray  # (N,) float32, raw front-end features
    submit_t: float
    payload: Any = None  # opaque service-side context (head slot, tau, ...)


@dataclasses.dataclass
class SlotResult:
    """Scheduler output for one served request."""

    item: WorkItem
    entry: TenantEntry | None  # placement at dispatch time; None on error
    pred_local: int  # tenant-local class id (global - tenant offset)
    margin: float  # Eq. 12 winner-vs-runner-up confidence margin
    error: str | None = None  # e.g. tenant evicted while queued
    escalate: bool = False  # in-kernel margin < tau(tenant) cascade bit
    #: winner's absolute per-class score in the backend's native units
    #: (match count 0..N, or matchline fraction 0..1). The margin above is
    #: relative — a one-row class window clamps it to the cap — so absolute
    #: acceptance floors (the semantic cache's hit_score) read this.
    score: float = 0.0


@dataclasses.dataclass
class SchedulerStats:
    slots: int = 0
    ticks: int = 0
    classify_dispatches: int = 0
    served: int = 0
    filled_slots: int = 0
    min_fill: int | None = None
    max_fill: int = 0
    tick_time_s: float = 0.0  # summed dispatch wall time
    slow_ticks: int = 0  # ticks flagged by the straggler monitor
    expired: int = 0  # requests expired past their queue deadline

    def record_tick(self, fill: int, *, dt_s: float = 0.0,
                    slow: bool = False) -> None:
        self.ticks += 1
        self.classify_dispatches += 1
        self.served += fill
        self.filled_slots += fill
        self.max_fill = max(self.max_fill, fill)
        self.min_fill = fill if self.min_fill is None else \
            min(self.min_fill, fill)
        self.tick_time_s += dt_s
        self.slow_ticks += int(slow)

    @property
    def occupancy(self) -> float:
        """Mean batch fill fraction across ticks (1.0 = every slot used)."""
        if self.ticks == 0:
            return 0.0
        return self.filled_slots / (self.ticks * self.slots)

    def as_dict(self) -> dict:
        return {
            "ticks": self.ticks,
            "classify_dispatches": self.classify_dispatches,
            "served": self.served,
            "occupancy": round(self.occupancy, 4),
            "min_fill": self.min_fill or 0,
            "max_fill": self.max_fill,
            "slots": self.slots,
            "tick_time_s": round(self.tick_time_s, 6),
            "slow_ticks": self.slow_ticks,
            "expired": self.expired,
        }


@functools.partial(jax.jit, static_argnames=("config", "mesh_gen"))
def _batched_classify(bank, thr_table, feats, tenant_slot, class_lo, class_hi,
                      tau, *, config, mesh_gen: int):
    """The whole tick on device: ONE `MatchEngine.classify_serve` dispatch
    over the multi-tenant super-bank — the per-slot threshold-row gather,
    binarisation, windowed Eq. 12 decision/margin and the ``margin < tau``
    escalation bit included (a single pallas_call on the kernel backend
    under ``serve_fusion="mega"``).

    ``config`` is the full `repro.match.EngineConfig`, a *static* argument
    resolved eagerly by `tick()` (never the process default read at trace
    time), so switching backends — or any other engine knob, e.g. the
    device-physics noise config of a spec-built service or the mega/compose
    serve fusion — between ticks re-traces instead of replaying a stale
    executable. ``mesh_gen`` (`distributed.context.generation()`, also
    static) does the same for the mesh: the engine bakes its
    `PartitionPlan` — batch over the dp axes, super-bank class rows over
    the model axis — into this trace, and installing a different mesh
    between ticks keys a fresh executable instead of silently replaying the
    stale layout."""
    del mesh_gen  # cache key only: a new mesh generation forces a re-trace
    eng = match_lib.engine_from_config(config)
    return eng.classify_serve(feats, thr_table, tenant_slot, bank, class_lo,
                              class_hi, tau)


class MicroBatchScheduler:
    """Fixed-slot continuous micro-batching over a `TemplateBankRegistry`.

    The matching setup is ONE `repro.match.EngineConfig` (`engine`, the
    spec path: `ServiceSpec.engine` is passed through verbatim). The
    legacy keyword surface (`method`/`alpha`/`backend`) still works and
    builds the same config; `backend=None` keeps its historical meaning —
    re-resolve the process default at every tick.
    """

    def __init__(self, registry: TemplateBankRegistry, *, slots: int = 64,
                 method: str = "feature_count", alpha: float = 1.0,
                 backend: str | None = None,
                 engine: match_lib.EngineConfig | None = None,
                 monitor: StragglerMonitor | None = None,
                 recorder=None):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.registry = registry
        self.slots = slots
        if engine is not None:
            self.engine_config = engine
            self.backend = engine.backend
        else:
            self.engine_config = match_lib.EngineConfig(
                method=method, alpha=alpha, backend=backend or "auto",
                margin=True)
            self.backend = backend
        self.stats = SchedulerStats(slots=slots)
        #: every tick's wall time heartbeats into this monitor
        #: (`StragglerMonitor.observe`): a tick blowing past the rolling
        #: median accrues strikes, surfaced via stats.slow_ticks and
        #: `last_verdict` — the service's health() view.
        self.monitor = monitor if monitor is not None else StragglerMonitor(
            n_hosts=1)
        self.last_verdict: dict | None = None
        #: optional `repro.obs.FlightRecorder`: the scheduler stamps every
        #: dispatched request's span with the tick id / dequeue time and
        #: feeds the registry's scheduler counters. `SchedulerStats` stays
        #: as a plain in-object mirror (cheap, and directly inspectable).
        self.recorder = recorder
        #: optional tenant_id -> margin threshold (float | None). Installed
        #: by the service layer; feeds the per-slot ``tau`` operand so the
        #: cascade's ``margin < tau`` compare runs inside the serve kernel.
        #: None (or a None return) pins tau to -inf: never escalate.
        self.tau_fn = None
        self._queue: deque[WorkItem] = deque()

    @property
    def method(self) -> str:
        return self.engine_config.method

    @property
    def alpha(self) -> float:
        return self.engine_config.alpha

    def set_engine(self, engine: match_lib.EngineConfig) -> None:
        """Live engine swap (the control plane's backend transition): the
        next tick dispatches under the new config — a fresh static jit key,
        so it re-traces instead of replaying the old executable."""
        self.engine_config = engine
        self.backend = engine.backend

    @property
    def qsize(self) -> int:
        return len(self._queue)

    def submit(self, item: WorkItem) -> None:
        self._queue.append(item)

    def expire(self, deadline_s: float,
               now: float | None = None) -> list[WorkItem]:
        """Pop queued items older than ``deadline_s`` (the cascade's
        per-request deadline). The queue is FIFO, so expired items are a
        prefix; the service answers them with a deadline error instead of
        serving them uselessly late."""
        now = time.perf_counter() if now is None else now
        out: list[WorkItem] = []
        while self._queue and now - self._queue[0].submit_t > deadline_s:
            out.append(self._queue.popleft())
        self.stats.expired += len(out)
        if out and self.recorder is not None:
            self.recorder.record_expired(len(out))
        return out

    def tick(self) -> list[SlotResult]:
        """Serve one micro-batch; returns [] when the queue is empty.

        Contract the double-buffered reshard (`repro.fleet.reshard`)
        leans on: every tick re-resolves placements via
        `registry.lookup` and re-reads `device_bank()` /
        `thresholds_table()` (generation-cached), and queued `WorkItem`s
        hold only tenant ids — so swapping the registry's arrays +
        offsets BETWEEN two ticks is invisible to queued work, and a
        bank flip needs no drain."""
        if not self._queue:
            return []
        t0 = time.perf_counter()
        popped = [self._queue.popleft()
                  for _ in range(min(self.slots, len(self._queue)))]
        # resolve placements NOW: queued requests must see the tenant's
        # current class window, not the one from submit time
        dead = []
        batch: list[tuple[WorkItem, TenantEntry]] = []
        for item in popped:
            entry = self.registry.lookup(item.tenant_id)
            if entry is None:
                dead.append(SlotResult(
                    item=item, entry=None, pred_local=-1, margin=0.0,
                    error=f"tenant {item.tenant_id!r} evicted while queued"))
            else:
                batch.append((item, entry))
        if not batch:
            return dead
        n = self.registry.num_features

        feats = np.zeros((self.slots, n), np.float32)
        slot_idx = np.zeros((self.slots,), np.int32)
        lo = np.zeros((self.slots,), np.int32)
        hi = np.zeros((self.slots,), np.int32)  # padding: empty window [0, 0)
        tau = np.full((self.slots,), -np.inf, np.float32)  # never escalate
        for i, (item, entry) in enumerate(batch):
            feats[i] = item.features
            slot_idx[i] = entry.slot
            lo[i], hi[i] = entry.window
            if self.tau_fn is not None:
                t = self.tau_fn(item.tenant_id)
                if t is not None:
                    tau[i] = t

        from repro.distributed import context

        cfg = self.engine_config._replace(
            backend=self.backend or match_lib.default_backend())
        annotate = self.recorder.profile_span("acam_fused_dispatch") \
            if self.recorder is not None else contextlib.nullcontext()
        with annotate:
            pred, per_class, margin, esc = _batched_classify(
                self.registry.device_bank(),
                self.registry.thresholds_table(),
                jnp.asarray(feats), jnp.asarray(slot_idx), jnp.asarray(lo),
                jnp.asarray(hi), jnp.asarray(tau), config=cfg,
                mesh_gen=context.generation())
            pred = np.asarray(pred)
            per_class = np.asarray(per_class)  # logically (slots, C_cap)
            margin = np.asarray(margin)
            esc = np.asarray(esc)
        dt = time.perf_counter() - t0
        self.last_verdict = self.monitor.observe(0, dt)
        slow = bool(self.last_verdict["stragglers"])
        self.stats.record_tick(len(batch), dt_s=dt, slow=slow)
        if self.recorder is not None:
            self.recorder.record_tick_dispatch(
                [item.request_id for item in popped], len(batch), dt, slow,
                t0)

        # winner's absolute score: per_class is logically (slots, C_cap)
        # under every plan, so per_class[i, pred[i]] is uniform. An empty
        # window's pred is 0 and its score -inf; clamp to 0.0 (no match).
        score = per_class[np.arange(len(batch)), pred[:len(batch)]]
        score = np.where(np.isfinite(score), score, 0.0)
        return dead + [
            SlotResult(item=item, entry=entry,
                       pred_local=int(pred[i]) - entry.offset,
                       margin=float(margin[i]), escalate=bool(esc[i]),
                       score=float(score[i]))
            for i, (item, entry) in enumerate(batch)]

    def drain(self) -> list[SlotResult]:
        out: list[SlotResult] = []
        while self._queue:
            out.extend(self.tick())
        return out
