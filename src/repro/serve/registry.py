"""Multi-tenant ACAM template-bank registry (the serving super-bank).

The wearable scenario (per-device calibrated templates — see PAPERS.md) puts
one small `TemplateBank` per tenant on the server. Serving them one kernel
launch per tenant would waste the fused classify kernel's batching, so the
registry **pads and stacks** every tenant's bank into ONE device-resident
super-bank:

  * tenant classes occupy a contiguous row range ``[offset, offset + C)``
    of a shared ``(C_cap, K_max, N)`` bank — the scheduler restricts each
    request's Eq. 12 decision to its tenant's range via the class-window
    margins kernel (`repro.kernels.acam_match.ops.classify_fused_margins`);
  * per-tenant binarisation thresholds live in a ``(T_cap, N)`` table; the
    scheduler gathers each slot's row and *shifts the query features* so one
    shared zero-threshold binarisation serves every tenant in the batch;
  * **bucketed shapes**: class ranges are allocated in ``class_bucket``
    units and capacities (``C_cap``, ``T_cap``) only ever grow by doubling,
    so hot register / update / evict leave the device arrays' shapes — and
    therefore every jitted caller's trace cache — untouched in the steady
    state. A capacity grow is the only (rare) retrace event.

Host-side numpy mirrors hold the authoritative state; device arrays are
rebuilt lazily (`device_bank`, `thresholds_table`) and cached per
`generation`, so an unchanged registry never re-uploads and the scheduler's
"one bank gather per tick" stays a gather, not a transfer.

The fused margins kernel keeps all ``K_max * padded_classes(C_cap)``
template rows VMEM-resident; past `repro.match.MAX_FUSED_ROWS` the kernel
backend switches to the class-chunked margins kernel — same semantics,
still one dispatch per tick. The scheduler's dispatch routes through
`repro.match.MatchEngine`, so the same super-bank also serves the
`reference` and `device` (RRAM-physics) backends and executes under the
engine's 2D `PartitionPlan` when a mesh is installed: the batch shards over
the data-parallel axes and the super-bank's class rows shard over the
model axis.

Bank sharding is why the registry is **shard-aligned**: constructed with
``bank_shards=S`` (the spec path passes `ServiceSpec.mesh.bank_shards`
explicitly; the legacy service shim infers it from the installed mesh via
`repro.match.bank_shards_in_mesh`), capacity stays divisible by S and the
allocator never places a tenant's bucket run across a shard boundary —
every tenant's Eq. 12 class window lives on ONE device, so a request's
scores come from a single shard and only the tiny (max, argmax) reduce
crosses devices. Per-shard padding rows keep ``valid = False`` and are
driven to -inf before the WTA, exactly like bucket padding. Capacity grows
by doubling, which doubles the shard row count: old shard boundaries are a
superset of the new ones, so existing placements stay aligned. `reshard`
re-packs every bucket run to NEW shard boundaries in place (live
resharding, driven by `HybridService.reconfigure`) — tenants keep their
ids, slots, thresholds and template rows; only offsets move.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.templates import TemplateBank


class RegistryError(ValueError):
    """Raised for invalid register/update/evict operations."""


@dataclasses.dataclass(frozen=True)
class TenantEntry:
    """Immutable snapshot of a tenant's placement in the super-bank."""

    tenant_id: str
    slot: int  # row in the thresholds (and service head) tables
    offset: int  # first class row in the super-bank
    num_classes: int  # true class count
    c_bucket: int  # allocated (bucketed) class rows
    k: int  # true templates-per-class
    valid_rows: int  # programmed template rows (ACAM energy, Eq. 14)
    generation: int  # registry generation at (re)registration

    @property
    def window(self) -> tuple[int, int]:
        """The tenant's Eq. 12 class window [lo, hi) in the super-bank."""
        return self.offset, self.offset + self.num_classes


@dataclasses.dataclass
class PreparedBank:
    """A shadow super-bank built alongside the live one (`prepare_reshard`):
    fresh host arrays re-packed to new shard boundaries plus the tenant
    placements that go with them. `adopt_prepared` flips the registry to
    this buffer between scheduler ticks; `source_generation` pins the
    registry state it was built from (any mutation in between makes the
    buffer stale and the flip refuses)."""

    bank_shards: int
    capacity: int
    source_generation: int
    arrays: dict  # _templates/_lower/_upper/_valid replacement arrays
    bucket_used: "np.ndarray"
    placements: list  # [(tenant_id, new_offset)]
    moved: int  # tenants whose offset changed


class TemplateBankRegistry:
    """Registry of per-tenant `TemplateBank`s stacked into one super-bank."""

    def __init__(self, num_features: int, *, k_max: int = 2,
                 class_bucket: int = 16, initial_classes: int = 128,
                 initial_tenants: int = 8, bank_shards: int = 1):
        if initial_classes % class_bucket:
            raise ValueError("initial_classes must be a class_bucket multiple")
        if bank_shards < 1:
            raise ValueError("bank_shards must be >= 1")
        self.num_features = num_features
        self.k_max = k_max
        self.class_bucket = class_bucket
        self.bank_shards = bank_shards
        # capacity must cut into bank_shards equal shards of whole buckets
        # (the engine's PartitionPlan shards class rows in C_cap/S chunks)
        align = bank_shards * class_bucket
        initial_classes = -(-initial_classes // align) * align
        self._c_cap = initial_classes
        self._t_cap = initial_tenants
        n = num_features
        self._templates = np.zeros((self._c_cap, k_max, n), np.float32)
        self._lower = np.zeros((self._c_cap, k_max, n), np.float32)
        self._upper = np.zeros((self._c_cap, k_max, n), np.float32)
        self._valid = np.zeros((self._c_cap, k_max), bool)
        self._thr = np.zeros((self._t_cap, n), np.float32)
        self._bucket_used = np.zeros(self._c_cap // class_bucket, bool)
        self._slot_used = np.zeros(self._t_cap, bool)
        self._tenants: dict[str, TenantEntry] = {}
        self.generation = 0
        self._device_cache: tuple[int, TemplateBank] | None = None
        self._thr_cache: tuple[int, jnp.ndarray] | None = None

    # -- introspection ------------------------------------------------------

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._tenants

    def __len__(self) -> int:
        return len(self._tenants)

    def get(self, tenant_id: str) -> TenantEntry:
        try:
            return self._tenants[tenant_id]
        except KeyError:
            raise RegistryError(f"unknown tenant {tenant_id!r}") from None

    def lookup(self, tenant_id: str) -> TenantEntry | None:
        """Non-raising `get` — the scheduler re-resolves entries at tick
        time so queued requests always see the tenant's *current* placement
        (hot update may relocate it; evict removes it)."""
        return self._tenants.get(tenant_id)

    def bank_of(self, tenant_id: str) -> TemplateBank:
        """The tenant's CURRENT bank, read back out of the packed host
        arrays as (num_classes, k, N) host copies — byte-identical to what
        `device_bank()` serves for this tenant's window. This is how
        restore paths (e.g. the semantic cache's template slots) rebuild
        per-tenant state from a loaded registry without re-deriving it."""
        e = self.get(tenant_id)
        sl = slice(e.offset, e.offset + e.num_classes)
        return TemplateBank(
            templates=self._templates[sl, :e.k].copy(),
            lower=self._lower[sl, :e.k].copy(),
            upper=self._upper[sl, :e.k].copy(),
            valid=self._valid[sl, :e.k].copy(),
            thresholds=self._thr[e.slot].copy())

    @property
    def capacity_classes(self) -> int:
        return self._c_cap

    @property
    def capacity_tenants(self) -> int:
        return self._t_cap

    def stats(self) -> dict:
        return {
            "tenants": len(self._tenants),
            "generation": self.generation,
            "capacity_classes": self._c_cap,
            "capacity_tenants": self._t_cap,
            "used_class_buckets": int(self._bucket_used.sum()),
            "programmed_rows": int(self._valid.sum()),
            "bank_shards": self.bank_shards,
            "rows_per_shard": self.rows_per_shard,
        }

    def shard_rows_used(self) -> list[int]:
        """Allocated class rows per bank shard (bucket granularity) — the
        autoscaling policy's primary signal: when the fullest shard
        approaches `rows_per_shard`, the next registration may force a
        capacity grow (device-shape change + retrace), so the policy
        escalates `bank_shards` *before* that happens."""
        per_shard = self.rows_per_shard // self.class_bucket
        return [int(self._bucket_used[s * per_shard:(s + 1) * per_shard]
                    .sum()) * self.class_bucket
                for s in range(self.bank_shards)]

    # -- allocation ---------------------------------------------------------

    @property
    def rows_per_shard(self) -> int:
        """Class rows per bank shard (== C_cap when unsharded)."""
        return self._c_cap // self.bank_shards

    def _alloc_classes(self, n_buckets: int) -> int:
        """First-fit contiguous bucket run that never straddles a shard
        boundary; grows capacity (doubling) when fragmented/full — the only
        event that changes device shapes. Growth doubles the shard size, so
        new boundaries are a subset of old ones and placements stay legal."""
        while True:
            shard_buckets = self.rows_per_shard // self.class_bucket
            run = 0
            for i, used in enumerate(self._bucket_used):
                if i % shard_buckets == 0:
                    run = 0  # runs restart at every shard boundary
                run = 0 if used else run + 1
                if run == n_buckets:
                    start = i - n_buckets + 1
                    self._bucket_used[start:i + 1] = True
                    return start * self.class_bucket
            self._grow_classes()

    def _grow_classes(self) -> None:
        old = self._c_cap
        self._c_cap *= 2
        for name in ("_templates", "_lower", "_upper"):
            arr = getattr(self, name)
            grown = np.zeros((self._c_cap,) + arr.shape[1:], arr.dtype)
            grown[:old] = arr
            setattr(self, name, grown)
        valid = np.zeros((self._c_cap, self.k_max), bool)
        valid[:old] = self._valid
        self._valid = valid
        used = np.zeros(self._c_cap // self.class_bucket, bool)
        used[:old // self.class_bucket] = self._bucket_used
        self._bucket_used = used

    def _alloc_slot(self) -> int:
        free = np.flatnonzero(~self._slot_used)
        if free.size == 0:
            old = self._t_cap
            self._t_cap *= 2
            thr = np.zeros((self._t_cap, self.num_features), np.float32)
            thr[:old] = self._thr
            self._thr = thr
            used = np.zeros(self._t_cap, bool)
            used[:old] = self._slot_used
            self._slot_used = used
            free = np.flatnonzero(~self._slot_used)
        slot = int(free[0])
        self._slot_used[slot] = True
        return slot

    # -- mutation -----------------------------------------------------------

    def _check_bank(self, bank: TemplateBank) -> tuple[int, int]:
        c, k, n = bank.templates.shape
        if n != self.num_features:
            raise RegistryError(
                f"bank has {n} features, registry serves {self.num_features}")
        if k > self.k_max:
            raise RegistryError(f"bank k={k} exceeds registry k_max={self.k_max}")
        return c, k

    def _write(self, offset: int, c_bucket: int, bank: TemplateBank) -> int:
        c, k = bank.templates.shape[0], bank.templates.shape[1]
        end = offset + c_bucket
        self._templates[offset:end] = 0.0
        self._lower[offset:end] = 0.0
        self._upper[offset:end] = 0.0
        self._valid[offset:end] = False
        self._templates[offset:offset + c, :k] = np.asarray(bank.templates)
        self._lower[offset:offset + c, :k] = np.asarray(bank.lower)
        self._upper[offset:offset + c, :k] = np.asarray(bank.upper)
        valid = np.asarray(bank.valid, bool)
        self._valid[offset:offset + c, :k] = valid
        return int(valid.sum())

    def _bump(self) -> None:
        self.generation += 1
        self._device_cache = None
        self._thr_cache = None

    def register(self, tenant_id: str, bank: TemplateBank) -> TenantEntry:
        """Hot-register a tenant's bank: allocate a bucketed class range,
        write templates + thresholds, no device-shape change (steady state)."""
        if tenant_id in self._tenants:
            raise RegistryError(f"tenant {tenant_id!r} already registered; "
                                "use update()")
        c, k = self._check_bank(bank)
        n_buckets = -(-c // self.class_bucket)
        offset = self._alloc_classes(n_buckets)
        slot = self._alloc_slot()
        rows = self._write(offset, n_buckets * self.class_bucket, bank)
        self._thr[slot] = np.asarray(bank.thresholds)
        self._bump()
        entry = TenantEntry(tenant_id, slot, offset, c,
                            n_buckets * self.class_bucket, k, rows,
                            self.generation)
        self._tenants[tenant_id] = entry
        return entry

    def update(self, tenant_id: str, bank: TemplateBank) -> TenantEntry:
        """Hot-update a tenant's bank in place (per-user recalibration).

        Re-uses the allocated class range when the new bank fits its bucket;
        otherwise relocates (evict + register semantics, same tenant slot)."""
        old = self.get(tenant_id)
        c, k = self._check_bank(bank)
        if c <= old.c_bucket:
            rows = self._write(old.offset, old.c_bucket, bank)
            self._thr[old.slot] = np.asarray(bank.thresholds)
            self._bump()
            entry = dataclasses.replace(old, num_classes=c, k=k,
                                        valid_rows=rows,
                                        generation=self.generation)
        else:
            # relocate: invalidate + free the old range before reallocating
            self._valid[old.offset:old.offset + old.c_bucket] = False
            self._templates[old.offset:old.offset + old.c_bucket] = 0.0
            start = old.offset // self.class_bucket
            self._bucket_used[start:start + old.c_bucket // self.class_bucket] \
                = False
            n_buckets = -(-c // self.class_bucket)
            offset = self._alloc_classes(n_buckets)
            rows = self._write(offset, n_buckets * self.class_bucket, bank)
            self._thr[old.slot] = np.asarray(bank.thresholds)
            self._bump()
            entry = TenantEntry(tenant_id, old.slot, offset, c,
                                n_buckets * self.class_bucket, k, rows,
                                self.generation)
        self._tenants[tenant_id] = entry
        return entry

    # -- live resharding ----------------------------------------------------

    def _pack(self, entries, cap: int, bank_shards: int):
        """First-fit placement of existing bucket runs into a fresh bank of
        ``cap`` rows cut into ``bank_shards`` shards (runs restart at shard
        boundaries, exactly like `_alloc_classes`). Returns
        [(entry, new_offset)] or None when the capacity cannot hold them."""
        shard_buckets = (cap // bank_shards) // self.class_bucket
        used = np.zeros(cap // self.class_bucket, bool)
        out = []
        for e in entries:
            n_buckets = e.c_bucket // self.class_bucket
            if n_buckets > shard_buckets:
                return None
            placed = None
            run = 0
            for i in range(len(used)):
                if i % shard_buckets == 0:
                    run = 0
                run = 0 if used[i] else run + 1
                if run == n_buckets:
                    start = i - n_buckets + 1
                    used[start:i + 1] = True
                    placed = start * self.class_bucket
                    break
            if placed is None:
                return None
            out.append((e, placed))
        return out

    def _build_shadow(self, cap: int, bank_shards: int) -> "PreparedBank":
        """Copy every tenant's bucket run into FRESH arrays of ``cap`` rows
        cut into ``bank_shards`` shards (first-fit via `_pack`, growing
        ``cap`` by doubling until everyone fits). Pure read of the live
        bank: nothing this registry serves changes until `adopt_prepared`."""
        order = sorted(self._tenants.values(), key=lambda e: e.offset)
        while (placement := self._pack(order, cap, bank_shards)) is None:
            cap *= 2  # doubling keeps future growth boundary-compatible
        arrays = {name: np.zeros((cap,) + getattr(self, name).shape[1:],
                                 getattr(self, name).dtype)
                  for name in ("_templates", "_lower", "_upper", "_valid")}
        bucket_used = np.zeros(cap // self.class_bucket, bool)
        moved = 0
        placements = []
        for entry, offset in placement:
            lo, hi = entry.offset, entry.offset + entry.c_bucket
            for name, arr in arrays.items():
                arr[offset:offset + entry.c_bucket] = \
                    getattr(self, name)[lo:hi]
            start = offset // self.class_bucket
            bucket_used[start:start + entry.c_bucket
                        // self.class_bucket] = True
            moved += offset != entry.offset
            placements.append((entry.tenant_id, offset))
        return PreparedBank(bank_shards=bank_shards, capacity=cap,
                            source_generation=self.generation,
                            arrays=arrays, bucket_used=bucket_used,
                            placements=placements, moved=moved)

    def prepare_reshard(self, bank_shards: int) -> "PreparedBank":
        """Build the re-packed super-bank ALONGSIDE the live one (the
        double-buffered reshard's prepare half — `repro.fleet.reshard`).
        The live bank keeps serving while this copies; `adopt_prepared`
        flips to the shadow between ticks. The prepared buffer records the
        source generation, so a registry mutation after prepare (register/
        update/evict) makes it stale and adopt refuses it."""
        if bank_shards < 1:
            raise ValueError("bank_shards must be >= 1")
        align = bank_shards * self.class_bucket
        cap = -(-self._c_cap // align) * align
        return self._build_shadow(cap, bank_shards)

    def adopt_prepared(self, prepared: "PreparedBank") -> int:
        """Flip to a shadow bank built by `prepare_reshard`: swap the host
        arrays + allocation map, move tenant offsets, bump the generation
        (device caches drop; the next `device_bank()` uploads the new
        buffer and the old one is garbage). O(tenants) pointer work — the
        O(rows) copy already happened in prepare, while serving continued.
        Raises `RegistryError` when the registry mutated since prepare."""
        if prepared.source_generation != self.generation:
            raise RegistryError(
                f"prepared bank is stale: built at generation "
                f"{prepared.source_generation}, registry is now at "
                f"{self.generation}; re-prepare")
        for name, arr in prepared.arrays.items():
            setattr(self, name, arr)
        self._bucket_used = prepared.bucket_used
        for tenant_id, offset in prepared.placements:
            entry = self._tenants[tenant_id]
            self._tenants[tenant_id] = dataclasses.replace(
                entry, offset=offset, generation=self.generation + 1)
        self._c_cap = prepared.capacity
        self.bank_shards = prepared.bank_shards
        self._bump()
        return prepared.moved

    def reshard(self, bank_shards: int) -> int:
        """Re-pack every tenant's bucket run to new shard boundaries
        WITHOUT re-registering anyone: tenant ids, slots, thresholds, head
        tables (slot-indexed, service-side), template rows and `valid_rows`
        all survive — only class-row offsets move (and capacity grows when
        the new alignment needs more rows; growth keeps doubling from
        there, so later boundaries remain a superset). Returns the number
        of tenants whose offset changed.

        The caller (the control plane) drains the scheduler first; queued
        work is safe regardless because placements are resolved at tick
        time (`lookup`), never at submit time. (`prepare_reshard` +
        `adopt_prepared` is the no-drain double-buffered variant.)
        """
        if bank_shards == self.bank_shards:
            return 0
        return self.adopt_prepared(self.prepare_reshard(bank_shards))

    def compact(self) -> int:
        """Shrink capacity back down after evictions: re-pack every tenant
        into the SMALLEST shard-aligned capacity that holds them.

        `_grow_classes` only ever doubles and `evict` only frees buckets,
        so a registry that once held many tenants serves a mostly-empty
        super-bank forever — every `device_bank()` upload, fused-kernel
        row budget and shard copy pays for rows nobody owns. This is the
        reclaim hook the fleet policy triggers when occupancy drops below
        its threshold (`repro.fleet.policy.should_compact`).

        Placement-invariant per tenant: `bank_of(t)` returns the same
        bytes before and after (only offsets move). Changes the device
        array shapes (the one retrace event, same as a capacity grow).
        Returns the number of class rows freed (0 = already minimal)."""
        align = self.bank_shards * self.class_bucket
        used = int(self._bucket_used.sum()) * self.class_bucket
        cap = max(align, -(-used // align) * align)
        if cap >= self._c_cap:
            return 0
        prepared = self._build_shadow(cap, self.bank_shards)
        if prepared.capacity >= self._c_cap:
            return 0  # fragmentation kept the pack from shrinking
        freed = self._c_cap - prepared.capacity
        self.adopt_prepared(prepared)
        return freed

    def evict(self, tenant_id: str) -> None:
        """Drop a tenant: invalidate its rows, free its bucket range + slot."""
        entry = self.get(tenant_id)
        end = entry.offset + entry.c_bucket
        self._valid[entry.offset:end] = False
        self._templates[entry.offset:end] = 0.0
        start = entry.offset // self.class_bucket
        self._bucket_used[start:start + entry.c_bucket // self.class_bucket] \
            = False
        self._slot_used[entry.slot] = False
        del self._tenants[tenant_id]
        self._bump()

    # -- durable state (service snapshot/restore) ---------------------------

    def snapshot_state(self) -> tuple[dict, dict]:
        """The registry's full durable state as ``(arrays, meta)``.

        ``arrays`` is a flat dict of host numpy copies (copies, so an async
        checkpoint writer never races a hot register/update), ``meta`` a
        JSON-serialisable dict of the scalars + tenant placements. Together
        they are everything `load_state` needs to rebuild this registry
        bit-identically — the super-bank a restored service gathers is the
        same bytes, so served predictions/margins are the same bits
        (`repro.serve.snapshot`).
        """
        arrays = {
            "templates": self._templates.copy(),
            "lower": self._lower.copy(),
            "upper": self._upper.copy(),
            "valid": self._valid.copy(),
            "thresholds": self._thr.copy(),
            "bucket_used": self._bucket_used.copy(),
            "slot_used": self._slot_used.copy(),
        }
        meta = {
            "num_features": self.num_features,
            "k_max": self.k_max,
            "class_bucket": self.class_bucket,
            "bank_shards": self.bank_shards,
            "capacity_classes": self._c_cap,
            "capacity_tenants": self._t_cap,
            "generation": self.generation,
            "tenants": [dataclasses.asdict(e)
                        for e in self._tenants.values()],
        }
        return arrays, meta

    def load_state(self, arrays: dict, meta: dict) -> None:
        """Adopt a `snapshot_state` payload wholesale: capacities, bank
        arrays, allocation maps and tenant placements — zero re-registrations
        (`register` is never called; `TenantEntry`s are reconstructed as
        snapshotted). The bank-shape fields must match this registry's
        construction parameters; everything else is overwritten."""
        for field in ("num_features", "k_max", "class_bucket"):
            if meta[field] != getattr(self, field):
                raise RegistryError(
                    f"snapshot {field}={meta[field]} does not match this "
                    f"registry's {field}={getattr(self, field)}; restore "
                    "through a spec built from the snapshot")
        self._c_cap = int(meta["capacity_classes"])
        self._t_cap = int(meta["capacity_tenants"])
        self.bank_shards = int(meta["bank_shards"])
        self._templates = np.array(arrays["templates"], np.float32)
        self._lower = np.array(arrays["lower"], np.float32)
        self._upper = np.array(arrays["upper"], np.float32)
        self._valid = np.array(arrays["valid"], bool)
        self._thr = np.array(arrays["thresholds"], np.float32)
        self._bucket_used = np.array(arrays["bucket_used"], bool)
        self._slot_used = np.array(arrays["slot_used"], bool)
        self._tenants = {d["tenant_id"]: TenantEntry(**d)
                         for d in meta["tenants"]}
        self.generation = int(meta["generation"])
        self._bump()  # drop caches; device views rebuild from the new bytes

    # -- device views -------------------------------------------------------

    def device_bank(self) -> TemplateBank:
        """The (C_cap, K_max, N) super-bank as a device-resident
        `TemplateBank`, cached per generation.

        `thresholds` is the shared zero vector: per-tenant thresholds are
        applied by *shifting the query features* (scheduler), which keeps
        the fused kernel's binarisation tenant-agnostic.
        """
        if self._device_cache is None or \
                self._device_cache[0] != self.generation:
            bank = TemplateBank(
                templates=jnp.asarray(self._templates),
                lower=jnp.asarray(self._lower),
                upper=jnp.asarray(self._upper),
                valid=jnp.asarray(self._valid),
                thresholds=jnp.zeros((self.num_features,), jnp.float32),
            )
            self._device_cache = (self.generation, bank)
        return self._device_cache[1]

    def thresholds_table(self) -> jnp.ndarray:
        """(T_cap, N) per-tenant binarisation thresholds, cached."""
        if self._thr_cache is None or self._thr_cache[0] != self.generation:
            self._thr_cache = (self.generation, jnp.asarray(self._thr))
        return self._thr_cache[1]
