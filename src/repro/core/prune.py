"""Magnitude pruning with polynomial-decay schedule (paper §II-B, Eq. 5-7).

    s(t) = s_f + (s_i - s_f) * (1 - t/n_t)^3          (Eq. 5)
    r(w_ij) = |w_ij|                                   (Eq. 6)
    theta_t = Q(|W|, s(t))                             (Eq. 7)

Weights below the s(t)-percentile of |W| are zeroed; masks are persistent so
pruned connections stay pruned across fine-tuning steps (iterative
prune + fine-tune). The mask pytree doubles as the sparse-format metadata.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


def polynomial_sparsity(
    t: int | Array, n_t: int, s_i: float = 0.50, s_f: float = 0.80
) -> Array:
    """Eq. 5. Clamps t to [0, n_t]."""
    frac = jnp.clip(jnp.asarray(t, jnp.float32) / n_t, 0.0, 1.0)
    return s_f + (s_i - s_f) * (1.0 - frac) ** 3


def _default_prunable(path: tuple, leaf: Array) -> bool:
    return leaf.ndim >= 2  # weights only; biases/norms untouched


def magnitude_threshold(w: Array, sparsity: Array) -> Array:
    """Eq. 7: the sparsity-quantile of |w| (per-tensor)."""
    return jnp.quantile(jnp.abs(w), sparsity)


def prune_tree(
    params: PyTree,
    sparsity: Array | float,
    *,
    prunable: Callable[[tuple, Array], bool] = _default_prunable,
    global_ranking: bool = False,
) -> tuple[PyTree, PyTree]:
    """Prune `params` to `sparsity`; returns (pruned_params, masks).

    global_ranking=True ranks all prunable weights together (one global
    threshold, Eq. 7 over the concatenated |W|); False applies Eq. 7
    per-tensor. The paper's description is a single Q(|W|, s(t)) —
    global ranking — but per-tensor is provided as it is the common
    deployment variant; both are tested.
    """
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    sparsity = jnp.asarray(sparsity, jnp.float32)

    if global_ranking:
        flat = [
            jnp.abs(leaf).ravel()
            for path, leaf in leaves_with_paths
            if prunable(path, leaf)
        ]
        theta = jnp.quantile(jnp.concatenate(flat), sparsity) if flat else 0.0

    def mask_fn(path, leaf):
        if not prunable(path, leaf):
            return jnp.ones_like(leaf, dtype=jnp.bool_)
        th = theta if global_ranking else magnitude_threshold(leaf, sparsity)
        return jnp.abs(leaf) >= th

    masks = jax.tree_util.tree_map_with_path(mask_fn, params)
    pruned = jax.tree_util.tree_map(lambda w, m: w * m.astype(w.dtype), params, masks)
    return pruned, masks


def apply_masks(params: PyTree, masks: PyTree) -> PyTree:
    """Re-apply persistent masks (after a fine-tuning gradient step)."""
    return jax.tree_util.tree_map(lambda w, m: w * m.astype(w.dtype), params, masks)


def mask_gradients(grads: PyTree, masks: PyTree) -> PyTree:
    """Zero gradients of pruned weights so optimiser state stays clean."""
    return jax.tree_util.tree_map(lambda g, m: g * m.astype(g.dtype), grads, masks)


def sparsity_of(params: PyTree, *, prunable=_default_prunable) -> float:
    """Measured sparsity over prunable leaves."""
    total, zeros = 0, 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if prunable(path, leaf):
            total += leaf.size
            zeros += int(jnp.sum(leaf == 0))
    return zeros / max(total, 1)


# ---------------------------------------------------------------------------
# Sparse storage format (paper: "remaining non-zero weights are then stored
# using a sparse matrix format")
# ---------------------------------------------------------------------------

def to_sparse(w: Array) -> dict[str, Array]:
    """COO-style sparse encoding of a pruned tensor."""
    idx = jnp.nonzero(w.ravel())[0]
    return {
        "shape": jnp.asarray(w.shape, jnp.int32),
        "indices": idx.astype(jnp.int32),
        "values": w.ravel()[idx],
    }


def from_sparse(s: dict[str, Array]) -> Array:
    shape = tuple(int(d) for d in s["shape"])
    out = jnp.zeros(int(jnp.prod(s["shape"])), s["values"].dtype)
    out = out.at[s["indices"]].set(s["values"])
    return out.reshape(shape)


def sparse_nbytes(s: dict[str, Array]) -> int:
    return int(s["indices"].size * 4 + s["values"].size * s["values"].dtype.itemsize)
