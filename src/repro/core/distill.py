"""Knowledge distillation (paper §II-A, Eq. 1-4).

Teacher-student framework with:
  - composite loss   L = alpha * L_KD(z_s, z_t) + (1 - alpha) * L_CE(z_s, y)   (Eq. 1)
  - KD loss          L_KD = T^2 * KL( sigma(z_s/T) || sigma(z_t/T) )            (Eq. 2-3)
    NOTE: we follow the standard (Hinton) direction KL(teacher || student),
    which is what the T^2-gradient argument in the paper's reference [11]
    assumes; the gradient magnitudes match Eq. 2 either way at T=1.
  - curriculum learning: samples ordered by teacher difficulty
    d(x, y) = CE(z_t(x), y)                                                    (Eq. 4)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


def softmax_t(logits: Array, temperature: float) -> Array:
    """Temperature-scaled softmax (Eq. 3)."""
    return jax.nn.softmax(logits / temperature, axis=-1)


def log_softmax_t(logits: Array, temperature: float) -> Array:
    return jax.nn.log_softmax(logits / temperature, axis=-1)


def kd_loss(student_logits: Array, teacher_logits: Array, temperature: float) -> Array:
    """Eq. 2: T^2 * KL(p_t || p_s), mean over batch."""
    log_p_s = log_softmax_t(student_logits, temperature)
    p_t = softmax_t(teacher_logits, temperature)
    log_p_t = log_softmax_t(teacher_logits, temperature)
    kl = jnp.sum(p_t * (log_p_t - log_p_s), axis=-1)
    return (temperature**2) * jnp.mean(kl)


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Standard CE with integer labels, mean over batch."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def distillation_loss(
    student_logits: Array,
    teacher_logits: Array,
    labels: Array,
    *,
    alpha: float = 0.5,
    temperature: float = 4.0,
) -> Array:
    """Eq. 1 composite loss."""
    return alpha * kd_loss(student_logits, teacher_logits, temperature) + (
        1.0 - alpha
    ) * cross_entropy(student_logits, labels)


def per_sample_difficulty(teacher_logits: Array, labels: Array) -> Array:
    """Eq. 4: d(x_i, y_i) = CE(z_t(x_i), y_i), per sample (no reduction)."""
    logp = jax.nn.log_softmax(teacher_logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def curriculum_order(teacher_logits: Array, labels: Array) -> Array:
    """Indices sorting the training set easiest -> hardest (paper §II-A)."""
    return jnp.argsort(per_sample_difficulty(teacher_logits, labels))


class CurriculumSchedule(NamedTuple):
    """Pacing function: at epoch e (of n), train on the easiest frac(e) part.

    A linear pacing from `start_frac` to 1.0 — the paper orders data easy to
    hard 'allowing the student to gradually progress'.
    """

    start_frac: float = 0.3
    warmup_epochs: int = 5

    def available(self, epoch: int, n_samples: int) -> int:
        frac = min(
            1.0,
            self.start_frac
            + (1.0 - self.start_frac) * (epoch / max(self.warmup_epochs, 1)),
        )
        return max(1, int(frac * n_samples))
