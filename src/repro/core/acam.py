"""RRAM-CMOS TXL-ACAM device/behaviour models (paper §III).

The paper employs the Template piXeL (TXL) ACAM in two cell flavours:

  - 6T4R charging cell (Fig. 4a): per-cell matching window set by the ratio of
    the upper/lower RRAM devices shifting hybrid-inverter thresholds; on a
    match the cell conditionally charges the row matchline through a
    current-limiter pMOS; a capacitor integrates the per-row charge and a
    sense amplifier thresholds the time-to-charge. Good for sparse
    activations (charge only on match).

  - 3T1R precharging cell (Fig. 4b): a 1T1R voltage divider drives a
    complementary nMOS/pMOS pair discharging dual matchlines ML_LOW / ML_HIGH
    when the input is below/above the window; evaluating both matchlines
    separately makes the cell *differentiable* (you know which bound failed).

This module gives a behavioural simulator faithful to those dynamics at the
level the software flow needs (the paper's program-once-read-many flow:
calibrate weights in software, program once):

  * window programming with RRAM variability (log-normal conductance noise),
  * matchline charge accumulation with per-cell current limits (6T4R) or
    dual-rail discharge counts (3T1R),
  * sense-amplifier thresholding with a calibratable reference,
  * a smooth (sigmoid-windowed) surrogate for gradient-based template
    calibration (3T1R differentiability).

Everything is jax.jit / vmap friendly and differentiable where stated.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class ACAMConfig(NamedTuple):
    cell: str = "6T4R"  # or "3T1R"
    vdd: float = 1.8  # 180 nm CMOS supply
    # matchline dynamics
    c_ml: float = 20e-15  # matchline capacitance [F]
    i_cell: float = 2e-6  # per-cell current-limited charge current [A]
    t_eval: float = 10e-9  # evaluation window [s]
    sense_frac: float = 0.5  # sense-amp threshold as fraction of VDD
    # RRAM programming
    sigma_program: float = 0.0  # log-normal sigma on window edges
    #: calibrate I_cell so a full-row match charges exactly to VDD within
    #: t_eval (§III-B: "sense amplifiers are calibrated to detect a specific
    #: voltage level ... time-to-charge dynamics of the matchline") — without
    #: this the line saturates after a few matches and ranking collapses.
    auto_calibrate: bool = True
    # energy
    e_cell: float = 185e-15  # J per similarity-search op per cell (paper §III-B)
    # differentiable surrogate sharpness
    beta: float = 25.0


class ProgrammedACAM(NamedTuple):
    """ACAM array with windows programmed into (noisy) RRAM conductances.

    lower/upper: (rows, cells) programmed window bounds (voltage-domain units;
    the software flow maps binary/real features onto [0, 1]).
    """

    lower: Array
    upper: Array
    valid: Array  # (rows,) template validity
    config: ACAMConfig


def program(
    lower: Array, upper: Array, valid: Array, config: ACAMConfig, key: Array | None = None
) -> ProgrammedACAM:
    """Program windows; apply RRAM variability if sigma_program > 0.

    Models the write-time log-normal spread of RRAM conductance which shifts
    the hybrid-inverter thresholds, i.e. the realised window edges.
    """
    lo, hi = lower, upper
    if config.sigma_program > 0.0 and key is not None:
        k1, k2 = jax.random.split(key)
        lo = lo * jnp.exp(config.sigma_program * jax.random.normal(k1, lo.shape))
        hi = hi * jnp.exp(config.sigma_program * jax.random.normal(k2, hi.shape))
        hi = jnp.maximum(hi, lo)  # windows cannot invert
    if config.auto_calibrate:
        n_cells = lower.shape[-1]
        i_cal = config.c_ml * config.vdd / (config.t_eval * n_cells)
        config = config._replace(i_cell=i_cal)
    return ProgrammedACAM(lo, hi, valid, config)


def cell_match(acam: ProgrammedACAM, queries: Array) -> Array:
    """Hard per-cell match: (B, rows, cells) in {0,1}.

    6T4R: match <=> input inside window (cell charges ML).
    3T1R: match <=> neither ML_LOW nor ML_HIGH discharges — same predicate,
    different polarity; the distinction matters for dynamics & energy below.
    """
    q = queries[:, None, :]
    return ((q >= acam.lower[None]) & (q <= acam.upper[None])).astype(jnp.float32)


def matchline_voltage(acam: ProgrammedACAM, queries: Array) -> Array:
    """6T4R matchline voltage after t_eval: (B, rows).

    n matching cells charge C_ml in parallel through current limiters:
        V(t) = min(VDD, n * I_cell * t_eval / C_ml)
    (linear ramp under the current limit — the regime the sense amps are
    calibrated for, §III-B).
    """
    cfg = acam.config
    n_match = jnp.sum(cell_match(acam, queries), axis=-1)
    v = n_match * cfg.i_cell * cfg.t_eval / cfg.c_ml
    return jnp.minimum(v, cfg.vdd)


def dual_rail_mismatch(acam: ProgrammedACAM, queries: Array) -> tuple[Array, Array]:
    """3T1R: per-row counts of low-side and high-side mismatches (B, rows)."""
    q = queries[:, None, :]
    low = jnp.sum((q < acam.lower[None]).astype(jnp.float32), axis=-1)
    high = jnp.sum((q > acam.upper[None]).astype(jnp.float32), axis=-1)
    return low, high


def sense(acam: ProgrammedACAM, queries: Array) -> Array:
    """Sense-amplifier output per template row: analogue similarity (B, rows).

    6T4R: normalised matchline voltage (fraction of VDD at readout).
    3T1R: fraction of cells whose dual rails both stayed high.
    Invalid rows are driven to -inf so the WTA never selects them.
    """
    cfg = acam.config
    if cfg.cell == "6T4R":
        s = matchline_voltage(acam, queries) / cfg.vdd
    elif cfg.cell == "3T1R":
        low, high = dual_rail_mismatch(acam, queries)
        n = acam.lower.shape[-1]
        s = 1.0 - (low + high) / n
    else:
        raise ValueError(f"unknown cell {cfg.cell}")
    return jnp.where(acam.valid[None, :], s, -jnp.inf)


def soft_sense(acam: ProgrammedACAM, queries: Array) -> Array:
    """Differentiable surrogate of `sense` (3T1R differentiability, §III).

    Each cell's match indicator is replaced by the product of two sigmoids
    around the window edges; gradients flow to lower/upper — this is the
    software-calibration path of the program-once flow.
    """
    cfg = acam.config
    q = queries[:, None, :]
    m = jax.nn.sigmoid(cfg.beta * (q - acam.lower[None])) * jax.nn.sigmoid(
        cfg.beta * (acam.upper[None] - q)
    )
    s = jnp.mean(m, axis=-1)
    return jnp.where(acam.valid[None, :], s, -1e9)


def wta(similarities: Array) -> Array:
    """Winner-take-all row index (B,) — the analogue argmax network."""
    return jnp.argmax(similarities, axis=-1)


def classify_rows_to_classes(row_winner: Array, rows_per_class: int) -> Array:
    """Map winning template row -> class id (rows laid out class-major)."""
    return row_winner // rows_per_class


def search_energy(acam: ProgrammedACAM, batch: int = 1) -> Array:
    """Energy per batch of similarity searches: rows x cells x E_cell x B.

    Matches Eq. 14 (E = N_templates x N_features x 185 fJ) when all rows are
    valid — we additionally exclude never-programmed rows, which a real
    deployment would power-gate.
    """
    cfg = acam.config
    cells = acam.lower.shape[-1]
    rows = jnp.sum(acam.valid.astype(jnp.int32))
    return rows * cells * cfg.e_cell * batch


def calibrate_windows(
    acam: ProgrammedACAM,
    features: Array,
    labels_rows: Array,
    *,
    steps: int = 100,
    lr: float = 0.05,
) -> ProgrammedACAM:
    """Gradient calibration of windows against known row assignments.

    Uses the 3T1R-style soft_sense surrogate and a cross-entropy on row
    scores; final windows are what gets programmed once to hardware.
    """

    def loss_fn(bounds):
        lo, hi = bounds
        sim = soft_sense(acam._replace(lower=lo, upper=hi), features)
        logp = jax.nn.log_softmax(sim * 10.0, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels_rows[:, None], axis=-1))

    bounds = (acam.lower, acam.upper)
    g = jax.jit(jax.grad(loss_fn))

    def body(_, b):
        lo, hi = b
        glo, ghi = g((lo, hi))
        lo = lo - lr * glo
        hi = hi - lr * ghi
        return lo, jnp.maximum(hi, lo)

    lo, hi = jax.lax.fori_loop(0, steps, body, bounds)
    return acam._replace(lower=lo, upper=hi)
