"""Quantisation schemes (paper §II-C).

Two stages, exactly as the paper describes:
  1. 8-bit integer quantisation-aware training (QAT) for model weights —
     fake-quant with a straight-through estimator so the model adapts to
     reduced precision during training.
  2. Binary (1-bit) feature-map quantisation for ACAM deployment, using a
     *mean-based* threshold per feature (the paper shows mean beats median
     for sparse ReLU feature maps, Fig. 1).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# 8-bit quantisation-aware training (weights)
# ---------------------------------------------------------------------------

def quantize_int8(w: Array) -> tuple[Array, Array]:
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


@jax.custom_vjp
def fake_quant_int8(w: Array) -> Array:
    """Fake-quantise to int8 grid with a straight-through estimator."""
    q, scale = quantize_int8(w)
    return dequantize_int8(q, scale)


def _fq_fwd(w):
    return fake_quant_int8(w), None


def _fq_bwd(_, g):
    return (g,)  # straight-through


fake_quant_int8.defvjp(_fq_fwd, _fq_bwd)


def fake_quant_tree(params, *, predicate=None):
    """Apply fake-quant to every weight leaf (ndim >= 2 by default).

    Biases / norms stay full precision, matching the paper's 8-bit weight
    scheme.
    """
    if predicate is None:
        predicate = lambda x: x.ndim >= 2

    def f(x):
        return fake_quant_int8(x) if predicate(x) else x

    return jax.tree_util.tree_map(f, params)


# ---------------------------------------------------------------------------
# Binary feature-map quantisation (mean / median thresholding)
# ---------------------------------------------------------------------------

def feature_thresholds(
    features: Array, method: Literal["mean", "median"] = "mean"
) -> Array:
    """Per-feature threshold over a set of samples.

    features: (num_samples, num_features). Returns (num_features,).

    The paper's analysis (Fig. 1): ReLU feature maps are sparse, so the mean
    sits below the median and keeps informative low-magnitude activations
    above the threshold.
    """
    if method == "mean":
        return jnp.mean(features, axis=0)
    elif method == "median":
        return jnp.median(features, axis=0)
    raise ValueError(f"unknown threshold method: {method}")


def binarize(features: Array, thresholds: Array) -> Array:
    """Binary quantisation: 1 where feature > threshold else 0 (float32)."""
    return (features > thresholds).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("method",))
def binarize_with_stats(features: Array, method: str = "mean") -> tuple[Array, Array]:
    thr = feature_thresholds(features, method)  # type: ignore[arg-type]
    return binarize(features, thr), thr
