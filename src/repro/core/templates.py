"""ACAM template generation (paper §II-D-1).

Pipeline: run the trained front-end over the training set, collect the
penultimate feature maps per class, threshold them (mean- or median-based,
`repro.core.quant`), and distil them into one or more binary templates per
class. Multi-template uses k-means on the class's feature maps; silhouette
scores pick the template count.

Templates come in two flavours matching the two ACAM matching models:
  - point templates T (binary vector)       -> feature-count matching (Eq. 8)
  - window templates [T^L, T^U] per feature -> similarity matching (Eq. 9-11)
Window templates are derived from per-cluster feature statistics
(mean +/- width * std), which is exactly what is programmed into the RRAM
pair that defines each TXL cell's matching window.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant

Array = jax.Array


class TemplateBank(NamedTuple):
    """Stored ACAM contents.

    templates:  (num_classes, k, num_features)  binary point templates
    lower:      (num_classes, k, num_features)  window lower bounds
    upper:      (num_classes, k, num_features)  window upper bounds
    valid:      (num_classes, k) bool — classes may use fewer than k templates
    thresholds: (num_features,) binarisation thresholds of the front-end
    """

    templates: Array
    lower: Array
    upper: Array
    valid: Array
    thresholds: Array

    @property
    def num_classes(self) -> int:
        return self.templates.shape[0]

    @property
    def k(self) -> int:
        return self.templates.shape[1]

    @property
    def num_features(self) -> int:
        return self.templates.shape[2]


# ---------------------------------------------------------------------------
# k-means (pure JAX, deterministic init) + silhouette score
# ---------------------------------------------------------------------------

def kmeans(
    x: Array, k: int, *, iters: int = 25, key: Array | None = None
) -> tuple[Array, Array]:
    """Lloyd's k-means. Returns (centroids (k,d), assignment (n,)).

    Deterministic k-means++-lite init: first centroid = point closest to the
    data mean, subsequent centroids = farthest point from current set
    (deterministic so templates are reproducible run-to-run, matching the
    paper's program-once flow).
    """
    n, d = x.shape
    # --- init ---
    mean = jnp.mean(x, axis=0)
    first = jnp.argmin(jnp.sum((x - mean) ** 2, axis=1))
    cents = jnp.zeros((k, d), x.dtype).at[0].set(x[first])

    def init_step(i, cents):
        d2 = jnp.min(
            jnp.sum((x[:, None, :] - cents[None, :, :]) ** 2, axis=-1)
            + jnp.where(jnp.arange(k)[None, :] < i, 0.0, jnp.inf),
            axis=1,
        )
        return cents.at[i].set(x[jnp.argmax(d2)])

    cents = jax.lax.fori_loop(1, k, init_step, cents)

    # --- Lloyd iterations ---
    def step(_, cents):
        d2 = jnp.sum((x[:, None, :] - cents[None, :, :]) ** 2, axis=-1)
        assign = jnp.argmin(d2, axis=1)
        one_hot = jax.nn.one_hot(assign, k, dtype=x.dtype)
        counts = one_hot.sum(axis=0)  # (k,)
        sums = one_hot.T @ x  # (k, d)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1), cents)
        return new

    cents = jax.lax.fori_loop(0, iters, step, cents)
    assign = jnp.argmin(jnp.sum((x[:, None, :] - cents[None, :, :]) ** 2, axis=-1), axis=1)
    return cents, assign


def silhouette_score(x: Array, assign: Array, k: int) -> Array:
    """Mean silhouette coefficient (paper uses it to pick template count).

    O(n^2) pairwise distances — fine for the per-class sample counts used in
    template generation.
    """
    n = x.shape[0]
    d = jnp.sqrt(jnp.maximum(jnp.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1), 0.0))
    same = assign[:, None] == assign[None, :]
    eye = jnp.eye(n, dtype=bool)
    # a(i): mean distance to own cluster (excluding self)
    own_cnt = jnp.sum(same & ~eye, axis=1)
    a = jnp.sum(jnp.where(same & ~eye, d, 0.0), axis=1) / jnp.maximum(own_cnt, 1)
    # b(i): min over other clusters of mean distance
    cluster_ids = jnp.arange(k)
    in_c = assign[None, :] == cluster_ids[:, None]  # (k, n)
    cnt_c = jnp.sum(in_c, axis=1)  # (k,)
    mean_d_to_c = (d @ in_c.T.astype(d.dtype)) / jnp.maximum(cnt_c[None, :], 1)  # (n,k)
    not_own = cluster_ids[None, :] != assign[:, None]
    b = jnp.min(jnp.where(not_own & (cnt_c[None, :] > 0), mean_d_to_c, jnp.inf), axis=1)
    s = jnp.where(own_cnt > 0, (b - a) / jnp.maximum(jnp.maximum(a, b), 1e-12), 0.0)
    return jnp.mean(s)


# ---------------------------------------------------------------------------
# Template generation
# ---------------------------------------------------------------------------

def generate_templates(
    features: Array,
    labels: Array,
    num_classes: int,
    *,
    k: int = 1,
    threshold_method: str = "mean",
    window_width: float = 1.0,
    binary_windows: bool = True,
) -> TemplateBank:
    """Build the template bank from front-end feature maps.

    features: (n, num_features) float feature maps (penultimate layer).
    labels:   (n,) int class labels.
    k:        templates per class (k-means centroids when k > 1).

    Window bounds: per-cluster mean +/- window_width * std in *feature* space,
    then binarised consistently with the point templates when binary_windows
    (the paper's deployed configuration is fully binary; real-valued windows
    are kept for the similarity model ablation).
    """
    thresholds = quant.feature_thresholds(features, threshold_method)  # type: ignore[arg-type]
    nf = features.shape[1]

    tmpl = jnp.zeros((num_classes, k, nf), jnp.float32)
    lo = jnp.zeros((num_classes, k, nf), jnp.float32)
    hi = jnp.zeros((num_classes, k, nf), jnp.float32)
    valid = jnp.zeros((num_classes, k), bool)

    for c in range(num_classes):
        sel = labels == c
        xc = features[sel]
        if xc.shape[0] == 0:
            continue
        if k == 1 or xc.shape[0] < k:
            cents = jnp.mean(xc, axis=0, keepdims=True)  # (1, nf)
            assign = jnp.zeros((xc.shape[0],), jnp.int32)
            used = 1
        else:
            cents, assign = kmeans(xc, k)
            used = k
        for j in range(used):
            members = xc[assign == j] if used > 1 else xc
            if members.shape[0] == 0:
                continue
            mu = jnp.mean(members, axis=0)
            sd = jnp.std(members, axis=0)
            tmpl = tmpl.at[c, j].set(quant.binarize(mu[None], thresholds)[0])
            l_, u_ = mu - window_width * sd, mu + window_width * sd
            if binary_windows:
                l_ = quant.binarize(l_[None], thresholds)[0]
                u_ = quant.binarize(u_[None], thresholds)[0]
                u_ = jnp.maximum(u_, l_)
            lo = lo.at[c, j].set(l_)
            hi = hi.at[c, j].set(u_)
            valid = valid.at[c, j].set(True)

    return TemplateBank(tmpl, lo, hi, valid, thresholds)


def select_k_by_silhouette(
    features: Array, labels: Array, num_classes: int, candidate_ks=(1, 2, 3)
) -> tuple[int, dict[int, float]]:
    """Pick templates-per-class by mean per-class silhouette (paper §II-D-1).

    k=1 gets silhouette 0 by convention (no clustering structure claim);
    larger k wins only if clustering is genuinely separated.
    """
    scores: dict[int, float] = {}
    for k in candidate_ks:
        if k == 1:
            scores[1] = 0.0
            continue
        per_class = []
        for c in range(num_classes):
            xc = features[labels == c]
            if xc.shape[0] <= k:
                continue
            _, assign = kmeans(xc, k)
            per_class.append(float(silhouette_score(xc, assign, k)))
        scores[k] = float(jnp.mean(jnp.asarray(per_class))) if per_class else -1.0
    best = max(scores, key=lambda kk: scores[kk])
    return best, scores
