"""ACAM pattern-matching models (paper §II-D-2, Eq. 8-12).

Two matching models, both vectorised over (batch, class, template):

  feature-count  S_fc(Q,T)  = sum_i 1(Q_i == T_i)                      (Eq. 8)
  similarity     D(Q,T)     = sum_i out-of-window squared distance     (Eq. 9)
                 H(Q,T)     = mean_i 1(T^L_i <= Q_i <= T^U_i)          (Eq. 10)
                 S_sim(Q,T) = H / (1 + alpha * D)                      (Eq. 11)
  decision       C(Q)       = argmax_j max_k S(Q, T_{j,k})             (Eq. 12,
                              max over the k templates of each class)

Backend dispatch
----------------
The public entry points (`feature_count_scores`, `similarity_scores`,
`classify`, `classify_features`, `classify_features_margin`) route through
the Pallas TPU kernels
(`repro.kernels.acam_match`, `repro.kernels.acam_similarity`) **by default**,
falling back to interpret mode on CPU and to the pure-jnp references for
tiny shapes. The hot (B, C, K, N) intermediate the references materialise in
HBM never exists on the kernel path, and `classify_features` is a *single*
pallas_call (fused binarize -> match -> valid mask -> Eq. 12 per-class max
-> WTA argmax).

Select the backend globally with `set_backend("auto" | "kernel" |
"reference")` or the ``REPRO_MATCHING_BACKEND`` environment variable, or
per call via the ``backend=`` keyword:

  auto       kernel path, except shapes with B*C*K*N < 32768 (reference)
  kernel     always the Pallas kernels (interpret mode off-TPU)
  reference  always the jnp references below

Kernel block sizes resolve through the `repro.kernels.tuning` autotuner
cache. The references remain exported (`feature_count_scores_ref`,
`similarity_scores_ref`) as the parity oracles.

The bank's (C, K, N) layout is flattened class-major for the two-stage
kernels and K-major (`repro.kernels.layout`) for the fused classify, with
`valid` masking and the Eq. 12 per-class max folded into the kernel
epilogue.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.templates import TemplateBank

Array = jax.Array

NEG = -jnp.inf

#: below this many (B * C * K * N) cell-match operations the jnp reference
#: beats the kernel's padding/launch overhead — stay on XLA.
TINY_ELEMENTS = 32768

#: fused classify keeps all K * Cp template rows VMEM-resident; past this
#: row count fall back to the two-stage kernel path.
MAX_FUSED_ROWS = 2048

_BACKENDS = ("auto", "kernel", "reference")
_backend = os.environ.get("REPRO_MATCHING_BACKEND", "auto")


def set_backend(name: str) -> None:
    """Select the matching backend: "auto" (default), "kernel", "reference".

    The selection is read at *trace time*: callers that jit around these
    entry points (e.g. `hybrid._fused_forward`) bake the dispatch decision
    into their jit cache, so a later `set_backend` does not retroactively
    change already-traced executables. Pin per call with ``backend=`` (a
    different value is a different trace) or set ``REPRO_MATCHING_BACKEND``
    before the first call when that matters.
    """
    global _backend
    if name not in _BACKENDS:
        raise ValueError(f"unknown matching backend {name!r}; use {_BACKENDS}")
    _backend = name


def get_backend() -> str:
    return _backend


def _use_kernel(n_elements: int, backend: str | None) -> bool:
    b = backend or _backend
    if b not in _BACKENDS:
        raise ValueError(f"unknown matching backend {b!r}; use {_BACKENDS}")
    if b == "auto":
        return n_elements >= TINY_ELEMENTS
    return b == "kernel"


# ---------------------------------------------------------------------------
# Pure-jnp references (the parity oracles; also the tiny-shape fallback)
# ---------------------------------------------------------------------------

def feature_count_scores_ref(queries: Array, templates: Array,
                             valid: Array | None = None) -> Array:
    """Eq. 8 reference: materialises the (B, C, K, N) comparison in HBM."""
    eq = queries[:, None, None, :] == templates[None, :, :, :]
    scores = jnp.sum(eq, axis=-1).astype(jnp.float32)
    if valid is not None:
        scores = jnp.where(valid[None, :, :], scores, NEG)
    return scores


def similarity_scores_ref(
    queries: Array,
    lower: Array,
    upper: Array,
    valid: Array | None = None,
    *,
    alpha: float = 1.0,
) -> Array:
    """Eq. 9-11 reference: materialises the (B, C, K, N) intermediate."""
    q = queries[:, None, None, :]
    lo = lower[None, :, :, :]
    hi = upper[None, :, :, :]
    above = jnp.maximum(q - hi, 0.0)
    below = jnp.maximum(lo - q, 0.0)
    d = jnp.sum(above**2 + below**2, axis=-1)  # Eq. 9
    hit = jnp.mean((q >= lo) & (q <= hi), axis=-1)  # Eq. 10
    s = hit / (1.0 + alpha * d)  # Eq. 11
    if valid is not None:
        s = jnp.where(valid[None, :, :], s, NEG)
    return s


# ---------------------------------------------------------------------------
# Dispatching entry points
# ---------------------------------------------------------------------------

def _binary_thresholds(n: int) -> Array:
    # binary {0,1} queries re-binarise exactly through a 0.5 threshold,
    # letting the kernels' fused binarisation stage pass them through.
    # Always float32: a bool-dtype 0.5 would collapse to True and binarise
    # every query bit to 0.
    return jnp.full((n,), 0.5, jnp.float32)


def feature_count_scores(queries: Array, templates: Array,
                         valid: Array | None = None, *,
                         backend: str | None = None) -> Array:
    """Eq. 8 for a bank of templates.

    queries:   (B, N) binary {0,1}
    templates: (C, K, N) binary {0,1}
    returns:   (B, C, K) match counts; invalid templates get -inf.

    Dispatches to the `acam_match` Pallas kernel (exact: the bipolar-matmul
    identity is integer-exact in f32) unless the shape is tiny or the
    backend is pinned to "reference".
    """
    b, n = queries.shape
    c, k, _ = templates.shape
    if not _use_kernel(b * c * k * n, backend):
        return feature_count_scores_ref(queries, templates, valid)
    from repro.kernels.acam_match import ops as match_ops

    flat = match_ops.match_scores(
        queries.astype(jnp.float32), _binary_thresholds(n),
        templates.reshape(c * k, n).astype(jnp.float32))
    scores = flat.reshape(b, c, k)
    if valid is not None:
        scores = jnp.where(valid[None, :, :], scores, NEG)
    return scores


def similarity_scores(
    queries: Array,
    lower: Array,
    upper: Array,
    valid: Array | None = None,
    *,
    alpha: float = 1.0,
    backend: str | None = None,
) -> Array:
    """Eq. 9-11 for a bank of window templates.

    queries:      (B, N)
    lower/upper:  (C, K, N)
    returns:      (B, C, K) similarity scores.

    Dispatches to the `acam_similarity` Pallas kernel (the (B, M, N)
    intermediate never reaches HBM) with reference fallback as above.
    """
    b, n = queries.shape
    c, k, _ = lower.shape
    if not _use_kernel(b * c * k * n, backend):
        return similarity_scores_ref(queries, lower, upper, valid,
                                     alpha=alpha)
    from repro.kernels.acam_similarity import ops as sim_ops

    flat = sim_ops.similarity_scores(queries, lower.reshape(c * k, n),
                                     upper.reshape(c * k, n), alpha=alpha)
    s = flat.reshape(b, c, k)
    if valid is not None:
        s = jnp.where(valid[None, :, :], s, NEG)
    return s


def classify_scores(scores: Array) -> tuple[Array, Array]:
    """Eq. 12 with multi-template max-pooling.

    scores: (B, C, K) -> (pred (B,), per_class (B, C)).
    """
    per_class = jnp.max(scores, axis=-1)
    return jnp.argmax(per_class, axis=-1), per_class


@functools.partial(jax.jit, static_argnames=("method", "alpha"))
def _classify_ref(queries: Array, bank: TemplateBank, *, method: str,
                  alpha: float) -> tuple[Array, Array]:
    if method == "feature_count":
        scores = feature_count_scores_ref(queries, bank.templates, bank.valid)
    else:
        scores = similarity_scores_ref(queries, bank.lower, bank.upper,
                                       bank.valid, alpha=alpha)
    return classify_scores(scores)


def _classify_kernel_path(features: Array, thresholds: Array,
                          bank: TemplateBank, method: str,
                          alpha: float) -> tuple[Array, Array]:
    """Kernel dispatch shared by `classify` and `classify_features`."""
    from repro.kernels import layout
    from repro.kernels.acam_match import ops as match_ops
    from repro.kernels.acam_similarity import ops as sim_ops

    c, k, n = bank.templates.shape
    fused_rows = k * layout.padded_classes(c)
    if method == "feature_count":
        if fused_rows <= MAX_FUSED_ROWS:
            return match_ops.classify_fused(features, thresholds,
                                            bank.templates, bank.valid)
        return match_ops.classify(features, thresholds,
                                  bank.templates.reshape(c * k, n),
                                  bank.valid.reshape(c * k), c)
    if fused_rows <= MAX_FUSED_ROWS:
        return sim_ops.classify_fused(features, thresholds, bank.lower,
                                      bank.upper, bank.valid, alpha=alpha)
    q = quant.binarize(features, thresholds)
    return sim_ops.classify(q, bank.lower.reshape(c * k, n),
                            bank.upper.reshape(c * k, n),
                            bank.valid.reshape(c * k), c, alpha=alpha)


def classify(
    queries: Array,
    bank: TemplateBank,
    *,
    method: str = "feature_count",
    alpha: float = 1.0,
    backend: str | None = None,
) -> tuple[Array, Array]:
    """End-to-end Eq. 8/11 + Eq. 12. queries are *binary* feature maps.

    On the kernel backend this executes as a single fused pallas_call
    (binarize->match->valid mask->per-class max->WTA) when the bank fits the
    fused layout, else as the two-stage kernel + jnp epilogue.
    """
    if method not in ("feature_count", "similarity"):
        raise ValueError(f"unknown matching method {method}")
    b, n = queries.shape
    c, k, _ = bank.templates.shape
    if not _use_kernel(b * c * k * n, backend):
        return _classify_ref(queries, bank, method=method, alpha=alpha)
    return _classify_kernel_path(queries.astype(jnp.float32),
                                 _binary_thresholds(n), bank, method, alpha)


def classify_features(
    features: Array,
    bank: TemplateBank,
    *,
    method: str = "feature_count",
    alpha: float = 1.0,
    backend: str | None = None,
) -> tuple[Array, Array]:
    """Raw front-end features -> binarize -> match -> WTA (paper Fig. 2).

    The kernel path fuses the §II-C mean-threshold binarisation with the
    match and the Eq. 12 decision into one pallas_call — this is what
    `ACAMHead.__call__` executes. The reference path binarises with
    `bank.thresholds` and reuses the jnp oracles.
    """
    if method not in ("feature_count", "similarity"):
        raise ValueError(f"unknown matching method {method}")
    b, n = features.shape
    c, k, _ = bank.templates.shape
    if not _use_kernel(b * c * k * n, backend):
        q = quant.binarize(features, bank.thresholds)
        return _classify_ref(q, bank, method=method, alpha=alpha)
    return _classify_kernel_path(features, bank.thresholds, bank, method,
                                 alpha)


def winner_take_all(per_class: Array) -> Array:
    """One-hot WTA output (the analogue WTA network's digital semantics)."""
    return jax.nn.one_hot(jnp.argmax(per_class, axis=-1), per_class.shape[-1])


# ---------------------------------------------------------------------------
# Confidence margin (serving / hybrid cascade)
# ---------------------------------------------------------------------------

def window_margin(per_class: Array, class_lo: Array | None = None,
                  class_hi: Array | None = None, *,
                  cap: float) -> tuple[Array, Array]:
    """Eq. 12 decision + winner-vs-runner-up margin inside class windows.

    jnp oracle for the fused margins kernel, and the fallback used by the
    reference/two-stage/similarity paths. ``per_class`` is (B, C) with -inf
    for invalid classes; windows default to the full class range. Returns
    (pred (B,) int32 global class index, margin (B,) f32 clamped to cap).
    """
    b, c = per_class.shape
    if class_lo is None:
        class_lo = jnp.zeros((b,), jnp.int32)
    if class_hi is None:
        class_hi = jnp.full((b,), c, jnp.int32)
    from repro.kernels.layout import windowed_margin
    return windowed_margin(per_class, class_lo.astype(jnp.int32)[:, None],
                           class_hi.astype(jnp.int32)[:, None], cap)


def classify_features_margin(
    features: Array,
    bank: TemplateBank,
    class_lo: Array | None = None,
    class_hi: Array | None = None,
    *,
    method: str = "feature_count",
    alpha: float = 1.0,
    backend: str | None = None,
) -> tuple[Array, Array, Array]:
    """`classify_features` + per-request confidence margin (serving path).

    The margin — Eq. 12 winner vs runner-up inside the request's class
    window ``[class_lo, class_hi)`` — is what the hybrid cascade thresholds
    to decide accept-at-ACAM vs escalate to the CNN logits head. On the
    kernel backend with a feature-count bank that fits the fused layout this
    is ONE pallas_call (`acam_match_classify_margins`); other paths compute
    per-class scores first and apply the jnp `window_margin` oracle.

    Returns (pred (B,) int32 global class index, per_class (B, C),
    margin (B,) f32 clamped to the score range: N for feature_count, 1 for
    similarity). Empty windows (slot padding) yield pred 0, margin 0.
    """
    if method not in ("feature_count", "similarity"):
        raise ValueError(f"unknown matching method {method}")
    b, n = features.shape
    c, k, _ = bank.templates.shape
    cap = float(n) if method == "feature_count" else 1.0
    if _use_kernel(b * c * k * n, backend) and method == "feature_count":
        from repro.kernels import layout
        from repro.kernels.acam_match import ops as match_ops

        if k * layout.padded_classes(c) <= MAX_FUSED_ROWS:
            return match_ops.classify_fused_margins(
                features.astype(jnp.float32), bank.thresholds,
                bank.templates, bank.valid, class_lo, class_hi)
    _, per_class = classify_features(features, bank, method=method,
                                     alpha=alpha, backend=backend)
    pred, margin = window_margin(per_class, class_lo, class_hi, cap=cap)
    return pred, per_class, margin
