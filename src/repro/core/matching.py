"""ACAM pattern-matching models (paper §II-D-2, Eq. 8-12).

Two matching models, both vectorised over (batch, class, template):

  feature-count  S_fc(Q,T)  = sum_i 1(Q_i == T_i)                      (Eq. 8)
  similarity     D(Q,T)     = sum_i out-of-window squared distance     (Eq. 9)
                 H(Q,T)     = mean_i 1(T^L_i <= Q_i <= T^U_i)          (Eq. 10)
                 S_sim(Q,T) = H / (1 + alpha * D)                      (Eq. 11)
  decision       C(Q)       = argmax_j max_k S(Q, T_{j,k})             (Eq. 12,
                              max over the k templates of each class)

These are the pure-jnp reference implementations; the Pallas TPU kernels in
`repro.kernels.acam_match` / `repro.kernels.acam_similarity` compute the same
quantities (kernels' ref.py delegates here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.templates import TemplateBank

Array = jax.Array

NEG = -jnp.inf


def feature_count_scores(queries: Array, templates: Array, valid: Array | None = None) -> Array:
    """Eq. 8 for a bank of templates.

    queries:   (B, N) binary {0,1}
    templates: (C, K, N) binary {0,1}
    returns:   (B, C, K) match counts; invalid templates get -inf.
    """
    eq = queries[:, None, None, :] == templates[None, :, :, :]
    scores = jnp.sum(eq, axis=-1).astype(jnp.float32)
    if valid is not None:
        scores = jnp.where(valid[None, :, :], scores, NEG)
    return scores


def similarity_scores(
    queries: Array,
    lower: Array,
    upper: Array,
    valid: Array | None = None,
    *,
    alpha: float = 1.0,
) -> Array:
    """Eq. 9-11 for a bank of window templates.

    queries:      (B, N)
    lower/upper:  (C, K, N)
    returns:      (B, C, K) similarity scores.
    """
    q = queries[:, None, None, :]
    lo = lower[None, :, :, :]
    hi = upper[None, :, :, :]
    above = jnp.maximum(q - hi, 0.0)
    below = jnp.maximum(lo - q, 0.0)
    d = jnp.sum(above**2 + below**2, axis=-1)  # Eq. 9
    hit = jnp.mean((q >= lo) & (q <= hi), axis=-1)  # Eq. 10
    s = hit / (1.0 + alpha * d)  # Eq. 11
    if valid is not None:
        s = jnp.where(valid[None, :, :], s, NEG)
    return s


def classify_scores(scores: Array) -> tuple[Array, Array]:
    """Eq. 12 with multi-template max-pooling.

    scores: (B, C, K) -> (pred (B,), per_class (B, C)).
    """
    per_class = jnp.max(scores, axis=-1)
    return jnp.argmax(per_class, axis=-1), per_class


@functools.partial(jax.jit, static_argnames=("method", "alpha"))
def classify(
    queries: Array,
    bank: TemplateBank,
    *,
    method: str = "feature_count",
    alpha: float = 1.0,
) -> tuple[Array, Array]:
    """End-to-end Eq. 8/11 + Eq. 12. queries are *binary* feature maps."""
    if method == "feature_count":
        scores = feature_count_scores(queries, bank.templates, bank.valid)
    elif method == "similarity":
        scores = similarity_scores(queries, bank.lower, bank.upper, bank.valid, alpha=alpha)
    else:
        raise ValueError(f"unknown matching method {method}")
    return classify_scores(scores)


def winner_take_all(per_class: Array) -> Array:
    """One-hot WTA output (the analogue WTA network's digital semantics)."""
    return jax.nn.one_hot(jnp.argmax(per_class, axis=-1), per_class.shape[-1])
