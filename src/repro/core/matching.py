"""ACAM pattern matching (paper §II-D-2, Eq. 8-12) — deprecated shims.

The matching implementation lives in **`repro.match`** (engine layer): a
`MatchEngine` built from a hashable `EngineConfig`, a backend registry
(`reference` jnp oracles / `kernel` Pallas fused+two-stage paths /
`device` RRAM-CMOS physics from `repro.core.acam`), and mesh-sharded
execution over the data-parallel axes when `repro.distributed.context`
holds a mesh. New code should use it directly:

    from repro import match
    eng = match.engine_for(method="feature_count", backend="kernel")
    pred, per_class = eng.classify_features(features, bank)

This module keeps the historical entry points as thin delegating shims so
existing imports, notebooks and the parity test-suite keep working:

  feature_count_scores / similarity_scores / classify / classify_features /
  classify_features_margin / classify_scores / winner_take_all /
  window_margin, the `*_ref` oracles, and the TINY_ELEMENTS /
  MAX_FUSED_ROWS dispatch constants (all resolved lazily from
  `repro.match` — this shim must not import the engine at module level,
  because `repro.match` itself imports `repro.core`).

Backend selection
-----------------
`set_backend("auto" | "kernel" | "reference" | "device")` now sets the
*process default* in `repro.match` (same as `REPRO_MATCHING_BACKEND`), and
`use_backend(...)` scopes it to a `with` block. The old trace-time footgun
is gone: jitted callers (`repro.core.hybrid._fused_forward`, the serving
scheduler tick) receive the backend as a **static jit argument** resolved
eagerly at call time, so changing the backend between calls produces a new
trace instead of silently replaying the old one. Per-call pinning via the
``backend=`` keyword still works and still wins over the default.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax

if TYPE_CHECKING:
    from repro.core.templates import TemplateBank

Array = jax.Array

NEG = -jax.numpy.inf

#: names resolved lazily from repro.match on first attribute access
#: (PEP 562) — matching <-> match would otherwise be an import cycle.
_REEXPORTS = {
    "TINY_ELEMENTS", "MAX_FUSED_ROWS", "classify_scores", "winner_take_all",
    "window_margin", "feature_count_scores_ref", "similarity_scores_ref",
    "use_backend",
}

__all__ = sorted(_REEXPORTS | {
    "set_backend", "get_backend", "feature_count_scores",
    "similarity_scores", "classify", "classify_features",
    "classify_features_margin",
})


def __getattr__(name: str):
    if name in _REEXPORTS:
        import repro.match as match_lib

        value = getattr(match_lib, name)
        globals()[name] = value  # cache: subsequent access is direct
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def set_backend(name: str) -> None:
    """Set the process default backend (shim over
    `repro.match.set_default_backend`); "auto" | any registered backend."""
    from repro.match import set_default_backend

    set_default_backend(name)


def get_backend() -> str:
    """The process default backend name (shim)."""
    from repro.match import default_backend

    return default_backend()


def feature_count_scores(queries: Array, templates: Array,
                         valid: Array | None = None, *,
                         backend: str | None = None) -> Array:
    """Eq. 8 for a bank of templates (shim over `MatchEngine`).

    queries:   (B, N) binary {0,1}
    templates: (C, K, N) binary {0,1}
    returns:   (B, C, K) match counts; invalid templates get -inf.
    """
    from repro.match import engine_for

    return engine_for(backend=backend).feature_count_scores(
        queries, templates, valid)


def similarity_scores(
    queries: Array,
    lower: Array,
    upper: Array,
    valid: Array | None = None,
    *,
    alpha: float = 1.0,
    backend: str | None = None,
) -> Array:
    """Eq. 9-11 for a bank of window templates (shim over `MatchEngine`).

    queries:      (B, N)
    lower/upper:  (C, K, N)
    returns:      (B, C, K) similarity scores.
    """
    from repro.match import engine_for

    return engine_for(method="similarity", alpha=alpha,
                      backend=backend).similarity_scores(
        queries, lower, upper, valid)


def classify(
    queries: Array,
    bank: "TemplateBank",
    *,
    method: str = "feature_count",
    alpha: float = 1.0,
    backend: str | None = None,
) -> tuple[Array, Array]:
    """End-to-end Eq. 8/11 + Eq. 12 over *binary* queries (engine shim)."""
    from repro.match import engine_for

    return engine_for(method=method, alpha=alpha,
                      backend=backend).classify(queries, bank)


def classify_features(
    features: Array,
    bank: "TemplateBank",
    *,
    method: str = "feature_count",
    alpha: float = 1.0,
    backend: str | None = None,
) -> tuple[Array, Array]:
    """Raw front-end features -> binarize -> match -> WTA (engine shim).

    On the kernel backend this is a single fused pallas_call when the bank
    fits the fused layout (see `repro.match.KernelBackend`).
    """
    from repro.match import engine_for

    return engine_for(method=method, alpha=alpha,
                      backend=backend).classify_features(features, bank)


def classify_features_margin(
    features: Array,
    bank: "TemplateBank",
    class_lo: Array | None = None,
    class_hi: Array | None = None,
    *,
    method: str = "feature_count",
    alpha: float = 1.0,
    backend: str | None = None,
) -> tuple[Array, Array, Array]:
    """`classify_features` + per-request confidence margin (engine shim).

    Returns (pred (B,) int32 global class index, per_class (B, C),
    margin (B,) f32 clamped to the backend's score range: N for
    feature_count, 1 for similarity and the device backend). Empty windows
    (slot padding) yield pred 0, margin 0.
    """
    from repro.match import engine_for

    return engine_for(method=method, alpha=alpha,
                      backend=backend).classify_features_margin(
        features, bank, class_lo, class_hi)
