"""The hybrid edge classifier (paper Fig. 2): CNN front-end + ACAM back-end.

Glues together the whole paper pipeline as a deployable object:

    teacher --KD+curriculum--> student --prune--> --QAT--> front-end
    front-end features --mean-threshold--> binary templates --program--> ACAM
    inference: features -> binarize -> ACAM match (feature-count/similarity)
               -> WTA -> class

Also exposes `ACAMHead`, the drop-in replacement for a model's final dense
classification layer — usable by any model in the zoo whose output is a
small-cardinality classification (see DESIGN.md §5/§7 for applicability).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import acam as acam_lib
from repro.core import energy as energy_lib
from repro.core import matching, quant, templates

Array = jax.Array


class ACAMHead(NamedTuple):
    """Binary template-matching classification head.

    Replaces `logits = features @ W + b; argmax(softmax(logits))` with
    binarise -> parallel template match -> WTA. `bank` is what gets
    programmed once into the TXL-ACAM array.
    """

    bank: templates.TemplateBank
    method: str = "feature_count"
    alpha: float = 1.0

    def __call__(self, features: Array) -> tuple[Array, Array]:
        """features: (B, N) raw front-end features -> (pred, per_class).

        Executes via `matching.classify_features`: on the kernel backend
        (the default) this is a single fused pallas_call — binarize ->
        match -> valid mask -> Eq. 12 per-class max -> WTA — with no
        (B, M) score round-trip through HBM.
        """
        return matching.classify_features(
            features, self.bank, method=self.method, alpha=self.alpha)

    def scores(self, features: Array) -> Array:
        q = quant.binarize(features, self.bank.thresholds)
        if self.method == "feature_count":
            s = matching.feature_count_scores(q, self.bank.templates, self.bank.valid)
        else:
            s = matching.similarity_scores(
                q, self.bank.lower, self.bank.upper, self.bank.valid, alpha=self.alpha
            )
        return jnp.max(s, axis=-1)  # (B, C)

    def to_acam(
        self, config: acam_lib.ACAMConfig | None = None, key: Array | None = None
    ) -> acam_lib.ProgrammedACAM:
        """Flatten the bank class-major into a programmed ACAM array."""
        cfg = config or acam_lib.ACAMConfig()
        c, k, n = self.bank.templates.shape
        lo = self.bank.lower.reshape(c * k, n)
        hi = self.bank.upper.reshape(c * k, n)
        valid = self.bank.valid.reshape(c * k)
        return acam_lib.program(lo, hi, valid, cfg, key)

    def energy_per_inference(self) -> float:
        rows = int(jnp.sum(self.bank.valid))
        return energy_lib.backend_energy(rows, self.bank.num_features)


def fit_acam_head(
    feature_fn: Callable[[Any, Array], Array],
    params: Any,
    inputs: Array,
    labels: Array,
    num_classes: int,
    *,
    k: int = 1,
    threshold_method: str = "mean",
    method: str = "feature_count",
    batch_size: int = 512,
) -> ACAMHead:
    """Generate templates from a trained front-end over a calibration set."""
    feats = []
    fn = jax.jit(feature_fn)
    for i in range(0, inputs.shape[0], batch_size):
        feats.append(fn(params, inputs[i : i + batch_size]))
    features = jnp.concatenate(feats, axis=0)
    bank = templates.generate_templates(
        features, labels, num_classes, k=k, threshold_method=threshold_method
    )
    return ACAMHead(bank=bank, method=method)


@functools.partial(jax.jit, static_argnames=("feature_fn", "method", "alpha"))
def _fused_forward(params: Any, bank: templates.TemplateBank, x: Array, *,
                   feature_fn: Callable[[Any, Array], Array], method: str,
                   alpha: float) -> tuple[Array, Array]:
    """One end-to-end jitted graph: front-end -> fused ACAM classify.

    Module-level (static feature_fn/method/alpha, bank as a pytree operand)
    so repeated `predict`/`accuracy` calls hit the jit cache instead of
    retracing per call.
    """
    feats = feature_fn(params, x)
    return matching.classify_features(feats, bank, method=method, alpha=alpha)


class HybridClassifier(NamedTuple):
    """Front-end params + feature_fn + ACAM head, with the energy report."""

    params: Any
    feature_fn: Callable[[Any, Array], Array]
    head: ACAMHead

    def predict(self, x: Array) -> Array:
        pred, _ = _fused_forward(self.params, self.head.bank, x,
                                 feature_fn=self.feature_fn,
                                 method=self.head.method,
                                 alpha=self.head.alpha)
        return pred

    def accuracy(self, x: Array, y: Array, *, batch_size: int = 1024) -> float:
        correct = 0
        for i in range(0, x.shape[0], batch_size):
            pred = self.predict(x[i : i + batch_size])
            correct += int(jnp.sum(pred == y[i : i + batch_size]))
        return correct / x.shape[0]
