"""The hybrid edge classifier (paper Fig. 2): CNN front-end + ACAM back-end.

Glues together the whole paper pipeline as a deployable object:

    teacher --KD+curriculum--> student --prune--> --QAT--> front-end
    front-end features --mean-threshold--> binary templates --program--> ACAM
    inference: features -> binarize -> ACAM match (feature-count/similarity)
               -> WTA -> class

Also exposes `ACAMHead`, the drop-in replacement for a model's final dense
classification layer — usable by any model in the zoo whose output is a
small-cardinality classification (see DESIGN.md §5/§7 for applicability).

All matching routes through `repro.match.MatchEngine`: the head's
(method, alpha, backend) become an `EngineConfig`, so the same head runs
against the jnp reference, the fused Pallas kernels, or the RRAM device-
physics models (`backend="device"`) — and shards over the data-parallel
mesh axes when `repro.distributed.context` holds a mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import match as match_lib
from repro.core import acam as acam_lib
from repro.core import energy as energy_lib
from repro.core import quant, templates

Array = jax.Array


class ACAMHead(NamedTuple):
    """Binary template-matching classification head.

    Replaces `logits = features @ W + b; argmax(softmax(logits))` with
    binarise -> parallel template match -> WTA. `bank` is what gets
    programmed once into the TXL-ACAM array. `backend=None` follows the
    process default (`repro.match.default_backend`); pin "reference" /
    "kernel" / "device" to force one.
    """

    bank: templates.TemplateBank
    method: str = "feature_count"
    alpha: float = 1.0
    backend: str | None = None

    def engine(self) -> match_lib.MatchEngine:
        """The head's matching engine (resolved default, memoised)."""
        return match_lib.engine_for(method=self.method, alpha=self.alpha,
                                    backend=self.backend)

    def __call__(self, features: Array) -> tuple[Array, Array]:
        """features: (B, N) raw front-end features -> (pred, per_class).

        On the kernel backend (the default) this is a single fused
        pallas_call — binarize -> match -> valid mask -> Eq. 12 per-class
        max -> WTA — with no (B, M) score round-trip through HBM.
        """
        return self.engine().classify_features(features, self.bank)

    def scores(self, features: Array) -> Array:
        eng = self.engine()
        q = quant.binarize(features, self.bank.thresholds)
        return jnp.max(eng.scores(q, self.bank), axis=-1)  # (B, C)

    def to_acam(
        self, config: acam_lib.ACAMConfig | None = None, key: Array | None = None
    ) -> acam_lib.ProgrammedACAM:
        """Flatten the bank class-major into a programmed ACAM array."""
        cfg = config or acam_lib.ACAMConfig()
        c, k, n = self.bank.templates.shape
        lo = self.bank.lower.reshape(c * k, n)
        hi = self.bank.upper.reshape(c * k, n)
        valid = self.bank.valid.reshape(c * k)
        return acam_lib.program(lo, hi, valid, cfg, key)

    def energy_per_inference(self) -> float:
        rows = int(jnp.sum(self.bank.valid))
        return energy_lib.backend_energy(rows, self.bank.num_features)


def fit_acam_head(
    feature_fn: Callable[[Any, Array], Array],
    params: Any,
    inputs: Array,
    labels: Array,
    num_classes: int,
    *,
    k: int = 1,
    threshold_method: str = "mean",
    method: str = "feature_count",
    batch_size: int = 512,
) -> ACAMHead:
    """Generate templates from a trained front-end over a calibration set."""
    feats = []
    fn = jax.jit(feature_fn)
    for i in range(0, inputs.shape[0], batch_size):
        feats.append(fn(params, inputs[i : i + batch_size]))
    features = jnp.concatenate(feats, axis=0)
    bank = templates.generate_templates(
        features, labels, num_classes, k=k, threshold_method=threshold_method
    )
    return ACAMHead(bank=bank, method=method)


@functools.partial(jax.jit, static_argnames=("feature_fn", "method", "alpha",
                                             "backend", "mesh_gen"))
def _fused_forward(params: Any, bank: templates.TemplateBank, x: Array, *,
                   feature_fn: Callable[[Any, Array], Array], method: str,
                   alpha: float, backend: str, mesh_gen: int = 0
                   ) -> tuple[Array, Array]:
    """One end-to-end jitted graph: front-end -> fused ACAM classify.

    Module-level (static feature_fn/method/alpha/backend, bank as a pytree
    operand) so repeated `predict`/`accuracy` calls hit the jit cache
    instead of retracing per call.

    ``backend`` is a **static argument by design**: the caller resolves the
    process default eagerly (`HybridClassifier.predict`), so a
    `matching.set_backend(...)` / `match.use_backend(...)` between calls
    keys a *different* executable — the old behaviour, where the default
    was read at trace time and a later change could never affect an
    already-traced graph, is gone (tested in tests/test_match_engine.py).
    ``mesh_gen`` (`distributed.context.generation()`) is static for the
    same reason: the engine bakes its `PartitionPlan` into this trace, so
    installing a new mesh must re-trace, not replay the stale layout.
    """
    del mesh_gen  # cache key only
    feats = feature_fn(params, x)
    eng = match_lib.engine_for(method=method, alpha=alpha, backend=backend)
    return eng.classify_features(feats, bank)


class HybridClassifier(NamedTuple):
    """Front-end params + feature_fn + ACAM head, with the energy report."""

    params: Any
    feature_fn: Callable[[Any, Array], Array]
    head: ACAMHead

    def predict(self, x: Array) -> Array:
        from repro.distributed import context

        # resolve the backend and mesh generation OUTSIDE the jit boundary:
        # both are static arguments, so changing either re-traces
        backend = self.head.backend or match_lib.default_backend()
        pred, _ = _fused_forward(self.params, self.head.bank, x,
                                 feature_fn=self.feature_fn,
                                 method=self.head.method,
                                 alpha=self.head.alpha,
                                 backend=backend,
                                 mesh_gen=context.generation())
        return pred

    def accuracy(self, x: Array, y: Array, *, batch_size: int = 1024) -> float:
        correct = 0
        for i in range(0, x.shape[0], batch_size):
            pred = self.predict(x[i : i + batch_size])
            correct += int(jnp.sum(pred == y[i : i + batch_size]))
        return correct / x.shape[0]
