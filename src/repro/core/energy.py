"""Energy model (paper §V-D), Horowitz ISSCC'14 figures.

The paper's arithmetic:

    E_backend  = N_templates x N_features x E_cell
               = 10 x 784 x 185 fJ = 1.45 nJ                      (Eq. 14)
    E_frontend = 4,749,174 effective ops -> 96.07 nJ
    E_teacher  = 3,858,551,808 ops       -> 78.06 uJ
    reduction ~= 792x

Unit-consistency note (recorded honestly): 96.07 nJ / 4,749,174 ops
= 20.23 fJ/op and 78.06 uJ / 3.859e9 ops = 20.23 fJ/op — i.e. the paper
applied the Horowitz figures "0.2 pJ mul + 0.03 pJ add + 20 pJ cache" as
*femto*joules. With true picojoule units the absolute energies are 1000x
larger (96 uJ front-end, 78 mJ teacher) but every ratio — including the
headline ~800x reduction — is unchanged. We expose both modes:
`paper_faithful=True` reproduces the printed numbers; False gives physical
Horowitz units.
"""
from __future__ import annotations

from typing import NamedTuple

# --- Horowitz energy/op table (J, physical) ---
E_MUL_8BIT = 0.2e-12
E_ADD_8BIT = 0.03e-12
E_MUL_FP32 = 3.7e-12
E_ADD_FP32 = 0.9e-12
E_CACHE_32KB = 20e-12
E_DRAM = 1.3e-9  # per 32-bit DRAM access (not charged by the paper's model)

#: effective per-op energy as the paper applied it (fJ where Horowitz says pJ)
PAPER_UNIT_SLIP = 1e-3

E_ACAM_CELL = 185e-15  # TXL-ACAM per-cell similarity-search energy (§III-B)


class EnergyReport(NamedTuple):
    frontend_j: float
    backend_j: float
    teacher_j: float

    @property
    def total_j(self) -> float:
        return self.frontend_j + self.backend_j

    @property
    def reduction(self) -> float:
        return self.teacher_j / self.total_j


def per_op_energy(*, bits: int = 8, mem_accesses_per_op: float = 1.0,
                  paper_faithful: bool = True) -> float:
    """Energy of one (MAC-ish) op: compute + charged cache traffic.

    The paper: "For each MAC operation, the computation energy is 0.23pJ and
    the memory access energy is 20pJ" — one 32KB-cache access per op.
    """
    if bits == 8:
        e = E_MUL_8BIT + E_ADD_8BIT
    elif bits == 32:
        e = E_MUL_FP32 + E_ADD_FP32
    else:
        raise ValueError(f"no Horowitz entry for {bits}-bit ops")
    e += mem_accesses_per_op * E_CACHE_32KB
    return e * (PAPER_UNIT_SLIP if paper_faithful else 1.0)


def backend_energy(n_templates: int, n_features: int, e_cell: float = E_ACAM_CELL) -> float:
    """Eq. 14 — this one is physically consistent as printed."""
    return n_templates * n_features * e_cell


def frontend_energy(effective_ops: int, *, paper_faithful: bool = True) -> float:
    return effective_ops * per_op_energy(bits=8, paper_faithful=paper_faithful)


def lm_decode_energy(active_params: int, tokens: int, *,
                     paper_faithful: bool = True) -> float:
    """Per-request LM decode cost, in the same op-energy model as §V-D.

    The semantic-cache router's "expensive backend" is a decode engine,
    not the paper's CNN; its cost model is the standard transformer
    inference count — 2 x N_active MACs per processed token (the forward
    half of the 6N rule; N_active = `ArchConfig.active_param_count()`, so
    MoE archs are charged for routed experts only) — priced at the same
    Horowitz per-op figure (and the same `paper_faithful` unit handling)
    as the front-end, so LM rows in the energy ledger are directly
    comparable to the Eq. 14 ACAM numbers. ``tokens`` should count every
    token the engine pushed through the stack for the request: prompt
    (prefill) + generated.
    """
    ops = 2 * int(active_params) * int(tokens)
    return ops * per_op_energy(bits=8, paper_faithful=paper_faithful)


def hybrid_report(
    *,
    student_macs: int = 23_785_120,
    sparsity: float = 0.80,
    softmax_layer_ops: int = 7_850,
    n_templates: int = 10,
    n_features: int = 784,
    teacher_ops: int = 3_858_551_808,
    paper_faithful: bool = True,
) -> EnergyReport:
    """The paper's §V-D arithmetic for the full hybrid classifier.

    effective ops = student_macs * (1 - sparsity) - softmax_layer_ops:
    pruned-weight MACs are skipped (80% sparsity) and the dense softmax
    head's 7,850 ops are removed, replaced by the ACAM back-end.
    """
    effective = int(round(student_macs * (1.0 - sparsity))) - softmax_layer_ops
    return EnergyReport(
        frontend_j=frontend_energy(effective, paper_faithful=paper_faithful),
        backend_j=backend_energy(n_templates, n_features),
        teacher_j=teacher_ops * per_op_energy(bits=8, paper_faithful=paper_faithful),
    )


def paper_numbers() -> dict[str, float]:
    """§V-D constants for validation in tests/benchmarks."""
    rep = hybrid_report(paper_faithful=True)
    return {
        "backend_nj": rep.backend_j * 1e9,
        "frontend_nj": rep.frontend_j * 1e9,
        "total_nj": rep.total_j * 1e9,
        "teacher_uj": rep.teacher_j * 1e6,
        "reduction_x": rep.reduction,
    }
