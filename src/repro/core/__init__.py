"""The paper's primary contribution: hybrid CNN + RRAM-CMOS ACAM classifier.

Modules:
  distill   — knowledge distillation + curriculum (Eq. 1-4)
  prune     — polynomial-decay magnitude pruning (Eq. 5-7)
  quant     — 8-bit QAT + binary mean-threshold feature quantisation
  templates — template generation, k-means, silhouette (§II-D-1)
  matching  — feature-count / similarity matching + WTA (Eq. 8-12)
  acam      — TXL-ACAM 6T4R / 3T1R behavioural device models (§III)
  energy    — Horowitz + Eq. 14 energy model (§V-D)
  hybrid    — the deployable hybrid classifier + ACAMHead
"""
from repro.core import acam, distill, energy, hybrid, matching, prune, quant, templates

__all__ = ["acam", "distill", "energy", "hybrid", "matching", "prune", "quant", "templates"]
