"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel package ships <name>.py (the pallas_call), ops.py (public
wrappers: interpret mode on CPU, compiled on TPU, block sizes resolved via
the `repro.kernels.tuning` autotuner cache) and ref.py (pure-jnp oracle).

`repro.core.matching` dispatches the ACAM hot path here by default; the
fused classify variants use the K-major bank layout in
`repro.kernels.layout`. Ref-vs-kernel timings are tracked in
BENCH_kernels.json (benchmarks/kernel_bench.py).
"""
