"""jit'd public wrapper for the acam_similarity kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.acam_similarity.acam_similarity import (
    DEFAULT_BLOCK, acam_similarity)


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


@functools.partial(jax.jit, static_argnames=("alpha", "block"))
def similarity_scores(queries: jax.Array, lower: jax.Array, upper: jax.Array,
                      *, alpha: float = 1.0, block=DEFAULT_BLOCK) -> jax.Array:
    return acam_similarity(queries, lower, upper, alpha=alpha, block=block,
                           interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("num_classes", "alpha", "block"))
def classify(queries: jax.Array, lower_flat: jax.Array, upper_flat: jax.Array,
             valid_flat: jax.Array, num_classes: int, *, alpha: float = 1.0,
             block=DEFAULT_BLOCK) -> tuple[jax.Array, jax.Array]:
    """Eq. 12 decision over a class-major flattened window-template bank."""
    s = similarity_scores(queries, lower_flat, upper_flat, alpha=alpha,
                          block=block)
    s = jnp.where(valid_flat[None, :], s, -jnp.inf)
    k = lower_flat.shape[0] // num_classes
    per_class = jnp.max(s.reshape(s.shape[0], num_classes, k), axis=-1)
    return jnp.argmax(per_class, axis=-1), per_class
