"""Public wrappers for the acam_similarity kernel.

`similarity_scores` runs the two-stage Pallas kernel; `classify` adds the
Eq. 12 epilogue in jnp; `classify_fused` is the single-pallas_call
binarize->window-match->WTA path over a K-major bank layout;
`classify_fused_margins` is the margins variant (class-chunked past
``max_rows``, so any bank size stays ONE pallas_call); `serve_classify` is
the multi-tenant serving mega-kernel (per-slot threshold gather + margins +
escalation mask in VMEM) — the similarity twin of
`repro.kernels.acam_match.ops.serve_classify`.

Blocks resolve through `repro.kernels.tuning.get_block` (persistent JSON
cache, `DEFAULT_BLOCK` fallback) when ``block`` is omitted — a pure lookup,
safe at jit trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import layout, tuning
from repro.kernels.acam_similarity.acam_similarity import (
    DEFAULT_BLOCK, acam_similarity, acam_similarity_classify,
    acam_similarity_serve)


_on_cpu = tuning.interpret_mode
_resolve = functools.partial(tuning.resolve_block, "acam_similarity")


def similarity_scores(queries: jax.Array, lower: jax.Array, upper: jax.Array,
                      *, alpha: float = 1.0, block=None) -> jax.Array:
    block = _resolve(queries, lower.shape[0], block)
    return acam_similarity(queries, lower, upper, alpha=alpha, block=block,
                           interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("num_classes", "alpha", "block",
                                             "interpret"))
def _classify_two_stage(queries, lower_flat, upper_flat, valid_flat,
                        num_classes, *, alpha, block, interpret):
    s = acam_similarity(queries, lower_flat, upper_flat, alpha=alpha,
                        block=block, interpret=interpret)
    s = jnp.where(valid_flat[None, :], s, -jnp.inf)
    k = lower_flat.shape[0] // num_classes
    per_class = jnp.max(s.reshape(s.shape[0], num_classes, k), axis=-1)
    return jnp.argmax(per_class, axis=-1), per_class


def classify(queries: jax.Array, lower_flat: jax.Array, upper_flat: jax.Array,
             valid_flat: jax.Array, num_classes: int, *, alpha: float = 1.0,
             block=None) -> tuple[jax.Array, jax.Array]:
    """Eq. 12 decision over a class-major flattened window-template bank."""
    block = _resolve(queries, lower_flat.shape[0], block)
    return _classify_two_stage(queries, lower_flat, upper_flat, valid_flat,
                               num_classes, alpha=alpha, block=block,
                               interpret=_on_cpu())


def classify_fused(features: jax.Array, thresholds: jax.Array,
                   lower_ck: jax.Array, upper_ck: jax.Array,
                   valid_ck: jax.Array, *, alpha: float = 1.0,
                   block=None) -> tuple[jax.Array, jax.Array]:
    """Single-pallas_call Eq. 9-12 over a (C, K, N) window bank."""
    c, k, n = lower_ck.shape
    block = _resolve(features, c * k, block)
    lo_km = layout.flatten_kmajor(lower_ck, c)
    hi_km = layout.flatten_kmajor(upper_ck, c)
    v_km = layout.valid_kmajor(valid_ck, c)
    return acam_similarity_classify(features, thresholds, lo_km, hi_km, v_km,
                                    c, alpha=alpha, block=block,
                                    interpret=_on_cpu())


def serve_classify(
        features: jax.Array, thr_table: jax.Array, tenant_slot: jax.Array,
        lower_ck: jax.Array, upper_ck: jax.Array, valid_ck: jax.Array,
        class_lo: jax.Array | None = None,
        class_hi: jax.Array | None = None, tau: jax.Array | None = None, *,
        alpha: float = 1.0, max_rows: int, block=None
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Multi-tenant serving mega-kernel over a (C, K, N) window bank.

    Same contract as `repro.kernels.acam_match.ops.serve_classify` with
    Eq. 9-11 scoring: ONE pallas_call from raw features + the (T, N)
    thresholds table to (pred, per_class, margin, escalate), class-chunked
    past ``max_rows`` template rows. ``tau`` defaults to -inf.
    """
    c, k, n = lower_ck.shape
    b = features.shape[0]
    if class_lo is None:
        class_lo = jnp.zeros((b,), jnp.int32)
    if class_hi is None:
        class_hi = jnp.full((b,), c, jnp.int32)
    if tau is None:
        tau = jnp.full((b,), -jnp.inf, jnp.float32)
    # never tile past the data (see tuning.clamp_block): bit-safe, and the
    # serving tick's B = slots / small-N regime is exactly where it pays
    block = tuning.clamp_block(_resolve(features, c * k, block), b, n)
    cp = layout.padded_classes(c)
    chunk = layout.class_chunk(cp, k, max_rows)
    lo_kcp = layout.stack_kcp(lower_ck, c)
    hi_kcp = layout.stack_kcp(upper_ck, c)
    v_kcp = layout.valid_kcp(valid_ck, c)
    return acam_similarity_serve(features, thr_table, tenant_slot, lo_kcp,
                                 hi_kcp, v_kcp, class_lo, class_hi, tau, c,
                                 alpha=alpha, chunk=chunk, block=block,
                                 interpret=_on_cpu())


def classify_fused_margins(
        features: jax.Array, thresholds: jax.Array, lower_ck: jax.Array,
        upper_ck: jax.Array, valid_ck: jax.Array,
        class_lo: jax.Array | None = None,
        class_hi: jax.Array | None = None, *, alpha: float = 1.0,
        max_rows: int, block=None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-pallas_call Eq. 9-12 + windowed margin (any bank size).

    The single-tenant face of the serve kernel: ONE shared thresholds row
    (T = 1, every query binarises against it) and tau pinned to -inf, with
    the escalation mask dropped. Returns (pred, per_class, margin) — the
    similarity twin of `acam_match.ops.classify_fused_margins[_chunked]`.
    """
    b = features.shape[0]
    pred, per_class, margin, _ = serve_classify(
        features, thresholds[None, :], jnp.zeros((b,), jnp.int32), lower_ck,
        upper_ck, valid_ck, class_lo, class_hi, None, alpha=alpha,
        max_rows=max_rows, block=block)
    return pred, per_class, margin
