"""Public wrappers for the acam_similarity kernel.

`similarity_scores` runs the two-stage Pallas kernel; `classify` adds the
Eq. 12 epilogue in jnp; `classify_fused` is the single-pallas_call
binarize->window-match->WTA path over a K-major bank layout.

Blocks resolve through `repro.kernels.tuning.get_block` (persistent JSON
cache, `DEFAULT_BLOCK` fallback) when ``block`` is omitted — a pure lookup,
safe at jit trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import layout, tuning
from repro.kernels.acam_similarity.acam_similarity import (
    DEFAULT_BLOCK, acam_similarity, acam_similarity_classify)


_on_cpu = tuning.interpret_mode
_resolve = functools.partial(tuning.resolve_block, "acam_similarity")


def similarity_scores(queries: jax.Array, lower: jax.Array, upper: jax.Array,
                      *, alpha: float = 1.0, block=None) -> jax.Array:
    block = _resolve(queries, lower.shape[0], block)
    return acam_similarity(queries, lower, upper, alpha=alpha, block=block,
                           interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("num_classes", "alpha", "block",
                                             "interpret"))
def _classify_two_stage(queries, lower_flat, upper_flat, valid_flat,
                        num_classes, *, alpha, block, interpret):
    s = acam_similarity(queries, lower_flat, upper_flat, alpha=alpha,
                        block=block, interpret=interpret)
    s = jnp.where(valid_flat[None, :], s, -jnp.inf)
    k = lower_flat.shape[0] // num_classes
    per_class = jnp.max(s.reshape(s.shape[0], num_classes, k), axis=-1)
    return jnp.argmax(per_class, axis=-1), per_class


def classify(queries: jax.Array, lower_flat: jax.Array, upper_flat: jax.Array,
             valid_flat: jax.Array, num_classes: int, *, alpha: float = 1.0,
             block=None) -> tuple[jax.Array, jax.Array]:
    """Eq. 12 decision over a class-major flattened window-template bank."""
    block = _resolve(queries, lower_flat.shape[0], block)
    return _classify_two_stage(queries, lower_flat, upper_flat, valid_flat,
                               num_classes, alpha=alpha, block=block,
                               interpret=_on_cpu())


def classify_fused(features: jax.Array, thresholds: jax.Array,
                   lower_ck: jax.Array, upper_ck: jax.Array,
                   valid_ck: jax.Array, *, alpha: float = 1.0,
                   block=None) -> tuple[jax.Array, jax.Array]:
    """Single-pallas_call Eq. 9-12 over a (C, K, N) window bank."""
    c, k, n = lower_ck.shape
    block = _resolve(features, c * k, block)
    lo_km = layout.flatten_kmajor(lower_ck, c)
    hi_km = layout.flatten_kmajor(upper_ck, c)
    v_km = layout.valid_kmajor(valid_ck, c)
    return acam_similarity_classify(features, thresholds, lo_km, hi_km, v_km,
                                    c, alpha=alpha, block=block,
                                    interpret=_on_cpu())
