"""Pallas TPU kernel: ACAM similarity matching (paper Eq. 9-11).

Per (query, template) pair with matching window [T^L, T^U] per cell:

    D = sum_i relu(Q_i - U_i)^2 + relu(L_i - Q_i)^2       (Eq. 9)
    H = (1/N) sum_i 1(L_i <= Q_i <= U_i)                  (Eq. 10)
    S = H / (1 + alpha * D)                               (Eq. 11)

This is the behavioural model of the analogue TXL array: D is the
out-of-window penalty, H the matchline hit fraction. The kernel is a
bandwidth-bound VPU fusion: grid (B/bm, M/bn, N/bk), broadcasting query and
window blocks to a (bm, bn, bk) VMEM tile, accumulating D and H into two
(bm, bn) f32 VMEM accumulators across the k loop, applying the Eq. 11
epilogue on the last k step — the (B, M, N) intermediate never exists in
HBM (the jnp oracle materialises it, which is exactly why this kernel
exists).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (8, 128, 128)  # bm (queries), bn (templates), bk (features)


def _kernel(q_ref, lo_ref, hi_ref, d_ref, h_ref, s_ref, *, nk: int,
            alpha: float, n_true: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        d_ref[...] = jnp.zeros_like(d_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    q = q_ref[...][:, None, :]  # (bm, 1, bk)
    lo = lo_ref[...][None, :, :]  # (1, bn, bk)
    hi = hi_ref[...][None, :, :]

    above = jnp.maximum(q - hi, 0.0)
    below = jnp.maximum(lo - q, 0.0)
    d_ref[...] += jnp.sum(above * above + below * below, axis=-1)
    hit = jnp.logical_and(q >= lo, q <= hi)
    h_ref[...] += jnp.sum(hit.astype(jnp.float32), axis=-1)

    @pl.when(k == nk - 1)
    def _epilogue():
        # padded feature columns have lo=0=hi and q=0 => they count as hits;
        # subtract the pad count from H before normalising by the true N.
        pad_hits = float(nk * q_ref.shape[-1] - n_true)
        h = (h_ref[...] - pad_hits) / float(n_true)
        s_ref[...] = h / (1.0 + alpha * d_ref[...])


@functools.partial(jax.jit, static_argnames=("alpha", "block", "interpret"))
def acam_similarity(queries: jax.Array, lower: jax.Array, upper: jax.Array,
                    *, alpha: float = 1.0, block=DEFAULT_BLOCK,
                    interpret: bool = False) -> jax.Array:
    """Similarity scores (B, M) for window templates.

    queries: (B, N); lower/upper: (M, N) with lower <= upper.
    """
    b, n = queries.shape
    m = lower.shape[0]
    bm, bn, bk = block
    bp, mp, np_ = (-(-b // bm) * bm, -(-m // bn) * bn, -(-n // bk) * bk)

    q = jnp.pad(queries, ((0, bp - b), (0, np_ - n)))
    lo = jnp.pad(lower, ((0, mp - m), (0, np_ - n)))
    hi = jnp.pad(upper, ((0, mp - m), (0, np_ - n)))

    nk = np_ // bk
    grid = (bp // bm, mp // bn, nk)
    _, _, s = pl.pallas_call(
        functools.partial(_kernel, nk=nk, alpha=alpha, n_true=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, mp), jnp.float32),  # D accumulator
            jax.ShapeDtypeStruct((bp, mp), jnp.float32),  # H accumulator
            jax.ShapeDtypeStruct((bp, mp), jnp.float32),  # S
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), lo.astype(jnp.float32), hi.astype(jnp.float32))
    return s[:b, :m]
