"""Pallas TPU kernel: ACAM similarity matching (paper Eq. 9-11).

Per (query, template) pair with matching window [T^L, T^U] per cell:

    D = sum_i relu(Q_i - U_i)^2 + relu(L_i - Q_i)^2       (Eq. 9)
    H = (1/N) sum_i 1(L_i <= Q_i <= U_i)                  (Eq. 10)
    S = H / (1 + alpha * D)                               (Eq. 11)

This is the behavioural model of the analogue TXL array: D is the
out-of-window penalty, H the matchline hit fraction. The kernel is a
bandwidth-bound VPU fusion: grid (B/bm, M/bn, N/bk), broadcasting query and
window blocks to a (bm, bn, bk) VMEM tile, accumulating D and H into two
(bm, bn) f32 VMEM accumulators across the k loop, applying the Eq. 11
epilogue on the last k step — the (B, M, N) intermediate never exists in
HBM (the jnp oracle materialises it, which is exactly why this kernel
exists).

Block sizes come from `repro.kernels.tuning` (persistent JSON cache at
``$REPRO_TUNING_CACHE`` / ``~/.cache/repro/pallas_blocks.json``, keyed
``kernel|backend|shape|dtype``) with `DEFAULT_BLOCK` as the untuned
fallback. Two entry points:

  `acam_similarity`          -> (B, M) Eq. 11 scores (two-stage path).
  `acam_similarity_classify` -> fused binarize->window-match->valid-mask->
                                per-class max->argmax/WTA (Eq. 12) in ONE
                                pallas_call over a K-major template layout
                                (`repro.kernels.layout`); no (B, M) score
                                round-trip.
  `acam_similarity_serve`    -> the symmetric serving/margins kernel: the
                                (K, Cp, N) class-chunked scheme of
                                `acam_match_serve` for the similarity
                                method — per-slot tenant threshold gather,
                                binarize, Eq. 9-11 window match with D/H
                                chunk accumulators, running per-class max,
                                windowed Eq. 12 margin and the escalation
                                mask, ONE pallas_call at any bank size (the
                                chunk degenerates to Cp for resident banks).

`repro.core.matching` dispatches here by default; the jnp reference stays
as the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (8, 128, 128)  # bm (queries), bn (templates), bk (features)
PRED_LANES = 128  # WTA index output padded to one lane tile


def _kernel(q_ref, lo_ref, hi_ref, d_ref, h_ref, s_ref, *, nk: int,
            alpha: float, n_true: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        d_ref[...] = jnp.zeros_like(d_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    q = q_ref[...][:, None, :]  # (bm, 1, bk)
    lo = lo_ref[...][None, :, :]  # (1, bn, bk)
    hi = hi_ref[...][None, :, :]

    above = jnp.maximum(q - hi, 0.0)
    below = jnp.maximum(lo - q, 0.0)
    d_ref[...] += jnp.sum(above * above + below * below, axis=-1)
    hit = jnp.logical_and(q >= lo, q <= hi)
    h_ref[...] += jnp.sum(hit.astype(jnp.float32), axis=-1)

    @pl.when(k == nk - 1)
    def _epilogue():
        # padded feature columns have lo=0=hi and q=0 => they count as hits;
        # subtract the pad count from H before normalising by the true N.
        pad_hits = float(nk * q_ref.shape[-1] - n_true)
        h = (h_ref[...] - pad_hits) / float(n_true)
        s_ref[...] = h / (1.0 + alpha * d_ref[...])


@functools.partial(jax.jit, static_argnames=("alpha", "block", "interpret"))
def acam_similarity(queries: jax.Array, lower: jax.Array, upper: jax.Array,
                    *, alpha: float = 1.0, block=DEFAULT_BLOCK,
                    interpret: bool = False) -> jax.Array:
    """Similarity scores (B, M) for window templates.

    queries: (B, N); lower/upper: (M, N) with lower <= upper.
    """
    b, n = queries.shape
    m = lower.shape[0]
    bm, bn, bk = block
    bp, mp, np_ = (-(-b // bm) * bm, -(-m // bn) * bn, -(-n // bk) * bk)

    q = jnp.pad(queries, ((0, bp - b), (0, np_ - n)))
    lo = jnp.pad(lower, ((0, mp - m), (0, np_ - n)))
    hi = jnp.pad(upper, ((0, mp - m), (0, np_ - n)))

    nk = np_ // bk
    grid = (bp // bm, mp // bn, nk)
    _, _, s = pl.pallas_call(
        functools.partial(_kernel, nk=nk, alpha=alpha, n_true=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, mp), jnp.float32),  # D accumulator
            jax.ShapeDtypeStruct((bp, mp), jnp.float32),  # H accumulator
            jax.ShapeDtypeStruct((bp, mp), jnp.float32),  # S
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), lo.astype(jnp.float32), hi.astype(jnp.float32))
    return s[:b, :m]


def _classify_kernel(f_ref, thr_ref, lo_ref, hi_ref, vrow_ref, d_ref, h_ref,
                     pc_ref, pred_ref, *, nk: int, alpha: float, n_true: int,
                     num_k: int, cp: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        d_ref[...] = jnp.zeros_like(d_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    # fused binarisation (paper §II-C): padded columns carry thr=+inf -> q=0,
    # matching the zero-padded windows, corrected in the epilogue.
    q = jnp.where(f_ref[...] > thr_ref[...], 1.0, 0.0)[:, None, :]
    lo = lo_ref[...][None, :, :]
    hi = hi_ref[...][None, :, :]

    above = jnp.maximum(q - hi, 0.0)
    below = jnp.maximum(lo - q, 0.0)
    d_ref[...] += jnp.sum(above * above + below * below, axis=-1)
    hit = jnp.logical_and(q >= lo, q <= hi)
    h_ref[...] += jnp.sum(hit.astype(jnp.float32), axis=-1)

    @pl.when(k == nk - 1)
    def _epilogue():
        from repro.kernels.layout import wta_epilogue

        pad_hits = float(nk * f_ref.shape[-1] - n_true)
        h = (h_ref[...] - pad_hits) / float(n_true)
        s = h / (1.0 + alpha * d_ref[...])
        per_class, pred = wta_epilogue(s, vrow_ref[...], cp, num_k)
        pc_ref[...] = per_class
        pred_ref[...] = jnp.broadcast_to(pred[:, None], pred_ref.shape)


@functools.partial(jax.jit, static_argnames=("num_classes", "alpha", "block",
                                             "interpret"))
def acam_similarity_classify(features: jax.Array, thresholds: jax.Array,
                             lower_kmajor: jax.Array, upper_kmajor: jax.Array,
                             valid_row: jax.Array, num_classes: int, *,
                             alpha: float = 1.0, block=DEFAULT_BLOCK,
                             interpret: bool = False
                             ) -> tuple[jax.Array, jax.Array]:
    """Fused Eq. 9-12: raw features -> binarize -> window match -> WTA.

    features:      (B, N) raw front-end feature maps
    thresholds:    (N,) binarisation thresholds
    lower/upper:   (K * Cp, N) K-major window bank (repro.kernels.layout)
    valid_row:     (K * Cp,) float {0,1}
    Returns (pred (B,) int32, per_class (B, C) f32). Only bm/bk of `block`
    are used; bm is shrunk if the (bm, K*Cp, bk) tile would bust VMEM.
    """
    b, n = features.shape
    mk = lower_kmajor.shape[0]
    from repro.kernels.layout import padded_classes
    cp = padded_classes(num_classes)
    num_k = mk // cp
    assert num_k * cp == mk, "windows must be K-major with padded classes"
    bm, _, bk = block
    while bm > 8 and bm * mk * bk * 4 > 8 * 1024 * 1024:
        bm //= 2
    bp, np_ = (-(-b // bm) * bm, -(-n // bk) * bk)

    f = jnp.pad(features.astype(jnp.float32), ((0, bp - b), (0, np_ - n)))
    thr = jnp.pad(thresholds.astype(jnp.float32), (0, np_ - n),
                  constant_values=jnp.inf)[None, :]
    lo = jnp.pad(lower_kmajor.astype(jnp.float32), ((0, 0), (0, np_ - n)))
    hi = jnp.pad(upper_kmajor.astype(jnp.float32), ((0, 0), (0, np_ - n)))
    vrow = valid_row[None, :]

    nk = np_ // bk
    grid = (bp // bm, nk)
    _, _, per_class, pred = pl.pallas_call(
        functools.partial(_classify_kernel, nk=nk, alpha=alpha, n_true=n,
                          num_k=num_k, cp=cp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((1, bk), lambda i, k: (0, k)),
            pl.BlockSpec((mk, bk), lambda i, k: (0, k)),
            pl.BlockSpec((mk, bk), lambda i, k: (0, k)),
            pl.BlockSpec((1, mk), lambda i, k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, mk), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, mk), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, cp), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, mk), jnp.float32),  # D accumulator
            jax.ShapeDtypeStruct((bp, mk), jnp.float32),  # H accumulator
            jax.ShapeDtypeStruct((bp, cp), jnp.float32),  # per-class max
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.int32),  # WTA index
        ],
        interpret=interpret,
    )(f, thr, lo, hi, vrow)
    return pred[:b, 0], per_class[:b, :num_classes]


def _serve_kernel(f_ref, slot_ref, thr_ref, lo_ref, hi_ref, v_ref, wlo_ref,
                  whi_ref, tau_ref, d_ref, h_ref, pc_ref, pred_ref,
                  margin_ref, esc_ref, *, nj: int, nk: int, alpha: float,
                  n_true: int, num_k: int, cc: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        d_ref[...] = jnp.zeros_like(d_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    # per-slot tenant threshold row: one-hot MXU select from the resident
    # (T_pad, bk) thresholds-table block (exact — see acam_match._serve_kernel)
    slot = slot_ref[..., :1]
    t_pad = thr_ref.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (slot.shape[0], t_pad), 1)
    onehot = (iota == slot).astype(jnp.float32)
    thr = jax.lax.dot_general(
        onehot, thr_ref[...], (((1,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)
    # binarize against the gathered row; padded feature columns carry
    # f = -inf so q = 0 there, matching the zero-padded windows (counted as
    # hits, corrected in the chunk epilogue)
    q = jnp.where(f_ref[...] - thr > 0, 1.0, 0.0)[:, None, :]
    lo = lo_ref[...].reshape(num_k * cc, lo_ref.shape[-1])[None, :, :]
    hi = hi_ref[...].reshape(num_k * cc, hi_ref.shape[-1])[None, :, :]

    above = jnp.maximum(q - hi, 0.0)
    below = jnp.maximum(lo - q, 0.0)
    d_ref[...] += jnp.sum(above * above + below * below, axis=-1)
    hit = jnp.logical_and(q >= lo, q <= hi)
    h_ref[...] += jnp.sum(hit.astype(jnp.float32), axis=-1)

    @pl.when(k == nk - 1)
    def _chunk_epilogue():
        from repro.kernels.layout import windowed_margin

        pad_hits = float(nk * f_ref.shape[-1] - n_true)
        h = (h_ref[...] - pad_hits) / float(n_true)
        s = h / (1.0 + alpha * d_ref[...])
        vrow = v_ref[...].reshape(1, num_k * cc)
        s = jnp.where(vrow > 0, s, -jnp.inf)
        chunk_pc = s[:, :cc]
        for kk in range(1, num_k):
            chunk_pc = jnp.maximum(chunk_pc, s[:, kk * cc:(kk + 1) * cc])
        prev = jnp.where(j == 0,
                         jnp.full(pc_ref.shape, -jnp.inf, pc_ref.dtype),
                         pc_ref[...])
        pc = jax.lax.dynamic_update_slice(prev, chunk_pc, (0, j * cc))
        pc_ref[...] = pc

        @pl.when(j == nj - 1)
        def _final():
            pred, margin = windowed_margin(pc, wlo_ref[..., :1],
                                           whi_ref[..., :1], 1.0)
            esc = (margin < tau_ref[..., 0]).astype(jnp.int32)
            pred_ref[...] = jnp.broadcast_to(pred[:, None], pred_ref.shape)
            margin_ref[...] = jnp.broadcast_to(margin[:, None],
                                               margin_ref.shape)
            esc_ref[...] = jnp.broadcast_to(esc[:, None], esc_ref.shape)


@functools.partial(jax.jit, static_argnames=("num_classes", "alpha", "chunk",
                                             "block", "interpret"))
def acam_similarity_serve(
        features: jax.Array, thr_table: jax.Array, tenant_slot: jax.Array,
        lower_kcp: jax.Array, upper_kcp: jax.Array, valid_kcp: jax.Array,
        class_lo: jax.Array, class_hi: jax.Array, tau: jax.Array,
        num_classes: int, *, alpha: float = 1.0, chunk: int,
        block=DEFAULT_BLOCK, interpret: bool = False
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Serving mega-kernel for the similarity method: gather -> binarize ->
    Eq. 9-11 window match -> per-class max -> windowed Eq. 12 margin ->
    escalation mask, ONE pallas_call at any bank size.

    Operand contract mirrors `acam_match.acam_match_serve`, with the bank as
    (K, Cp, N) lower/upper window stacks (`repro.kernels.layout.stack_kcp`).
    Margins are in Eq. 11 score units (cap 1.0). ``chunk`` class columns of
    all K window rows are VMEM-resident per grid step; D and H accumulate
    per chunk and the running per-class max crosses chunks in a revisited
    (bm, Cp) block. Returns (pred, per_class, margin, escalate).
    """
    b, n = features.shape
    num_k, cp, _ = lower_kcp.shape
    assert cp % chunk == 0, "chunk must divide the padded class count"
    t_rows = thr_table.shape[0]
    t_pad = -(-t_rows // 8) * 8
    bm, _, bk = block
    # the window compare broadcasts a (bm, K * chunk, bk) tile: shrink the
    # query rows per step if that would bust the VMEM budget
    while bm > 8 and bm * num_k * chunk * bk * 4 > 8 * 1024 * 1024:
        bm //= 2
    bp, np_ = (-(-b // bm) * bm, -(-n // bk) * bk)

    f = jnp.pad(features.astype(jnp.float32), ((0, bp - b), (0, np_ - n)),
                constant_values=-jnp.inf)
    thr = jnp.pad(thr_table.astype(jnp.float32),
                  ((0, t_pad - t_rows), (0, np_ - n)))
    lo = jnp.pad(lower_kcp.astype(jnp.float32), ((0, 0), (0, 0),
                                                 (0, np_ - n)))
    hi = jnp.pad(upper_kcp.astype(jnp.float32), ((0, 0), (0, 0),
                                                 (0, np_ - n)))
    slot = jnp.broadcast_to(
        jnp.pad(tenant_slot.astype(jnp.int32), (0, bp - b))[:, None],
        (bp, PRED_LANES))
    wlo = jnp.broadcast_to(
        jnp.pad(class_lo.astype(jnp.int32), (0, bp - b))[:, None],
        (bp, PRED_LANES))
    whi = jnp.broadcast_to(
        jnp.pad(class_hi.astype(jnp.int32), (0, bp - b))[:, None],
        (bp, PRED_LANES))
    tau_c = jnp.broadcast_to(
        jnp.pad(tau.astype(jnp.float32), (0, bp - b),
                constant_values=-jnp.inf)[:, None],
        (bp, PRED_LANES))

    nj = cp // chunk
    nk = np_ // bk
    grid = (bp // bm, nj, nk)
    _, _, per_class, pred, margin, esc = pl.pallas_call(
        functools.partial(_serve_kernel, nj=nj, nk=nk, alpha=alpha,
                          n_true=n, num_k=num_k, cc=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((t_pad, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((num_k, chunk, bk), lambda i, j, k: (0, j, k)),
            pl.BlockSpec((num_k, chunk, bk), lambda i, j, k: (0, j, k)),
            pl.BlockSpec((num_k, chunk), lambda i, j, k: (0, j)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, num_k * chunk), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, num_k * chunk), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, cp), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, num_k * cp), jnp.float32),  # D
            jax.ShapeDtypeStruct((bp, num_k * cp), jnp.float32),  # H
            jax.ShapeDtypeStruct((bp, cp), jnp.float32),  # running per-class
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.int32),  # WTA index
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.float32),  # margin
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.int32),  # escalate
        ],
        interpret=interpret,
    )(f, slot, thr, lo, hi, valid_kcp, wlo, whi, tau_c)
    return (pred[:b, 0], per_class[:b, :num_classes], margin[:b, 0],
            esc[:b, 0].astype(bool))
