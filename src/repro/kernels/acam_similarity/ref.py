"""Pure-jnp oracle for the acam_similarity kernel (paper Eq. 9-11)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def acam_similarity_ref(queries: jax.Array, lower: jax.Array,
                        upper: jax.Array, *, alpha: float = 1.0) -> jax.Array:
    q = queries[:, None, :].astype(jnp.float32)
    lo = lower[None, :, :].astype(jnp.float32)
    hi = upper[None, :, :].astype(jnp.float32)
    above = jnp.maximum(q - hi, 0.0)
    below = jnp.maximum(lo - q, 0.0)
    d = jnp.sum(above**2 + below**2, axis=-1)
    h = jnp.mean(((q >= lo) & (q <= hi)).astype(jnp.float32), axis=-1)
    return h / (1.0 + alpha * d)
