"""Block-size autotuner for the Pallas ACAM kernels.

The kernels historically ran with a single hardcoded ``DEFAULT_BLOCK``. This
module replaces that with a two-layer scheme:

  1. **Lookup** (`get_block`) — a pure, trace-time-safe read: consult the
     persistent JSON cache for a tuned block matching
     ``kernel|backend|shape|dtype``; fall back to the kernel's per-backend
     default. Safe to call while tracing a jitted caller (no timing, no IO
     beyond a once-per-process cache load).
  2. **Tuning** (`autotune`) — an explicit, eager grid-search over
     MXU/VREG-aligned candidate blocks, timing real calls and writing the
     winner back to the cache. Run it offline (``python -m
     repro.kernels.tuning``) or via ``benchmarks/kernel_bench.py --tune``.

Cache file
----------
``$REPRO_TUNING_CACHE`` if set, else ``~/.cache/repro/pallas_blocks.json``:

    {"version": 2,
     "entries": {"acam_match|cpu+interp|b256_m10_n784|float32":
                 {"block": [128, 128, 512], "us": 83.1}}}

Keys are exact-shape (no bucketing): the ACAM deployment shapes are few and
static (the bank is programmed once), so exact keys stay small and never
mis-tune. The platform token grows a ``+interp`` suffix when the kernels
run under the Pallas interpreter (CPU): interpreted timings favour very
different blocks than compiled ones, and v1's bare-platform keys let a
cache tuned in interpret mode poison a compiled run on the same platform
string. v2 keys separate the two populations; v1 caches are discarded on
load (version gate), so stale keys can never be consulted. Writes are
atomic (tmp + rename) so concurrent benchmark runs cannot corrupt the
cache. Tune offline with ``python -m repro.kernels.tuning`` or
``python benchmarks/kernel_bench.py --tune`` (grid-searches every
benchmarked shape and persists the winners here).

Candidate grids
---------------
All candidates keep the TPU tiling contract: second-to-last block dims are
multiples of 8 (f32 sublanes), last dims multiples of 128 (lanes), and the
working set per grid step is capped below VMEM (~16 MB/core, we budget 8).

  acam_match      (MXU matmul):   bm,bn in {128, 256}, bk in {256, 512, 1024}
  acam_similarity (VPU 3D fuse):  bm in {8, 16, 32, 64}, bn in {128, 256},
                                  bk in {128, 256, 512}
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Callable, Iterable, Sequence

import jax

Block = tuple[int, int, int]

_VMEM_BUDGET_BYTES = 8 * 1024 * 1024
CACHE_VERSION = 2


def cache_path() -> str:
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "pallas_blocks.json")


def backend() -> str:
    return jax.devices()[0].platform  # "cpu" | "tpu" | "gpu"


def interpret_mode() -> bool:
    """Shared predicate: kernels run via the pallas interpreter off-TPU CPU."""
    return backend() == "cpu"


def resolve_block(kernel: str, operand: jax.Array, m: int, block):
    """ops.py helper: explicit ``block`` wins, else cached/tuned lookup."""
    if block is not None:
        return tuple(block)
    b, n = operand.shape
    return get_block(kernel, (b, m, n), operand.dtype)


def clamp_block(block, b: int, n: int) -> tuple[int, int, int]:
    """Cap ``(bm, bn, bk)`` to the data: bm to the sublane-padded batch, bk
    to the lane-padded feature width.

    Tiling past the operand only adds padding work — padded batch rows are
    row-independent and padded feature columns contribute exact zeros (or
    exactly-corrected constants, recomputed by each wrapper from its own
    padded width) — so the cap is bit-safe and a pure win in the serving
    tick's small regime (B = scheduler slots, N = 64-ish front-end maps),
    where the default (128, ., 512) tile would 4-8x every block op.
    """
    bm, bn, bk = block
    return (min(bm, -(-b // 8) * 8), bn, min(bk, -(-n // 128) * 128))


def shape_key(b: int, m: int, n: int) -> str:
    return f"b{b}_m{m}_n{n}"


def entry_key(kernel: str, shape: tuple[int, int, int], dtype,
              device: str | None = None) -> str:
    b, m, n = shape
    dt = jax.numpy.dtype(dtype).name
    if device is None:
        device = backend() + ("+interp" if interpret_mode() else "")
    return f"{kernel}|{device}|{shape_key(b, m, n)}|{dt}"


# ---------------------------------------------------------------------------
# Cache IO
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _load_cache() -> dict:
    path = cache_path()
    try:
        with open(path) as f:
            data = json.load(f)
        if data.get("version") != CACHE_VERSION:
            return {}
        return dict(data.get("entries", {}))
    except (OSError, ValueError):
        return {}


def _save_entry(key: str, block: Block, us: float) -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    entries = dict(_load_cache())
    entries[key] = {"block": list(block), "us": round(us, 2)}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"version": CACHE_VERSION, "entries": entries}, f, indent=1)
    os.replace(tmp, path)
    _load_cache.cache_clear()


def clear_cache_for_tests() -> None:
    """Drop the in-process cache view (tests point REPRO_TUNING_CACHE at tmp)."""
    _load_cache.cache_clear()


# ---------------------------------------------------------------------------
# Candidates
# ---------------------------------------------------------------------------

def _fits(bm: int, bn: int, bk: int, *, bufs: int) -> bool:
    # inputs (bm,bk)+(bn,bk), accumulators/outputs bufs x (bm,bn), f32.
    words = bm * bk + bn * bk + bufs * bm * bn
    return words * 4 <= _VMEM_BUDGET_BYTES


def candidates(kernel: str) -> list[Block]:
    """MXU/VREG-aligned candidate blocks for a kernel family."""
    if kernel == "acam_match":
        grid = [(bm, bn, bk)
                for bm in (128, 256) for bn in (128, 256)
                for bk in (256, 512, 1024) if _fits(bm, bn, bk, bufs=1)]
    elif kernel == "acam_similarity":
        # the kernel broadcasts to a (bm, bn, bk) tile: count that too.
        grid = [(bm, bn, bk)
                for bm in (8, 16, 32, 64) for bn in (128, 256)
                for bk in (128, 256, 512)
                if (bm * bn * bk + 3 * bm * bn) * 4 <= _VMEM_BUDGET_BYTES]
    else:
        raise ValueError(f"no candidate grid for kernel {kernel!r}")
    assert all(bm % 8 == 0 or bm < 8 for bm, _, _ in grid)
    assert all(bn % 128 == 0 and bk % 128 == 0 for _, bn, bk in grid)
    return grid


_DEFAULTS: dict[tuple[str, str], Block] = {
    ("acam_match", "tpu"): (128, 128, 512),
    ("acam_match", "cpu"): (128, 128, 512),
    ("acam_similarity", "tpu"): (8, 128, 128),
    # interpret mode pays per-grid-step Python/HLO overhead: favour fewer,
    # fatter steps on CPU.
    ("acam_similarity", "cpu"): (64, 128, 256),
}


def default_block(kernel: str, device: str | None = None) -> Block:
    device = device or backend()
    return _DEFAULTS.get((kernel, device), _DEFAULTS[(kernel, "tpu")])


def get_block(kernel: str, shape: tuple[int, int, int], dtype,
              device: str | None = None) -> Block:
    """Tuned block for (kernel, shape, dtype) or the per-backend default.

    Pure lookup — never times anything, so it is safe at jit trace time.
    """
    hit = _load_cache().get(entry_key(kernel, shape, dtype, device))
    if hit is not None:
        return tuple(hit["block"])  # type: ignore[return-value]
    return default_block(kernel, device)


# ---------------------------------------------------------------------------
# Tuning
# ---------------------------------------------------------------------------

def _time_call(fn: Callable[[], jax.Array], iters: int) -> float:
    out = fn()
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / iters * 1e6


def autotune(kernel: str, shape: tuple[int, int, int], dtype,
             run: Callable[[Block], jax.Array], *,
             cands: Sequence[Block] | None = None, iters: int = 5,
             save: bool = True) -> Block:
    """Grid-search `run(block)` over the candidate blocks; cache the winner.

    `run` must execute the kernel end to end for a given block (the caller
    binds the concrete operands). Candidates that fail to lower (e.g. VMEM
    overflow on a real TPU) are skipped rather than fatal.
    """
    best: tuple[float, Block] | None = None
    for block in (cands if cands is not None else candidates(kernel)):
        try:
            us = _time_call(lambda: run(block), iters)
        except Exception:  # noqa: BLE001 — lowering/OOM failures just lose
            continue
        if best is None or us < best[0]:
            best = (us, block)
    if best is None:
        return default_block(kernel)
    if save:
        _save_entry(entry_key(kernel, shape, dtype), best[1], best[0])
    return best[1]


def autotune_acam(shapes: Iterable[tuple[int, int, int]] = ((1, 16, 784),
                                                            (256, 16, 784)),
                  *, iters: int = 5) -> dict[str, Block]:
    """Tune both ACAM kernels over deployment shapes; returns {key: block}."""
    import jax.numpy as jnp

    from repro.kernels.acam_match.acam_match import acam_match
    from repro.kernels.acam_similarity.acam_similarity import acam_similarity

    interp = backend() == "cpu"
    out: dict[str, Block] = {}
    key = jax.random.PRNGKey(0)
    for b, m, n in shapes:
        f = jax.random.normal(key, (b, n), jnp.float32)
        thr = jnp.zeros((n,), jnp.float32)
        t = (jax.random.uniform(key, (m, n)) > 0.5).astype(jnp.float32)
        out[entry_key("acam_match", (b, m, n), jnp.float32)] = autotune(
            "acam_match", (b, m, n), jnp.float32,
            lambda blk: acam_match(f, thr, t, block=blk, interpret=interp),
            iters=iters)
        lo = jnp.zeros((m, n), jnp.float32)
        hi = jnp.ones((m, n), jnp.float32)
        out[entry_key("acam_similarity", (b, m, n), jnp.float32)] = autotune(
            "acam_similarity", (b, m, n), jnp.float32,
            lambda blk: acam_similarity(f, lo, hi, block=blk,
                                        interpret=interp),
            iters=iters)
    return out


if __name__ == "__main__":
    for k, blk in autotune_acam().items():
        print(f"{k} -> {blk}")
