"""Public wrappers for the acam_match kernel.

`match_scores` runs the Pallas kernel (interpret=True on CPU, compiled on
TPU); `classify` adds the WTA argmax epilogue (Eq. 12) with multi-template
max-pooling, mirroring repro.core.matching.classify semantics;
`classify_fused` is the single-pallas_call binarize->match->WTA path over a
K-major bank layout (no (B, M) score round-trip); `classify_fused_margins`
additionally returns the Eq. 12 winner-vs-runner-up confidence margin and
accepts per-row class windows — the multi-tenant serving entry point
(`repro.serve`).

Block sizes: when ``block`` is omitted the wrapper resolves a tuned
``(bm, bn, bk)`` via `repro.kernels.tuning.get_block` (persistent JSON cache
keyed by kernel|backend|shape|dtype, `DEFAULT_BLOCK` fallback). Resolution
is a pure dict lookup, so these wrappers stay safe to call at jit trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import layout, tuning
from repro.kernels.acam_match.acam_match import (
    DEFAULT_BLOCK, acam_match, acam_match_classify,
    acam_match_classify_margins, acam_match_classify_margins_chunked,
    acam_match_serve)


_on_cpu = tuning.interpret_mode
_resolve = functools.partial(tuning.resolve_block, "acam_match")


def match_scores(features: jax.Array, thresholds: jax.Array,
                 templates: jax.Array, *, block=None) -> jax.Array:
    block = _resolve(features, templates.shape[0], block)
    return acam_match(features, thresholds, templates, block=block,
                      interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("num_classes", "block",
                                             "interpret"))
def _classify_two_stage(features, thresholds, templates_flat, valid_flat,
                        num_classes, *, block, interpret):
    scores = acam_match(features, thresholds, templates_flat, block=block,
                        interpret=interpret)
    scores = jnp.where(valid_flat[None, :], scores, -jnp.inf)
    k = templates_flat.shape[0] // num_classes
    per_class = jnp.max(scores.reshape(scores.shape[0], num_classes, k),
                        axis=-1)
    return jnp.argmax(per_class, axis=-1), per_class


def classify(features: jax.Array, thresholds: jax.Array,
             templates_flat: jax.Array, valid_flat: jax.Array,
             num_classes: int, *, block=None) -> tuple[jax.Array, jax.Array]:
    """templates_flat: (C*K, N) class-major; valid_flat: (C*K,) bool.

    Returns (pred (B,), per_class (B, C))."""
    block = _resolve(features, templates_flat.shape[0], block)
    return _classify_two_stage(features, thresholds, templates_flat,
                               valid_flat, num_classes, block=block,
                               interpret=_on_cpu())


def classify_fused(features: jax.Array, thresholds: jax.Array,
                   templates_ck: jax.Array, valid_ck: jax.Array, *,
                   block=None) -> tuple[jax.Array, jax.Array]:
    """Single-pallas_call Eq. 8 + Eq. 12 over a (C, K, N) bank.

    Flattens the bank K-major (repro.kernels.layout) and runs
    `acam_match_classify`. Returns (pred (B,) int32, per_class (B, C))."""
    c, k, n = templates_ck.shape
    block = _resolve(features, c * k, block)
    t_km = layout.flatten_kmajor(templates_ck, c)
    v_km = layout.valid_kmajor(valid_ck, c)
    return acam_match_classify(features, thresholds, t_km, v_km, c,
                               block=block, interpret=_on_cpu())


def classify_fused_margins(features: jax.Array, thresholds: jax.Array,
                           templates_ck: jax.Array, valid_ck: jax.Array,
                           class_lo: jax.Array | None = None,
                           class_hi: jax.Array | None = None, *,
                           block=None) -> tuple[jax.Array, jax.Array,
                                                jax.Array]:
    """Return-margins variant of `classify_fused` (the serving path).

    Adds per-row class windows ``[class_lo, class_hi)`` (int32 (B,); defaults
    to the whole bank) and returns ``(pred, per_class, margin)`` where
    ``margin`` is the Eq. 12 winner-vs-runner-up gap inside the window — the
    confidence cascade's accept/escalate signal. Still ONE pallas_call."""
    c, k, n = templates_ck.shape
    b = features.shape[0]
    if class_lo is None:
        class_lo = jnp.zeros((b,), jnp.int32)
    if class_hi is None:
        class_hi = jnp.full((b,), c, jnp.int32)
    block = _resolve(features, c * k, block)
    t_km = layout.flatten_kmajor(templates_ck, c)
    v_km = layout.valid_kmajor(valid_ck, c)
    return acam_match_classify_margins(features, thresholds, t_km, v_km,
                                       class_lo, class_hi, c, block=block,
                                       interpret=_on_cpu())


def classify_fused_margins_chunked(
        features: jax.Array, thresholds: jax.Array, templates_ck: jax.Array,
        valid_ck: jax.Array, class_lo: jax.Array | None = None,
        class_hi: jax.Array | None = None, *, max_rows: int,
        block=None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """`classify_fused_margins` for banks past the fused-row budget.

    Stacks the bank (K, Cp, N) (`layout.stack_kcp`) and tiles the class
    dimension in `layout.class_chunk(..., max_rows)`-column chunks, keeping
    the big-bank serving path a SINGLE pallas_call (no two-stage kernel +
    jnp margin epilogue). Same contract/outputs as `classify_fused_margins`.
    """
    c, k, n = templates_ck.shape
    b = features.shape[0]
    if class_lo is None:
        class_lo = jnp.zeros((b,), jnp.int32)
    if class_hi is None:
        class_hi = jnp.full((b,), c, jnp.int32)
    block = _resolve(features, c * k, block)
    cp = layout.padded_classes(c)
    chunk = layout.class_chunk(cp, k, max_rows)
    t_kcp = layout.stack_kcp(templates_ck, c)
    v_kcp = layout.valid_kcp(valid_ck, c)
    return acam_match_classify_margins_chunked(
        features, thresholds, t_kcp, v_kcp, class_lo, class_hi, c,
        chunk=chunk, block=block, interpret=_on_cpu())


def serve_classify(
        features: jax.Array, thr_table: jax.Array, tenant_slot: jax.Array,
        templates_ck: jax.Array, valid_ck: jax.Array,
        class_lo: jax.Array | None = None,
        class_hi: jax.Array | None = None, tau: jax.Array | None = None, *,
        max_rows: int, block=None
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """The multi-tenant serving mega-kernel entry point (`acam_match_serve`).

    ONE pallas_call from raw per-slot features + the (T, N) per-tenant
    thresholds table to (pred, per_class, margin, escalate): the tenant
    threshold-row gather, binarisation, Eq. 8 match, per-class max, windowed
    Eq. 12 margin and the cascade's ``margin < tau`` escalation mask all run
    in VMEM. The class chunk degenerates to the padded class count for banks
    inside ``max_rows`` (fully resident) and tiles the class dimension past
    it — single dispatch at any bank size. ``tau`` defaults to -inf (never
    escalate); windows default to the whole bank.
    """
    c, k, n = templates_ck.shape
    b = features.shape[0]
    if class_lo is None:
        class_lo = jnp.zeros((b,), jnp.int32)
    if class_hi is None:
        class_hi = jnp.full((b,), c, jnp.int32)
    if tau is None:
        tau = jnp.full((b,), -jnp.inf, jnp.float32)
    # serve ticks are small (B = slots, N = front-end map width): never tile
    # past the data — bit-safe (see tuning.clamp_block) and a pure win
    block = tuning.clamp_block(_resolve(features, c * k, block), b, n)
    cp = layout.padded_classes(c)
    chunk = layout.class_chunk(cp, k, max_rows)
    t_kcp = layout.stack_kcp(templates_ck, c)
    v_kcp = layout.valid_kcp(valid_ck, c)
    return acam_match_serve(features, thr_table, tenant_slot, t_kcp, v_kcp,
                            class_lo, class_hi, tau, c, chunk=chunk,
                            block=block, interpret=_on_cpu())
