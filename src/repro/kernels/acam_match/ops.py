"""jit'd public wrapper for the acam_match kernel.

`match_scores` runs the Pallas kernel (interpret=True on CPU, compiled on
TPU); `classify` adds the WTA argmax epilogue (Eq. 12) with multi-template
max-pooling, mirroring repro.core.matching.classify semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.acam_match.acam_match import DEFAULT_BLOCK, acam_match


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


@functools.partial(jax.jit, static_argnames=("block",))
def match_scores(features: jax.Array, thresholds: jax.Array,
                 templates: jax.Array, *, block=DEFAULT_BLOCK) -> jax.Array:
    return acam_match(features, thresholds, templates, block=block,
                      interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("num_classes", "block"))
def classify(features: jax.Array, thresholds: jax.Array,
             templates_flat: jax.Array, valid_flat: jax.Array,
             num_classes: int, *, block=DEFAULT_BLOCK) -> tuple[jax.Array, jax.Array]:
    """templates_flat: (C*K, N) class-major; valid_flat: (C*K,) bool.

    Returns (pred (B,), per_class (B, C))."""
    scores = match_scores(features, thresholds, templates_flat, block=block)
    scores = jnp.where(valid_flat[None, :], scores, -jnp.inf)
    k = templates_flat.shape[0] // num_classes
    per_class = jnp.max(scores.reshape(scores.shape[0], num_classes, k), axis=-1)
    return jnp.argmax(per_class, axis=-1), per_class
