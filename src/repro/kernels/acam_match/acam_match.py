"""Pallas TPU kernel: ACAM feature-count matching (paper Eq. 8).

TPU adaptation (DESIGN.md §4): the binary match count
    S_fc(Q, T) = sum_i 1(Q_i == T_i)
is a Hamming affinity. GPU implementations reach for XNOR/popcount; the TPU
has no popcount path that beats the MXU, but with bits encoded as +/-1 bf16:

    S_fc = (N + Q~ . T~^T) / 2,     Q~ = 2Q-1, T~ = 2T-1

— a plain matmul. The kernel fuses the *binarisation* (mean-threshold
compare, paper §II-C) and the bipolar encoding into the K-loop so the binary
feature map never round-trips to HBM, then runs an MXU-tiled matmul:

    grid = (B/bm, M/bn, N/bk)           (k innermost: VMEM accumulation)
    features block (bm, bk)  VMEM
    thresholds block (1, bk) VMEM
    templates block (bn, bk) VMEM       (stored {0,1}, encoded on the fly)
    out block (bm, bn) f32   VMEM accumulator

All block dims are multiples of (8, 128) so MXU/VREG tiling is aligned.
Block sizes are no longer hardcoded at the call sites: `repro.kernels.tuning`
resolves a tuned `(bm, bn, bk)` from its persistent JSON cache
(``$REPRO_TUNING_CACHE``, default ``~/.cache/repro/pallas_blocks.json``,
keyed ``kernel|backend|shape|dtype``) and falls back to `DEFAULT_BLOCK`.

Three entry points:

  `acam_match`          -> (B, M) match-count scores (two-stage path).
  `acam_match_classify` -> fused binarize->match->valid-mask->per-class max
                           ->argmax/WTA (Eq. 12) in ONE pallas_call: the
                           (B, M) score matrix never round-trips to HBM.
                           Templates arrive K-major (`repro.kernels.layout`)
                           so the per-class max is K contiguous lane-aligned
                           slices of the score row.
  `acam_match_classify_margins`
                        -> the serving variant: same fused pipeline, plus a
                           per-row class *window* [class_lo, class_hi) (the
                           tenant's contiguous class range in a multi-tenant
                           super-bank) and the Eq. 12 winner-vs-runner-up
                           **margin** — the confidence signal the hybrid
                           cascade thresholds to decide accept-at-ACAM vs
                           escalate to the CNN head.
  `acam_match_classify_margins_chunked`
                        -> the big-bank margins variant: the template rows
                           arrive as a (K, Cp, N) stack and the grid tiles
                           the *class* dimension in ``cc``-column chunks, so
                           only K * cc template rows are VMEM-resident at a
                           time while the per-class running max accumulates
                           in a revisited (bm, Cp) block. Banks past the
                           fused-row budget (`repro.match.MAX_FUSED_ROWS`)
                           stay a SINGLE pallas_call instead of falling back
                           to the two-stage kernel + jnp margin epilogue.
  `acam_match_serve`    -> the resident serving mega-kernel: the whole
                           multi-tenant scheduler tick in ONE pallas_call.
                           On top of the chunked margins pipeline it folds
                           the per-slot tenant *threshold-row gather* (a
                           one-hot MXU select from the (T, N) thresholds
                           table — exact under HIGHEST precision) and the
                           cascade's escalation mask (margin < tau) into the
                           kernel, so the tick's super-bank path never
                           leaves VMEM and never runs a jnp epilogue. The
                           class chunk degenerates to the full padded class
                           count for banks inside the fused-row budget, so
                           one kernel covers both resident and chunked
                           regimes.

`repro.core.matching` dispatches to these by default (see its docstring for
the backend-selection API); the jnp references remain as oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (128, 128, 512)  # bm, bn, bk
PRED_LANES = 128  # WTA index output padded to one lane tile


def _kernel(f_ref, thr_ref, t_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    f = f_ref[...]  # (bm, bk) raw features
    thr = thr_ref[...]  # (1, bk)
    t = t_ref[...]  # (bn, bk) binary {0,1} template

    q_pm = jnp.where(f > thr, 1.0, -1.0).astype(jnp.bfloat16)  # fused binarise
    t_pm = (2.0 * t - 1.0).astype(jnp.bfloat16)
    # MXU matmul on bipolar codes; f32 accumulate
    acc = jax.lax.dot_general(
        q_pm, t_pm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def acam_match(features: jax.Array, thresholds: jax.Array,
               templates: jax.Array, *, block=DEFAULT_BLOCK,
               interpret: bool = False) -> jax.Array:
    """Match scores (B, M): count of features agreeing with each template.

    features:   (B, N) float — raw front-end feature maps
    thresholds: (N,) float — per-feature binarisation thresholds
    templates:  (M, N) float {0, 1} — programmed ACAM point templates
    """
    b, n = features.shape
    m = templates.shape[0]
    bm, bn, bk = block
    bp, mp, np_ = (-(-b // bm) * bm, -(-m // bn) * bn, -(-n // bk) * bk)

    f = jnp.pad(features, ((0, bp - b), (0, np_ - n)))
    # pad thresholds with +inf so padded features binarise to -1 on BOTH the
    # query and (0-padded) template side: they agree, adding a constant that
    # cancels in the bipolar identity below.
    thr = jnp.pad(thresholds, (0, np_ - n), constant_values=jnp.inf)[None, :]
    t = jnp.pad(templates, ((0, mp - m), (0, np_ - n)))

    nk = np_ // bk
    grid = (bp // bm, mp // bn, nk)
    dot = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        interpret=interpret,
    )(f, thr, t)
    # bipolar identity: matches = (N_padded + dot)/2 minus the padded-column
    # contribution (pad columns always "match": (-1)*(-1)=+1), i.e. use the
    # true N in the correction term.
    scores = (np_ + dot[:b, :m]) * 0.5 - (np_ - n)
    return scores


def _classify_kernel(f_ref, thr_ref, t_ref, vrow_ref, acc_ref, pc_ref,
                     pred_ref, *, nk: int, n_true: int, num_k: int, cp: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pm = jnp.where(f_ref[...] > thr_ref[...], 1.0, -1.0).astype(jnp.bfloat16)
    t_pm = (2.0 * t_ref[...] - 1.0).astype(jnp.bfloat16)
    acc_ref[...] += jax.lax.dot_general(
        q_pm, t_pm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        from repro.kernels.layout import wta_epilogue

        np_ = float(nk * f_ref.shape[-1])
        # bipolar identity + padded-column correction (same as acam_match)
        scores = (np_ + acc_ref[...]) * 0.5 - (np_ - n_true)
        per_class, pred = wta_epilogue(scores, vrow_ref[...], cp, num_k)
        pc_ref[...] = per_class
        pred_ref[...] = jnp.broadcast_to(pred[:, None], pred_ref.shape)


@functools.partial(jax.jit,
                   static_argnames=("num_classes", "block", "interpret"))
def acam_match_classify(features: jax.Array, thresholds: jax.Array,
                        templates_kmajor: jax.Array, valid_row: jax.Array,
                        num_classes: int, *, block=DEFAULT_BLOCK,
                        interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Fused Eq. 8 + Eq. 12: one pallas_call from raw features to WTA.

    features:         (B, N) float — raw front-end feature maps
    thresholds:       (N,) binarisation thresholds
    templates_kmajor: (K * Cp, N) {0,1}, K-major layout (repro.kernels.layout)
    valid_row:        (K * Cp,) float {0,1} row validity
    num_classes:      true C (Cp = padded lane multiple)

    Returns (pred (B,) int32, per_class (B, C) f32). Only `bm`/`bk` of
    `block` are used — the template dimension is resident in full.
    """
    b, n = features.shape
    mk, _ = templates_kmajor.shape
    from repro.kernels.layout import padded_classes
    cp = padded_classes(num_classes)
    num_k = mk // cp
    assert num_k * cp == mk, "templates must be K-major with padded classes"
    bm, _, bk = block
    bp, np_ = (-(-b // bm) * bm, -(-n // bk) * bk)

    f = jnp.pad(features, ((0, bp - b), (0, np_ - n)))
    thr = jnp.pad(thresholds, (0, np_ - n), constant_values=jnp.inf)[None, :]
    t = jnp.pad(templates_kmajor, ((0, 0), (0, np_ - n)))
    vrow = valid_row[None, :]

    nk = np_ // bk
    grid = (bp // bm, nk)
    _, per_class, pred = pl.pallas_call(
        functools.partial(_classify_kernel, nk=nk, n_true=n, num_k=num_k,
                          cp=cp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((1, bk), lambda i, k: (0, k)),
            pl.BlockSpec((mk, bk), lambda i, k: (0, k)),
            pl.BlockSpec((1, mk), lambda i, k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, mk), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, cp), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, mk), jnp.float32),  # score accumulator
            jax.ShapeDtypeStruct((bp, cp), jnp.float32),  # per-class max
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.int32),  # WTA index
        ],
        interpret=interpret,
    )(f, thr, t, vrow)
    return pred[:b, 0], per_class[:b, :num_classes]


def _classify_margins_kernel(f_ref, thr_ref, t_ref, vrow_ref, lo_ref, hi_ref,
                             acc_ref, pc_ref, pred_ref, margin_ref, *,
                             nk: int, n_true: int, num_k: int, cp: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pm = jnp.where(f_ref[...] > thr_ref[...], 1.0, -1.0).astype(jnp.bfloat16)
    t_pm = (2.0 * t_ref[...] - 1.0).astype(jnp.bfloat16)
    acc_ref[...] += jax.lax.dot_general(
        q_pm, t_pm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        from repro.kernels.layout import windowed_margin, wta_epilogue

        np_ = float(nk * f_ref.shape[-1])
        scores = (np_ + acc_ref[...]) * 0.5 - (np_ - n_true)
        per_class, _ = wta_epilogue(scores, vrow_ref[...], cp, num_k)
        pred, margin = windowed_margin(per_class, lo_ref[..., :1],
                                       hi_ref[..., :1], float(n_true))
        pc_ref[...] = per_class
        pred_ref[...] = jnp.broadcast_to(pred[:, None], pred_ref.shape)
        margin_ref[...] = jnp.broadcast_to(margin[:, None], margin_ref.shape)


@functools.partial(jax.jit,
                   static_argnames=("num_classes", "block", "interpret"))
def acam_match_classify_margins(
        features: jax.Array, thresholds: jax.Array,
        templates_kmajor: jax.Array, valid_row: jax.Array,
        class_lo: jax.Array, class_hi: jax.Array, num_classes: int, *,
        block=DEFAULT_BLOCK, interpret: bool = False
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused Eq. 8 + windowed Eq. 12 + confidence margin, one pallas_call.

    Operands as `acam_match_classify`, plus per-row class windows:

    class_lo/class_hi: (B,) int32 — each row's decision is restricted to
        classes ``[class_lo, class_hi)`` of the (shared, multi-tenant) bank;
        pass 0 / num_classes for the single-tenant case.

    Returns (pred (B,) int32 *global* class index, per_class (B, C) f32,
    margin (B,) f32 winner-vs-runner-up gap clamped to N). Rows whose window
    is empty (lo == hi, e.g. scheduler slot padding) get pred 0, margin 0.
    """
    b, n = features.shape
    mk, _ = templates_kmajor.shape
    from repro.kernels.layout import padded_classes
    cp = padded_classes(num_classes)
    num_k = mk // cp
    assert num_k * cp == mk, "templates must be K-major with padded classes"
    bm, _, bk = block
    bp, np_ = (-(-b // bm) * bm, -(-n // bk) * bk)

    f = jnp.pad(features, ((0, bp - b), (0, np_ - n)))
    thr = jnp.pad(thresholds, (0, np_ - n), constant_values=jnp.inf)[None, :]
    t = jnp.pad(templates_kmajor, ((0, 0), (0, np_ - n)))
    vrow = valid_row[None, :]
    # windows ride in lane-aligned (B, PRED_LANES) int32 carriers (col 0 is
    # the payload); batch padding rows get the empty window [0, 0)
    lo = jnp.broadcast_to(
        jnp.pad(class_lo.astype(jnp.int32), (0, bp - b))[:, None],
        (bp, PRED_LANES))
    hi = jnp.broadcast_to(
        jnp.pad(class_hi.astype(jnp.int32), (0, bp - b))[:, None],
        (bp, PRED_LANES))

    nk = np_ // bk
    grid = (bp // bm, nk)
    _, per_class, pred, margin = pl.pallas_call(
        functools.partial(_classify_margins_kernel, nk=nk, n_true=n,
                          num_k=num_k, cp=cp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((1, bk), lambda i, k: (0, k)),
            pl.BlockSpec((mk, bk), lambda i, k: (0, k)),
            pl.BlockSpec((1, mk), lambda i, k: (0, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, k: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, mk), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, cp), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, mk), jnp.float32),  # score accumulator
            jax.ShapeDtypeStruct((bp, cp), jnp.float32),  # per-class max
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.int32),  # WTA index
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.float32),  # margin
        ],
        interpret=interpret,
    )(f, thr, t, vrow, lo, hi)
    return pred[:b, 0], per_class[:b, :num_classes], margin[:b, 0]


def _classify_margins_chunked_kernel(f_ref, thr_ref, t_ref, v_ref, lo_ref,
                                     hi_ref, acc_ref, pc_ref, pred_ref,
                                     margin_ref, *, nj: int, nk: int,
                                     n_true: int, num_k: int, cc: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pm = jnp.where(f_ref[...] > thr_ref[...], 1.0, -1.0).astype(jnp.bfloat16)
    # this chunk's K * cc template rows, flattened K-major: row kk*cc + c
    t = t_ref[...].reshape(num_k * cc, t_ref.shape[-1])
    t_pm = (2.0 * t - 1.0).astype(jnp.bfloat16)
    acc_ref[...] += jax.lax.dot_general(
        q_pm, t_pm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _chunk_epilogue():
        from repro.kernels.layout import windowed_margin

        np_ = float(nk * f_ref.shape[-1])
        scores = (np_ + acc_ref[...]) * 0.5 - (np_ - n_true)
        vrow = v_ref[...].reshape(1, num_k * cc)
        s = jnp.where(vrow > 0, scores, -jnp.inf)
        chunk_pc = s[:, :cc]
        for kk in range(1, num_k):
            chunk_pc = jnp.maximum(chunk_pc, s[:, kk * cc:(kk + 1) * cc])
        # running per-class max in the revisited (bm, Cp) block; the j == 0
        # chunk overwrites whatever the buffer held (uninitialised memory)
        prev = jnp.where(j == 0,
                         jnp.full(pc_ref.shape, -jnp.inf, pc_ref.dtype),
                         pc_ref[...])
        # chunk offsets are cc (lane-tile) multiples, so the dynamic lane
        # slice stays aligned on TPU
        pc = jax.lax.dynamic_update_slice(prev, chunk_pc, (0, j * cc))
        pc_ref[...] = pc

        @pl.when(j == nj - 1)
        def _final():
            pred, margin = windowed_margin(pc, lo_ref[..., :1],
                                           hi_ref[..., :1], float(n_true))
            pred_ref[...] = jnp.broadcast_to(pred[:, None], pred_ref.shape)
            margin_ref[...] = jnp.broadcast_to(margin[:, None],
                                               margin_ref.shape)


@functools.partial(jax.jit, static_argnames=("num_classes", "chunk", "block",
                                             "interpret"))
def acam_match_classify_margins_chunked(
        features: jax.Array, thresholds: jax.Array,
        templates_kcp: jax.Array, valid_kcp: jax.Array,
        class_lo: jax.Array, class_hi: jax.Array, num_classes: int, *,
        chunk: int, block=DEFAULT_BLOCK, interpret: bool = False
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Class-chunked `acam_match_classify_margins` for big banks.

    Same contract and outputs as the fused margins kernel, but the
    templates arrive as a (K, Cp, N) stack (`repro.kernels.layout.stack_kcp`)
    and the grid walks the class dimension in ``chunk``-column tiles
    (``chunk`` a lane-multiple divisor of Cp, `layout.class_chunk`): at any
    moment only K * chunk template rows sit in VMEM, the Eq. 12 per-class
    max accumulates across chunks in a revisited (bm, Cp) output block, and
    the windowed-margin epilogue runs once at the last chunk — ONE
    pallas_call at any bank size.
    """
    b, n = features.shape
    num_k, cp, _ = templates_kcp.shape
    assert cp % chunk == 0, "chunk must divide the padded class count"
    bm, _, bk = block
    bp, np_ = (-(-b // bm) * bm, -(-n // bk) * bk)

    f = jnp.pad(features, ((0, bp - b), (0, np_ - n)))
    thr = jnp.pad(thresholds, (0, np_ - n), constant_values=jnp.inf)[None, :]
    t = jnp.pad(templates_kcp, ((0, 0), (0, 0), (0, np_ - n)))
    lo = jnp.broadcast_to(
        jnp.pad(class_lo.astype(jnp.int32), (0, bp - b))[:, None],
        (bp, PRED_LANES))
    hi = jnp.broadcast_to(
        jnp.pad(class_hi.astype(jnp.int32), (0, bp - b))[:, None],
        (bp, PRED_LANES))

    nj = cp // chunk
    nk = np_ // bk
    grid = (bp // bm, nj, nk)
    _, per_class, pred, margin = pl.pallas_call(
        functools.partial(_classify_margins_chunked_kernel, nj=nj, nk=nk,
                          n_true=n, num_k=num_k, cc=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((num_k, chunk, bk), lambda i, j, k: (0, j, k)),
            pl.BlockSpec((num_k, chunk), lambda i, j, k: (0, j)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, num_k * chunk), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, cp), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            # per-chunk score accumulator (K * cc live rows per grid step)
            jax.ShapeDtypeStruct((bp, num_k * cp), jnp.float32),
            jax.ShapeDtypeStruct((bp, cp), jnp.float32),  # running per-class
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.int32),  # WTA index
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.float32),  # margin
        ],
        interpret=interpret,
    )(f, thr, t, valid_kcp, lo, hi)
    return pred[:b, 0], per_class[:b, :num_classes], margin[:b, 0]


def _serve_kernel(f_ref, slot_ref, thr_ref, t_ref, v_ref, lo_ref, hi_ref,
                  tau_ref, acc_ref, pc_ref, pred_ref, margin_ref, esc_ref, *,
                  nj: int, nk: int, n_true: int, num_k: int, cc: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-slot tenant threshold row, gathered IN the kernel: a one-hot MXU
    # select from the resident (T_pad, bk) thresholds-table block. Exact:
    # each output element sums exactly one table entry (1.0 * thr) plus
    # zeros, and HIGHEST precision keeps the f32 values unrounded — so
    # (f - thr) > 0 below reproduces the jnp take-then-shift composition
    # bit for bit.
    slot = slot_ref[..., :1]  # (bm, 1) payload column
    t_pad = thr_ref.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (slot.shape[0], t_pad), 1)
    onehot = (iota == slot).astype(jnp.float32)
    thr = jax.lax.dot_general(
        onehot, thr_ref[...], (((1,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)
    # per-tenant thresholds -> shared zero threshold (the scheduler's shift
    # trick, now in VMEM): binarize(f, thr_t) == (f - thr_t) > 0
    q_pm = jnp.where(f_ref[...] - thr > 0, 1.0, -1.0).astype(jnp.bfloat16)
    t = t_ref[...].reshape(num_k * cc, t_ref.shape[-1])
    t_pm = (2.0 * t - 1.0).astype(jnp.bfloat16)
    acc_ref[...] += jax.lax.dot_general(
        q_pm, t_pm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _chunk_epilogue():
        from repro.kernels.layout import windowed_margin

        np_ = float(nk * f_ref.shape[-1])
        scores = (np_ + acc_ref[...]) * 0.5 - (np_ - n_true)
        vrow = v_ref[...].reshape(1, num_k * cc)
        s = jnp.where(vrow > 0, scores, -jnp.inf)
        chunk_pc = s[:, :cc]
        for kk in range(1, num_k):
            chunk_pc = jnp.maximum(chunk_pc, s[:, kk * cc:(kk + 1) * cc])
        prev = jnp.where(j == 0,
                         jnp.full(pc_ref.shape, -jnp.inf, pc_ref.dtype),
                         pc_ref[...])
        pc = jax.lax.dynamic_update_slice(prev, chunk_pc, (0, j * cc))
        pc_ref[...] = pc

        @pl.when(j == nj - 1)
        def _final():
            pred, margin = windowed_margin(pc, lo_ref[..., :1],
                                           hi_ref[..., :1], float(n_true))
            # the cascade's escalation mask: strictly below tau asks for the
            # CNN head; padding rows carry tau = -inf (never escalate)
            esc = (margin < tau_ref[..., 0]).astype(jnp.int32)
            pred_ref[...] = jnp.broadcast_to(pred[:, None], pred_ref.shape)
            margin_ref[...] = jnp.broadcast_to(margin[:, None],
                                               margin_ref.shape)
            esc_ref[...] = jnp.broadcast_to(esc[:, None], esc_ref.shape)


@functools.partial(jax.jit, static_argnames=("num_classes", "chunk", "block",
                                             "interpret"))
def acam_match_serve(
        features: jax.Array, thr_table: jax.Array, tenant_slot: jax.Array,
        templates_kcp: jax.Array, valid_kcp: jax.Array,
        class_lo: jax.Array, class_hi: jax.Array, tau: jax.Array,
        num_classes: int, *, chunk: int, block=DEFAULT_BLOCK,
        interpret: bool = False
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """The resident serving mega-kernel: gather -> binarize -> match ->
    per-class max -> WTA -> windowed Eq. 12 margin -> escalation mask, ONE
    pallas_call over the multi-tenant super-bank.

    features:    (B, N) raw per-slot front-end feature maps (UNshifted)
    thr_table:   (T, N) per-tenant binarisation threshold rows
    tenant_slot: (B,) int32 — each row's tenant slot in ``thr_table``
    templates_kcp/valid_kcp: (K, Cp, N) / (K, Cp) super-bank stack
                 (`repro.kernels.layout.stack_kcp`)
    class_lo/class_hi: (B,) int32 tenant class windows (global indices)
    tau:         (B,) f32 cascade threshold; escalate = margin < tau
    chunk:       class columns per grid tile (`layout.class_chunk`) — equal
                 to Cp for banks inside the fused-row budget (nj == 1, the
                 fully resident case)

    Returns (pred (B,) int32 global class index, per_class (B, C) f32,
    margin (B,) f32, escalate (B,) bool). Rows with empty windows (slot
    padding) get pred 0 / margin 0, and padding rows ride tau = -inf so
    they never escalate.
    """
    b, n = features.shape
    num_k, cp, _ = templates_kcp.shape
    assert cp % chunk == 0, "chunk must divide the padded class count"
    t_rows = thr_table.shape[0]
    t_pad = -(-t_rows // 8) * 8  # sublane-align the thresholds table
    bm, _, bk = block
    bp, np_ = (-(-b // bm) * bm, -(-n // bk) * bk)

    # features pad with -inf (not 0): q = (f - thr) > 0 must binarise padded
    # columns to -1 for ANY thr, matching the 0-padded template bits; the
    # thresholds table itself pads with zeros so the one-hot select stays
    # finite (0 * inf would poison the MXU sum with NaN).
    f = jnp.pad(features, ((0, bp - b), (0, np_ - n)),
                constant_values=-jnp.inf)
    thr = jnp.pad(thr_table.astype(jnp.float32),
                  ((0, t_pad - t_rows), (0, np_ - n)))
    t = jnp.pad(templates_kcp, ((0, 0), (0, 0), (0, np_ - n)))
    # scalar per-row operands ride lane-aligned (B, PRED_LANES) carriers
    slot = jnp.broadcast_to(
        jnp.pad(tenant_slot.astype(jnp.int32), (0, bp - b))[:, None],
        (bp, PRED_LANES))
    lo = jnp.broadcast_to(
        jnp.pad(class_lo.astype(jnp.int32), (0, bp - b))[:, None],
        (bp, PRED_LANES))
    hi = jnp.broadcast_to(
        jnp.pad(class_hi.astype(jnp.int32), (0, bp - b))[:, None],
        (bp, PRED_LANES))
    tau_c = jnp.broadcast_to(
        jnp.pad(tau.astype(jnp.float32), (0, bp - b),
                constant_values=-jnp.inf)[:, None],
        (bp, PRED_LANES))

    nj = cp // chunk
    nk = np_ // bk
    grid = (bp // bm, nj, nk)
    _, per_class, pred, margin, esc = pl.pallas_call(
        functools.partial(_serve_kernel, nj=nj, nk=nk, n_true=n,
                          num_k=num_k, cc=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((t_pad, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((num_k, chunk, bk), lambda i, j, k: (0, j, k)),
            pl.BlockSpec((num_k, chunk), lambda i, j, k: (0, j)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, num_k * chunk), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, cp), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, PRED_LANES), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, num_k * cp), jnp.float32),
            jax.ShapeDtypeStruct((bp, cp), jnp.float32),  # running per-class
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.int32),  # WTA index
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.float32),  # margin
            jax.ShapeDtypeStruct((bp, PRED_LANES), jnp.int32),  # escalate
        ],
        interpret=interpret,
    )(f, slot, thr, t, valid_kcp, lo, hi, tau_c)
    return (pred[:b, 0], per_class[:b, :num_classes], margin[:b, 0],
            esc[:b, 0].astype(bool))
