"""Pallas TPU kernel: ACAM feature-count matching (paper Eq. 8).

TPU adaptation (DESIGN.md §4): the binary match count
    S_fc(Q, T) = sum_i 1(Q_i == T_i)
is a Hamming affinity. GPU implementations reach for XNOR/popcount; the TPU
has no popcount path that beats the MXU, but with bits encoded as +/-1 bf16:

    S_fc = (N + Q~ . T~^T) / 2,     Q~ = 2Q-1, T~ = 2T-1

— a plain matmul. The kernel fuses the *binarisation* (mean-threshold
compare, paper §II-C) and the bipolar encoding into the K-loop so the binary
feature map never round-trips to HBM, then runs an MXU-tiled matmul:

    grid = (B/bm, M/bn, N/bk)           (k innermost: VMEM accumulation)
    features block (bm, bk)  VMEM
    thresholds block (1, bk) VMEM
    templates block (bn, bk) VMEM       (stored {0,1}, encoded on the fly)
    out block (bm, bn) f32   VMEM accumulator

All block dims are multiples of (8, 128) so MXU/VREG tiling is aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (128, 128, 512)  # bm, bn, bk


def _kernel(f_ref, thr_ref, t_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    f = f_ref[...]  # (bm, bk) raw features
    thr = thr_ref[...]  # (1, bk)
    t = t_ref[...]  # (bn, bk) binary {0,1} template

    q_pm = jnp.where(f > thr, 1.0, -1.0).astype(jnp.bfloat16)  # fused binarise
    t_pm = (2.0 * t - 1.0).astype(jnp.bfloat16)
    # MXU matmul on bipolar codes; f32 accumulate
    acc = jax.lax.dot_general(
        q_pm, t_pm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def acam_match(features: jax.Array, thresholds: jax.Array,
               templates: jax.Array, *, block=DEFAULT_BLOCK,
               interpret: bool = False) -> jax.Array:
    """Match scores (B, M): count of features agreeing with each template.

    features:   (B, N) float — raw front-end feature maps
    thresholds: (N,) float — per-feature binarisation thresholds
    templates:  (M, N) float {0, 1} — programmed ACAM point templates
    """
    b, n = features.shape
    m = templates.shape[0]
    bm, bn, bk = block
    bp, mp, np_ = (-(-b // bm) * bm, -(-m // bn) * bn, -(-n // bk) * bk)

    f = jnp.pad(features, ((0, bp - b), (0, np_ - n)))
    # pad thresholds with +inf so padded features binarise to -1 on BOTH the
    # query and (0-padded) template side: they agree, adding a constant that
    # cancels in the bipolar identity below.
    thr = jnp.pad(thresholds, (0, np_ - n), constant_values=jnp.inf)[None, :]
    t = jnp.pad(templates, ((0, mp - m), (0, np_ - n)))

    nk = np_ // bk
    grid = (bp // bm, mp // bn, nk)
    dot = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        interpret=interpret,
    )(f, thr, t)
    # bipolar identity: matches = (N_padded + dot)/2 minus the padded-column
    # contribution (pad columns always "match": (-1)*(-1)=+1), i.e. use the
    # true N in the correction term.
    scores = (np_ + dot[:b, :m]) * 0.5 - (np_ - n)
    return scores
