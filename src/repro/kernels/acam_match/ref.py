"""Pure-jnp oracle for the acam_match kernel (paper Eq. 8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def acam_match_ref(features: jax.Array, thresholds: jax.Array,
                   templates: jax.Array) -> jax.Array:
    """(B, M) count of agreeing features: S = sum_i 1(Q_i == T_i)."""
    q = (features > thresholds[None, :]).astype(jnp.float32)
    eq = q[:, None, :] == templates[None, :, :]
    return jnp.sum(eq, axis=-1).astype(jnp.float32)
