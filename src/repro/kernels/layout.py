"""Template-bank layouts shared by the fused ACAM classify kernels.

The bank is stored class-major ``(C, K, N)`` (class c, template k). The fused
binarize->match->WTA kernels need the Eq. 12 per-class max to be computable
from *contiguous, lane-aligned* slices of the score row, so they use a
**K-major** flattening: template row ``kk * Cp + c`` holds ``bank[c, kk]``,
with C padded up to ``Cp`` (a lane multiple, 128). The per-class max is then

    per_class = max_kk scores[:, kk*Cp : (kk+1)*Cp]          # K static slices

— no strided gather, no in-kernel reshape. Padded class columns and invalid
templates carry ``valid_row = 0`` and are driven to -inf before the max.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128


def padded_classes(num_classes: int, lane: int = LANE) -> int:
    return -(-num_classes // lane) * lane


def flatten_kmajor(arr: jax.Array, num_classes: int) -> jax.Array:
    """(C, K, N) -> (K * Cp, N), row kk*Cp + c = arr[c, kk], zero-padded."""
    c, k, n = arr.shape
    assert c == num_classes
    cp = padded_classes(c)
    out = jnp.zeros((k, cp, n), arr.dtype).at[:, :c, :].set(
        jnp.swapaxes(arr, 0, 1))
    return out.reshape(k * cp, n)


def valid_kmajor(valid: jax.Array, num_classes: int) -> jax.Array:
    """(C, K) bool -> (K * Cp,) float {0,1}; padded classes are invalid."""
    c, k = valid.shape
    assert c == num_classes
    cp = padded_classes(c)
    out = jnp.zeros((k, cp), jnp.float32).at[:, :c].set(
        jnp.swapaxes(valid.astype(jnp.float32), 0, 1))
    return out.reshape(k * cp)


def stack_kcp(arr: jax.Array, num_classes: int) -> jax.Array:
    """(C, K, N) -> (K, Cp, N), zero-padded: the 3D operand of the
    class-chunked margins kernel. Row [kk, c] = arr[c, kk]; a chunk of
    ``cc`` class columns is a contiguous (K, cc, N) block, so the kernel
    tiles the class dimension with a plain BlockSpec instead of keeping
    all K * Cp rows VMEM-resident."""
    c, k, n = arr.shape
    assert c == num_classes
    cp = padded_classes(c)
    return jnp.zeros((k, cp, n), arr.dtype).at[:, :c, :].set(
        jnp.swapaxes(arr, 0, 1))


def valid_kcp(valid: jax.Array, num_classes: int) -> jax.Array:
    """(C, K) bool -> (K, Cp) float {0,1}; padded classes are invalid."""
    c, k = valid.shape
    assert c == num_classes
    cp = padded_classes(c)
    return jnp.zeros((k, cp), jnp.float32).at[:, :c].set(
        jnp.swapaxes(valid.astype(jnp.float32), 0, 1))


def class_chunk(cp: int, num_k: int, max_rows: int, lane: int = LANE) -> int:
    """Class columns per chunk for the chunked margins kernel: the largest
    lane-multiple divisor of ``Cp`` whose ``num_k * cc`` template rows fit
    the fused-row budget; ``lane`` when even one K-slice of a single lane
    tile exceeds it (the budget is a VMEM policy, not a hard limit)."""
    best = lane
    for units in range(cp // lane, 0, -1):
        cc = units * lane
        if cp % cc == 0 and num_k * cc <= max_rows:
            best = cc
            break
    return best


def wta_epilogue(scores: jax.Array, valid_row: jax.Array, cp: int,
                 num_k: int) -> tuple[jax.Array, jax.Array]:
    """Shared fused-kernel epilogue over K-major scores (pure jnp, runs
    inside both classify kernels): valid mask -> Eq. 12 per-class max over
    the K contiguous class slices -> WTA argmax.

    scores: (bm, K * Cp); valid_row: (1, K * Cp) float {0,1}.
    Returns (per_class (bm, Cp), pred (bm,) int32).
    """
    s = jnp.where(valid_row > 0, scores, -jnp.inf)
    per_class = s[:, :cp]
    for kk in range(1, num_k):
        per_class = jnp.maximum(per_class, s[:, kk * cp:(kk + 1) * cp])
    pred = jnp.argmax(per_class, axis=-1).astype(jnp.int32)
    return per_class, pred


def windowed_margin(per_class: jax.Array, class_lo: jax.Array,
                    class_hi: jax.Array, cap: float
                    ) -> tuple[jax.Array, jax.Array]:
    """Eq. 12 decision + winner-vs-runner-up margin inside a class window.

    The multi-tenant serving path stacks every tenant's classes into one
    super-bank; each request only competes within its tenant's contiguous
    class range ``[class_lo, class_hi)``. The margin is the confidence
    signal of the hybrid cascade (accept-at-ACAM vs escalate to the CNN
    head), clamped to ``cap`` (the score range: N for feature counts, 1 for
    similarities) so a single-valid-class window reads as fully confident
    instead of +inf.

    per_class: (bm, Cp) scores (-inf for invalid/padded classes)
    class_lo/class_hi: (bm, 1) int32 window bounds per row
    Returns (pred (bm,) int32 global class index, margin (bm,) f32).
    Rows with an empty window (lo == hi, e.g. batch padding) get pred 0,
    margin 0. Pure jnp, safe inside a Pallas kernel body.
    """
    bm, cp = per_class.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bm, cp), 1)
    win = (iota >= class_lo) & (iota < class_hi)
    s = jnp.where(win, per_class, -jnp.inf)
    top1 = jnp.max(s, axis=-1)
    pred = jnp.argmax(s, axis=-1).astype(jnp.int32)
    runner = jnp.where(iota == pred[:, None], -jnp.inf, s)
    # clamp the runner-up at (top1 - cap): bounds the margin and keeps the
    # subtraction finite when the window holds a single valid class
    top2 = jnp.maximum(jnp.max(runner, axis=-1), top1 - cap)
    margin = jnp.where(jnp.isfinite(top1), top1 - top2, 0.0)
    return pred, margin.astype(jnp.float32)
