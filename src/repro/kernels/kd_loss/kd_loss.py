"""Pallas TPU kernel: fused knowledge-distillation loss (paper Eq. 1-3).

    L_i = alpha * T^2 * KL(sigma(z_t/T) || sigma(z_s/T))
        + (1 - alpha) * CE(z_s, y_i)

per sample i. The paper distils a 10-class CNN; at the LM scale of the
assigned architectures (vocab up to 152k) the naive formulation materialises
four (B, V) f32 temporaries (two softmaxes, two log-softmaxes). This kernel
streams the vocab axis in VMEM tiles with online (rescaled) accumulators, so
HBM traffic is exactly one read of z_s and z_t:

  grid = (B/bm, V/bk), k innermost. Per-row carried state (f32, VMEM):
    m_u, l_u : running max / rescaled expsum of z_t/T   (teacher lse)
    m_v, l_v : same for z_s/T                           (student lse)
    m_w, l_w : same for z_s at T=1                      (CE lse)
    a        : running sum  e^{z_t/T - m_u} * (z_t/T - z_s/T)
    picked   : z_s[label]   (one-hot within tile)
  epilogue:
    KL = a/l_u - (m_u + log l_u) + (m_v + log l_v)
    CE = (m_w + log l_w) - picked
    L  = alpha*T^2*KL + (1-alpha)*CE

Using the identity KL = sum p_t (u - v) - lse_u + lse_v with u = z_t/T,
v = z_s/T; `a` is rescaled exactly like l_u when m_u changes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 2048)  # bm rows, bk vocab tile
NEG = -1e30


def _kernel(zs_ref, zt_ref, lbl_ref, loss_ref,
            mu_ref, lu_ref, mv_ref, lv_ref, mw_ref, lw_ref, a_ref, pick_ref,
            *, nk: int, bk: int, temperature: float, alpha: float):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        for r in (mu_ref, mv_ref, mw_ref):
            r[...] = jnp.full_like(r, NEG)
        for r in (lu_ref, lv_ref, lw_ref, a_ref, pick_ref):
            r[...] = jnp.zeros_like(r)

    zs = zs_ref[...].astype(jnp.float32)  # (bm, bk)
    zt = zt_ref[...].astype(jnp.float32)
    u = zt / temperature
    v = zs / temperature

    # --- teacher lse + cross-term accumulator (shared max m_u) ---
    mu_old = mu_ref[...]
    mu_new = jnp.maximum(mu_old, jnp.max(u, axis=-1, keepdims=True))
    scale_u = jnp.exp(mu_old - mu_new)
    e_u = jnp.exp(u - mu_new)
    lu_ref[...] = lu_ref[...] * scale_u + jnp.sum(e_u, axis=-1, keepdims=True)
    a_ref[...] = a_ref[...] * scale_u + jnp.sum(e_u * (u - v), axis=-1,
                                                keepdims=True)
    mu_ref[...] = mu_new

    # --- student lse at temperature T ---
    mv_old = mv_ref[...]
    mv_new = jnp.maximum(mv_old, jnp.max(v, axis=-1, keepdims=True))
    lv_ref[...] = lv_ref[...] * jnp.exp(mv_old - mv_new) + jnp.sum(
        jnp.exp(v - mv_new), axis=-1, keepdims=True)
    mv_ref[...] = mv_new

    # --- student lse at T=1 + one-hot pick (CE term) ---
    mw_old = mw_ref[...]
    mw_new = jnp.maximum(mw_old, jnp.max(zs, axis=-1, keepdims=True))
    lw_ref[...] = lw_ref[...] * jnp.exp(mw_old - mw_new) + jnp.sum(
        jnp.exp(zs - mw_new), axis=-1, keepdims=True)
    mw_ref[...] = mw_new
    cols = k * bk + jax.lax.broadcasted_iota(jnp.int32, zs.shape, 1)
    onehot = (cols == lbl_ref[...]).astype(jnp.float32)
    pick_ref[...] += jnp.sum(onehot * zs, axis=-1, keepdims=True)

    @pl.when(k == nk - 1)
    def _epilogue():
        lse_u = mu_ref[...] + jnp.log(lu_ref[...])
        lse_v = mv_ref[...] + jnp.log(lv_ref[...])
        lse_w = mw_ref[...] + jnp.log(lw_ref[...])
        kl = a_ref[...] / lu_ref[...] - lse_u + lse_v
        ce = lse_w - pick_ref[...]
        loss_ref[...] = (alpha * temperature**2) * kl + (1.0 - alpha) * ce


@functools.partial(jax.jit, static_argnames=("temperature", "alpha", "block",
                                             "interpret"))
def kd_loss(student_logits: jax.Array, teacher_logits: jax.Array,
            labels: jax.Array, *, temperature: float = 4.0,
            alpha: float = 0.5, block=DEFAULT_BLOCK,
            interpret: bool = False) -> jax.Array:
    """Per-sample fused distillation loss (B,)."""
    b, v = student_logits.shape
    bm, bk = block
    bm = min(bm, -(-b // 8) * 8)
    bp, vp = -(-b // bm) * bm, -(-v // bk) * bk

    zs = jnp.pad(student_logits, ((0, bp - b), (0, vp - v)),
                 constant_values=NEG)
    zt = jnp.pad(teacher_logits, ((0, bp - b), (0, vp - v)),
                 constant_values=NEG)
    lbl = jnp.pad(labels, (0, bp - b)).astype(jnp.int32)[:, None]

    nk = vp // bk
    acc = lambda: pl.BlockSpec((bm, 1), lambda i, k: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bk=bk, temperature=temperature,
                          alpha=alpha),
        grid=(bp // bm, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),
        ],
        out_specs=[acc() for _ in range(9)],
        out_shape=[jax.ShapeDtypeStruct((bp, 1), jnp.float32)
                   for _ in range(9)],
        interpret=interpret,
    )(zs, zt, lbl)
    return outs[0][:b, 0]
