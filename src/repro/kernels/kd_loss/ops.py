"""jit'd public wrapper for the kd_loss kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kd_loss.kd_loss import DEFAULT_BLOCK, kd_loss


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


@functools.partial(jax.jit, static_argnames=("temperature", "alpha", "block"))
def distillation_loss(student_logits: jax.Array, teacher_logits: jax.Array,
                      labels: jax.Array, *, temperature: float = 4.0,
                      alpha: float = 0.5, block=DEFAULT_BLOCK) -> jax.Array:
    """Mean fused KD loss (paper Eq. 1). Accepts (B, V) or (B, S, V)."""
    zs, zt, y = student_logits, teacher_logits, labels
    if zs.ndim == 3:
        zs = zs.reshape(-1, zs.shape[-1])
        zt = zt.reshape(-1, zt.shape[-1])
        y = y.reshape(-1)
    per = kd_loss(zs, zt, y, temperature=temperature, alpha=alpha,
                  block=block, interpret=_on_cpu())
    return jnp.mean(per)
