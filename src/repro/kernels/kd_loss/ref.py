"""Pure-jnp oracle for the kd_loss kernel (delegates to repro.core.distill
semantics, per-sample)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kd_loss_ref(student_logits: jax.Array, teacher_logits: jax.Array,
                labels: jax.Array, *, temperature: float = 4.0,
                alpha: float = 0.5) -> jax.Array:
    zs = student_logits.astype(jnp.float32)
    zt = teacher_logits.astype(jnp.float32)
    log_ps = jax.nn.log_softmax(zs / temperature, axis=-1)
    pt = jax.nn.softmax(zt / temperature, axis=-1)
    log_pt = jax.nn.log_softmax(zt / temperature, axis=-1)
    kl = jnp.sum(pt * (log_pt - log_ps), axis=-1)
    logp = jax.nn.log_softmax(zs, axis=-1)
    ce = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return alpha * temperature**2 * kl + (1 - alpha) * ce
