"""Pallas TPU kernel: causal flash attention (forward).

The XLA fallback (repro.models.layers.chunked_attention) streams q-chunks
but still materialises (bq, Sk) scores per chunk in HBM on CPU; on TPU this
kernel keeps the whole online-softmax state in VMEM:

    grid = (B*H, Sq/bq, Sk/bk)   (k innermost)
    q block  (1, bq, D)  VMEM      kv blocks (1, bk, D) VMEM
    scratch  m (bq, 128), l (bq, 128), acc (bq, D)  f32 VMEM

Causal masking skips fully-masked kv blocks via pl.when (no MXU work issued
for the upper triangle — the ~2x causal saving the XLA fallback lacks).
GQA is handled in ops.py by reshaping kv-head groups into the batch dim.
Backward pass: the training path keeps the XLA fallback under remat (a
custom VJP kernel is listed as future work in DESIGN.md); this kernel
targets the serving/prefill path, which is where the 32k cells run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (512, 512)  # bq, bk
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc,
            *, nk: int, bq: int, bk: int, causal: bool, scale: float,
            sk_true: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # causal: kv block strictly above the diagonal of this q block -> skip
    live = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(live)
    def _block():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < sk_true  # padded keys never win
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask = jnp.logical_and(mask, kpos <= qpos)
        s = jnp.where(mask, s, NEG)
        m_old = m_sc[:, :1]  # (bq, 1)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)  # (bq, bk)
        corr = jnp.exp(m_old - m_new)  # (bq, 1)
        l_sc[:, :1] = l_sc[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[:, :1] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_sc[:, :1]
        o_ref[0] = (acc_sc[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block=DEFAULT_BLOCK,
                    interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, D) (heads pre-flattened into batch). Returns (BH, Sq, D)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq, bk = block
    bq, bk = min(bq, sq), min(bk, sk)
    sqp, skp = -(-sq // bq) * bq, -(-sk // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, sqp - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skp - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skp - sk), (0, 0)))
    nk = skp // bk
    scale = d ** -0.5

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bq=bq, bk=bk, causal=causal,
                          scale=scale, sk_true=sk),
        grid=(bh, sqp // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq]
