"""Pure-jnp oracle for the flash_attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q, k, v: (BH, S, D). Plain softmax attention in f32."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
