"""jit'd public wrapper: GQA-aware flash attention entry point.

Accepts model-layout tensors q (B, S, H, D), k/v (B, S, KV, D); expands GQA
groups and flattens (B, H) into the kernel's batch dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    DEFAULT_BLOCK, flash_attention)


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "block"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, block=DEFAULT_BLOCK) -> jax.Array:
    """(B, S, H, D) x (B, S, KV, D) -> (B, S, H, D)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q3 = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    k3 = k.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    v3 = v.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    o = flash_attention(q3, k3, v3, causal=causal, block=block,
                        interpret=_on_cpu())
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
