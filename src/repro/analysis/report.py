"""Assemble the roofline table from dry-run JSON records.

    PYTHONPATH=src python -m repro.analysis.report [--dir experiments/dryrun]

Emits the EXPERIMENTS.md §Roofline markdown table plus hillclimb-candidate
ranking (worst roofline fraction / most collective-bound).
"""
from __future__ import annotations

import argparse
import json
import pathlib


def load(dir_: str) -> list[dict]:
    recs = []
    for p in sorted(pathlib.Path(dir_).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(recs: list[dict], mesh: str = "pod16x16", opt: bool = False) -> str:
    rows = ["| arch | shape | mode | t_compute | t_memory | t_collective | "
            "dominant | useful | MFU-bound | args/dev | temp/dev |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("skipped") or r["mesh"] != mesh or bool(r.get("opt")) != opt:
            continue
        ro = r["roofline"]
        mem = r.get("memory", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r.get('mode','?')} | "
            f"{fmt_s(ro['t_compute_s'])} | {fmt_s(ro['t_memory_s'])} | "
            f"{fmt_s(ro['t_collective_s'])} | {ro['dominant']} | "
            f"{ro['useful_frac']:.2f} | {ro['mfu_bound']*100:.1f}% | "
            f"{mem.get('argument_bytes', 0)/2**30:.1f}GiB | "
            f"{mem.get('temp_bytes', 0)/2**30:.1f}GiB |")
    return "\n".join(rows)


def compare(recs: list[dict], mesh: str = "pod16x16") -> str:
    """Baseline vs --opt side-by-side (t_bound and MFU-bound)."""
    base = {(r["arch"], r["shape"]): r for r in recs
            if not r.get("skipped") and r["mesh"] == mesh and not r.get("opt")}
    opt = {(r["arch"], r["shape"]): r for r in recs
           if not r.get("skipped") and r["mesh"] == mesh and r.get("opt")}
    rows = ["| arch | shape | t_bound base | t_bound opt | speedup | "
            "MFU base | MFU opt |", "|---|---|---|---|---|---|---|"]
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key]["roofline"], opt[key]["roofline"]
        sp = b["t_bound_s"] / max(o["t_bound_s"], 1e-12)
        rows.append(f"| {key[0]} | {key[1]} | {fmt_s(b['t_bound_s'])} | "
                    f"{fmt_s(o['t_bound_s'])} | {sp:.2f}x | "
                    f"{b['mfu_bound']*100:.1f}% | {o['mfu_bound']*100:.1f}% |")
    return "\n".join(rows)


def candidates(recs: list[dict], mesh: str = "pod16x16") -> dict:
    live = [r for r in recs if not r.get("skipped") and r["mesh"] == mesh
            and not r.get("opt")]
    worst_frac = min(live, key=lambda r: r["roofline"]["roofline_frac"])
    most_coll = max(live, key=lambda r: (r["roofline"]["t_collective_s"]
                                         / max(r["roofline"]["t_bound_s"], 1e-12)
                                         * r["roofline"]["t_collective_s"]))
    return {"worst_roofline_frac": (worst_frac["arch"], worst_frac["shape"]),
            "most_collective_bound": (most_coll["arch"], most_coll["shape"])}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--opt", action="store_true")
    ap.add_argument("--compare", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.compare:
        print(compare(recs, args.mesh))
        return
    print(table(recs, args.mesh, opt=args.opt))
    print()
    print("hillclimb candidates:", candidates(recs, args.mesh))


if __name__ == "__main__":
    main()
