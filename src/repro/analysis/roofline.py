"""Roofline model for the TPU v5e target (see EXPERIMENTS.md §Roofline).

All inputs are PER-DEVICE quantities — XLA cost_analysis on an
SPMD-partitioned module reports the per-device program (verified
empirically: an 8-way sharded matmul reports 1/8 of total FLOPs), and
collective bytes are parsed from the per-device HLO.

    compute term    = flops_dev / 197e12 FLOP/s      [bf16 MXU]
    memory term     = bytes_dev / 819e9  B/s         [HBM]
    collective term = coll_dev  / 50e9   B/s         [ICI link]

Totals for MFU-style reporting multiply by `chips`.
"""
from __future__ import annotations

from typing import NamedTuple

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link


class Roofline(NamedTuple):
    flops_dev: float  # per-device HLO flops
    bytes_dev: float  # per-device HLO bytes accessed
    coll_dev: float  # per-device collective bytes
    chips: int
    model_flops: float  # 6*N*D (train) / 2*N*D (decode/prefill), global

    @property
    def t_compute(self) -> float:
        return self.flops_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_dev / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Step-time lower bound if all three engines fully overlap."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / total HLO_FLOPs — remat/dispatch waste diagnostic."""
        total = self.flops_dev * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """MFU ceiling at the roofline: MODEL_FLOPS/(t_bound x chips x peak)."""
        denom = self.t_bound * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    @property
    def roofline_frac(self) -> float:
        """How close the compute term is to the binding constraint — the
        perf 'score': 1.0 means compute-bound at the roofline."""
        return self.t_compute / self.t_bound if self.t_bound else 0.0

    def row(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "t_bound_s": self.t_bound,
            "model_flops": self.model_flops,
            "hlo_flops_dev": self.flops_dev,
            "hlo_bytes_dev": self.bytes_dev,
            "coll_bytes_dev": self.coll_dev,
            "useful_frac": self.useful_flops_frac,
            "mfu_bound": self.mfu_bound,
            "roofline_frac": self.roofline_frac,
        }


def model_flops(param_count: int, tokens: int, kind: str) -> float:
    """6*N*D for training, 2*N*D forward-only (prefill/decode)."""
    return (6.0 if kind == "train" else 2.0) * param_count * tokens
