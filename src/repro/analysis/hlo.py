"""Optimised-HLO inspection: collective traffic + op census.

`collective_bytes(hlo_text)` sums the output-operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(including their async -start forms), grouped by op kind — the collective
roofline term's numerator. Bytes are *per-device* shard bytes, matching the
per-chip link-bandwidth denominator.

Caveat handled by the caller (dryrun.py): ops inside while-loop bodies appear
once in the text but execute trip-count times; the dry-run therefore derives
per-layer costs from loop-free layer probes and scales by n_layers.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%name = TYPE op-name(` — TYPE may be a tuple `(bf16[..], ..)`
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-kind output bytes of collective ops in the (post-SPMD) HLO.

    XLA:CPU promotes bf16 reductions to f32 (`to_apply=%..._promoted`); the
    TPU target reduces bf16 natively, so promoted ops are counted at half
    their f32 size (the wire dtype the TPU would use).
    """
    out: dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        kind = op.replace("-start", "")
        b = _shape_bytes(type_str)
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.end(): line_end if line_end > 0 else m.end() + 400]
        if "_promoted" in line and "f32" in type_str:
            b //= 2
        out[kind] += b
    return dict(out)


def total_collective_bytes(hlo_text: str) -> int:
    return sum(collective_bytes(hlo_text).values())


def op_census(hlo_text: str, ops=("fusion", "dot", "convolution", "custom-call",
                                  "while", "sort", "scatter", "gather")) -> dict[str, int]:
    """Rough op-count census for HLO inspection in §Perf iterations."""
    counts = {}
    for op in ops:
        counts[op] = len(re.findall(rf"=\s*[^=]*\b{op}\(", hlo_text))
    return counts


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = (\w+)\[([0-9,]*)\]")

#: ops that are pure layout / precision conversion: the TPU backend fuses
#: these into neighbouring compute (zero extra HBM traffic); XLA:CPU
#: materialises them (observed: 13 standalone f32 copies of the (B,S,d)
#: activation stream per layer). Fusions whose name is composed solely of
#: these tokens are treated the same.
_LAYOUT_TOKENS = {"convert", "copy", "bitcast", "transpose", "reshape",
                  "broadcast", "slice", "pad", "wrapped", "fusion", "in",
                  "dim", "select"}
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+) = (\w+)\[([0-9,]*)\]\S*\s+(\w[\w-]*)\(")


def bytes_with_chunk_pair(hlo_text: str, chunk: int) -> int:
    """Sum output bytes of materialised ops carrying an (chunk x chunk) SSD
    decay/score matrix in their trailing dims (e.g. [..., 256, 256] or the
    backward's [..., 256, 256, 80]) — the Mamba2 SSD analogue of attention
    scores, streamed through VMEM by fused SSD kernels (Triton/Pallas
    reference implementations); same treatment as flash-attention scores."""
    total = 0
    cur_fused = False
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        if ls.startswith("%") or ls.startswith("ENTRY"):
            cur_fused = "fused" in ls.split()[0]
            continue
        if cur_fused:
            continue
        m = _DEF_RE.match(line)
        if not m or m.group(1) not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",")] if m.group(2) else []
        tail = dims[-3:]
        if len(dims) >= 2 and sum(1 for d in tail if d == chunk) >= 2:
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES[m.group(1)]
    return total


def bytes_of_layout_ops(hlo_text: str) -> int:
    """Sum output bytes of materialised pure-layout/conversion ops (see
    _LAYOUT_TOKENS) outside fusion bodies — the TPU-fusion adjustment of the
    roofline memory term (EXPERIMENTS.md §Roofline, measurement notes)."""
    total = 0
    cur_fused = False
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        if ls.startswith("%") or ls.startswith("ENTRY"):
            cur_fused = "fused" in ls.split()[0]
            continue
        if cur_fused:
            continue
        m = _NAME_RE.match(line)
        if not m or m.group(2) not in _DTYPE_BYTES:
            continue
        name, opcode = m.group(1), m.group(4)
        is_layout = opcode in ("convert", "copy", "bitcast", "transpose",
                               "reshape", "broadcast", "slice", "pad")
        if not is_layout and opcode == "fusion":
            tokens = set(re.split(r"[._\d]+", name)) - {""}
            is_layout = tokens <= _LAYOUT_TOKENS
        if is_layout:
            dims = [int(x) for x in m.group(3).split(",")] if m.group(3) else []
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES[m.group(2)]
    return total


def bytes_with_trailing_dims(hlo_text: str, d1: int, d2: int) -> int:
    """Sum output bytes of materialised ops whose shape ends with [.., d1, d2]
    (ops inside fusion bodies are skipped — they never touch HBM).

    Used to quantify (S, S) attention-score materialisation in the loop-free
    dry-run probes: the deployed path (Pallas flash kernel / chunked XLA
    attention) streams those scores through VMEM, so the roofline memory
    term subtracts this traffic (see dryrun.py)."""
    total = 0
    cur_fused = False
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        if ls.startswith("%") or ls.startswith("ENTRY"):
            cur_fused = "fused" in ls.split()[0]
            continue
        if cur_fused:
            continue
        m = _DEF_RE.match(line)
        if not m or m.group(1) not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",")] if m.group(2) else []
        if len(dims) >= 2 and dims[-2] == d1 and dims[-1] == d2:
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES[m.group(1)]
    return total
