"""The matching engine: one API over the backend registry + mesh sharding.

`MatchEngine` is the single entry point every production caller
(`repro.core.hybrid`, `repro.serve.*`, `repro.launch.serve`, the
benchmarks and examples) routes Eq. 8-12 template matching through:

    eng = engine_for(method="feature_count", backend="kernel")
    pred, per_class = eng.classify_features(features, bank)

Construction is cheap and memoised per `EngineConfig` (`engine_for`), and
every method is safe to call at jit trace time: backend resolution is a
pure dict lookup, block resolution is the `repro.kernels.tuning` cached
lookup, and the "auto" policy decides reference-vs-kernel from static
shapes only.

Backend defaults
----------------
The process default backend (what `backend=None` / an omitted engine
backend resolves to) is ``REPRO_MATCHING_BACKEND`` at import, "auto"
otherwise. `set_default_backend` changes it; `use_backend("...")` scopes a
change to a `with` block (tests / env parity). Unlike the old
`repro.core.matching._backend` global, the default is only ever read
*eagerly at the caller boundary* — jitted callers receive the backend as a
static argument (`hybrid._fused_forward`, the scheduler tick), so changing
the default triggers a fresh trace instead of being silently baked into an
existing executable.

Mesh sharding (the PartitionPlan layer)
---------------------------------------
When `repro.distributed.context` holds a mesh (set by a launcher), every
call derives a `repro.match.plan.PartitionPlan` from its static shapes and
executes under a plan-driven 2D `jax.shard_map`:

  * the **batch** shards over the data-parallel axes (when it divides the
    dp device count) — embarrassingly parallel, as in PR 3;
  * the **bank's class rows** shard over the model axis (when C divides the
    model-axis size and the backend supports it): each device runs the
    backend's (fused) classify on its class-row shard, producing per-class
    partials, and one tiny cross-shard ``(max, argmax)`` reduce over the
    model axis recovers the exact global Eq. 12 decision — and the windowed
    winner-vs-runner-up margin — **bit-identically** to replicated
    execution (ties resolve to the lowest global class index, exactly like
    `jnp.argmax`; see `_reduce_winner` / `_reduce_margin`).

Callers that jit around the engine bake the plan into their trace;
launchers must install the mesh before the first call (the same contract
as `context.constrain`), and jitted callers thread
`distributed.context.generation()` as a static argument so installing a
*different* mesh re-traces instead of silently replaying the old layout.
"""
from __future__ import annotations

import contextlib
import functools
import math
import os

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.templates import TemplateBank
from repro.match import backends as backends_lib
from repro.match import plan as plan_lib
from repro.match.backends import backend_for, backend_names, tiny_cutoff
from repro.match.config import EngineConfig, validate
from repro.match.plan import PartitionPlan, plan_for

Array = jax.Array

_default_backend = os.environ.get("REPRO_MATCHING_BACKEND", "auto")


def default_backend() -> str:
    """The process default backend name ("auto" unless overridden)."""
    return _default_backend


def set_default_backend(name: str) -> None:
    """Set the process default backend ("auto" or any registered name).

    Read eagerly by callers (never inside traced code): jitted entry points
    take the resolved name as a static argument, so a change here produces
    a new trace on the next call rather than mutating a compiled one.
    """
    global _default_backend
    if name != "auto" and name not in backend_names():
        raise ValueError(f"unknown matching backend {name!r}; use "
                         f"{('auto',) + backend_names()}")
    _default_backend = name


@contextlib.contextmanager
def use_backend(name: str):
    """Scope the default backend to a `with` block (tests / env parity)."""
    prev = default_backend()
    set_default_backend(name)
    try:
        yield
    finally:
        set_default_backend(prev)


# ---------------------------------------------------------------------------
# Mesh plumbing
# ---------------------------------------------------------------------------

def dp_axes_in_mesh():
    """(mesh, dp_axes) from the distributed context, or (None, None) when
    no usable data-parallel mesh is installed."""
    mesh, axes = plan_lib.mesh_axes()
    if mesh is None:
        return None, None
    dp = axes.dp if isinstance(axes.dp, tuple) else (axes.dp,)
    if any(a not in mesh.axis_names for a in dp):
        return None, None
    if math.prod(mesh.shape[a] for a in dp) <= 1:
        return None, None
    return mesh, dp


def batch_specs(dp, n_batch_args: int, out_ranks: tuple[int, ...]):
    """shard_map specs for a dp-only matching call: batch-leading operands
    sharded over the dp axes, the bank replicated, outputs batch-leading.

    Exposed for tests: the first `n_batch_args` in_specs carry P(dp) — the
    queries ARE dp-sharded — and the bank spec is P(). Bank-sharded calls
    derive their 2D specs from the `PartitionPlan` instead
    (`plan.batch_spec` / `plan.class_spec` / `plan.batch_class_spec`).
    """
    in_specs = tuple(P(dp) for _ in range(n_batch_args)) + (P(),)
    out_specs = tuple(P(dp, *([None] * (r - 1))) for r in out_ranks)
    return in_specs, out_specs


def bank_specs(plan: PartitionPlan) -> TemplateBank:
    """shard_map in_specs for a `TemplateBank` under the plan: class-row
    leading arrays cut over the model axis, thresholds replicated."""
    row = plan.class_spec()
    return TemplateBank(templates=row, lower=row, upper=row, valid=row,
                        thresholds=P())


# ---------------------------------------------------------------------------
# Cross-shard reduces (the "one tiny argmax reduce" of a bank-sharded call)
# ---------------------------------------------------------------------------
#
# Each model-axis shard contributes a (top1, global winner index[, top2])
# summary of its class rows. Shards hold *disjoint* class-index ranges, so
# the merge below is exact: the winner is the lexicographic max on
# (score desc, index asc) — precisely `jnp.argmax`'s lowest-index tie rule —
# and the global runner-up over "all classes except the winner's position"
# is max(loser shards' top1, winner shard's top2).
#
# Two strategies, both bit-identical (`plan.reduce` picks one):
#
#   allgather  gather all S partials ((S, B) scalars), fold sequentially —
#              one collective, O(S) merge depth. The PR-6 behaviour.
#   tree       XOR-butterfly: log2(S) `ppermute` rounds, each merging a
#              partial from rank s ^ d. After round log2(S) every rank holds
#              the identical global result. Exactness: the merge is
#              associative + commutative on disjoint index sets (f32 max is
#              exact, the lexicographic tie rule is order-free), so ANY
#              reduction tree yields the same bits as the sequential fold.
#              Requires a power-of-two axis (`plan.reduce_strategy` only
#              selects it there).

def _merge_winner(at, ai, bt, bi):
    take = (bt > at) | ((bt == at) & (bi < ai))
    return jnp.where(take, bt, at), jnp.where(take, bi, ai)


def _merge_margin(at, ai, ar, bt, bi, br):
    take = (bt > at) | ((bt == at) & (bi < ai))
    # new runner-up: the losing side's top1 joins the candidate set
    r = jnp.where(take, jnp.maximum(br, at), jnp.maximum(ar, bt))
    return jnp.where(take, bt, at), jnp.where(take, bi, ai), r


def _butterfly(parts: tuple, merge, axis: str, num_shards: int) -> tuple:
    """XOR-butterfly all-reduce of per-shard partials under ``merge``."""
    d = 1
    while d < num_shards:
        perm = [(s, s ^ d) for s in range(num_shards)]
        peer = tuple(jax.lax.ppermute(p, axis, perm) for p in parts)
        parts = merge(*parts, *peer)
        d *= 2
    return parts


def _reduce_winner(top1: Array, gidx: Array, axis: str, num_shards: int,
                   strategy: str = "allgather") -> tuple[Array, Array]:
    if strategy == "tree":
        return _butterfly((top1, gidx), _merge_winner, axis, num_shards)
    t = jax.lax.all_gather(top1, axis)  # (S, B)
    i = jax.lax.all_gather(gidx, axis)
    best_t, best_i = t[0], i[0]
    for s in range(1, num_shards):
        best_t, best_i = _merge_winner(best_t, best_i, t[s], i[s])
    return best_t, best_i


def _reduce_margin(top1: Array, gidx: Array, top2: Array, axis: str,
                   num_shards: int, cap: float,
                   strategy: str = "allgather") -> tuple[Array, Array]:
    """Combine windowed margin partials -> (pred, margin), matching
    `repro.kernels.layout.windowed_margin` bit for bit (same clamp, same
    empty-window pred 0 / margin 0 behaviour)."""
    if strategy == "tree":
        best_t, best_i, best_r = _butterfly((top1, gidx, top2), _merge_margin,
                                            axis, num_shards)
    else:
        t = jax.lax.all_gather(top1, axis)
        i = jax.lax.all_gather(gidx, axis)
        r = jax.lax.all_gather(top2, axis)
        best_t, best_i, best_r = t[0], i[0], r[0]
        for s in range(1, num_shards):
            best_t, best_i, best_r = _merge_margin(best_t, best_i, best_r,
                                                   t[s], i[s], r[s])
    top2g = jnp.maximum(best_r, best_t - cap)
    margin = jnp.where(jnp.isfinite(best_t), best_t - top2g, 0.0)
    return best_i.astype(jnp.int32), margin.astype(jnp.float32)


class MatchEngine:
    """Pluggable, mesh-aware Eq. 8-12 matching over a `TemplateBank`."""

    def __init__(self, config: EngineConfig = EngineConfig()):
        validate(config, backend_names())
        self.config = config

    def __repr__(self) -> str:
        return f"MatchEngine({self.config!r})"

    # -- backend resolution --------------------------------------------------

    def backend(self, n_elements: int | None = None) -> backends_lib.MatchBackend:
        """Resolve the backend ("auto" -> reference for tiny shapes).

        The tiny cutoff is per method (`repro.match.backends.tiny_cutoff`):
        the measured reference/kernel crossover sits ~16x higher for the
        similarity method than for feature_count."""
        name = self.config.backend
        if name == "auto":
            name = ("reference" if n_elements is not None
                    and n_elements < tiny_cutoff(self.config.method)
                    else "kernel")
        return backend_for(name, self.config)

    # -- plan-driven sharded execution ---------------------------------------

    def plan(self, batch: int, num_classes: int,
             be: backends_lib.MatchBackend) -> tuple[PartitionPlan, object]:
        """The `PartitionPlan` for a call with these static shapes."""
        return plan_for(batch=batch, num_classes=num_classes,
                        bank_shardable=be.supports_bank_sharding)

    def _shard(self, fn, batch_args: tuple, bank_args: tuple,
               plan: PartitionPlan, mesh, out_specs: tuple):
        """shard_map `fn(*batch_args, *bank_args)` under the plan: batch
        operands on the dp axes, class-row operands on the model axis."""
        in_specs = tuple(plan.batch_spec() for _ in batch_args) + tuple(
            bank_specs(plan) if isinstance(a, TemplateBank)
            else plan.class_spec() for a in bank_args)
        # check_rep=False: pallas_call has no replication rule; outputs are
        # either batch-local or made identical on every shard by the reduce.
        sharded = shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False)
        return sharded(*batch_args, *bank_args)

    @staticmethod
    def _row0(plan: PartitionPlan) -> Array:
        """This shard's first global class row (traced, inside shard_map)."""
        return jax.lax.axis_index(plan.model) * plan.rows_per_shard

    # -- raw score entry points (template arrays, not banks) -----------------

    def _raw_scores(self, queries: Array, bank_args: tuple, valid, fn):
        b = queries.shape[0]
        c, k = bank_args[0].shape[0], bank_args[0].shape[1]
        be = self.backend(b * c * k * queries.shape[-1])
        plan, mesh = self.plan(b, c, be)
        if not plan.sharded:
            return fn(be, queries, *bank_args, valid)
        if valid is None:
            valid = jnp.ones((c, k), bool)

        def run(q, *rest):
            return (fn(be, q, *rest),)

        return self._shard(run, (queries,), bank_args + (valid,), plan, mesh,
                           (plan.batch_class_spec(3),))[0]

    def feature_count_scores(self, queries: Array, templates: Array,
                             valid: Array | None = None) -> Array:
        """Eq. 8: queries (B, N) binary, templates (C, K, N) -> (B, C, K)."""
        return self._raw_scores(
            queries, (templates,), valid,
            lambda be, q, t, v: be.feature_count_scores(q, t, v))

    def similarity_scores(self, queries: Array, lower: Array, upper: Array,
                          valid: Array | None = None) -> Array:
        """Eq. 9-11: queries (B, N), windows (C, K, N) -> (B, C, K)."""
        alpha = self.config.alpha
        return self._raw_scores(
            queries, (lower, upper), valid,
            lambda be, q, lo, hi, v: be.similarity_scores(q, lo, hi, v,
                                                          alpha=alpha))

    # -- bank entry points ---------------------------------------------------

    def _elements(self, batch: int, bank: TemplateBank) -> int:
        c, k, n = bank.templates.shape
        return batch * c * k * n

    def scores(self, queries: Array, bank: TemplateBank) -> Array:
        """(B, C, K) scores for the configured method; invalid rows -inf."""
        be = self.backend(self._elements(queries.shape[0], bank))
        plan, mesh = self.plan(queries.shape[0], bank.templates.shape[0], be)
        if not plan.sharded:
            return be.scores(queries, bank)

        def fn(q, bk):
            # 1-tuple so the output pytree matches the out_specs tuple
            # (shard_map requires structural agreement, not a bare array)
            return (be.scores(q, bk),)

        return self._shard(fn, (queries,), (bank,), plan, mesh,
                           (plan.batch_class_spec(3),))[0]

    def _classify_via(self, shard_method: str, plain_method: str,
                      queries: Array, bank: TemplateBank
                      ) -> tuple[Array, Array]:
        be = self.backend(self._elements(queries.shape[0], bank))
        plan, mesh = self.plan(queries.shape[0], bank.templates.shape[0], be)
        if not plan.sharded:
            return getattr(be, plain_method)(queries, bank)
        if not plan.bank_sharded:
            return self._shard(getattr(be, plain_method), (queries,), (bank,),
                               plan, mesh,
                               (plan.batch_spec(1), plan.batch_spec(2)))

        def fn(q, bk):
            per_class, top1, gidx = getattr(be, shard_method)(
                q, bk, self._row0(plan))
            _, pred = _reduce_winner(top1, gidx, plan.model, plan.bank_shards,
                                     plan.reduce)
            return pred, per_class

        return self._shard(fn, (queries,), (bank,), plan, mesh,
                           (plan.batch_spec(1), plan.batch_class_spec(2)))

    def classify(self, queries: Array, bank: TemplateBank
                 ) -> tuple[Array, Array]:
        """Eq. 8/11 + Eq. 12 over *binary* queries -> (pred, per_class)."""
        return self._classify_via("classify_shard", "classify", queries, bank)

    def classify_features(self, features: Array, bank: TemplateBank
                          ) -> tuple[Array, Array]:
        """Raw features -> binarize -> match -> WTA -> (pred, per_class).

        The kernel backend executes this as a single fused pallas_call when
        the bank fits the fused layout; under a bank-sharded plan each
        device runs the fused kernel on its class-row shard and the winner
        comes from the cross-shard argmax reduce.
        """
        return self._classify_via("classify_features_shard",
                                  "classify_features", features, bank)

    def classify_features_margin(
        self, features: Array, bank: TemplateBank,
        class_lo: Array | None = None, class_hi: Array | None = None,
    ) -> tuple[Array, Array, Array]:
        """`classify_features` + per-request confidence margin (serving).

        Returns (pred (B,) int32 global class index, per_class (B, C),
        margin (B,) f32 clamped to the backend's score range). Empty class
        windows (slot padding) yield pred 0, margin 0. Class windows are
        global indices and may straddle bank shards — the margin reduce is
        exact either way (the serving registry still aligns tenant windows
        to shard boundaries so a tenant's rows share a device).
        """
        b = features.shape[0]
        c = bank.templates.shape[0]
        if class_lo is None:
            class_lo = jnp.zeros((b,), jnp.int32)
        if class_hi is None:
            class_hi = jnp.full((b,), c, jnp.int32)
        be = self.backend(self._elements(b, bank))
        plan, mesh = self.plan(b, c, be)
        if not plan.sharded:
            return be.classify_features_margin(features, bank, class_lo,
                                               class_hi)
        if not plan.bank_sharded:
            def fn(feats, lo, hi, bk):
                return be.classify_features_margin(feats, bk, lo, hi)

            return self._shard(fn, (features, class_lo, class_hi), (bank,),
                               plan, mesh, (plan.batch_spec(1),
                                            plan.batch_spec(2),
                                            plan.batch_spec(1)))
        cap = be.margin_cap(features.shape[-1])

        def fn(feats, lo, hi, bk):
            per_class, top1, gidx, top2 = be.classify_features_margin_shard(
                feats, bk, lo, hi, self._row0(plan))
            pred, margin = _reduce_margin(top1, gidx, top2, plan.model,
                                          plan.bank_shards, cap, plan.reduce)
            return pred, per_class, margin

        return self._shard(fn, (features, class_lo, class_hi), (bank,), plan,
                           mesh, (plan.batch_spec(1),
                                  plan.batch_class_spec(2),
                                  plan.batch_spec(1)))

    def classify_serve(self, features: Array, thr_table: Array,
                       tenant_slot: Array, bank: TemplateBank,
                       class_lo: Array | None = None,
                       class_hi: Array | None = None,
                       tau: Array | None = None
                       ) -> tuple[Array, Array, Array, Array]:
        """The multi-tenant serving tick: gather + margins + escalation.

        Each row binarises against its tenant's thresholds row
        (``thr_table[tenant_slot[i]]``) and classifies inside its class
        window; ``escalate[i] = margin[i] < tau[i]`` is the confidence
        cascade's per-request routing bit (tau -inf = never escalate — the
        padding/no-head value). Returns (pred, per_class, margin, escalate).

        On the kernel backend the whole thing is ONE pallas_call
        (`acam_match_serve` / `acam_similarity_serve`) unless
        ``config.serve_fusion == "compose"``. Under a bank-sharded plan each
        device serves its class-row shard and `plan.reduce` picks the
        cross-shard merge (all-gather fold or the XOR-butterfly tree), then
        the tau compare runs on the reduced margin — all bit-identical to
        replicated execution.
        """
        b = features.shape[0]
        c = bank.templates.shape[0]
        if class_lo is None:
            class_lo = jnp.zeros((b,), jnp.int32)
        if class_hi is None:
            class_hi = jnp.full((b,), c, jnp.int32)
        if tau is None:
            tau = jnp.full((b,), -jnp.inf, jnp.float32)
        be = self.backend(self._elements(b, bank))
        plan, mesh = self.plan(b, c, be)
        if not plan.sharded:
            return be.classify_serve(features, thr_table, tenant_slot, bank,
                                     class_lo, class_hi, tau)
        batch_args = (features, tenant_slot, class_lo, class_hi, tau)
        if not plan.bank_sharded:
            def fn(feats, slot, lo, hi, t, thr, bk):
                return be.classify_serve(feats, thr, slot, bk, lo, hi, t)

            in_specs = tuple(plan.batch_spec() for _ in batch_args) + (
                P(), bank_specs(plan))
            sharded = shard_map(fn, mesh=mesh, in_specs=in_specs,
                                out_specs=(plan.batch_spec(1),
                                           plan.batch_spec(2),
                                           plan.batch_spec(1),
                                           plan.batch_spec(1)),
                                check_rep=False)
            return sharded(*batch_args, thr_table, bank)
        cap = be.margin_cap(features.shape[-1])

        def fn(feats, slot, lo, hi, t, thr, bk):
            per_class, top1, gidx, top2 = be.classify_serve_shard(
                feats, thr, slot, bk, lo, hi, self._row0(plan))
            pred, margin = _reduce_margin(top1, gidx, top2, plan.model,
                                          plan.bank_shards, cap, plan.reduce)
            return pred, per_class, margin, margin < t

        in_specs = tuple(plan.batch_spec() for _ in batch_args) + (
            P(), bank_specs(plan))
        sharded = shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=(plan.batch_spec(1),
                                       plan.batch_class_spec(2),
                                       plan.batch_spec(1),
                                       plan.batch_spec(1)),
                            check_rep=False)
        return sharded(*batch_args, thr_table, bank)

    def __call__(self, features: Array, bank: TemplateBank,
                 class_lo: Array | None = None,
                 class_hi: Array | None = None):
        """Config-directed forward: margins when `config.margin` is set."""
        if self.config.margin:
            return self.classify_features_margin(features, bank, class_lo,
                                                 class_hi)
        return self.classify_features(features, bank)

    # -- Monte-Carlo programming sweep (device backend) ----------------------

    def sweep_program_noise(self, features: Array, bank: TemplateBank,
                            keys: Array | int, *,
                            bank_shards: int | None = None
                            ) -> tuple[Array, Array]:
        """vmap the `sigma_program` programming draw over PRNG keys.

        The device backend's program-once-read-many flow draws ONE noisy
        array per engine config; this sweeps M independent programming
        draws in a single vmapped graph, turning point accuracies into
        confidence intervals on noisy-hardware behaviour.

        keys: an (M,)-leading array of PRNG keys, or an int M (keys are then
        split from ``PRNGKey(config.seed)``). Returns (pred (M, B) int32,
        per_class (M, B, C)). Requires ``backend="device"``; at
        ``sigma_program = 0`` every draw is the ideal array.

        Under ``device_noise="per_shard"`` each draw programs the S-array
        tiling (array s keyed ``fold_in(draw_key, s)`` — the same noise
        layout a bank-sharded plan realises per device). ``bank_shards``
        picks S; None infers it from the installed mesh
        (`repro.match.bank_shards_in_mesh`, 1 when the class count does not
        divide). Ignored under "global" noise (one array, one field).
        """
        be = self.backend(None)
        if not isinstance(be, backends_lib.DeviceBackend):
            raise ValueError(
                "sweep_program_noise requires the device backend; build the "
                'engine with engine_for(backend="device", device=ACAMConfig('
                "sigma_program=...))")
        shards = 1
        if be.per_shard_noise:
            c = bank.templates.shape[0]
            if bank_shards is None:
                bank_shards = plan_lib.bank_shards_in_mesh()
            shards = bank_shards if c % bank_shards == 0 else 1
        if isinstance(keys, int):
            keys = jax.random.split(jax.random.PRNGKey(self.config.seed),
                                    keys)
        return jax.vmap(
            lambda key: be.classify_features_keyed(features, bank, key,
                                                   bank_shards=shards)
        )(keys)


@functools.lru_cache(maxsize=None)
def _engine_for(config: EngineConfig) -> MatchEngine:
    return MatchEngine(config)


def engine_from_config(config: EngineConfig) -> MatchEngine:
    """Memoised engine for a fully-resolved `EngineConfig` (the spec path:
    `ServiceSpec.engine` and the scheduler tick hand the whole config over
    as one hashable static value instead of re-spelling its fields)."""
    return _engine_for(config)


def engine_for(method: str = "feature_count", alpha: float = 1.0,
               backend: str | None = None,
               block: tuple[int, int, int] | None = None,
               margin: bool = False, device=None, seed: int = 0,
               device_noise: str = "global") -> MatchEngine:
    """Memoised engine per config; `backend=None` -> the process default.

    The default is resolved HERE (eagerly, at the caller boundary), so a
    jitted caller that passes the resolved `engine.config` — or the backend
    name — as a static argument re-traces when the default changes.
    """
    cfg = EngineConfig(method=method, alpha=alpha,
                       backend=backend or default_backend(),
                       block=None if block is None else tuple(block),
                       margin=margin, device=device, seed=seed,
                       device_noise=device_noise)
    return _engine_for(cfg)
