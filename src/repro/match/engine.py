"""The matching engine: one API over the backend registry + mesh sharding.

`MatchEngine` is the single entry point every production caller
(`repro.core.hybrid`, `repro.serve.*`, `repro.launch.serve`, the
benchmarks and examples) routes Eq. 8-12 template matching through:

    eng = engine_for(method="feature_count", backend="kernel")
    pred, per_class = eng.classify_features(features, bank)

Construction is cheap and memoised per `EngineConfig` (`engine_for`), and
every method is safe to call at jit trace time: backend resolution is a
pure dict lookup, block resolution is the `repro.kernels.tuning` cached
lookup, and the "auto" policy decides reference-vs-kernel from static
shapes only.

Backend defaults
----------------
The process default backend (what `backend=None` / an omitted engine
backend resolves to) is ``REPRO_MATCHING_BACKEND`` at import, "auto"
otherwise. `set_default_backend` changes it; `use_backend("...")` scopes a
change to a `with` block (tests / env parity). Unlike the old
`repro.core.matching._backend` global, the default is only ever read
*eagerly at the caller boundary* — jitted callers receive the backend as a
static argument (`hybrid._fused_forward`, the scheduler tick), so changing
the default triggers a fresh trace instead of being silently baked into an
existing executable.

Mesh sharding
-------------
When `repro.distributed.context` holds a mesh (set by a launcher), engine
calls whose batch divides the data-parallel device count execute under
`jax.shard_map`: queries/features (and per-row class windows) are sharded
over the dp axes, the template bank is replicated, and each device runs
the backend on its batch shard — the template-matching batch dimension is
embarrassingly parallel, so results are bit-identical to single-device
execution. Callers that jit around the engine bake the mesh decision into
their trace; launchers must install the mesh before the first call (the
same contract as `context.constrain`).
"""
from __future__ import annotations

import contextlib
import functools
import math
import os

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.templates import TemplateBank
from repro.match import backends as backends_lib
from repro.match.backends import TINY_ELEMENTS, backend_for, backend_names
from repro.match.config import EngineConfig, validate

Array = jax.Array

_default_backend = os.environ.get("REPRO_MATCHING_BACKEND", "auto")


def default_backend() -> str:
    """The process default backend name ("auto" unless overridden)."""
    return _default_backend


def set_default_backend(name: str) -> None:
    """Set the process default backend ("auto" or any registered name).

    Read eagerly by callers (never inside traced code): jitted entry points
    take the resolved name as a static argument, so a change here produces
    a new trace on the next call rather than mutating a compiled one.
    """
    global _default_backend
    if name != "auto" and name not in backend_names():
        raise ValueError(f"unknown matching backend {name!r}; use "
                         f"{('auto',) + backend_names()}")
    _default_backend = name


@contextlib.contextmanager
def use_backend(name: str):
    """Scope the default backend to a `with` block (tests / env parity)."""
    prev = default_backend()
    set_default_backend(name)
    try:
        yield
    finally:
        set_default_backend(prev)


# ---------------------------------------------------------------------------
# Mesh plumbing
# ---------------------------------------------------------------------------

def dp_axes_in_mesh():
    """(mesh, dp_axes) from the distributed context, or (None, None) when
    no usable data-parallel mesh is installed."""
    from repro.distributed import context

    mesh = context.get_mesh()
    axes = context.get()
    if mesh is None or axes is None:
        return None, None
    dp = axes.dp if isinstance(axes.dp, tuple) else (axes.dp,)
    if any(a not in mesh.axis_names for a in dp):
        return None, None
    if math.prod(mesh.shape[a] for a in dp) <= 1:
        return None, None
    return mesh, dp


def batch_specs(dp, n_batch_args: int, out_ranks: tuple[int, ...]):
    """shard_map specs for a matching call: batch-leading operands sharded
    over the dp axes, the bank replicated, outputs batch-leading.

    Exposed for tests: the first `n_batch_args` in_specs carry P(dp) — the
    queries ARE dp-sharded — and the bank spec is P().
    """
    in_specs = tuple(P(dp) for _ in range(n_batch_args)) + (P(),)
    out_specs = tuple(P(dp, *([None] * (r - 1))) for r in out_ranks)
    return in_specs, out_specs


class MatchEngine:
    """Pluggable, mesh-aware Eq. 8-12 matching over a `TemplateBank`."""

    def __init__(self, config: EngineConfig = EngineConfig()):
        validate(config, backend_names())
        self.config = config

    def __repr__(self) -> str:
        return f"MatchEngine({self.config!r})"

    # -- backend resolution --------------------------------------------------

    def backend(self, n_elements: int | None = None) -> backends_lib.MatchBackend:
        """Resolve the backend ("auto" -> reference for tiny shapes)."""
        name = self.config.backend
        if name == "auto":
            name = ("reference" if n_elements is not None
                    and n_elements < TINY_ELEMENTS else "kernel")
        return backend_for(name, self.config)

    # -- sharded execution ---------------------------------------------------

    def _run(self, fn, batch_args: tuple, bank, out_ranks: tuple[int, ...]):
        """Run `fn(*batch_args, bank)`, shard_map-ed over the dp mesh axes
        when one is installed and the batch divides the device count."""
        mesh, dp = dp_axes_in_mesh()
        b = batch_args[0].shape[0]
        if mesh is None or b % math.prod(mesh.shape[a] for a in dp):
            return fn(*batch_args, bank)
        in_specs, out_specs = batch_specs(dp, len(batch_args), out_ranks)
        # check_rep=False: pallas_call has no replication rule; the bank is
        # replicated by construction and outputs are purely batch-local.
        sharded = shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False)
        return sharded(*batch_args, bank)

    # -- raw score entry points (template arrays, not banks) -----------------

    def feature_count_scores(self, queries: Array, templates: Array,
                             valid: Array | None = None) -> Array:
        """Eq. 8: queries (B, N) binary, templates (C, K, N) -> (B, C, K)."""
        b, n = queries.shape
        c, k, _ = templates.shape
        be = self.backend(b * c * k * n)
        return be.feature_count_scores(queries, templates, valid)

    def similarity_scores(self, queries: Array, lower: Array, upper: Array,
                          valid: Array | None = None) -> Array:
        """Eq. 9-11: queries (B, N), windows (C, K, N) -> (B, C, K)."""
        b, n = queries.shape
        c, k, _ = lower.shape
        be = self.backend(b * c * k * n)
        return be.similarity_scores(queries, lower, upper, valid,
                                    alpha=self.config.alpha)

    # -- bank entry points ---------------------------------------------------

    def _elements(self, batch: int, bank: TemplateBank) -> int:
        c, k, n = bank.templates.shape
        return batch * c * k * n

    def scores(self, queries: Array, bank: TemplateBank) -> Array:
        """(B, C, K) scores for the configured method; invalid rows -inf."""
        be = self.backend(self._elements(queries.shape[0], bank))

        def fn(q, bk):
            # 1-tuple so the output pytree matches _run's out_specs tuple
            # (shard_map requires structural agreement, not a bare array)
            return (be.scores(q, bk),)

        return self._run(fn, (queries,), bank, (3,))[0]

    def classify(self, queries: Array, bank: TemplateBank
                 ) -> tuple[Array, Array]:
        """Eq. 8/11 + Eq. 12 over *binary* queries -> (pred, per_class)."""
        be = self.backend(self._elements(queries.shape[0], bank))
        return self._run(be.classify, (queries,), bank, (1, 2))

    def classify_features(self, features: Array, bank: TemplateBank
                          ) -> tuple[Array, Array]:
        """Raw features -> binarize -> match -> WTA -> (pred, per_class).

        The kernel backend executes this as a single fused pallas_call when
        the bank fits the fused layout.
        """
        be = self.backend(self._elements(features.shape[0], bank))
        return self._run(be.classify_features, (features,), bank, (1, 2))

    def classify_features_margin(
        self, features: Array, bank: TemplateBank,
        class_lo: Array | None = None, class_hi: Array | None = None,
    ) -> tuple[Array, Array, Array]:
        """`classify_features` + per-request confidence margin (serving).

        Returns (pred (B,) int32 global class index, per_class (B, C),
        margin (B,) f32 clamped to the backend's score range). Empty class
        windows (slot padding) yield pred 0, margin 0.
        """
        import jax.numpy as jnp

        b = features.shape[0]
        c = bank.templates.shape[0]
        if class_lo is None:
            class_lo = jnp.zeros((b,), jnp.int32)
        if class_hi is None:
            class_hi = jnp.full((b,), c, jnp.int32)
        be = self.backend(self._elements(b, bank))

        def fn(feats, lo, hi, bk):
            return be.classify_features_margin(feats, bk, lo, hi)

        return self._run(fn, (features, class_lo, class_hi), bank, (1, 2, 1))

    def __call__(self, features: Array, bank: TemplateBank,
                 class_lo: Array | None = None,
                 class_hi: Array | None = None):
        """Config-directed forward: margins when `config.margin` is set."""
        if self.config.margin:
            return self.classify_features_margin(features, bank, class_lo,
                                                 class_hi)
        return self.classify_features(features, bank)


@functools.lru_cache(maxsize=None)
def _engine_for(config: EngineConfig) -> MatchEngine:
    return MatchEngine(config)


def engine_for(method: str = "feature_count", alpha: float = 1.0,
               backend: str | None = None,
               block: tuple[int, int, int] | None = None,
               margin: bool = False, device=None, seed: int = 0
               ) -> MatchEngine:
    """Memoised engine per config; `backend=None` -> the process default.

    The default is resolved HERE (eagerly, at the caller boundary), so a
    jitted caller that passes the resolved `engine.config` — or the backend
    name — as a static argument re-traces when the default changes.
    """
    cfg = EngineConfig(method=method, alpha=alpha,
                       backend=backend or default_backend(),
                       block=None if block is None else tuple(block),
                       margin=margin, device=device, seed=seed)
    return _engine_for(cfg)
