"""Matching backends: the three first-class implementations of Eq. 8-12.

Every backend implements the same entry points over a
`TemplateBank` (or raw template arrays):

  feature_count_scores(queries, templates, valid)            -> (B, C, K)
  similarity_scores(queries, lower, upper, valid, alpha)     -> (B, C, K)
  classify(queries, bank)              binary queries        -> (pred, per_class)
  classify_features(features, bank)    raw features          -> (pred, per_class)
  classify_features_margin(features, bank, lo, hi)           -> (pred, per_class, margin)
  classify_serve(features, thr_table, slot, bank, lo, hi, tau)
                                       multi-tenant tick     -> (pred, per_class, margin, escalate)

Backends:

  reference  pure-jnp oracles — the parity baseline and the tiny-shape
             fast path (XLA fuses them well below the kernels' padding/
             launch overhead).
  kernel     the Pallas paths: fused binarize->match->valid-mask->per-class
             max->WTA [+windowed margins] in ONE pallas_call for both
             methods at ANY bank size — banks inside `MAX_FUSED_ROWS` keep
             every template row VMEM-resident, bigger banks walk the class
             dimension in chunks (same single dispatch, running per-class
             max in a revisited block). The serve path adds the per-slot
             threshold gather and escalation mask in-kernel
             (`acam_match_serve` / `acam_similarity_serve`). Blocks resolve
             through the `repro.kernels.tuning` autotuner unless the engine
             config pins them.
  device     the RRAM-CMOS physics models from `repro.core.acam` (§III):
             the bank is programmed into a (C*K)-row TXL array (point
             templates become lower == upper windows), optionally with
             log-normal `sigma_program` write noise, and scores are the
             analogue sense-amplifier outputs. 6T4R senses the matchline
             charge fraction, 3T1R the dual-rail survival fraction — both
             equal the in-window fraction at sigma_program = 0, so classify
             decisions match the reference backend exactly at zero noise
             while scores/margins are in matchline units (cap 1.0, not N).
             The Eq. 9 distance term is digital post-processing the
             matchline does not integrate, so `alpha` is ignored here.

Register additional backends with `register_backend(name, factory)`; the
factory takes the `EngineConfig` so backends can read `block`, `device`,
`seed`, ...
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import acam as acam_lib
from repro.core import quant
from repro.core.templates import TemplateBank
from repro.match.config import EngineConfig

Array = jax.Array

NEG = -jnp.inf

#: below this many (B * C * K * N) cell-match operations the jnp reference
#: beats the kernel's padding/launch overhead — "auto" stays on XLA. This
#: is the feature-count crossover; the similarity kernel does ~16x less
#: useful work per microsecond (BENCH_kernels.json: 584 vs 40.5
#: cell-matches/us at B=256), so its crossover sits ~16x later.
TINY_ELEMENTS = 32768

#: the similarity method's crossover: TINY_ELEMENTS * 16 (measured ratio of
#: kernel cell-matches/us between the two methods).
TINY_ELEMENTS_SIMILARITY = 524288


def tiny_cutoff(method: str) -> int:
    """Per-method "auto" dispatch cutoff in B * C * K * N cell matches."""
    return TINY_ELEMENTS_SIMILARITY if method == "similarity" \
        else TINY_ELEMENTS


#: fused classify keeps all K * Cp template rows VMEM-resident; past this
#: row count the kernel backend walks the bank in class-column chunks
#: (still a single pallas_call — `layout.class_chunk`).
MAX_FUSED_ROWS = 2048


# ---------------------------------------------------------------------------
# Shared epilogues (pure jnp)
# ---------------------------------------------------------------------------

def classify_scores(scores: Array) -> tuple[Array, Array]:
    """Eq. 12 with multi-template max-pooling.

    scores: (B, C, K) -> (pred (B,), per_class (B, C)).
    """
    per_class = jnp.max(scores, axis=-1)
    return jnp.argmax(per_class, axis=-1), per_class


def winner_take_all(per_class: Array) -> Array:
    """One-hot WTA output (the analogue WTA network's digital semantics)."""
    return jax.nn.one_hot(jnp.argmax(per_class, axis=-1), per_class.shape[-1])


def shard_window_top2(per_class: Array, class_lo: Array | None,
                      class_hi: Array | None, row0: Array
                      ) -> tuple[Array, Array, Array]:
    """Shard-local windowed (top1, winner index, top2) — the margin partial.

    ``per_class`` is this shard's (B, C_local) slice of the per-class
    scores; ``row0`` the shard's first *global* class row; windows are
    global class indices (they may straddle shards — the iota offset
    intersects them with this shard's rows). Returns the three (B,) partials
    the engine's cross-shard margin reduce combines: the top1 value, its
    global class index (lowest-first among local ties, like `jnp.argmax`),
    and the runner-up *excluding only the winner's position* (so a tied
    class elsewhere yields top2 == top1, margin 0 — exactly
    `repro.kernels.layout.windowed_margin` semantics). No cap clamp here:
    the clamp is a global property, applied after the reduce.
    """
    b, c = per_class.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (b, c), 1)
    giota = iota + row0
    if class_lo is not None:
        win = (giota >= class_lo[:, None]) & (giota < class_hi[:, None])
        s = jnp.where(win, per_class, NEG)
    else:
        s = per_class
    local = jnp.argmax(s, axis=-1)
    top1 = jnp.take_along_axis(s, local[:, None], axis=-1)[:, 0]
    top2 = jnp.max(jnp.where(iota == local[:, None], NEG, s), axis=-1)
    return top1, (local + row0).astype(jnp.int32), top2


def window_margin(per_class: Array, class_lo: Array | None = None,
                  class_hi: Array | None = None, *,
                  cap: float) -> tuple[Array, Array]:
    """Eq. 12 decision + winner-vs-runner-up margin inside class windows.

    jnp oracle for the fused margins kernel, and the fallback used by the
    reference/two-stage/similarity/device paths. ``per_class`` is (B, C)
    with -inf for invalid classes; windows default to the full class range.
    Returns (pred (B,) int32 global class index, margin (B,) f32 clamped to
    cap).
    """
    b, c = per_class.shape
    if class_lo is None:
        class_lo = jnp.zeros((b,), jnp.int32)
    if class_hi is None:
        class_hi = jnp.full((b,), c, jnp.int32)
    from repro.kernels.layout import windowed_margin
    return windowed_margin(per_class, class_lo.astype(jnp.int32)[:, None],
                           class_hi.astype(jnp.int32)[:, None], cap)


# ---------------------------------------------------------------------------
# Pure-jnp references (the parity oracles; also the tiny-shape fast path)
# ---------------------------------------------------------------------------

def feature_count_scores_ref(queries: Array, templates: Array,
                             valid: Array | None = None) -> Array:
    """Eq. 8 reference: materialises the (B, C, K, N) comparison in HBM."""
    eq = queries[:, None, None, :] == templates[None, :, :, :]
    scores = jnp.sum(eq, axis=-1).astype(jnp.float32)
    if valid is not None:
        scores = jnp.where(valid[None, :, :], scores, NEG)
    return scores


def similarity_scores_ref(
    queries: Array,
    lower: Array,
    upper: Array,
    valid: Array | None = None,
    *,
    alpha: float = 1.0,
) -> Array:
    """Eq. 9-11 reference: materialises the (B, C, K, N) intermediate."""
    q = queries[:, None, None, :]
    lo = lower[None, :, :, :]
    hi = upper[None, :, :, :]
    above = jnp.maximum(q - hi, 0.0)
    below = jnp.maximum(lo - q, 0.0)
    d = jnp.sum(above**2 + below**2, axis=-1)  # Eq. 9
    hit = jnp.mean((q >= lo) & (q <= hi), axis=-1)  # Eq. 10
    s = hit / (1.0 + alpha * d)  # Eq. 11
    if valid is not None:
        s = jnp.where(valid[None, :, :], s, NEG)
    return s


# ---------------------------------------------------------------------------
# Backend protocol
# ---------------------------------------------------------------------------

class MatchBackend:
    """Base class: default implementations compose the two score entry
    points with the shared jnp epilogues; subclasses override the hot paths
    they can fuse."""

    name = "base"

    #: whether the engine may cut this backend's bank into class-row shards
    #: (`repro.match.plan`). True for the digital backends — their scores
    #: are row-independent, so a shard computes bit-identical per-class
    #: values. The device backend overrides this (programming noise is drawn
    #: per physical array, not per shard).
    supports_bank_sharding = True

    def __init__(self, config: EngineConfig):
        self.config = config

    # -- scores --------------------------------------------------------------

    def feature_count_scores(self, queries: Array, templates: Array,
                             valid: Array | None = None) -> Array:
        raise NotImplementedError

    def similarity_scores(self, queries: Array, lower: Array, upper: Array,
                          valid: Array | None = None, *,
                          alpha: float = 1.0) -> Array:
        raise NotImplementedError

    def scores(self, queries: Array, bank: TemplateBank) -> Array:
        if self.config.method == "feature_count":
            return self.feature_count_scores(queries, bank.templates,
                                             bank.valid)
        return self.similarity_scores(queries, bank.lower, bank.upper,
                                      bank.valid, alpha=self.config.alpha)

    # -- classify ------------------------------------------------------------

    def classify(self, queries: Array, bank: TemplateBank
                 ) -> tuple[Array, Array]:
        """Eq. 8/11 + Eq. 12 over *binary* queries."""
        return classify_scores(self.scores(queries, bank))

    def classify_features(self, features: Array, bank: TemplateBank
                          ) -> tuple[Array, Array]:
        """Raw front-end features -> binarize -> match -> WTA (Fig. 2)."""
        return self.classify(quant.binarize(features, bank.thresholds), bank)

    def margin_cap(self, num_features: int) -> float:
        """Score range the margin is clamped to (empty-runner-up guard)."""
        return (float(num_features) if self.config.method == "feature_count"
                else 1.0)

    def classify_features_margin(
        self, features: Array, bank: TemplateBank,
        class_lo: Array | None = None, class_hi: Array | None = None,
    ) -> tuple[Array, Array, Array]:
        _, per_class = self.classify_features(features, bank)
        pred, margin = window_margin(per_class, class_lo, class_hi,
                                     cap=self.margin_cap(features.shape[-1]))
        return pred, per_class, margin

    # -- multi-tenant serve path (the scheduler tick) ------------------------
    #
    # One micro-batch of per-slot raw features, each row binarising against
    # ITS tenant's threshold row of a stacked (T, N) table, matched over the
    # shared super-bank (whose own thresholds are zeros — the registry packs
    # tenants that way), inside per-row class windows, with the cascade's
    # escalation compare folded in. The default composes existing pieces
    # (gather + the shift identity binarize(f, thr_t) == binarize(f - thr_t,
    # 0) + classify_features_margin + margin < tau); the kernel backend
    # overrides it with the resident mega-kernel.

    def classify_serve(
        self, features: Array, thr_table: Array, tenant_slot: Array,
        bank: TemplateBank, class_lo: Array, class_hi: Array, tau: Array,
    ) -> tuple[Array, Array, Array, Array]:
        """-> (pred, per_class, margin, escalate (B,) bool)."""
        thr_rows = jnp.take(thr_table, tenant_slot, axis=0)
        pred, per_class, margin = self.classify_features_margin(
            features - thr_rows, bank, class_lo, class_hi)
        return pred, per_class, margin, margin < tau

    def classify_serve_shard(
        self, features: Array, thr_table: Array, tenant_slot: Array,
        bank: TemplateBank, class_lo: Array, class_hi: Array, row0: Array,
    ) -> tuple[Array, Array, Array, Array]:
        """Bank-sharded serve partials: gather + shift on this shard, then
        the margin partials (per_class, top1, gidx, top2) — the engine's
        cross-shard reduce recovers the global decision and applies tau."""
        thr_rows = jnp.take(thr_table, tenant_slot, axis=0)
        return self.classify_features_margin_shard(
            features - thr_rows, bank, class_lo, class_hi, row0)

    # -- shard-local classify (bank-sharded execution, repro.match.plan) -----
    #
    # Under a bank-sharded PartitionPlan each device holds only class rows
    # [row0, row0 + C_local) of the bank. These entry points run the
    # backend's (fused) classify on that shard and return row-offset-aware
    # partials — per_class plus the (top1, global winner index[, top2])
    # summary the engine's cross-shard (max, argmax) reduce combines into
    # the exact global Eq. 12 decision and margin.

    def classify_shard(self, queries: Array, bank: TemplateBank, row0: Array
                       ) -> tuple[Array, Array, Array]:
        """Binary queries -> (per_class (B, C_local), top1 (B,), gidx (B,))."""
        pred, per_class = self.classify(queries, bank)
        return per_class, jnp.max(per_class, axis=-1), \
            (pred + row0).astype(jnp.int32)

    def classify_features_shard(self, features: Array, bank: TemplateBank,
                                row0: Array) -> tuple[Array, Array, Array]:
        """Raw features -> (per_class (B, C_local), top1 (B,), gidx (B,))."""
        pred, per_class = self.classify_features(features, bank)
        return per_class, jnp.max(per_class, axis=-1), \
            (pred + row0).astype(jnp.int32)

    def classify_features_margin_shard(
        self, features: Array, bank: TemplateBank, class_lo: Array,
        class_hi: Array, row0: Array,
    ) -> tuple[Array, Array, Array, Array]:
        """Margin partials: (per_class, top1, gidx, top2), windows global."""
        _, per_class = self.classify_features(features, bank)
        top1, gidx, top2 = shard_window_top2(per_class, class_lo, class_hi,
                                             row0)
        return per_class, top1, gidx, top2


# ---------------------------------------------------------------------------
# reference backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("method", "alpha"))
def _classify_ref(queries: Array, bank: TemplateBank, *, method: str,
                  alpha: float) -> tuple[Array, Array]:
    if method == "feature_count":
        scores = feature_count_scores_ref(queries, bank.templates, bank.valid)
    else:
        scores = similarity_scores_ref(queries, bank.lower, bank.upper,
                                       bank.valid, alpha=alpha)
    return classify_scores(scores)


class ReferenceBackend(MatchBackend):
    name = "reference"

    def feature_count_scores(self, queries, templates, valid=None):
        return feature_count_scores_ref(queries, templates, valid)

    def similarity_scores(self, queries, lower, upper, valid=None, *,
                          alpha=1.0):
        return similarity_scores_ref(queries, lower, upper, valid,
                                     alpha=alpha)

    def classify(self, queries, bank):
        return _classify_ref(queries, bank, method=self.config.method,
                             alpha=self.config.alpha)


# ---------------------------------------------------------------------------
# kernel backend (Pallas)
# ---------------------------------------------------------------------------

def _binary_thresholds(n: int) -> Array:
    # binary {0,1} queries re-binarise exactly through a 0.5 threshold,
    # letting the kernels' fused binarisation stage pass them through.
    # Always float32: a bool-dtype 0.5 would collapse to True and binarise
    # every query bit to 0.
    return jnp.full((n,), 0.5, jnp.float32)


class KernelBackend(MatchBackend):
    name = "kernel"

    def feature_count_scores(self, queries, templates, valid=None):
        from repro.kernels.acam_match import ops as match_ops

        b, n = queries.shape
        c, k, _ = templates.shape
        flat = match_ops.match_scores(
            queries.astype(jnp.float32), _binary_thresholds(n),
            templates.reshape(c * k, n).astype(jnp.float32),
            block=self.config.block)
        scores = flat.reshape(b, c, k)
        if valid is not None:
            scores = jnp.where(valid[None, :, :], scores, NEG)
        return scores

    def similarity_scores(self, queries, lower, upper, valid=None, *,
                          alpha=1.0):
        from repro.kernels.acam_similarity import ops as sim_ops

        b, n = queries.shape
        c, k, _ = lower.shape
        flat = sim_ops.similarity_scores(queries, lower.reshape(c * k, n),
                                         upper.reshape(c * k, n),
                                         alpha=alpha, block=self.config.block)
        s = flat.reshape(b, c, k)
        if valid is not None:
            s = jnp.where(valid[None, :, :], s, NEG)
        return s

    def _classify_kernel_path(self, features: Array, thresholds: Array,
                              bank: TemplateBank) -> tuple[Array, Array]:
        """ONE pallas_call at any bank size: fully fused when the bank fits
        the VMEM row budget, class-chunked (same dispatch, running per-class
        max) past it. The old two-stage kernel + jnp epilogue fallback is
        gone — the raw-scores kernels remain only behind the explicit
        `*_scores` entry points."""
        from repro.kernels import layout
        from repro.kernels.acam_match import ops as match_ops
        from repro.kernels.acam_similarity import ops as sim_ops

        method, alpha, block = (self.config.method, self.config.alpha,
                                self.config.block)
        c, k, n = bank.templates.shape
        fused_rows = k * layout.padded_classes(c)
        if method == "feature_count":
            if fused_rows <= MAX_FUSED_ROWS:
                return match_ops.classify_fused(features, thresholds,
                                                bank.templates, bank.valid,
                                                block=block)
            pred, per_class, _ = match_ops.classify_fused_margins_chunked(
                features.astype(jnp.float32), thresholds, bank.templates,
                bank.valid, max_rows=MAX_FUSED_ROWS, block=block)
            return pred, per_class
        if fused_rows <= MAX_FUSED_ROWS:
            return sim_ops.classify_fused(features, thresholds, bank.lower,
                                          bank.upper, bank.valid, alpha=alpha,
                                          block=block)
        pred, per_class, _ = sim_ops.classify_fused_margins(
            features, thresholds, bank.lower, bank.upper, bank.valid,
            alpha=alpha, max_rows=MAX_FUSED_ROWS, block=block)
        return pred, per_class

    def classify(self, queries, bank):
        n = queries.shape[-1]
        return self._classify_kernel_path(queries.astype(jnp.float32),
                                          _binary_thresholds(n), bank)

    def classify_features(self, features, bank):
        return self._classify_kernel_path(features, bank.thresholds, bank)

    def classify_features_margin(self, features, bank, class_lo=None,
                                 class_hi=None):
        from repro.kernels import layout
        from repro.kernels.acam_match import ops as match_ops
        from repro.kernels.acam_similarity import ops as sim_ops

        c, k, n = bank.templates.shape
        if self.config.method == "feature_count":
            # ONE pallas_call either way: binarize -> match -> per-class max
            # -> WTA -> windowed winner-vs-runner-up margin. Banks whose
            # K * Cp rows fit the fused budget keep the whole bank VMEM-
            # resident; bigger banks walk it in class-column chunks.
            if k * layout.padded_classes(c) <= MAX_FUSED_ROWS:
                return match_ops.classify_fused_margins(
                    features.astype(jnp.float32), bank.thresholds,
                    bank.templates, bank.valid, class_lo, class_hi,
                    block=self.config.block)
            return match_ops.classify_fused_margins_chunked(
                features.astype(jnp.float32), bank.thresholds,
                bank.templates, bank.valid, class_lo, class_hi,
                max_rows=MAX_FUSED_ROWS, block=self.config.block)
        # similarity: the symmetric single-dispatch margins kernel (chunked
        # past the row budget; no more fused-classify + jnp margin epilogue)
        return sim_ops.classify_fused_margins(
            features, bank.thresholds, bank.lower, bank.upper, bank.valid,
            class_lo, class_hi, alpha=self.config.alpha,
            max_rows=MAX_FUSED_ROWS, block=self.config.block)

    def classify_serve(self, features, thr_table, tenant_slot, bank,
                       class_lo, class_hi, tau):
        """The resident serving mega-kernel: the whole multi-tenant tick —
        gather, binarize, match, per-class max, WTA, windowed margin,
        escalation mask — in ONE pallas_call for BOTH methods.

        ``serve_fusion="compose"`` keeps the pre-megakernel composition
        (jnp gather/shift + fused margins kernel + jnp tau compare) as the
        bit-identical benchmark baseline."""
        if self.config.serve_fusion == "compose":
            return super().classify_serve(features, thr_table, tenant_slot,
                                          bank, class_lo, class_hi, tau)
        from repro.kernels.acam_match import ops as match_ops
        from repro.kernels.acam_similarity import ops as sim_ops

        if self.config.method == "feature_count":
            return match_ops.serve_classify(
                features.astype(jnp.float32), thr_table, tenant_slot,
                bank.templates, bank.valid, class_lo, class_hi, tau,
                max_rows=MAX_FUSED_ROWS, block=self.config.block)
        return sim_ops.serve_classify(
            features, thr_table, tenant_slot, bank.lower, bank.upper,
            bank.valid, class_lo, class_hi, tau, alpha=self.config.alpha,
            max_rows=MAX_FUSED_ROWS, block=self.config.block)


# ---------------------------------------------------------------------------
# device backend (RRAM-CMOS physics, repro.core.acam)
# ---------------------------------------------------------------------------

class DeviceBackend(MatchBackend):
    """Matching through the §III TXL-ACAM behavioural models.

    The bank is flattened class-major into a (C*K, N) array and *programmed*
    (`acam.program`): point templates become degenerate lower == upper
    windows, window templates keep their bounds. `sigma_program > 0` applies
    the log-normal RRAM write noise, keyed by the engine config's seed, so
    noisy-hardware accuracy/energy sweeps run through the exact same API as
    the ideal backends. Scores are `acam.sense` outputs — the matchline
    charge fraction (6T4R) or dual-rail survival fraction (3T1R) — in [0, 1]
    matchline units (margins cap at 1.0, not N).
    """

    name = "device"

    def __init__(self, config: EngineConfig):
        super().__init__(config)
        self.acam_config = config.device or acam_lib.ACAMConfig()

    @property
    def per_shard_noise(self) -> bool:
        """Per-shard programming keys (`EngineConfig.device_noise`): real
        tiled deployments program one physical array per bank shard, so
        array s draws its write noise from ``fold_in(PRNGKey(seed), s)``."""
        return self.config.device_noise == "per_shard"

    @property
    def supports_bank_sharding(self) -> bool:
        # "global" noise: sigma_program > 0 draws one noise field per
        # *programmed array*; programming per-shard sub-arrays with the same
        # key would realise a different noise layout than the replicated
        # array, breaking the engine's bit-identical-to-replicated contract.
        # The ideal array (sigma = 0) is row-independent and shards exactly,
        # and "per_shard" noise makes the tiled layout the *defined*
        # semantics (one programming key per shard), lifting the refusal.
        return self.acam_config.sigma_program <= 0.0 or self.per_shard_noise

    def _program_rows(self, lower: Array, upper: Array, valid_flat: Array,
                      key: Array | None = None) -> acam_lib.ProgrammedACAM:
        if key is None and self.acam_config.sigma_program > 0.0:
            key = jax.random.PRNGKey(self.config.seed)
        return acam_lib.program(lower, upper, valid_flat, self.acam_config,
                                key)

    def _bank_rows(self, bank: TemplateBank) -> tuple[Array, Array, Array]:
        c, k, n = bank.templates.shape
        if self.config.method == "feature_count":
            lo = hi = bank.templates.reshape(c * k, n)
        else:
            lo = bank.lower.reshape(c * k, n)
            hi = bank.upper.reshape(c * k, n)
        return lo, hi, bank.valid.reshape(c * k)

    def program_bank(self, bank: TemplateBank, key: Array | None = None,
                     *, shard_index: Array | int = 0,
                     bank_shards: int = 1) -> acam_lib.ProgrammedACAM:
        """The acam.py bridge: bank -> programmed (C*K, N) TXL array.

        Public so calibration flows (`acam.calibrate_windows`,
        `acam.soft_sense` gradients) can reach the exact array the engine
        matches against. ``key`` overrides the config-seed programming draw
        (the Monte-Carlo sweep's per-draw keys); None keeps the
        program-once-read-many default.

        Under ``device_noise="per_shard"`` the programming key is
        ``fold_in(base, shard_index)``: inside a bank-sharded shard_map the
        engine passes this shard's index, and ``bank_shards > 1`` *emulates*
        the S-array tiling on a replicated bank — class rows are programmed
        in S per-shard groups keyed ``fold_in(base, s)``, bit-identical to
        what the sharded execution realises per device.
        """
        lo, hi, valid = self._bank_rows(bank)
        sigma = self.acam_config.sigma_program
        if sigma <= 0.0 or not self.per_shard_noise:
            return self._program_rows(lo, hi, valid, key)
        base = key if key is not None \
            else jax.random.PRNGKey(self.config.seed)
        if bank_shards <= 1:
            return self._program_rows(lo, hi, valid,
                                      jax.random.fold_in(base, shard_index))
        c = bank.templates.shape[0]
        if c % bank_shards:
            raise ValueError(
                f"per-shard programming emulation needs class rows ({c}) "
                f"divisible by bank_shards ({bank_shards})")
        rows = lo.shape[0] // bank_shards  # = (C/S) * K rows per array
        progs = [self._program_rows(lo[s * rows:(s + 1) * rows],
                                    hi[s * rows:(s + 1) * rows],
                                    valid[s * rows:(s + 1) * rows],
                                    jax.random.fold_in(base, s))
                 for s in range(bank_shards)]
        return acam_lib.ProgrammedACAM(
            lower=jnp.concatenate([p.lower for p in progs]),
            upper=jnp.concatenate([p.upper for p in progs]),
            valid=jnp.concatenate([p.valid for p in progs]),
            config=progs[0].config)

    def _sense_rows(self, prog: acam_lib.ProgrammedACAM, queries: Array,
                    c: int, k: int) -> Array:
        s = acam_lib.sense(prog, queries)  # (B, C*K), invalid rows -> -inf
        return s.reshape(queries.shape[0], c, k)

    def feature_count_scores(self, queries, templates, valid=None):
        c, k, n = templates.shape
        flat = templates.reshape(c * k, n)
        v = (valid if valid is not None
             else jnp.ones((c, k), bool)).reshape(c * k)
        return self._sense_rows(self._program_rows(flat, flat, v), queries,
                                c, k)

    def similarity_scores(self, queries, lower, upper, valid=None, *,
                          alpha=1.0):
        # alpha (the Eq. 9/11 distance weight) is digital post-processing
        # the analogue matchline does not integrate: the device senses the
        # Eq. 10 in-window fraction H only.
        del alpha
        c, k, n = lower.shape
        v = (valid if valid is not None
             else jnp.ones((c, k), bool)).reshape(c * k)
        prog = self._program_rows(lower.reshape(c * k, n),
                                  upper.reshape(c * k, n), v)
        return self._sense_rows(prog, queries, c, k)

    def scores(self, queries, bank):
        c, k, _ = bank.templates.shape
        return self._sense_rows(self.program_bank(bank), queries, c, k)

    def classify_features_keyed(self, features: Array, bank: TemplateBank,
                                key: Array, *, bank_shards: int = 1
                                ) -> tuple[Array, Array]:
        """One Monte-Carlo draw: program the bank with an explicit PRNG key
        (instead of the config-seed key) and classify.

        vmap-safe over ``key`` — `MatchEngine.sweep_program_noise` maps this
        over a batch of keys to turn the single programming sample of the
        program-once flow into per-draw confidence intervals. Under
        ``device_noise="per_shard"``, ``bank_shards=S`` programs the S-array
        tiling (array s keyed ``fold_in(key, s)``).
        """
        c, k, _ = bank.templates.shape
        prog = self.program_bank(bank, key, bank_shards=bank_shards)
        q = quant.binarize(features, bank.thresholds)
        return classify_scores(self._sense_rows(prog, q, c, k))

    # -- shard-local entry points (bank-sharded plans) -----------------------
    #
    # Each device programs its OWN physical array: under "per_shard" noise
    # the programming key folds in the shard index (row0 / C_local), so
    # shard s realises the same noise field whether it runs sharded on
    # device s or is emulated by `program_bank(..., bank_shards=S)`.

    def _shard_scores(self, queries: Array, bank: TemplateBank, row0: Array
                      ) -> Array:
        c, k, _ = bank.templates.shape
        prog = self.program_bank(bank, shard_index=row0 // c)
        return self._sense_rows(prog, queries, c, k)

    def classify_shard(self, queries, bank, row0):
        pred, per_class = classify_scores(
            self._shard_scores(queries, bank, row0))
        return per_class, jnp.max(per_class, axis=-1), \
            (pred + row0).astype(jnp.int32)

    def classify_features_shard(self, features, bank, row0):
        q = quant.binarize(features, bank.thresholds)
        return self.classify_shard(q, bank, row0)

    def classify_features_margin_shard(self, features, bank, class_lo,
                                       class_hi, row0):
        q = quant.binarize(features, bank.thresholds)
        _, per_class = classify_scores(self._shard_scores(q, bank, row0))
        top1, gidx, top2 = shard_window_top2(per_class, class_lo, class_hi,
                                             row0)
        return per_class, top1, gidx, top2

    def margin_cap(self, num_features: int) -> float:
        return 1.0  # sense outputs live in [0, 1] matchline units


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[EngineConfig], MatchBackend]] = {
    "reference": ReferenceBackend,
    "kernel": KernelBackend,
    "device": DeviceBackend,
}


def register_backend(name: str,
                     factory: Callable[[EngineConfig], MatchBackend]) -> None:
    """Add (or replace) a backend. `factory(config)` -> MatchBackend."""
    if name == "auto":
        raise ValueError('"auto" is the engine dispatch policy, '
                         "not a backend name")
    _REGISTRY[name] = factory
    backend_for.cache_clear()  # a replaced factory must not serve stale hits


def backend_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


@functools.lru_cache(maxsize=None)
def backend_for(name: str, config: EngineConfig) -> MatchBackend:
    """Memoised backend instance per (name, config) — backends are
    stateless value objects, so sharing them keeps jit caches shared too."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown matching backend {name!r}; use "
                         f"{('auto',) + backend_names()}") from None
    return factory(config)
