"""repro.match — the pluggable, mesh-aware template-matching engine.

Layering (backends x callers):

    callers   repro.core.hybrid (ACAMHead / HybridClassifier)
              repro.serve.{scheduler, acam_service}  (super-bank serving)
              repro.launch.serve --workload acam --backend ...
              benchmarks/{kernel_bench, serving_bench}, examples/*
                 |
    engine    MatchEngine(EngineConfig)   one hashable config; jit-static;
              shard_map over the dp mesh axes when a mesh is installed
                 |
    backends  reference (jnp oracles) | kernel (Pallas fused/two-stage +
              autotuner) | device (repro.core.acam RRAM-CMOS physics)

See `repro.match.engine` and `repro.match.backends` for the contracts.
"""
from repro.match.backends import (MAX_FUSED_ROWS, TINY_ELEMENTS,
                                  DeviceBackend, KernelBackend, MatchBackend,
                                  ReferenceBackend, backend_for,
                                  backend_names, classify_scores,
                                  feature_count_scores_ref, register_backend,
                                  similarity_scores_ref, window_margin,
                                  winner_take_all)
from repro.match.config import EngineConfig
from repro.match.engine import (MatchEngine, batch_specs, default_backend,
                                dp_axes_in_mesh, engine_for,
                                set_default_backend, use_backend)

__all__ = [
    "MAX_FUSED_ROWS", "TINY_ELEMENTS", "DeviceBackend", "KernelBackend",
    "MatchBackend", "ReferenceBackend", "backend_for", "backend_names",
    "classify_scores", "feature_count_scores_ref", "register_backend",
    "similarity_scores_ref", "window_margin", "winner_take_all",
    "EngineConfig", "MatchEngine", "batch_specs", "default_backend",
    "dp_axes_in_mesh", "engine_for", "set_default_backend", "use_backend",
]
