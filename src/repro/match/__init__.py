"""repro.match — the pluggable, mesh-aware template-matching engine.

Layering (plan x backends x callers):

    callers   repro.core.hybrid (ACAMHead / HybridClassifier)
              repro.serve.{registry, scheduler, acam_service}  (super-bank)
              repro.launch.serve --workload acam --backend/--bank-shards
              benchmarks/{kernel_bench, serving_bench}, examples/*
                 |
    engine    MatchEngine(EngineConfig)   one hashable config; jit-static
                 |
    plan      PartitionPlan(config, mesh, static shapes): batch over the dp
              axes, bank class rows over the model axis, or both (2D); the
              cross-shard (max, argmax) reduce keeps decisions/margins
              bit-identical to replicated execution
                 |
    backends  reference (jnp oracles) | kernel (single-dispatch Pallas at
              any bank size + the serving mega-kernel + autotuner) | device
              (repro.core.acam RRAM-CMOS physics)

See `repro.match.engine`, `repro.match.plan` and `repro.match.backends`
for the contracts.
"""
from repro.match.backends import (MAX_FUSED_ROWS, TINY_ELEMENTS,
                                  TINY_ELEMENTS_SIMILARITY, DeviceBackend,
                                  KernelBackend, MatchBackend,
                                  ReferenceBackend, backend_for,
                                  backend_names, classify_scores,
                                  feature_count_scores_ref, register_backend,
                                  shard_window_top2, similarity_scores_ref,
                                  tiny_cutoff, window_margin, winner_take_all)
from repro.match.config import EngineConfig
from repro.match.engine import (MatchEngine, bank_specs, batch_specs,
                                default_backend, dp_axes_in_mesh, engine_for,
                                engine_from_config, set_default_backend,
                                use_backend)
from repro.match.plan import (REPLICATED, TREE_REDUCE_MIN_SHARDS,
                              PartitionPlan, bank_shards_in_mesh, plan_for,
                              reduce_strategy)

__all__ = [
    "MAX_FUSED_ROWS", "TINY_ELEMENTS", "TINY_ELEMENTS_SIMILARITY",
    "DeviceBackend", "KernelBackend", "MatchBackend", "ReferenceBackend",
    "backend_for", "backend_names", "classify_scores",
    "feature_count_scores_ref", "register_backend", "shard_window_top2",
    "similarity_scores_ref", "tiny_cutoff", "window_margin",
    "winner_take_all", "EngineConfig", "MatchEngine", "bank_specs",
    "batch_specs", "default_backend", "dp_axes_in_mesh", "engine_for",
    "engine_from_config", "set_default_backend", "use_backend", "REPLICATED",
    "TREE_REDUCE_MIN_SHARDS", "PartitionPlan", "bank_shards_in_mesh",
    "plan_for", "reduce_strategy",
]
