"""PartitionPlan: how one matching call maps onto the installed mesh.

PR 3 gave the engine dp-only `shard_map` execution: the batch sharded over
the data-parallel axes, the template bank replicated on every device. That
replication is the engine's biggest scaling assumption — past ~10^5 tenant
class rows the super-bank itself is the memory wall (ROADMAP "Model-parallel
banks"). This module retires it: a `PartitionPlan` is a small hashable value
object, derived *eagerly* from the `EngineConfig`, the mesh in
`repro.distributed.context` and the call's static shapes, that says how a
single matching call executes:

    batch  sharded over the dp axes   (when the batch divides the dp devices)
    bank   class rows sharded over the model axis
           (when C divides the model-axis size and the backend supports it)
    both   2D: each device holds a (B / dp, C / shards) tile of the problem

Bank sharding follows the hardware line's own scaling story (tiling the
analogue template store across 9T4R ACAM units): every device computes
Eq. 8/11 scores and the per-class Eq. 12 partial max on its *class-row
shard*, then one tiny cross-shard `(max, argmax)` reduce over the model axis
recovers the exact global decision — and the windowed winner-vs-runner-up
margin — bit-identically to replicated execution (ties resolve to the lowest
global class index, exactly like `jnp.argmax`).

Because the plan is a NamedTuple of primitives it is hashable, so jitted
callers can treat it (or anything derived from it) as a static argument; and
because it is derived eagerly at the call boundary, installing a different
mesh yields a different plan — paired with `distributed.context.generation()`
as a static arg, callers re-trace instead of replaying a stale layout.

Who consumes the plan:

    repro.match.engine        builds the 2D shard_map specs + reduces from it
    repro.serve.registry      aligns tenant class buckets to shard boundaries
                              (`TemplateBankRegistry(bank_shards=...)`)
    repro.serve.acam_service  infers `bank_shards` via `bank_shards_in_mesh`
    repro.launch.serve        installs the mesh (`--bank-shards`)
"""
from __future__ import annotations

import math
import os
from typing import NamedTuple

from jax.sharding import PartitionSpec as P

#: below this many bank shards the sequential all-gather fold wins: the
#: butterfly's log2(S) ppermute rounds cost more launch latency than one
#: all-gather of S tiny rows. At S >= 8 the tree's O(log S) depth takes over.
TREE_REDUCE_MIN_SHARDS = 8

REDUCE_STRATEGIES = ("allgather", "tree")


class PartitionPlan(NamedTuple):
    """How one matching call is partitioned over the installed mesh.

    dp:             mesh axis names the batch is sharded over (() = batch
                    replicated / single device)
    model:          mesh axis name the bank's class rows are sharded over
                    (None = bank replicated on every device)
    dp_devices:     product of the dp axis sizes (1 when unsharded)
    bank_shards:    model-axis size (1 when the bank is replicated)
    rows_per_shard: class rows per bank shard, C // bank_shards (0 when the
                    bank is replicated) — shard s owns global class rows
                    [s * rows_per_shard, (s + 1) * rows_per_shard)
    reduce:         cross-shard reduce strategy over the model axis:
                    "allgather" (gather all S partials, sequential fold) or
                    "tree" (XOR-butterfly ppermute, log2(S) rounds). Both are
                    bit-identical — the merge is associative and f32 max is
                    exact — so this is purely a latency knob
                    (`reduce_strategy`).
    """

    dp: tuple[str, ...] = ()
    model: str | None = None
    dp_devices: int = 1
    bank_shards: int = 1
    rows_per_shard: int = 0
    reduce: str = "allgather"

    @property
    def batch_sharded(self) -> bool:
        return self.dp_devices > 1

    @property
    def bank_sharded(self) -> bool:
        return self.bank_shards > 1

    @property
    def sharded(self) -> bool:
        return self.batch_sharded or self.bank_sharded

    # -- spec builders (the single source of truth for the 2D layout) -------

    def batch_spec(self, rank: int = 1) -> P:
        """Spec for a batch-leading operand/output (rank >= 1)."""
        lead = self.dp if self.dp else None
        return P(lead, *([None] * (rank - 1)))

    def class_spec(self, rank: int = 1) -> P:
        """Spec for a class-row-leading operand (templates, valid)."""
        return P(self.model, *([None] * (rank - 1)))

    def batch_class_spec(self, rank: int = 2) -> P:
        """Spec for a (B, C, ...) output (per_class, scores)."""
        lead = self.dp if self.dp else None
        return P(lead, self.model, *([None] * (rank - 2)))


#: the no-mesh / no-divisibility plan: run the backend directly.
REPLICATED = PartitionPlan()


def reduce_strategy(bank_shards: int) -> str:
    """Pick the cross-shard reduce for a model axis of ``bank_shards``.

    Default: the XOR-butterfly tree when the shard count is a power of two
    at or past `TREE_REDUCE_MIN_SHARDS` (log2(S) hops beat gathering S
    partials), the sequential all-gather fold otherwise. The butterfly
    pairs rank s with s ^ d, so it needs a power-of-two axis.

    ``REPRO_REDUCE_STRATEGY=tree|allgather`` overrides the heuristic —
    "tree" still falls back to all-gather on non-power-of-two axes, where
    the butterfly is undefined.
    """
    pow2 = bank_shards > 1 and (bank_shards & (bank_shards - 1)) == 0
    env = os.environ.get("REPRO_REDUCE_STRATEGY", "").strip().lower()
    if env in REDUCE_STRATEGIES:
        return env if env != "tree" or pow2 else "allgather"
    if pow2 and bank_shards >= TREE_REDUCE_MIN_SHARDS:
        return "tree"
    return "allgather"


def mesh_axes():
    """(mesh, MeshAxes) from the distributed context, or (None, None)."""
    from repro.distributed import context

    mesh = context.get_mesh()
    axes = context.get()
    if mesh is None or axes is None:
        return None, None
    return mesh, axes


def plan_for(*, batch: int, num_classes: int,
             bank_shardable: bool = True) -> tuple[PartitionPlan, object]:
    """Derive the plan for a call with static shapes (batch, num_classes).

    Returns (plan, mesh). Pure and eager — safe at jit trace time (the mesh
    decision is baked into the caller's trace, same contract as
    `distributed.context.constrain`; thread `context.generation()` as a
    static arg to re-trace on mesh changes).

    Rules: the batch shards over the dp axes iff it divides their device
    product; the bank's class rows shard over the model axis iff C divides
    the model-axis size and the backend supports a sharded bank
    (`MatchBackend.supports_bank_sharding` — the device-physics backend
    declines when `sigma_program > 0`, where splitting the programming draw
    would change the realised noise layout vs one physical array).
    """
    mesh, axes = mesh_axes()
    if mesh is None:
        return REPLICATED, None
    dp_axes = axes.dp if isinstance(axes.dp, tuple) else (axes.dp,)
    dp: tuple[str, ...] = ()
    dp_devices = 1
    if all(a in mesh.axis_names for a in dp_axes):
        n = math.prod(mesh.shape[a] for a in dp_axes)
        if n > 1 and batch % n == 0:
            dp, dp_devices = tuple(dp_axes), n
    model = None
    bank_shards = 1
    rows = 0
    if bank_shardable and axes.model in mesh.axis_names:
        s = mesh.shape[axes.model]
        if s > 1 and num_classes % s == 0:
            model, bank_shards, rows = axes.model, s, num_classes // s
    plan = PartitionPlan(dp=dp, model=model, dp_devices=dp_devices,
                         bank_shards=bank_shards, rows_per_shard=rows,
                         reduce=reduce_strategy(bank_shards))
    if not plan.sharded:
        return REPLICATED, None
    return plan, mesh


def bank_shards_in_mesh() -> int:
    """Model-axis size of the installed mesh (1 when none is installed).

    The serving tier uses this to align the registry's class buckets to the
    shard boundaries the engine will cut the super-bank along
    (`TemplateBankRegistry(bank_shards=...)`).
    """
    mesh, axes = mesh_axes()
    if mesh is None or axes.model not in mesh.axis_names:
        return 1
    return int(mesh.shape[axes.model])
