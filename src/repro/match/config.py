"""Engine configuration: one hashable value object per matching setup.

`EngineConfig` replaces the old mutable module global in
`repro.core.matching` (`_backend`) as the way a caller selects matching
behaviour. Because it is a NamedTuple of hashables it can be

  * a `functools.lru_cache` key (`repro.match.engine_for` memoises one
    `MatchEngine` per distinct config), and
  * a **static jit argument** — jitted callers that close over a config
    (e.g. `repro.core.hybrid._fused_forward`, the serving scheduler's tick)
    get a *separate trace per config*, so changing the backend can never be
    silently baked into a stale executable.

Fields map onto the knobs the old dispatch layer spread across module
globals, keywords and environment variables:

  method   "feature_count" (Eq. 8) or "similarity" (Eq. 9-11)
  alpha    Eq. 11 distance weight (similarity method only)
  backend  "auto" | "reference" | "kernel" | "device" (or any name added
           via `repro.match.register_backend`); "auto" picks reference for
           tiny shapes and kernel otherwise
  block    optional (bm, bn, bk) Pallas block override; None = autotuner
  margin   `MatchEngine.__call__` returns (pred, per_class, margin) instead
           of (pred, per_class) — the serving cascade's signal
  device   `repro.core.acam.ACAMConfig` for the device-physics backend
           (cell flavour, sigma_program, ...); None = ACAMConfig() defaults
  seed     PRNG seed for `sigma_program > 0` programming noise
  device_noise
           how `sigma_program > 0` write noise maps onto bank shards
           (device backend only):
             "global"     ONE physical array draws one noise field — the
                          backend declines bank sharding, since per-shard
                          sub-arrays keyed alike would realise a different
                          layout than the replicated array.
             "per_shard"  real tiled deployments program one array PER
                          shard: array s (class rows [s*C/S, (s+1)*C/S))
                          draws its noise from fold_in(PRNGKey(seed), s).
                          Lifts the sharding refusal; an unsharded call is
                          the S = 1 tiling (fold_in(seed, 0)).
  serve_fusion
           how the kernel backend executes the multi-tenant serve path
           (`MatchEngine.classify_serve`, the scheduler tick):
             "mega"     the resident mega-kernel (`acam_match_serve` /
                        `acam_similarity_serve`): threshold gather, match,
                        windowed margin and the escalation mask in ONE
                        pallas_call — the default.
             "compose"  the pre-megakernel composition (jnp gather + shift,
                        then the fused margins kernel, then the jnp
                        escalation compare) — kept as the bit-identical
                        before/after benchmark baseline.
"""
from __future__ import annotations

from typing import NamedTuple

from repro.core.acam import ACAMConfig

METHODS = ("feature_count", "similarity")

DEVICE_NOISE_MODES = ("global", "per_shard")

SERVE_FUSION_MODES = ("mega", "compose")


class EngineConfig(NamedTuple):
    method: str = "feature_count"
    alpha: float = 1.0
    backend: str = "auto"
    block: tuple[int, int, int] | None = None
    margin: bool = False
    device: ACAMConfig | None = None
    seed: int = 0
    device_noise: str = "global"
    serve_fusion: str = "mega"


def validate(config: EngineConfig, backend_names: tuple[str, ...]) -> None:
    """Raise ValueError for unknown methods/backends (same errors the old
    `repro.core.matching` dispatch raised, so callers/tests are unchanged)."""
    if config.method not in METHODS:
        raise ValueError(f"unknown matching method {config.method}")
    if config.backend != "auto" and config.backend not in backend_names:
        raise ValueError(
            f"unknown matching backend {config.backend!r}; use "
            f"{('auto',) + backend_names}")
    if config.block is not None and len(tuple(config.block)) != 3:
        raise ValueError(f"block must be (bm, bn, bk), got {config.block!r}")
    if config.device_noise not in DEVICE_NOISE_MODES:
        raise ValueError(f"unknown device_noise {config.device_noise!r}; "
                         f"use {DEVICE_NOISE_MODES}")
    if config.serve_fusion not in SERVE_FUSION_MODES:
        raise ValueError(f"unknown serve_fusion {config.serve_fusion!r}; "
                         f"use {SERVE_FUSION_MODES}")
    hash(config)  # fail fast: configs must stay usable as static jit args
