"""Production mesh definitions (multi-pod dry-run spec).

A function, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod mesh: (data=16, model=16); two pods add a leading
    "pod" axis: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (axes kept for spec parity)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(*, bank_shards: int = 1,
                      axis_names: tuple[str, str] = ("data", "model"),
                      devices=None):
    """(data = devices/bank_shards, model = bank_shards) over the available
    devices — the ACAM serving layout: request batches shard over "data",
    the template super-bank's class rows shard over "model" (the engine's
    `repro.match.plan.PartitionPlan`). ``bank_shards=1`` degenerates to
    pure data parallelism (bank replicated). ``axis_names`` follows a
    `ServiceSpec.mesh` with custom axis names
    (`repro.serve.control.install_mesh` is the usual caller).

    ``devices`` restricts the mesh to an explicit device subset — the
    degraded-fleet path (`HybridService.handle_device_loss` passes the
    survivors after a simulated device failure). Default: all of
    `jax.devices()`.

    On CPU, force host devices first (``REPRO_FORCE_MESH`` /
    `repro.distributed.forcemesh.apply_xla_flags` before jax initialises).
    """
    devs = list(jax.devices()) if devices is None else list(devices)
    ndev = len(devs)
    if bank_shards < 1 or ndev % bank_shards:
        raise ValueError(
            f"bank_shards={bank_shards} must divide the {ndev} available "
            "devices")
    return jax.sharding.Mesh(
        np.asarray(devs).reshape(ndev // bank_shards, bank_shards),
        tuple(axis_names))
