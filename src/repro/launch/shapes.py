"""Assigned input-shape sets and ShapeDtypeStruct stand-ins for the dry-run.

Shapes (from the assignment brief):
    train_4k     seq_len=4,096   global_batch=256   (training)
    prefill_32k  seq_len=32,768  global_batch=32    (inference-prefill)
    decode_32k   seq_len=32,768  global_batch=128   (inference-decode:
                 one new token with a KV cache of seq_len)
    long_500k    seq_len=524,288 global_batch=1     (long-context-decode)

Applicability (DESIGN.md §7): decode_* / long_* skip encoder-only archs;
long_500k runs only for SSM/hybrid archs (sub-quadratic state).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import lm

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

SHAPE_IDS = list(SHAPES)


def applicable(cfg: lm.ArchConfig, shape_id: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the brief's applicability rules."""
    kind = SHAPES[shape_id]["kind"]
    if not cfg.causal and kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape_id == "long_500k" and not (cfg.ssm or cfg.hybrid):
        return False, "pure full-attention arch skips long_500k (sub-quadratic required)"
    return True, ""


class CellSpecs(NamedTuple):
    """ShapeDtypeStruct stand-ins for one (arch x shape) dry-run cell."""
    kind: str  # train | prefill | decode
    args: tuple  # positional args for the step fn (after params/opt_state)
    seq: int
    batch: int


def _maybe_smoke(cfg: lm.ArchConfig, seq: int, batch: int, smoke: bool):
    if smoke:  # reduced geometry for CPU integration tests
        return min(seq, 64), min(batch, 4)
    return seq, batch


def input_specs(cfg: lm.ArchConfig, shape_id: str, *, smoke: bool = False) -> CellSpecs:
    """Build the (allocation-free) input ShapeDtypeStructs for a cell."""
    sh = SHAPES[shape_id]
    seq, batch = _maybe_smoke(cfg, sh["seq"], sh["batch"], smoke)
    kind = sh["kind"]
    f_embed = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)
    i32 = jnp.int32

    if kind == "train":
        batch_d: dict[str, Any] = {
            "inputs": (f_embed if cfg.input_mode == "embeds"
                       else jax.ShapeDtypeStruct((batch, seq), i32)),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        }
        if cfg.rope == "mrope":
            batch_d["positions"] = jax.ShapeDtypeStruct((3, batch, seq), i32)
        return CellSpecs("train", (batch_d,), seq, batch)

    if kind == "prefill":
        inputs = (f_embed if cfg.input_mode == "embeds"
                  else jax.ShapeDtypeStruct((batch, seq), i32))
        args: tuple = (inputs,)
        if cfg.rope == "mrope":
            args += (jax.ShapeDtypeStruct((3, batch, seq), i32),)
        return CellSpecs("prefill", args, seq, batch)

    # decode: one new token against a cache of `seq` tokens
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, batch, seq))
    tok = (jax.ShapeDtypeStruct((batch, 1, cfg.d_model), jnp.bfloat16)
           if cfg.input_mode == "embeds"
           else jax.ShapeDtypeStruct((batch, 1), i32))
    return CellSpecs("decode", (tok, cache), seq, batch)
