"""Serving launcher: LM decode engine OR the multi-tenant ACAM service.

Two workloads behind one CLI:

  lm    — batched generation over any zoo architecture
          (`repro.serve.engine.Engine`). CPU smoke scale by default; on a
          real pod the same engine runs under `make_production_mesh()` with
          the `tp`/`fsdp_tp` shardings whose lowering the decode_32k /
          long_500k dry-run cells prove.

  acam  — the multi-tenant hybrid-classifier service, constructed through
          the ONE front door: a declarative `repro.serve.spec.ServiceSpec`
          (built from the CLI flags, or loaded verbatim via
          ``--spec service.json``) handed to
          `repro.serve.control.HybridService.from_spec`, which owns the
          whole boot sequence — mesh install -> registry -> scheduler ->
          cascade — so there is no constructor ordering to get wrong.

  lm-cached — the two composed (`repro.serve.semantic_cache`): the ACAM
          tier fronts the decode engine as a template router; repeats of
          an admitted prompt answer from the response store at Eq. 14
          ACAM energy, cold prompts escalate to decode and are admitted
          back as templates.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --requests 8 --max-new 16 --temperature 0.8
  PYTHONPATH=src python -m repro.launch.serve --workload lm-cached \
      --requests 32 --unique 8 --temperature 0.7
  PYTHONPATH=src python -m repro.launch.serve --workload acam \
      --tenants 8 --requests 256 --slots 64
  PYTHONPATH=src python -m repro.launch.serve --workload acam \
      --backend device   # serve through the RRAM-CMOS physics models
  PYTHONPATH=src python -m repro.launch.serve --workload acam \
      --spec service.json --print-spec   # declarative boot from a file
  REPRO_FORCE_MESH=2x2 PYTHONPATH=src python -m repro.launch.serve \
      --workload acam --bank-shards 2   # 2D-sharded: batch over "data",
                                        # super-bank class rows over "model"
  PYTHONPATH=src python -m repro.launch.serve --workload acam \
      --snapshot-dir /tmp/acam-ckpt     # durable state: snapshot on exit
  PYTHONPATH=src python -m repro.launch.serve --workload acam \
      --snapshot-dir /tmp/acam-ckpt --restore   # restart bit-identical
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def build_acam_spec(args):
    """The launcher's flag surface -> one `ServiceSpec` (or load the spec
    verbatim from ``--spec file.json`` — flags are then ignored)."""
    from repro import match as match_lib
    from repro.match.config import EngineConfig
    from repro.serve import spec as spec_lib

    if args.spec:
        return spec_lib.ServiceSpec.from_file(args.spec)
    return spec_lib.ServiceSpec(
        registry=spec_lib.RegistrySpec(
            num_features=args.features,
            initial_classes=spec_lib.aligned_classes(args.bank_shards)),
        engine=EngineConfig(backend=args.backend
                            or match_lib.default_backend(), margin=True,
                            device_noise=args.device_noise),
        mesh=spec_lib.MeshSpec(bank_shards=args.bank_shards),
        scheduler=spec_lib.SchedulerSpec(slots=args.slots),
        cascade=spec_lib.CascadeSpec(tau=args.margin_tau,
                                     tau_units="count",
                                     deadline_ms=args.deadline_ms,
                                     shed_queue=args.shed_queue),
        obs=spec_lib.ObsSpec(telemetry_dir=args.telemetry_dir,
                             span_sample=args.span_sample,
                             profile_annotations=args.profile_annotations),
    )


def run_lm(args) -> dict:
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine, Request

    cfg = configs.get(args.arch, smoke=args.smoke)
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(cfg, params, batch_size=args.batch_size,
                 max_len=args.max_len, temperature=args.temperature)
    rng = np.random.RandomState(args.seed)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=rng.randint(4, 32)),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    eng.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"{cfg.name}: {len(reqs)} requests, {total} tokens, "
          f"{dt:.2f}s ({total / dt:.1f} tok/s)")
    return {"tokens": total, "seconds": dt}


def run_acam(args) -> dict:
    from repro.serve import acam_service as svc_lib
    from repro.serve.control import HybridService

    # ONE declarative spec drives the whole stack; from_spec installs the
    # (data, model=bank_shards) mesh itself, then builds registry ->
    # scheduler -> cascade in order. margin_tau rides in the spec with
    # explicit units ("count"); the service converts to the backend's
    # native margin units (matchline fractions for "device") itself.
    spec = build_acam_spec(args)
    if args.print_spec:
        print(spec.to_json())
    if args.restore:
        from repro.checkpoint.checkpointer import Checkpointer

        if not args.snapshot_dir:
            raise SystemExit("--restore needs --snapshot-dir")
        svc, report = HybridService.restore(Checkpointer(args.snapshot_dir))
        print(f"restored step {report.step}: {report.tenants} tenants, "
              f"{report.restore_s * 1e3:.1f} ms"
              + (" (resharded)" if report.resharded else ""))
        spec = svc.spec
    else:
        svc = HybridService.from_spec(spec)
    n_features = spec.registry.num_features
    if spec.mesh.bank_shards > 1:
        print(f"installed serving mesh model={spec.mesh.bank_shards} "
              f"({len(jax.devices())} devices)")

    protos = {}
    if args.manifest:
        # declarative tenant population: ONE FleetManifest JSON file,
        # diffed against the (empty) manifest in force — the same
        # apply_manifest the autopilot uses for churn
        from repro.fleet import FleetManifest

        rep = svc.apply_manifest(FleetManifest.from_file(args.manifest))
        print(f"manifest applied: +{len(rep.added)} added, "
              f"-{len(rep.evicted)} evicted, {len(rep.updated)} updated, "
              f"{len(rep.retuned)} retuned "
              f"({len(svc.registry)} tenants live)")
        for t in rep.manifest.tenants:
            if t.seed is not None:  # checkpoint tenants have no protos
                protos[t.tenant_id] = svc_lib.make_synthetic_tenant(
                    t.seed, num_classes=t.num_classes, k=t.k,
                    num_features=n_features)[2]
    else:
        for t in range(args.tenants):
            bank, head, p = svc_lib.make_synthetic_tenant(
                args.seed * 1000 + t, num_classes=args.classes,
                num_features=n_features)
            tid = f"tenant-{t}"
            if tid not in svc.registry:  # a restored service adopted them
                svc.register_tenant(tid, bank, head=head)
            protos[tid] = p

    # mixed-tenant request stream (round-robin interleave, then shuffled —
    # every micro-batch holds several tenants)
    rng = np.random.RandomState(args.seed)
    reqs, truth = [], []
    tids = sorted(protos)
    per_tenant = -(-args.requests // max(len(tids), 1))
    for t, tid in enumerate(tids):
        feats, labels = svc_lib.sample_tenant_queries(
            args.seed + 7 * t, protos[tid], per_tenant, noise=args.noise)
        for i in range(per_tenant):
            reqs.append(svc_lib.ClassifyRequest(tid, feats[i]))
            truth.append(int(labels[i]))
    order = rng.permutation(len(reqs))[:args.requests]
    reqs = [reqs[i] for i in order]
    truth = [truth[i] for i in order]

    if args.autopilot:
        # closed-loop serving: bursty submission, one observe_tick per
        # step — the policy controller may reshard (double-buffered
        # flip), swap backends, widen slots or compact mid-stream
        from repro.fleet import Autopilot, PolicySpec

        pilot = Autopilot(svc, policy=PolicySpec())
        burst = spec.scheduler.slots
        responses, i = [], 0
        while i < len(reqs) or svc.scheduler.qsize:
            for r in reqs[i:i + burst]:
                svc.submit(r)
            i += burst
            responses.extend(svc.step())
            pilot.observe_tick()
            responses.extend(pilot.take_drained())
        if pilot.actions:
            acts = ", ".join(f"t{a['tick']}:{a['action']}"
                             for a in pilot.actions)
            print(f"autopilot: {len(pilot.actions)} actions ({acts}); "
                  f"now bank_shards={svc.spec.mesh.bank_shards}, "
                  f"slots={svc.spec.scheduler.slots}, "
                  f"backend={svc.spec.engine.backend}")
        else:
            print("autopilot: no action (no threshold crossed)")
        spec = svc.spec
    else:
        responses = svc.serve(reqs)
    m = svc.metrics()
    if args.snapshot_dir:
        from repro.checkpoint.checkpointer import Checkpointer

        step = svc.snapshot(Checkpointer(args.snapshot_dir))
        print(f"service snapshot -> {args.snapshot_dir} step {step} "
              f"(restart with --restore)")
    acc = float(np.mean([r.pred == y for r, y in zip(responses, truth)]))
    print(f"acam service: {m['completed']} requests over "
          f"{len(svc.registry)} tenants, "
          f"{m['classify_dispatches']} fused dispatches "
          f"(occupancy {m['occupancy']:.2f}), accuracy {acc:.4f}")
    print(f"  escalation rate {m['escalation_rate']:.3f} "
          f"({m['escalated']} escalated, "
          f"{m['escalation_dispatches']} head dispatches), "
          f"{m['nj_per_request']:.2f} nJ/request, "
          f"{m['requests_per_s']:.1f} req/s, "
          f"p50 {m['latency_p50_ms']:.1f} ms / p99 {m['latency_p99_ms']:.1f} ms")
    fleet = svc.obs.ledger.fleet()
    print(f"  energy ledger: {fleet['total_nj']:.1f} nJ fleet total, "
          f"backend share {fleet['backend_share']:.3f} "
          f"(E_backend {fleet['backend_nj']:.1f} nJ / "
          f"E_frontend {fleet['frontend_nj']:.1f} nJ)")
    if spec.obs.telemetry_dir:
        import os

        from repro.obs import write_prometheus

        prom = os.path.join(spec.obs.telemetry_dir, "metrics.prom")
        write_prometheus(svc.obs.registry, prom)
        print(f"  telemetry: {svc.obs.events.path} (event log), "
              f"{prom} (Prometheus scrape)")
    return {"accuracy": acc, **m}


def run_lm_cached(args) -> dict:
    """The two engines composed: ACAM semantic cache fronting LM decode.

    A Zipf-repeat prompt trace is routed through
    `repro.serve.semantic_cache.SemanticCacheService` — repeats of an
    admitted prompt answer from the response store at Eq. 14 ACAM energy,
    cold prompts escalate to ONE `Engine.generate` call per tick and are
    admitted back as templates."""
    from repro import configs
    from repro.models import lm
    from repro.serve import spec as spec_lib
    from repro.serve.engine import Engine
    from repro.serve.semantic_cache import (PromptRequest,
                                            SemanticCacheService,
                                            synthetic_prompt_trace)

    cfg = configs.get(args.arch, smoke=args.smoke)
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(cfg, params, batch_size=args.batch_size,
                 max_len=args.max_len, temperature=args.temperature,
                 seed=args.seed)
    spec = spec_lib.ServiceSpec(
        registry=spec_lib.RegistrySpec(num_features=args.features),
        scheduler=spec_lib.SchedulerSpec(slots=args.slots),
        cascade=spec_lib.CascadeSpec(backend="lm", tau=args.margin_tau,
                                     tau_units="count"),
        router=spec_lib.RouterSpec(max_templates=max(args.unique, 1)),
        mesh=spec_lib.MeshSpec(install=False))
    if args.print_spec:
        print(spec.to_json())
    svc = SemanticCacheService.from_spec(spec, engine=eng)
    svc.add_tenant("edge-0")

    trace = synthetic_prompt_trace(args.seed, vocab=cfg.vocab,
                                   n_unique=args.unique,
                                   n_requests=args.requests)
    # arrivals come in bursts, not all at once: a template admitted on a
    # miss can only serve hits on LATER ticks, so a single slots-wide
    # tick over the whole trace would (correctly) never hit
    burst = max(1, min(args.slots, args.unique))
    t0 = time.time()
    out = []
    for i in range(0, len(trace), burst):
        out.extend(svc.serve_prompts(
            PromptRequest("edge-0", p, max_new_tokens=args.max_new)
            for p in trace[i:i + burst]))
    dt = time.time() - t0
    m = svc.metrics()
    hits = sum(r.cache_hit for r in out)
    served = sum(r.error is None for r in out)
    fleet = svc.obs.ledger.fleet()
    print(f"{cfg.name} behind ACAM semantic cache: {served} requests "
          f"({args.unique} unique), {hits} cache hits "
          f"({hits / max(served, 1):.2f} hit rate), "
          f"{m['classify_dispatches']} fused match dispatches over "
          f"{m['ticks']} ticks, {dt:.2f}s")
    print(f"  energy: {m['nj_per_request']:.1f} nJ/request mean "
          f"(ACAM share {fleet['backend_share']:.4f}; decode misses carry "
          f"{fleet['frontend_nj']:.1f} nJ of the "
          f"{fleet['total_nj']:.1f} nJ total)")
    return {"hits": hits, "served": served, "seconds": dt, **m}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "acam", "lm-cached"),
                    default="lm")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    # lm
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    # lm-cached
    ap.add_argument("--unique", type=int, default=8,
                    help="lm-cached: distinct prompts in the Zipf trace "
                         "(the rest are cache-hitting repeats)")
    # acam
    ap.add_argument("--spec", default=None, metavar="FILE.json",
                    help="boot the acam service from a declarative "
                         "ServiceSpec JSON file (other acam flags ignored)")
    ap.add_argument("--print-spec", action="store_true",
                    help="print the resolved ServiceSpec JSON before boot")
    ap.add_argument("--manifest", default=None, metavar="FILE.json",
                    help="populate tenants from a declarative FleetManifest "
                         "JSON file (diffed + applied as live transitions) "
                         "instead of the synthetic --tenants loop")
    ap.add_argument("--autopilot", action="store_true",
                    help="drive serving through the repro.fleet Autopilot: "
                         "the telemetry policy may reshard (double-buffered "
                         "flip), swap backends, widen slots or compact the "
                         "registry mid-stream")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--slots", type=int, default=64)
    ap.add_argument("--classes", type=int, default=10,
                    help="classes per synthetic tenant")
    ap.add_argument("--features", type=int, default=64,
                    help="feature dim of the synthetic tenants")
    ap.add_argument("--margin-tau", type=float, default=8.0,
                    help="cascade accept threshold (match-count units)")
    ap.add_argument("--noise", type=float, default=0.8,
                    help="query noise (drives the escalation rate)")
    ap.add_argument("--backend", default=None,
                    choices=("auto", "kernel", "reference", "device"),
                    help="repro.match engine backend for the ACAM service "
                         "(device = RRAM-CMOS physics models; margin-tau "
                         "is auto-rescaled to matchline-fraction units); "
                         "default: process REPRO_MATCHING_BACKEND / auto")
    ap.add_argument("--bank-shards", type=int, default=1,
                    help="shard the template super-bank's class rows over "
                         "a model mesh axis of this size (must divide the "
                         "device count; on CPU set REPRO_FORCE_MESH or "
                         "XLA_FLAGS host-device count first)")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="durable service state: snapshot the service "
                         "(registry, placements, taus, heads, spec) into "
                         "DIR after serving, via the atomic-rename "
                         "checkpointer")
    ap.add_argument("--restore", action="store_true",
                    help="boot by restoring the latest snapshot from "
                         "--snapshot-dir instead of building fresh "
                         "(bit-identical serving, zero re-registrations)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request queue deadline: requests older than "
                         "this at tick time are expired with an error")
    ap.add_argument("--shed-queue", type=int, default=None,
                    help="queue depth at which the service enters load-shed "
                         "mode (ACAM stage alone, no CNN escalation)")
    ap.add_argument("--telemetry-dir", default=None, metavar="DIR",
                    help="flight-recorder sinks: append a JSONL event log "
                         "(DIR/events.jsonl, one line per serving tick + "
                         "every lifecycle event) and write a Prometheus "
                         "scrape file (DIR/metrics.prom) after serving")
    ap.add_argument("--span-sample", type=float, default=1.0,
                    help="fraction of requests carrying a full per-request "
                         "span (deterministic in the request id)")
    ap.add_argument("--profile-annotations", action="store_true",
                    help="wrap the fused dispatch in a jax.profiler "
                         "TraceAnnotation (visible in device traces)")
    ap.add_argument("--device-noise", default="global",
                    choices=("global", "per_shard"),
                    help="sigma_program noise semantics for the device "
                         "backend: per_shard programs one physical array "
                         "per bank shard (fold_in(seed, shard))")
    args = ap.parse_args(argv)
    if args.requests is None:
        args.requests = {"lm": 8, "acam": 256, "lm-cached": 32}[args.workload]
    runner = {"lm": run_lm, "acam": run_acam,
              "lm-cached": run_lm_cached}[args.workload]
    return runner(args)


if __name__ == "__main__":
    main()
