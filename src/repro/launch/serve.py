"""Serving launcher: LM decode engine OR the multi-tenant ACAM service.

Two workloads behind one CLI:

  lm    — batched generation over any zoo architecture
          (`repro.serve.engine.Engine`). CPU smoke scale by default; on a
          real pod the same engine runs under `make_production_mesh()` with
          the `tp`/`fsdp_tp` shardings whose lowering the decode_32k /
          long_500k dry-run cells prove.

  acam  — the multi-tenant hybrid-classifier service
          (`repro.serve.acam_service.ACAMService`): per-tenant template
          banks stacked into one super-bank, micro-batched cross-tenant
          scheduling with ONE fused classify dispatch per tick, and the
          confidence cascade (accept-at-ACAM vs escalate to the CNN head)
          with paper §V-D energy attribution.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --requests 8 --max-new 16 --temperature 0.8
  PYTHONPATH=src python -m repro.launch.serve --workload acam \
      --tenants 8 --requests 256 --slots 64
  PYTHONPATH=src python -m repro.launch.serve --workload acam \
      --backend device   # serve through the RRAM-CMOS physics models
  REPRO_FORCE_MESH=2x2 PYTHONPATH=src python -m repro.launch.serve \
      --workload acam --bank-shards 2   # 2D-sharded: batch over "data",
                                        # super-bank class rows over "model"
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def install_acam_mesh(bank_shards: int) -> None:
    """Install the (data, model=bank_shards) serving mesh into the
    distributed context — BEFORE the service is constructed, so the
    registry aligns tenant placement to the same shards the engine's
    `PartitionPlan` cuts the super-bank along."""
    from repro.distributed import context
    from repro.launch.mesh import make_serving_mesh

    mesh = make_serving_mesh(bank_shards=bank_shards)
    context.set_mesh_axes("data", "model", mesh)
    shape = dict(mesh.shape)
    print(f"installed serving mesh data={shape['data']} "
          f"model={shape['model']} ({len(mesh.devices.flat)} devices)")


def run_lm(args) -> dict:
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine, Request

    cfg = configs.get(args.arch, smoke=args.smoke)
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(cfg, params, batch_size=args.batch_size,
                 max_len=args.max_len, temperature=args.temperature)
    rng = np.random.RandomState(args.seed)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=rng.randint(4, 32)),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    eng.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"{cfg.name}: {len(reqs)} requests, {total} tokens, "
          f"{dt:.2f}s ({total / dt:.1f} tok/s)")
    return {"tokens": total, "seconds": dt}


def run_acam(args) -> dict:
    from repro.serve import acam_service as svc_lib

    if args.bank_shards > 1:
        install_acam_mesh(args.bank_shards)
    # margin_tau is in match-count units for every backend: the service
    # rescales to matchline fractions itself when backend == "device";
    # bank_shards is inferred from the just-installed mesh
    cfg = svc_lib.ServiceConfig(slots=args.slots, margin_tau=args.margin_tau)
    svc = svc_lib.ACAMService(args.features, config=cfg,
                              backend=args.backend)

    protos = {}
    for t in range(args.tenants):
        bank, head, p = svc_lib.make_synthetic_tenant(
            args.seed * 1000 + t, num_classes=args.classes,
            num_features=args.features)
        tid = f"tenant-{t}"
        svc.register_tenant(tid, bank, head=head)
        protos[tid] = p

    # mixed-tenant request stream (round-robin interleave, then shuffled —
    # every micro-batch holds several tenants)
    rng = np.random.RandomState(args.seed)
    reqs, truth = [], []
    per_tenant = -(-args.requests // args.tenants)
    for t in range(args.tenants):
        tid = f"tenant-{t}"
        feats, labels = svc_lib.sample_tenant_queries(
            args.seed + 7 * t, protos[tid], per_tenant, noise=args.noise)
        for i in range(per_tenant):
            reqs.append(svc_lib.ClassifyRequest(tid, feats[i]))
            truth.append(int(labels[i]))
    order = rng.permutation(len(reqs))[:args.requests]
    reqs = [reqs[i] for i in order]
    truth = [truth[i] for i in order]

    responses = svc.serve(reqs)
    m = svc.metrics()
    acc = float(np.mean([r.pred == y for r, y in zip(responses, truth)]))
    print(f"acam service: {m['completed']} requests over {args.tenants} "
          f"tenants, {m['classify_dispatches']} fused dispatches "
          f"(occupancy {m['occupancy']:.2f}), accuracy {acc:.4f}")
    print(f"  escalation rate {m['escalation_rate']:.3f} "
          f"({m['escalated']} escalated, "
          f"{m['escalation_dispatches']} head dispatches), "
          f"{m['nj_per_request']:.2f} nJ/request, "
          f"{m['requests_per_s']:.1f} req/s, "
          f"p50 {m['latency_p50_ms']:.1f} ms / p99 {m['latency_p99_ms']:.1f} ms")
    return {"accuracy": acc, **m}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "acam"), default="lm")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    # lm
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    # acam
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--slots", type=int, default=64)
    ap.add_argument("--classes", type=int, default=10,
                    help="classes per synthetic tenant")
    ap.add_argument("--features", type=int, default=64,
                    help="feature dim of the synthetic tenants")
    ap.add_argument("--margin-tau", type=float, default=8.0,
                    help="cascade accept threshold (match-count units)")
    ap.add_argument("--noise", type=float, default=0.8,
                    help="query noise (drives the escalation rate)")
    ap.add_argument("--backend", default=None,
                    choices=("auto", "kernel", "reference", "device"),
                    help="repro.match engine backend for the ACAM service "
                         "(device = RRAM-CMOS physics models; margin-tau "
                         "is auto-rescaled to matchline-fraction units); "
                         "default: process REPRO_MATCHING_BACKEND / auto")
    ap.add_argument("--bank-shards", type=int, default=1,
                    help="shard the template super-bank's class rows over "
                         "a model mesh axis of this size (must divide the "
                         "device count; on CPU set REPRO_FORCE_MESH or "
                         "XLA_FLAGS host-device count first)")
    args = ap.parse_args(argv)
    if args.requests is None:
        args.requests = 8 if args.workload == "lm" else 256
    return (run_acam if args.workload == "acam" else run_lm)(args)


if __name__ == "__main__":
    main()
