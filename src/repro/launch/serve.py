"""Serving launcher: batched generation over any zoo architecture.

CPU smoke scale by default; on a real pod the same engine runs under
`make_production_mesh()` with the `tp`/`fsdp_tp` shardings whose lowering
the decode_32k / long_500k dry-run cells prove.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --requests 8 --max-new 16 --temperature 0.8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine, Request


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, smoke=args.smoke)
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(cfg, params, batch_size=args.batch_size,
                 max_len=args.max_len, temperature=args.temperature)
    rng = np.random.RandomState(args.seed)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=rng.randint(4, 32)),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    eng.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"{cfg.name}: {len(reqs)} requests, {total} tokens, "
          f"{dt:.2f}s ({total / dt:.1f} tok/s)")
    return {"tokens": total, "seconds": dt}


if __name__ == "__main__":
    main()
