"""Training launcher: end-to-end driver with fault tolerance.

Runs any registered architecture (full config on the production mesh when
real TPUs back the process; reduced smoke geometry on CPU) with:
  - checkpoint/restart (atomic, async; `--resume` continues from the newest
    durable step — kill the process mid-run and relaunch to exercise it),
  - gradient accumulation (global batch preserved under elastic resizes),
  - optional int8 error-feedback gradient compression (`--compress`),
  - straggler/heartbeat bookkeeping hooks (single-host here).

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
      --steps 30 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.distributed import context as mesh_ctx
from repro.distributed import sharding
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.optim import compression


def synthetic_batch(cfg: lm.ArchConfig, batch: int, seq: int, step: int) -> dict:
    """Deterministic synthetic token stream (per-step seeded)."""
    rng = np.random.RandomState(step)
    if cfg.input_mode == "tokens":
        toks = rng.randint(0, cfg.vocab, size=(batch, seq), dtype=np.int64)
        inputs = jnp.asarray(toks, jnp.int32)
    else:
        inputs = jnp.asarray(
            rng.randn(batch, seq, cfg.d_model), jnp.bfloat16)
    labels = jnp.asarray(
        rng.randint(0, cfg.vocab, size=(batch, seq)), jnp.int32)
    out = {"inputs": inputs, "labels": labels}
    if cfg.rope == "mrope":
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32)[None, None],
                              (3, batch, seq))
        out["positions"] = jnp.asarray(pos)
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--async-ckpt", action="store_true")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, smoke=args.smoke)
    mesh = mesh_lib.make_host_mesh() if jax.device_count() < 16 else \
        mesh_lib.make_production_mesh()
    mesh_ctx.set_mesh_axes(sharding.dp_axes(mesh), "model", mesh=mesh)

    opt = steps_lib.make_optimizer(cfg, args.lr)

    def train_step(params, opt_state, err, batch):
        def loss_microbatch(p, b):
            return lm.loss_fn(p, cfg, b)

        loss, grads = jax.value_and_grad(loss_microbatch)(params, batch)
        if args.compress:
            grads, err = compression.compress_decompress(grads, err)
        from repro.optim.optimizers import clip_by_global_norm
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, err, loss

    with mesh:
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt.init(params)
        err = compression.init_error_state(params) if args.compress else None
        step0 = 0

        ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        if ckpt and args.resume:
            latest = ckpt.latest_step()
            if latest is not None:
                state = ckpt.restore(latest, {"params": params,
                                              "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                step0 = latest + 1
                print(f"resumed from step {latest}")

        jstep = jax.jit(train_step, donate_argnums=(0, 1))
        losses = []
        for step in range(step0, args.steps):
            t0 = time.time()
            loss_acc = 0.0
            for micro in range(args.grad_accum):
                batch = synthetic_batch(cfg, args.batch, args.seq,
                                        step * args.grad_accum + micro)
                params, opt_state, err, loss = jstep(params, opt_state, err,
                                                     batch)
                loss_acc += float(loss)
            losses.append(loss_acc / args.grad_accum)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          blocking=not args.async_ckpt)
            print(f"step {step}: loss={losses[-1]:.4f} "
                  f"({time.time()-t0:.2f}s)")
        if ckpt:
            ckpt.save(args.steps - 1, {"params": params, "opt": opt_state})
            ckpt.wait()
    mesh_ctx.clear()
    return {"losses": losses, "final_loss": losses[-1] if losses else None}


if __name__ == "__main__":
    main()
