"""jit-able step functions (train / prefill / decode) with their shardings.

These are shared by the real launcher (launch/train.py, launch/serve.py) and
the dry-run (launch/dryrun.py). Each builder returns (fn, in_shardings,
out_shardings, arg_specs) so the dry-run can `.lower().compile()` with
ShapeDtypeStructs and the launcher can run with real arrays.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding
from repro.models import lm
from repro.optim import optimizers as optim

PyTree = Any


def make_optimizer(cfg: lm.ArchConfig, lr: float = 3e-4) -> optim.Optimizer:
    return optim.adamw(lr, weight_decay=0.1)


def build_train_step(cfg: lm.ArchConfig, mesh: Mesh, *, mode: str = "fsdp_tp",
                     lr: float = 3e-4, donate: bool = True,
                     example_batch=None):
    """train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    sharding.register_zero3_constraints(cfg, mesh, mode)
    opt = make_optimizer(cfg, lr)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch))(params)
        grads, gnorm = optim.clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    p_specs = sharding.param_specs(cfg, mesh, mode)
    o_specs = sharding.opt_state_specs(p_specs)
    b_specs = sharding.batch_specs(cfg, mesh)
    if example_batch is not None:
        b_specs = sharding.fit_tree(b_specs, example_batch, mesh)
    metric_specs = {"loss": P(), "grad_norm": P()}
    in_specs = (p_specs, o_specs, b_specs)
    out_specs = (p_specs, o_specs, metric_specs)
    jit_kwargs = dict(
        in_shardings=sharding.to_shardings(in_specs, mesh),
        out_shardings=sharding.to_shardings(out_specs, mesh),
    )
    if donate:
        jit_kwargs["donate_argnums"] = (0, 1)
    return jax.jit(train_step, **jit_kwargs), in_specs, out_specs, opt


def build_prefill_step(cfg: lm.ArchConfig, mesh: Mesh, *, mode: str = "fsdp_tp",
                       max_len: int | None = None, example_args=None):
    """prefill(params, inputs[, positions]) -> (last_logits, cache)."""

    def prefill_step(params, inputs, positions=None):
        return lm.prefill(params, cfg, inputs, positions, max_len=max_len)

    sharding.register_zero3_constraints(cfg, mesh, mode)
    dp = sharding.dp_axes(mesh)
    p_specs = sharding.param_specs(cfg, mesh, mode)
    in_sp: tuple = (p_specs,
                    P(dp, None, None) if cfg.input_mode == "embeds" else P(dp, None))
    if cfg.rope == "mrope":
        in_sp += (P(None, dp, None),)
    out_sp = (P(dp, "model"), sharding.cache_specs(cfg, mesh))
    if example_args is not None:
        in_sp = sharding.fit_tree(in_sp, example_args, mesh)
        out_shapes = jax.eval_shape(prefill_step, *example_args)
        out_sp = sharding.fit_tree(out_sp, out_shapes, mesh)
    fn = jax.jit(
        prefill_step,
        in_shardings=sharding.to_shardings(in_sp, mesh),
        out_shardings=sharding.to_shardings(out_sp, mesh),
    )
    return fn, in_sp, out_sp


def build_decode_step(cfg: lm.ArchConfig, mesh: Mesh, *, mode: str = "fsdp_tp",
                      donate: bool = True, example_args=None):
    """decode(params, tokens, cache) -> (logits, cache). Cache donated."""

    def decode_step(params, tokens, cache):
        return lm.decode_step(params, cfg, tokens, cache)

    sharding.register_zero3_constraints(cfg, mesh, mode)
    dp = sharding.dp_axes(mesh)
    p_specs = sharding.param_specs(cfg, mesh, mode)
    tok_sp = P(dp, None, None) if cfg.input_mode == "embeds" else P(dp, None)
    cache_sp = sharding.cache_specs(cfg, mesh)
    in_sp = (p_specs, tok_sp, cache_sp)
    out_sp = (P(dp, None, "model"), cache_sp)
    if example_args is not None:
        in_sp = sharding.fit_tree(in_sp, example_args, mesh)
        out_shapes = jax.eval_shape(decode_step, *example_args)
        out_sp = sharding.fit_tree(out_sp, out_shapes, mesh)
    jit_kwargs = dict(
        in_shardings=sharding.to_shardings(in_sp, mesh),
        out_shardings=sharding.to_shardings(out_sp, mesh),
    )
    if donate:
        jit_kwargs["donate_argnums"] = (2,)
    fn = jax.jit(decode_step, **jit_kwargs)
    return fn, in_sp, out_sp


# ---------------------------------------------------------------------------
# per-layer probes (exact roofline terms; see launch/dryrun.py)
# ---------------------------------------------------------------------------

def build_layer_probe(cfg: lm.ArchConfig, mesh: Mesh, *, kind: str,
                      seq: int, batch: int, mode: str = "fsdp_tp",
                      with_grad: bool) -> tuple[Callable, tuple, tuple]:
    """A single transformer layer at cell shapes/shardings.

    kind: "train"/"prefill" run the full-sequence layer; "decode" the
    single-token layer with this layer's cache slice. cost_analysis of the
    compiled probe x n_layers gives the scan-body contribution that XLA's
    cost analysis reports only once (see dryrun.py docstring).
    """
    from repro.models.lm import (_layer_train, _layer_decode, _default_positions,
                                 init_cache)

    sharding.register_zero3_constraints(cfg, mesh, mode)
    dp = sharding.dp_axes(mesh)
    full_p = sharding.param_specs(cfg, mesh, mode)
    layer_specs = jax.tree_util.tree_map(
        lambda s: P(*s[1:]), full_p["layers"],
        is_leaf=lambda x: isinstance(x, P))
    layer_shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
        jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))["layers"])

    h_spec = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.dtype)

    # precomputed rope tables are scan-invariant in the real model: the probe
    # takes them as *inputs* so their (once-per-step) construction cost does
    # not get charged per layer.
    use_tabs = (cfg.precompute_rope and cfg.rope == "standard"
                and cfg.uses_attention)
    tab_d = (cfg.qk_rope_dim if cfg.mla else cfg.head_dim) // 2
    tab_spec = jax.ShapeDtypeStruct((batch, seq, tab_d), jnp.float32)

    if kind in ("train", "prefill"):
        def probe(layer_p, h, *tabs):
            pos = _default_positions(cfg, h.shape[0], h.shape[1])
            out, aux = _layer_train(layer_p, cfg, h, pos,
                                    tabs if use_tabs else None)
            if with_grad:
                return out, aux
            return out

        if with_grad:
            def probe_grad(layer_p, h, *tabs):
                def f(lp, hh):
                    o, aux = _layer_train(
                        lp, cfg, hh,
                        _default_positions(cfg, hh.shape[0], hh.shape[1]),
                        tabs if use_tabs else None)
                    return jnp.sum(o.astype(jnp.float32)) + aux
                return jax.grad(f, argnums=(0, 1))(layer_p, h)
            fn = probe_grad
        else:
            fn = probe
        in_sp = (layer_specs, P(dp, None, None))
        args = (layer_shapes, h_spec)
        if use_tabs:
            in_sp += (P(dp, None, None), P(dp, None, None))
            args += (tab_spec, tab_spec)
        in_sp = sharding.fit_tree(in_sp, args, mesh)
    else:  # decode
        cache = jax.eval_shape(lambda: init_cache(cfg, batch, seq))
        fields = lm._cache_layer_fields(cfg)
        cache_layer = {f: jax.ShapeDtypeStruct(getattr(cache, f).shape[1:],
                                               getattr(cache, f).dtype)
                       for f in fields}
        full_cache_sp = sharding.cache_specs(cfg, mesh)
        cache_layer_sp = {f: P(*getattr(full_cache_sp, f)[1:]) for f in fields}
        h1 = jax.ShapeDtypeStruct((batch, 1, cfg.d_model), cfg.dtype)

        def fn(layer_p, h, cache_l):
            out, new = _layer_decode(layer_p, cfg, h, cache_l,
                                     jnp.asarray(seq - 1, jnp.int32))
            return out, new

        in_sp = (layer_specs, P(dp, None, None), cache_layer_sp)
        args = (layer_shapes, h1, cache_layer)
        in_sp = sharding.fit_tree(in_sp, args, mesh)

    jfn = jax.jit(fn, in_shardings=sharding.to_shardings(in_sp, mesh))
    return jfn, args, in_sp


def build_embed_head_probe(cfg: lm.ArchConfig, mesh: Mesh, *, kind: str,
                           seq: int, batch: int, mode: str = "fsdp_tp",
                           with_grad: bool):
    """Embedding + final norm + unembed (+ loss & grad for train) probe."""
    sharding.register_zero3_constraints(cfg, mesh, mode)
    dp = sharding.dp_axes(mesh)
    full_p = sharding.param_specs(cfg, mesh, mode)
    sub_keys = [k for k in ("embed", "unembed", "final_norm")
                if k in jax.eval_shape(
                    lambda: lm.init_params(jax.random.PRNGKey(0), cfg))]
    shapes_all = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    sub_shapes = {k: shapes_all[k] for k in sub_keys}
    sub_specs = {k: full_p[k] for k in sub_keys}

    s = seq if kind != "decode" else 1
    if cfg.input_mode == "embeds":
        inp = jax.ShapeDtypeStruct((batch, s, cfg.d_model), jnp.bfloat16)
        inp_sp = P(dp, None, None)
    else:
        inp = jax.ShapeDtypeStruct((batch, s), jnp.int32)
        inp_sp = P(dp, None)
    lbl = jax.ShapeDtypeStruct((batch, s), jnp.int32)

    import repro.models.layers as L

    def head(p, inputs, labels):
        p = lm._head_params(p)
        h = lm._embed_in(p, cfg, inputs)
        h = L.rmsnorm(p["final_norm"], h)
        logits = L.linear(p["unembed"], h)
        if kind == "train":
            return jnp.mean(lm.sharded_ce(logits, labels))
        return jnp.sum(logits.astype(jnp.float32))

    fn = jax.grad(head) if (with_grad and kind == "train") else head
    in_sp = (sub_specs, inp_sp, P(dp, None))
    in_sp = sharding.fit_tree(in_sp, (sub_shapes, inp, lbl), mesh)
    jfn = jax.jit(fn, in_shardings=sharding.to_shardings(in_sp, mesh))
    return jfn, (sub_shapes, inp, lbl), in_sp
