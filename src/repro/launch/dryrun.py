import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config compiles for every
(architecture x input-shape x mesh) cell and extract the roofline terms.

The two lines above MUST precede any jax-importing statement: jax locks the
device count at first backend init, and the production meshes need 512
placeholder host devices.

Per cell this driver:
  1. builds the full step fn (train / prefill / decode) with its shardings,
     `.lower().compile()`s it under the mesh, and records
     `compiled.memory_analysis()` (fits-per-device proof) and
     `compiled.cost_analysis()` (reference numbers);
  2. lowers loop-free single-layer probes (fwd, and fwd+bwd for train) plus
     an embed/head probe at identical shapes+shardings, and derives exact
     totals — XLA cost analysis counts `lax.scan` while-bodies once, so
     whole-model numbers undercount by ~n_layers (verified empirically);
     with remat the true per-layer cost is fwd + (fwd+bwd);
  3. parses collective bytes from the probe HLO (repro.analysis.hlo);
  4. writes one JSON per cell into --out (default experiments/dryrun/).

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from repro import configs
from repro.analysis import hlo as hlo_lib
from repro.analysis import roofline as rl
from repro.distributed import context as mesh_ctx
from repro.distributed import sharding
from repro.launch import mesh as mesh_lib
from repro.launch import shapes as shapes_lib
from repro.launch import steps as steps_lib
from repro.models import lm


def _mem_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }


def _cost(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


OPT_FLOPS_PER_PARAM = 18.0  # AdamW update + global-norm clip (analytic)
OPT_BYTES_PER_PARAM = 22.0  # bf16 param rw + f32 mu/nu rw + grad read


#: beyond-baseline performance settings (§Perf hillclimb). Applied by --opt.
#: head padding is train/prefill-only: padded kv heads would inflate the
#: decode KV cache (measured 2-4x decode memory-term regressions).
OPT_FLAGS = dict(precompute_rope=True, moe_impl="shard_map",
                 capacity_factor=1.0)
OPT_FLAGS_TRAIN = dict(head_pad_multiple=16)


def run_cell(arch: str, shape_id: str, multi_pod: bool, out_dir: pathlib.Path,
             *, keep_hlo: bool = False, mode: str | None = None,
             opt: bool = False) -> dict:
    cfg = configs.get(arch)
    if opt:
        kind = shapes_lib.SHAPES[shape_id]["kind"]
        flags = dict(OPT_FLAGS)
        if kind in ("train", "prefill"):
            flags.update(OPT_FLAGS_TRAIN)
        cfg = dataclasses.replace(cfg, **flags)
    ok, reason = shapes_lib.applicable(cfg, shape_id)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape_id, "mesh": mesh_name,
                 "opt": opt}
    if not ok:
        rec["skipped"] = reason
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_ctx.set_mesh_axes(sharding.dp_axes(mesh), "model", mesh=mesh)
    spec = shapes_lib.input_specs(cfg, shape_id)
    chips = mesh.size
    if mode is None:
        if spec.kind == "train":
            mode = "fsdp_tp"  # ZeRO-3: opt state + master weights sharded
        else:
            # serving: replicate-over-dp ("tp") when a model shard fits HBM
            # alongside the cache; otherwise gather-at-use fsdp_tp.
            model_shard_bytes = cfg.param_count() * 2 / mesh.shape["model"]
            mode = "tp" if model_shard_bytes <= 4.5e9 else "fsdp_tp"
    rec["mode"] = mode
    t0 = time.time()

    with mesh:
        # ---- 1. full step: the compile proof + memory analysis ----
        params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        if spec.kind == "train":
            fn, in_specs, _, opt = steps_lib.build_train_step(
                cfg, mesh, mode=mode, example_batch=spec.args[0])
            opt_s = jax.eval_shape(opt.init, params_s)
            args = (params_s, opt_s) + spec.args
        elif spec.kind == "prefill":
            args = (params_s,) + spec.args
            fn, _, _ = steps_lib.build_prefill_step(
                cfg, mesh, mode=mode, max_len=spec.seq, example_args=args)
        else:
            args = (params_s, spec.args[0], spec.args[1])
            fn, _, _ = steps_lib.build_decode_step(
                cfg, mesh, mode=mode, example_args=args)

        lowered = fn.lower(*args)
        compiled = lowered.compile()
        rec["memory"] = _mem_stats(compiled)
        rec["cost_reported"] = _cost(compiled)
        rec["compile_s"] = round(time.time() - t0, 1)
        print(f"[{arch} x {shape_id} x {mesh_name}] compiled in "
              f"{rec['compile_s']}s; memory={rec['memory']}")

        # ---- 2. loop-free probes for true totals ----
        probe_cfg = dataclasses.replace(cfg, q_chunk=max(spec.seq, 1),
                                        remat=False)
        n_l = cfg.n_layers
        with_grad = spec.kind == "train"
        kind = spec.kind if spec.kind != "prefill" else "train"

        lay_fwd_fn, lay_args, _ = steps_lib.build_layer_probe(
            probe_cfg, mesh, kind="train" if spec.kind != "decode" else "decode",
            seq=spec.seq, batch=spec.batch, mode=mode, with_grad=False)
        lay_fwd = lay_fwd_fn.lower(*lay_args).compile()
        c_fwd = _cost(lay_fwd)
        fwd_text = lay_fwd.as_text()
        coll_fwd = hlo_lib.total_collective_bytes(fwd_text)
        # (S,S) score materialisation is a probe artifact (the deployed path
        # streams scores through VMEM: Pallas flash kernel / chunked XLA);
        # count writes, charge ~1 read per write, subtract from the memory
        # term. FLOPs are unaffected.
        ss_fwd = hlo_lib.bytes_with_trailing_dims(fwd_text, spec.seq, spec.seq)
        if cfg.ssm or cfg.hybrid:  # SSD chunk matrices stream through VMEM
            ss_fwd += hlo_lib.bytes_with_chunk_pair(fwd_text, cfg.ssm_chunk)
        layout_fwd = hlo_lib.bytes_of_layout_ops(fwd_text)

        if with_grad:
            lay_fb_fn, lay_fb_args, _ = steps_lib.build_layer_probe(
                probe_cfg, mesh, kind="train", seq=spec.seq, batch=spec.batch,
                mode=mode, with_grad=True)
            lay_fb = lay_fb_fn.lower(*lay_fb_args).compile()
            c_fb = _cost(lay_fb)
            fb_text = lay_fb.as_text()
            coll_fb = hlo_lib.total_collective_bytes(fb_text)
            ss_fb = hlo_lib.bytes_with_trailing_dims(fb_text, spec.seq, spec.seq)
            if cfg.ssm or cfg.hybrid:
                ss_fb += hlo_lib.bytes_with_chunk_pair(fb_text, cfg.ssm_chunk)
            layout_fb = hlo_lib.bytes_of_layout_ops(fb_text)
            # remat: true per-layer = fwd (forward pass) + fwd+bwd (backward)
            layer_flops = c_fwd["flops"] + c_fb["flops"]
            layer_bytes_raw = c_fwd["bytes"] + c_fb["bytes"]
            layer_ss = 2.0 * (ss_fwd + ss_fb)
            layer_layout = 2.0 * (layout_fwd + layout_fb)  # write + re-read
            layer_coll = coll_fwd + coll_fb
        else:
            layer_flops, layer_bytes_raw, layer_coll = (
                c_fwd["flops"], c_fwd["bytes"], coll_fwd)
            layer_ss = 2.0 * ss_fwd
            layer_layout = 2.0 * layout_fwd
        # memory term: subtract (a) (S,S) score materialisation (streamed in
        # VMEM by the flash path) and (b) pure layout/conversion ops (fused
        # by the TPU backend) — both write+read charged; floor at 20%.
        layer_bytes = max(layer_bytes_raw - layer_ss - layer_layout,
                          0.2 * layer_bytes_raw)

        head_fn, head_args, _ = steps_lib.build_embed_head_probe(
            probe_cfg, mesh, kind=spec.kind, seq=spec.seq, batch=spec.batch,
            mode=mode, with_grad=with_grad)
        head = head_fn.lower(*head_args).compile()
        c_head = _cost(head)
        coll_head = hlo_lib.total_collective_bytes(head.as_text())

        n_params = cfg.param_count()
        # per-device totals (cost_analysis reports the per-device program)
        flops = n_l * layer_flops + c_head["flops"]
        bytes_ = n_l * layer_bytes + c_head["bytes"]
        coll = n_l * layer_coll + coll_head
        if with_grad:
            flops += OPT_FLOPS_PER_PARAM * n_params / chips
            bytes_ += OPT_BYTES_PER_PARAM * n_params / chips

        tokens = spec.batch * (spec.seq if spec.kind != "decode" else 1)
        mf = rl.model_flops(
            cfg.active_param_count(), tokens,
            "train" if spec.kind == "train" else "serve")
        roof = rl.Roofline(flops_dev=flops, bytes_dev=bytes_,
                           coll_dev=float(coll), chips=chips,
                           model_flops=mf)
        rec["roofline"] = roof.row()
        rec["probe"] = {
            "layer_flops": layer_flops, "layer_bytes": layer_bytes,
            "layer_bytes_raw": layer_bytes_raw,
            "layer_layout_bytes": layer_layout,
            "layer_score_materialization_bytes": layer_ss,
            "layer_collective_bytes": layer_coll,
            "head_flops": c_head["flops"], "head_bytes": c_head["bytes"],
            "head_collective_bytes": coll_head,
            "collective_by_kind": hlo_lib.collective_bytes(fwd_text),
        }
        rec["padding_report"] = sharding.validate_divisibility(cfg, mesh, mode)[:8]
        if keep_hlo:
            (out_dir / f"{arch}_{shape_id}_{mesh_name}.hlo.txt").write_text(
                lay_fwd.as_text())

    mesh_ctx.clear()
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "_opt" if opt else ""
    path = out_dir / f"{arch}_{shape_id}_{mesh_name}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1))
    r = rec["roofline"]
    print(f"    roofline: compute={r['t_compute_s']:.3e}s "
          f"memory={r['t_memory_s']:.3e}s collective={r['t_collective_s']:.3e}s "
          f"dominant={r['dominant']} useful={r['useful_frac']:.2f}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--mode", default=None, help="tp | fsdp_tp")
    ap.add_argument("--opt", action="store_true",
                    help="apply §Perf beyond-baseline settings (OPT_FLAGS)")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    archs = [args.arch] if args.arch else configs.list_archs()
    shape_ids = [args.shape] if args.shape else shapes_lib.SHAPE_IDS
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_id in shape_ids:
            for mp in meshes:
                try:
                    run_cell(arch, shape_id, mp, out_dir,
                             keep_hlo=args.keep_hlo, mode=args.mode,
                             opt=args.opt)
                except Exception as e:  # noqa: BLE001 — report all cells
                    failures.append((arch, shape_id, mp, repr(e)))
                    print(f"FAIL [{arch} x {shape_id} x mp={mp}]: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")
    print("all dry-run cells passed")


if __name__ == "__main__":
    main()
