"""hymba-1.5b [hybrid] — parallel attn + mamba heads, GQA kv=5.
[arXiv:2411.13676; hf]

Deviations (DESIGN.md §6): meta-tokens omitted; sliding-window attention
(window 1024) for the attention branch so long_500k decode is sub-quadratic,
matching hymba's SWA-in-most-layers design.
"""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    hybrid=True, ssm_state=16, ssm_headdim=64,
    sliding_window=1024, rope_theta=10000.0,
)

SMOKE = ArchConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512,
    hybrid=True, ssm_state=16, ssm_headdim=32, ssm_chunk=32,
    sliding_window=64, q_chunk=64,
)
