"""hubert-xlarge [audio] — encoder-only transformer backbone.
[arXiv:2106.07447; unverified]

The conv waveform frontend is a STUB per the brief: input_specs() provides
precomputed frame embeddings (B, T, 1280). The 504-unit masked-prediction
head is also where the paper's ACAM template-matching head applies
(DESIGN.md §5) — 504 classes is ACAM-scale.
"""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504, causal=False, input_mode="embeds",
    rope_theta=10000.0,
)

SMOKE = ArchConfig(
    name="hubert-smoke", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab=64, causal=False, input_mode="embeds", q_chunk=64,
)
