"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, sigmoid router.
[arXiv:2412.19437; hf]

Deviations (DESIGN.md §6): the 3 leading dense layers are folded into the
homogeneous MoE scan; the MTP head is omitted.
"""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=2048, vocab=129280,
    n_experts=256, top_k=8, n_shared_experts=1, d_ff_expert=2048,
    router_type="sigmoid",
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    rope_theta=10000.0,
)

SMOKE = ArchConfig(
    name="deepseek-v3-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=128, vocab=512,
    n_experts=8, top_k=2, n_shared_experts=1, d_ff_expert=128,
    router_type="sigmoid",
    mla=True, q_lora_rank=64, kv_lora_rank=32,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, q_chunk=64,
)
