"""qwen3-1.7b [dense] — qk_norm, GQA kv=8. [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab=151936, qk_norm=True, rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen3-1.7b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, qk_norm=True, q_chunk=64,
)
