"""qwen1.5-32b [dense] — QKV bias, kv=40 (full MHA). [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen1.5-32b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab=512, qkv_bias=True, q_chunk=64,
)
