"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2, GQA kv=8.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab=32064, n_experts=16, top_k=2, d_ff_expert=6400,
    router_type="softmax", rope_theta=10000.0,
)

SMOKE = ArchConfig(
    name="phi3.5-moe-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, n_experts=4, top_k=2, d_ff_expert=256, q_chunk=64,
)
