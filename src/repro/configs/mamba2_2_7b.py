"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50280,
    ssm=True, ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    rope="none",
)

SMOKE = ArchConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=1, n_kv_heads=1, head_dim=32,
    d_ff=0, vocab=512,
    ssm=True, ssm_state=16, ssm_headdim=32, ssm_expand=2, ssm_chunk=32,
    rope="none",
)
