"""qwen2.5-14b [dense] — GQA kv=8, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen2.5-14b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, qkv_bias=True, q_chunk=64,
)
