"""Assigned-architecture configs (exact hyper-parameters from the brief) and
reduced smoke variants for CPU tests.

Each module exports CONFIG (full) and SMOKE (reduced, same family/features).
`get(name)` / `list_archs()` are the registry the launcher uses for --arch.
"""
from __future__ import annotations

import importlib

from repro.models.lm import ArchConfig

ARCH_IDS = [
    "qwen2_5_14b",
    "tinyllama_1_1b",
    "qwen3_1_7b",
    "qwen1_5_32b",
    "phi3_5_moe",
    "deepseek_v3",
    "qwen2_vl_72b",
    "hymba_1_5b",
    "hubert_xlarge",
    "mamba2_2_7b",
]

# canonical ids from the assignment brief -> module names
ALIASES = {
    "qwen2.5-14b": "qwen2_5_14b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen1.5-32b": "qwen1_5_32b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "deepseek-v3-671b": "deepseek_v3",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "hymba-1.5b": "hymba_1_5b",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-2.7b": "mamba2_2_7b",
}


def _module(name: str):
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod_name}")


def get(name: str, smoke: bool = False) -> ArchConfig:
    mod = _module(name)
    return mod.SMOKE if smoke else mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCH_IDS)
