"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

The vision frontend is a STUB per the brief: input_specs() provides
precomputed patch embeddings (B, S, d_model); this config is the LM backbone.
"""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064, qkv_bias=True,
    rope="mrope", mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
    input_mode="embeds",
)

SMOKE = ArchConfig(
    name="qwen2-vl-smoke", family="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, qkv_bias=True,
    rope="mrope", mrope_sections=(4, 6, 6),
    input_mode="embeds", q_chunk=64,
)
