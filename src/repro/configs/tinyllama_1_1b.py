"""tinyllama-1.1b [dense] — llama2-arch small, GQA kv=4. [arXiv:2401.02385; hf]"""
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632, vocab=32000, rope_theta=10000.0,
)

SMOKE = ArchConfig(
    name="tinyllama-1.1b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab=512, q_chunk=64,
)
