"""Gradient compression: int8 quantised reduction with error feedback.

Large-scale data-parallel training spends its collective budget on the f32
(or bf16) gradient all-reduce. This module quantises gradients to int8 with
a per-tensor scale before the reduction (4x/2x traffic cut) and carries the
quantisation error into the next step (error feedback), which is the
standard fix that keeps SGD/Adam convergence unharmed (Seide et al.;
Karimireddy et al.).

Usage in the train step (before optimizer.update):

    grads_q, new_err = compress_decompress(grads, err_state)

Under pjit, the quantised tensors are what crosses the reduction — the
int8 cast happens before GSPMD's all-reduce when grads are unreduced
per-shard values (shard_map manual-reduction path), or acts as a
traffic-equivalent model under full-auto sharding. Convergence semantics are
exactly what the tests validate (tests/test_compression.py).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8), scale


def compress_decompress(grads: PyTree, err: PyTree) -> tuple[PyTree, PyTree]:
    """Quantise (grad + carried error) to int8, return the dequantised grads
    actually applied and the new error carry."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _q8(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    flat = jax.tree_util.tree_map(one, grads, err)
    new_grads = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(lambda t: t[1], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err


def compression_ratio(grads: PyTree, *, from_dtype_bytes: int = 4) -> float:
    """Collective-traffic reduction factor (int8 payload + one f32 scale)."""
    total = sum(g.size for g in jax.tree_util.tree_leaves(grads))
    return (total * from_dtype_bytes) / (total * 1 + 4 * len(
        jax.tree_util.tree_leaves(grads)))
