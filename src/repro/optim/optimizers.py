"""Pure-JAX optimisers (no optax in the container): AdamW, SGD+momentum,
LR schedules, global-norm clipping. Optimiser states are pytrees mirroring
the params, so they shard/checkpoint with the same rules.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class OptState(NamedTuple):
    step: Array
    mu: PyTree  # first moment / momentum
    nu: PyTree | None  # second moment (None for SGD)


class Optimizer(NamedTuple):
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]


def _zeros_like_f32(p: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), p)


def adamw(
    lr: float | Callable[[Array], Array],
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), _zeros_like_f32(params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p.ndim >= 2:  # decay weights, not bias/norm
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, OptState(step, mu, nu)

    return Optimizer(init, update)


def sgd(lr: float | Callable[[Array], Array], *, momentum: float = 0.9,
        nesterov: bool = False, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), None)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr

        def add_wd(g, p):
            g = g.astype(jnp.float32)
            return g + weight_decay * p.astype(jnp.float32) if (weight_decay and p.ndim >= 2) else g

        g_wd = jax.tree_util.tree_map(add_wd, grads, params)
        mu = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state.mu, g_wd)
        upd_src = (
            jax.tree_util.tree_map(lambda g, m: g + momentum * m, g_wd, mu)
            if nesterov else mu
        )
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), params, upd_src
        )
        return new_params, OptState(step, mu, None)

    return Optimizer(init, update)


# --- schedules ---

def cosine_schedule(base_lr: float, total_steps: int, warmup: int = 0,
                    final_frac: float = 0.0) -> Callable[[Array], Array]:
    def f(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        cos = base_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return f


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm
