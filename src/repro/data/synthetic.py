"""Synthetic CIFAR-10-like dataset (offline substitute — see DESIGN.md §2).

The container has no CIFAR-10 and no network, so the paper's data substrate
is a *deterministic, procedurally generated* 10-class 32x32x3 image dataset
with CIFAR-like statistics:

  - each class is a generative program: an oriented sinusoidal texture
    (class-specific frequency/orientation band) + a class-conditioned shape
    mask (disc/square/stripe) at a random position/scale + a class-tinted
    colour field, corrupted with instance noise;
  - intra-class variability (random phase, position, scale, tint jitter)
    is large enough that k>1 template clustering is meaningful;
  - classes overlap enough that the task is non-trivial (a linear probe
    lands far below a small CNN, mirroring CIFAR's difficulty ordering).

Deterministic in (seed, split), so experiments are exactly reproducible.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

NUM_CLASSES = 10
IMAGE_SHAPE = (32, 32, 3)

CLASS_NAMES = [
    "airplane", "automobile", "bird", "cat", "deer",
    "dog", "frog", "horse", "ship", "truck",
]  # kept for report parity with the paper's CIFAR-10 framing


class Dataset(NamedTuple):
    images: np.ndarray  # (n, 32, 32, 3) float32 in [0, 1]
    labels: np.ndarray  # (n,) int32


def _class_params(c: int) -> dict:
    """Fixed per-class generative parameters."""
    rng = np.random.RandomState(1000 + c)
    return {
        # overlapping frequency bands so neighbouring classes confuse
        "freq": 1.5 + 0.35 * c + rng.uniform(-0.15, 0.15),
        "theta": (np.pi / NUM_CLASSES) * c + rng.uniform(-0.1, 0.1),
        "tint": rng.uniform(0.25, 0.95, size=3),
        "shape": c % 3,  # 0: disc, 1: square, 2: stripe
        "shape_gain": 0.45 + 0.03 * c,
    }


_PARAMS = [_class_params(c) for c in range(NUM_CLASSES)]


def _generate_class(c: int, n: int, rng: np.random.RandomState) -> np.ndarray:
    h, w, _ = IMAGE_SHAPE
    yy, xx = np.meshgrid(np.linspace(-1, 1, h), np.linspace(-1, 1, w), indexing="ij")
    p = _PARAMS[c]

    phase = rng.uniform(0, 2 * np.pi, size=(n, 1, 1))
    theta = p["theta"] + rng.normal(0, 0.25, size=(n, 1, 1))
    freq = p["freq"] * (1 + rng.normal(0, 0.15, size=(n, 1, 1)))
    u = xx[None] * np.cos(theta) + yy[None] * np.sin(theta)
    texture = 0.5 + 0.5 * np.sin(2 * np.pi * freq * u + phase)  # (n, h, w)

    cx = rng.uniform(-0.5, 0.5, size=(n, 1, 1))
    cy = rng.uniform(-0.5, 0.5, size=(n, 1, 1))
    scale = rng.uniform(0.18, 0.55, size=(n, 1, 1))
    dx, dy = xx[None] - cx, yy[None] - cy
    if p["shape"] == 0:
        mask = (dx**2 + dy**2 < scale**2).astype(np.float32)
    elif p["shape"] == 1:
        mask = ((np.abs(dx) < scale) & (np.abs(dy) < scale)).astype(np.float32)
    else:
        mask = (np.abs(dx + dy) < 0.5 * scale).astype(np.float32)

    base = 0.55 * texture + p["shape_gain"] * mask  # (n, h, w)
    tint = p["tint"][None, None, None, :] * (
        1 + rng.normal(0, 0.22, size=(n, 1, 1, 3))
    )
    img = base[..., None] * tint
    # contrast/brightness jitter + occlusion patch + instance noise
    img = img * rng.uniform(0.6, 1.3, size=(n, 1, 1, 1)) + rng.uniform(
        -0.15, 0.15, size=(n, 1, 1, 1)
    )
    ox = rng.randint(0, w - 8, size=n)
    oy = rng.randint(0, h - 8, size=n)
    osz = rng.randint(4, 10, size=n)
    for i in range(n):  # small loop, vectorised inner assignment
        img[i, oy[i] : oy[i] + osz[i], ox[i] : ox[i] + osz[i], :] = rng.uniform(0, 1)
    img += rng.normal(0, 0.18, size=img.shape)  # instance noise
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def make_dataset(n_per_class: int, seed: int) -> Dataset:
    rng = np.random.RandomState(seed)
    images = np.concatenate(
        [_generate_class(c, n_per_class, rng) for c in range(NUM_CLASSES)], axis=0
    )
    labels = np.repeat(np.arange(NUM_CLASSES, dtype=np.int32), n_per_class)
    perm = rng.permutation(len(labels))
    return Dataset(images[perm], labels[perm])


def load(
    split: str = "train", *, n_per_class: int | None = None, seed: int = 0
) -> Dataset:
    """CIFAR-10-shaped splits: train 5000/class, test 1000/class by default."""
    if split == "train":
        return make_dataset(n_per_class or 5000, seed=seed)
    if split == "test":
        return make_dataset(n_per_class or 1000, seed=seed + 777)
    raise ValueError(f"unknown split {split}")


def to_grayscale(images: np.ndarray) -> np.ndarray:
    """The paper's §IV-A conversion: Y = .2989 R + .5870 G + .1140 B."""
    w = np.asarray([0.2989, 0.5870, 0.1140], dtype=np.float32)
    return (images @ w)[..., None]


def normalize(images: np.ndarray) -> np.ndarray:
    """Zero-mean/unit-std normalisation (paper: 'values are normalised')."""
    mu = images.mean()
    sd = images.std() + 1e-8
    return ((images - mu) / sd).astype(np.float32)
