"""Input pipeline: batching, shuffling, host sharding, curriculum ordering.

Designed for multi-host training: each process reads only its slice
(`host_shard`), batches are globally shuffled per epoch from a seeded rng,
and curriculum mode consumes a precomputed easy->hard ordering
(`repro.core.distill.curriculum_order`) with a pacing schedule.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


def host_shard(n: int, process_index: int, process_count: int) -> slice:
    """Contiguous per-host slice of the dataset (same convention as jax
    process-local data loading)."""
    per = n // process_count
    start = process_index * per
    end = start + per if process_index < process_count - 1 else n
    return slice(start, end)


def batches(
    images: np.ndarray,
    labels: np.ndarray,
    batch_size: int,
    *,
    seed: int = 0,
    epoch: int = 0,
    shuffle: bool = True,
    order: np.ndarray | None = None,
    limit: int | None = None,
    drop_remainder: bool = True,
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield (x, y) batches.

    order: optional explicit index order (curriculum easy->hard); `limit`
    restricts to the first `limit` indices of that order (pacing), with
    shuffling *within* the available pool so batches stay i.i.d.-ish.
    """
    n = len(labels)
    idx = np.asarray(order) if order is not None else np.arange(n)
    if limit is not None:
        idx = idx[:limit]
    if shuffle:
        rng = np.random.RandomState((seed * 9973 + epoch) & 0x7FFFFFFF)
        idx = rng.permutation(idx)
    stop = (len(idx) // batch_size) * batch_size if drop_remainder else len(idx)
    for i in range(0, stop, batch_size):
        sel = idx[i : i + batch_size]
        if not drop_remainder and len(sel) < batch_size:
            pass
        yield images[sel], labels[sel]


def num_batches(n: int, batch_size: int, drop_remainder: bool = True) -> int:
    return n // batch_size if drop_remainder else -(-n // batch_size)


def prefetch(it: Iterator, size: int = 2) -> Iterator:
    """Background-thread prefetcher (overlap host data prep with device step)."""
    import queue
    import threading

    q: "queue.Queue" = queue.Queue(maxsize=size)
    _END = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(_END)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is _END:
            return
        yield item
