"""The autopilot: evaluates the policy every K ticks and acts on it.

Everything the pure controller (`repro.fleet.policy`) cannot own lives
here: cadence, hysteresis, cooldown, and executing transitions through
the control plane. Driven by calling `observe_tick()` after every
`service.step()` — the benchmark trace replayer and the launcher both
hook it there, so "a tick happened" is the autopilot's only clock (no
wall time, no threads: deterministic under test).

    ap = Autopilot(service, policy=PolicySpec(interval=8, hysteresis=2))
    for ...:
        service.step()
        ap.observe_tick()

Per evaluation (every ``policy.interval`` observed ticks, outside the
post-action ``policy.cooldown`` window): snapshot `view_of(service)`,
run `policy.explain`, and require the SAME proposal on
``policy.hysteresis`` consecutive evaluations before acting — one noisy
window never triggers a transition. Actions execute as:

  escalate_shards   the double-buffered rolling path: `reshard.prepare`
                    builds the shadow bank NOW (overlapped with serving)
                    and the flip lands at the next observed tick
                    boundary — no drain. A flip that finds its buffer
                    stale (tenant churn won the race) re-prepares and
                    retries at the following boundary.
  swap_backend /    the drained `reconfigure` path — these change how
  widen_slots       queued requests are served, so the quiesce is the
                    correct semantics, not a cost to optimise away.
  compact           `service.compact_registry()` (the eviction-debt
                    reclaim hook), taken when the policy proposes no
                    spec change but `should_compact` fires.

Every executed action emits a `policy_decision` event carrying the FULL
frozen view it decided from — replaying `policy.explain` over the logged
views reproduces the action stream exactly, which is how
`tests/test_fleet.py` proves the autopilot is reconstructible from the
JSONL log alone.
"""
from __future__ import annotations

from repro.fleet import reshard as reshard_lib
from repro.fleet.policy import (PolicySpec, explain, should_compact,
                                view_of)
from repro.serve.registry import RegistryError


class Autopilot:
    """Telemetry-driven controller loop over one `HybridService`."""

    def __init__(self, service, *, policy: PolicySpec = PolicySpec()):
        self.service = service
        self.policy = policy
        self.ticks = 0
        self.actions: list[dict] = []  # executed actions, for operators
        self.drained: list = []  # responses served by drained reconfigures
        self._streak_key = None
        self._streak = 0
        self._cooldown_until = -1
        self._pending: reshard_lib.PreparedReshard | None = None

    # -- driver hook --------------------------------------------------------

    def observe_tick(self) -> str | None:
        """Call after every `service.step()`. Returns the action executed
        at THIS boundary (including a pending buffer flip landing), or
        None."""
        self.ticks += 1
        if self._pending is not None:
            return self._flip_pending()
        if self.ticks % self.policy.interval:
            return None
        if self.ticks < self._cooldown_until:
            return None
        return self._evaluate()

    def take_drained(self) -> list:
        """Responses served inside autopilot-initiated drained
        reconfigures since the last call. Collect right after
        `observe_tick()` — the drained requests were the queue head, so
        appending them there preserves submission-order FIFO."""
        out, self.drained = self.drained, []
        return out

    # -- internals ----------------------------------------------------------

    def _evaluate(self) -> str | None:
        view = view_of(self.service)
        action, reason, target = explain(view, self.policy)
        if action == "hold":
            if should_compact(view, self.policy):
                action, reason = "compact", (
                    f"occupancy {sum(view.shard_rows_used)}/"
                    f"{view.capacity_classes} rows below compaction "
                    "threshold")
            else:
                self._streak_key, self._streak = None, 0
                return None
        key = (action, target)
        self._streak = self._streak + 1 if key == self._streak_key else 1
        self._streak_key = key
        if self._streak < self.policy.hysteresis:
            return None

        if action == "escalate_shards":
            # double-buffered: build the shadow now, flip next boundary
            self._pending = reshard_lib.prepare(self.service, target)
        elif action == "compact":
            self.service.compact_registry()
        else:
            # the drained path serves the queue head DURING the quiesce:
            # those responses surface via `take_drained()` so the driver
            # keeps global FIFO order (drained work was next up anyway)
            report = self.service.reconfigure(target)
            self.drained.extend(report.drained)
        self._record(action, reason, view, applied=True)
        self._streak_key, self._streak = None, 0
        self._cooldown_until = self.ticks + self.policy.cooldown
        return action

    def _flip_pending(self) -> str | None:
        prep = self._pending
        try:
            self.service.rolling_reshard(prep.spec, prepared=prep)
        except RegistryError:
            # tenant churn between prepare and flip: re-prepare against
            # the registry as it is now, flip at the next boundary
            try:
                self._pending = reshard_lib.prepare(self.service, prep.spec)
            except (RegistryError, ValueError):
                self._pending = None  # target no longer viable; re-evaluate
            return None
        self._pending = None
        self._cooldown_until = self.ticks + self.policy.cooldown
        return "buffer_flip"

    def _record(self, action: str, reason: str, view,
                applied: bool) -> None:
        entry = {"tick": self.ticks, "action": action, "reason": reason,
                 "applied": applied}
        self.actions.append(entry)
        self.service.obs.emit("policy_decision", view=view.to_dict(),
                              **entry)
