"""The autoscaling policy: a pure function from telemetry to the next spec.

The `repro.obs` flight recorder (PR 7) made the service self-observing;
this module closes the loop. `RegistryView` is a FROZEN snapshot of the
controller's inputs — per-shard registered rows vs capacity, the fused
kernel's VMEM row budget, queue depth, the exact rolling p99, rolling
batch fill, and the §V-D energy ledger's backend/frontend split — built
exclusively from `service.health()` and the spec in force (the policy
never reaches into private registry state; `health()` carries every
field it needs, by contract).

`decide(view, policy) -> ServiceSpec` is pure and deterministic: the
same view and policy in, the same spec out, no I/O, no clocks, no
mutation (property-tested in `tests/test_fleet.py`). One evaluation
proposes at most ONE transition — the minimal-diff discipline
`reconfigure` is built around — in fixed priority order:

  1. **escalate `bank_shards`** when the fullest shard's registered rows
     approach its row budget (capacity pressure: the next registration
     would force a capacity grow = device-shape change + retrace) or the
     per-shard fused row count approaches `MAX_FUSED_ROWS` (VMEM
     pressure: the resident mega-kernel would fall back to the chunked
     path). More shards -> fewer rows per shard, both pressures relieved
     without growing the bank.
  2. **swap kernel -> device backend** when the ledger says E_backend
     dominates fleet energy: the matching stage is where the joules go,
     so move it onto the RRAM-CMOS physics backend (the paper's Eq. 14
     regime). Per-shard programming noise is forced so the swap stays
     legal under bank sharding.
  3. **widen scheduler slots** under sustained batch-fill saturation:
     the rolling mean fill sits at the slot count AND a queue has
     formed — bigger ticks, same dispatch count.

`should_compact(view, policy)` is the separate reclaim signal (a spec
cannot express "shrink the super-bank"): occupancy below the threshold
means `registry.compact()` would give real rows back.

The `Autopilot` (`repro.fleet.autopilot`) owns everything impure:
evaluation cadence, hysteresis, cooldown, and executing the transition.
"""
from __future__ import annotations

from typing import NamedTuple

from repro.serve.spec import ServiceSpec


class PolicySpec(NamedTuple):
    """Controller thresholds + the autopilot's cadence knobs."""

    # rule 1: shard escalation
    shard_rows_frac: float = 0.75  # fullest shard used/capacity trigger
    vmem_rows_frac: float = 1.0  # fused rows / MAX_FUSED_ROWS trigger
    max_bank_shards: int = 8
    # rule 2: backend swap (kernel -> device)
    backend_energy_frac: float = 0.9  # E_backend share of fleet energy
    min_energy_j: float = 0.0  # ignore the ledger below this total
    # rule 3: slot widening
    widen_fill_frac: float = 0.95  # rolling fill / slots trigger
    widen_queue_factor: float = 2.0  # AND queue_depth >= factor * slots
    max_slots: int = 256
    # compaction
    compact_below: float = 0.5  # used rows / capacity
    # autopilot cadence (impure half, carried here so ONE value object
    # describes the whole controller)
    interval: int = 8  # evaluate every K observed ticks
    hysteresis: int = 2  # consecutive identical proposals before acting
    cooldown: int = 64  # observed ticks to hold after any action


class RegistryView(NamedTuple):
    """Frozen controller input: the spec in force + the health() fields.
    Hashable (shard_rows_used is a tuple), so views key caches and diff
    cleanly; JSON-round-trippable (`to_dict`/`from_dict`) so every logged
    `policy_decision` carries the exact view it decided from."""

    spec: ServiceSpec
    tenants: int = 0
    shard_rows_used: tuple = ()  # allocated class rows per shard
    rows_per_shard: int = 0
    capacity_classes: int = 0
    fused_rows_per_shard: int = 0  # k_max * padded(rows_per_shard)
    vmem_budget_rows: int = 0  # repro.match MAX_FUSED_ROWS
    queue_depth: int = 0
    p99_ms: float = 0.0
    rolling_fill: float = 0.0  # mean batch fill over the rolling window
    slots: int = 0
    devices: int = 1
    backend_j: float = 0.0  # ledger: fleet ACAM-stage joules
    frontend_j: float = 0.0  # ledger: fleet CNN/decode-stage joules

    def to_dict(self) -> dict:
        d = self._asdict()
        d["spec"] = self.spec.to_dict()
        d["shard_rows_used"] = list(self.shard_rows_used)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RegistryView":
        d = dict(d)
        d["spec"] = ServiceSpec.from_dict(d["spec"])
        d["shard_rows_used"] = tuple(int(x) for x in d["shard_rows_used"])
        return cls(**d)


def view_of(service) -> RegistryView:
    """Snapshot a live service into a frozen `RegistryView` — reads ONLY
    `service.health()` (satellite contract: the controller's inputs are
    first-class health fields) and the public spec."""
    h = service.health()
    return RegistryView(
        spec=service.spec,
        tenants=h["tenants"],
        shard_rows_used=tuple(h["shard_rows_used"]),
        rows_per_shard=h["rows_per_shard"],
        capacity_classes=h["capacity_classes"],
        fused_rows_per_shard=h["fused_rows_per_shard"],
        vmem_budget_rows=h["vmem_budget_rows"],
        queue_depth=h["queue_depth"],
        p99_ms=h["p99_ms"],
        rolling_fill=h["rolling_batch_fill"],
        slots=h["slots"],
        devices=h["devices"],
        backend_j=h["energy_backend_j"],
        frontend_j=h["energy_frontend_j"])


def _shards_allowed(view: RegistryView, shards: int) -> bool:
    """Can the fleet actually form a ``shards``-wide model axis?"""
    if view.spec.mesh.install:
        return view.devices % shards == 0 and shards <= view.devices
    return True  # no installed mesh: replicated execution, any count packs


def explain(view: RegistryView,
            policy: PolicySpec = PolicySpec()) -> tuple[str, str,
                                                        ServiceSpec]:
    """`decide` plus the why: ``(action, reason, next_spec)``. ``action``
    is "hold" when the spec should stand. Pure — see module docstring."""
    spec = view.spec

    # 1. shard escalation: capacity or VMEM pressure on the fullest shard
    if view.tenants and view.rows_per_shard:
        hot = max(view.shard_rows_used) / view.rows_per_shard
        vmem = (view.fused_rows_per_shard / view.vmem_budget_rows
                if view.vmem_budget_rows else 0.0)
        if hot >= policy.shard_rows_frac or vmem >= policy.vmem_rows_frac:
            shards = spec.mesh.bank_shards * 2
            if shards <= policy.max_bank_shards \
                    and _shards_allowed(view, shards):
                align = shards * spec.registry.class_bucket
                initial = -(-spec.registry.initial_classes // align) * align
                target = spec._replace(
                    mesh=spec.mesh._replace(bank_shards=shards),
                    registry=spec.registry._replace(
                        initial_classes=initial))
                reason = (f"fullest shard at {hot:.2f} of "
                          f"{view.rows_per_shard} rows, fused rows at "
                          f"{vmem:.2f} of VMEM budget -> bank_shards "
                          f"{spec.mesh.bank_shards} -> {shards}")
                return "escalate_shards", reason, target

    # 2. backend swap: the ACAM stage dominates the energy ledger
    total_j = view.backend_j + view.frontend_j
    if (spec.engine.backend == "kernel" and total_j > policy.min_energy_j
            and total_j > 0.0
            and view.backend_j / total_j >= policy.backend_energy_frac):
        engine = spec.engine._replace(backend="device",
                                      device_noise="per_shard")
        reason = (f"E_backend is {view.backend_j / total_j:.2f} of fleet "
                  "energy -> serve the matching stage on the RRAM device "
                  "backend")
        return "swap_backend", reason, spec._replace(engine=engine)

    # 3. slot widening: sustained saturation with a standing queue
    if (view.slots and view.rolling_fill >= policy.widen_fill_frac
            * view.slots
            and view.queue_depth >= policy.widen_queue_factor * view.slots):
        slots = min(view.slots * 2, policy.max_slots)
        if slots > view.slots:
            reason = (f"rolling fill {view.rolling_fill:.1f} saturates "
                      f"{view.slots} slots with queue_depth="
                      f"{view.queue_depth} -> slots {slots}")
            return "widen_slots", reason, spec._replace(
                scheduler=spec.scheduler._replace(slots=slots))

    return "hold", "no threshold crossed", spec


def decide(view: RegistryView,
           policy: PolicySpec = PolicySpec()) -> ServiceSpec:
    """The controller: frozen registry view in, next `ServiceSpec` out.
    Pure and deterministic (property-tested); returns the CURRENT spec
    when nothing should change."""
    return explain(view, policy)[2]


def should_compact(view: RegistryView,
                   policy: PolicySpec = PolicySpec()) -> bool:
    """The reclaim signal: occupancy fell below the threshold and the
    bank is above its minimal aligned capacity, so `registry.compact()`
    would actually return rows. Pure, like `decide`."""
    if not view.capacity_classes:
        return False
    used = sum(view.shard_rows_used)
    spec = view.spec
    align = spec.mesh.bank_shards * spec.registry.class_bucket
    minimal = max(align, -(-used // align) * align)
    return (used / view.capacity_classes < policy.compact_below
            and view.capacity_classes > minimal)
