"""Tenant manifests: a whole deployment's tenant set as ONE value.

`ServiceSpec` (PR 5) made the service's *shape* declarative; the tenant
population stayed imperative — launchers and benchmarks loop
`register_tenant` by hand, and there is no artifact that says what a
deployment's tenants SHOULD be. `FleetManifest` closes that gap the same
way `ServiceSpec` did: a hashable, JSON-round-trippable NamedTuple tree,

    manifest = FleetManifest(tenants=(
        TenantSpec("t0", seed=17, num_classes=40, tau=6.0,
                   tau_units="count"),
        TenantSpec("t1", checkpoint="banks/t1.npz"),
    ))
    svc.apply_manifest(manifest)      # diffs vs the manifest in force

`HybridService.apply_manifest` diffs manifests exactly like `reconfigure`
diffs specs: tenants only in the new manifest are registered, tenants
only in the old are evicted, a changed bank source (seed / checkpoint
path / class count / k / head) hot-updates in place, and a tau-only
change retunes the threshold without touching the registry at all. All
of it rides the hot register/update/evict paths, so bucketed shapes stay
untouched and nothing retraces in the steady state.

Per-tenant banks come from one of two sources:

  * ``seed`` — `make_synthetic_tenant(seed, ...)`, the deterministic
    fixture every launcher/bench/test already shares;
  * ``checkpoint`` — an ``.npz`` written by `save_bank` (templates,
    lower, upper, valid, thresholds, optional head), the real-deployment
    path: recalibrate offline, point the manifest at the new file, apply.

``epoch`` is the manifest's "turn it off and on again" knob: bumping it
forces evict + re-register even when every other field is unchanged
(fresh placement, fresh `TenantEntry.generation`).

Tau overrides carry their OWN units (`tau_units`), independent of the
spec's `cascade.tau_units`: a manifest written in match counts serves
unchanged on a service whose spec speaks fractions — `tau_in_units`
converts at apply time via the same 1/N rule as `ServiceSpec.tau_scale`.
"""
from __future__ import annotations

import json
from typing import NamedTuple

import numpy as np

from repro.core.templates import TemplateBank
from repro.serve.spec import TAU_UNITS


class ManifestError(ValueError):
    """Raised for malformed manifests / unloadable bank sources."""


class TenantSpec(NamedTuple):
    """One tenant's declared state: bank source + head + tau override."""

    tenant_id: str
    seed: int | None = None  # synthetic bank (make_synthetic_tenant)
    checkpoint: str | None = None  # .npz bank checkpoint (save_bank)
    num_classes: int = 10  # synthetic source only
    k: int = 1  # synthetic source only
    head: bool = True  # register the escalation head?
    tau: float | None = None  # per-tenant threshold (None: cascade default)
    tau_units: str = "count"  # units TAU is written in ("count"|"fraction")
    epoch: int = 0  # bump to force evict + re-register

    def validate(self) -> "TenantSpec":
        if not self.tenant_id:
            raise ManifestError("tenant_id must be non-empty")
        if (self.seed is None) == (self.checkpoint is None):
            raise ManifestError(
                f"tenant {self.tenant_id!r} needs exactly one bank source "
                f"(seed={self.seed}, checkpoint={self.checkpoint})")
        if self.num_classes < 1 or self.k < 1:
            raise ManifestError(
                f"tenant {self.tenant_id!r}: num_classes and k must be "
                f">= 1, got ({self.num_classes}, {self.k})")
        if self.tau_units not in TAU_UNITS:
            raise ManifestError(
                f"tenant {self.tenant_id!r}: unknown tau_units "
                f"{self.tau_units!r}; use {TAU_UNITS}")
        if self.tau is not None and self.tau <= 0:
            raise ManifestError(
                f"tenant {self.tenant_id!r}: tau must be > 0 (or None), "
                f"got {self.tau}")
        return self

    @property
    def bank_source(self) -> tuple:
        """The fields whose change means "reload the bank" (vs a tau-only
        retune): source identity + shape knobs + head presence + epoch."""
        return (self.seed, self.checkpoint, self.num_classes, self.k,
                self.head)


class FleetManifest(NamedTuple):
    """The deployment's declared tenant set (order-insensitive identity:
    two manifests with the same tenants in a different order are equal)."""

    tenants: tuple = ()  # tuple[TenantSpec, ...]

    def validate(self) -> "FleetManifest":
        seen = set()
        for t in self.tenants:
            t.validate()
            if t.tenant_id in seen:
                raise ManifestError(
                    f"duplicate tenant_id {t.tenant_id!r} in manifest")
            seen.add(t.tenant_id)
        hash(self.normalized())  # manifests key caches like specs do
        return self

    def normalized(self) -> "FleetManifest":
        """Canonical tenant order (by id) — the identity `apply_manifest`
        stores and diffs against."""
        return FleetManifest(tenants=tuple(
            sorted(self.tenants, key=lambda t: t.tenant_id)))

    def by_id(self) -> dict:
        return {t.tenant_id: t for t in self.tenants}

    # -- JSON ---------------------------------------------------------------

    def to_dict(self) -> dict:
        return {"tenants": [t._asdict() for t in self.normalized().tenants]}

    @classmethod
    def from_dict(cls, d: dict) -> "FleetManifest":
        return cls(tenants=tuple(TenantSpec(**t)
                                 for t in d.get("tenants", ())))

    def to_json(self, *, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "FleetManifest":
        return cls.from_dict(json.loads(text))

    @classmethod
    def from_file(cls, path: str) -> "FleetManifest":
        with open(path) as f:
            return cls.from_json(f.read())


class ManifestDiff(NamedTuple):
    """What `apply_manifest` will do, as sorted tenant-id tuples. A tenant
    whose ``epoch`` changed appears in BOTH `evict` and `add` (forced
    re-registration); `update` reloads the bank in place; `retune` only
    re-resolves the threshold."""

    add: tuple = ()
    evict: tuple = ()
    update: tuple = ()
    retune: tuple = ()

    @property
    def empty(self) -> bool:
        return not (self.add or self.evict or self.update or self.retune)


def diff_manifests(old: FleetManifest, new: FleetManifest) -> ManifestDiff:
    """Pure manifest diff (the tenant-set analogue of the spec diff in
    `HybridService.reconfigure`): minimal transitions, deterministic
    order."""
    o, n = old.by_id(), new.by_id()
    add = [t for t in n if t not in o]
    evict = [t for t in o if t not in n]
    update, retune = [], []
    for tid in sorted(set(o) & set(n)):
        ot, nt = o[tid], n[tid]
        if ot == nt:
            continue
        if ot.epoch != nt.epoch:
            evict.append(tid)  # forced re-registration: evict + re-add
            add.append(tid)
        elif ot.bank_source != nt.bank_source:
            update.append(tid)
        else:  # only tau / tau_units moved
            retune.append(tid)
    return ManifestDiff(add=tuple(sorted(add)), evict=tuple(sorted(evict)),
                        update=tuple(sorted(update)),
                        retune=tuple(sorted(retune)))


def tau_in_units(tau: float | None, given: str, target: str,
                 num_features: int) -> float | None:
    """Convert a tenant tau between "count" (0..N) and "fraction" (0..1)
    units — the same 1/N rule as `ServiceSpec.tau_scale`, applied at
    manifest apply time so a per-tenant override written in either unit
    lands in the spec's `cascade.tau_units` before `_resolve_tau` sees
    it."""
    if tau is None or given == target:
        return tau
    n = float(num_features)
    return tau / n if target == "fraction" else tau * n


# ---------------------------------------------------------------------------
# Bank materialisation (seed or checkpoint -> TemplateBank + head)
# ---------------------------------------------------------------------------

_BANK_FIELDS = ("templates", "lower", "upper", "valid", "thresholds")


def save_bank(path: str, bank: TemplateBank,
              head: tuple[np.ndarray, np.ndarray] | None = None) -> None:
    """Write a tenant bank (+ optional (W, b) head) as the ``.npz``
    checkpoint a manifest's ``checkpoint`` field points at."""
    arrays = {f: np.asarray(getattr(bank, f)) for f in _BANK_FIELDS}
    if head is not None:
        arrays["head_w"] = np.asarray(head[0], np.float32)
        arrays["head_b"] = np.asarray(head[1], np.float32)
    np.savez(path, **arrays)


def load_bank(path: str):
    """Read a `save_bank` checkpoint back as ``(bank, head | None)``."""
    with np.load(path) as z:
        missing = [f for f in _BANK_FIELDS if f not in z]
        if missing:
            raise ManifestError(
                f"bank checkpoint {path!r} missing arrays {missing}")
        bank = TemplateBank(
            templates=z["templates"].astype(np.float32),
            lower=z["lower"].astype(np.float32),
            upper=z["upper"].astype(np.float32),
            valid=z["valid"].astype(bool),
            thresholds=z["thresholds"].astype(np.float32))
        head = (z["head_w"], z["head_b"]) if "head_w" in z else None
    return bank, head


def materialize(tenant: TenantSpec, num_features: int):
    """Resolve a tenant's declared bank source into ``(bank, head)``:
    synthetic seed or checkpoint file. ``head`` is None when the manifest
    disables the escalation head."""
    if tenant.checkpoint is not None:
        bank, head = load_bank(tenant.checkpoint)
    else:
        from repro.serve.acam_service import make_synthetic_tenant

        bank, head, _ = make_synthetic_tenant(
            tenant.seed, num_classes=tenant.num_classes, k=tenant.k,
            num_features=num_features)
    if bank.templates.shape[-1] != num_features:
        raise ManifestError(
            f"tenant {tenant.tenant_id!r}: bank has "
            f"{bank.templates.shape[-1]} features, service serves "
            f"{num_features}")
    return bank, (head if tenant.head else None)
