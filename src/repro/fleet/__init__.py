"""`repro.fleet` — the self-driving deployment layer.

Everything above the PR-5 control plane that turns operator actions into
inputs:

  * `manifest` — `FleetManifest`: the tenant population as one hashable,
    JSON-round-trippable value; `HybridService.apply_manifest` diffs it
    like `reconfigure` diffs specs (add/evict/update/retune as minimal
    hot transitions).
  * `policy` — `decide(view) -> ServiceSpec`: a pure, deterministic
    controller from a frozen `repro.obs` telemetry snapshot to the next
    spec, plus the separate `should_compact` reclaim signal.
  * `autopilot` — the impure driver: evaluate every K ticks with
    hysteresis + cooldown, execute through `reconfigure` / the rolling
    reshard, log every action as a reconstructible `policy_decision`
    event.
  * `reshard` — the double-buffered rolling reshard: build the re-packed
    super-bank alongside the live one, flip between ticks (one
    generation bump instead of a queue drain), bit-identical to the
    drained path.
"""
from repro.fleet.autopilot import Autopilot
from repro.fleet.manifest import (FleetManifest, ManifestDiff,
                                  ManifestError, TenantSpec,
                                  diff_manifests, load_bank, materialize,
                                  save_bank, tau_in_units)
from repro.fleet.policy import (PolicySpec, RegistryView, decide, explain,
                                should_compact, view_of)
from repro.fleet.reshard import PreparedReshard, flip, prepare

__all__ = [
    "Autopilot", "FleetManifest", "ManifestDiff", "ManifestError",
    "TenantSpec", "diff_manifests", "load_bank", "materialize",
    "save_bank", "tau_in_units", "PolicySpec", "RegistryView", "decide",
    "explain", "should_compact", "view_of", "PreparedReshard", "flip",
    "prepare",
]
