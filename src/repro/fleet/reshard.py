"""Double-buffered rolling reshard: re-pack alongside, flip between ticks.

The PR-5 drained reshard is correct but pays for its correctness in
downtime: `reconfigure` quiesces the scheduler (serving the whole backlog
under the old config — ~10 ms against a full queue) before
`registry.reshard` re-packs in place. The queue only holds tenant IDS,
though — placements are resolved at tick time (`registry.lookup`) and
every tick re-reads `device_bank()` / `thresholds_table()` fresh per
generation — so nothing about a queued request pins the OLD packing.
That makes the drain unnecessary for a pure shard-count change:

    prepare(svc, new_spec)   copy every tenant's rows into a SHADOW bank
                             packed to the new shard boundaries
                             (`registry.prepare_reshard`) while the live
                             bank keeps serving — the O(rows) work,
                             entirely off the serving path;
    flip(svc, prep)          between two ticks: swap the host arrays +
                             offsets (`adopt_prepared`, O(tenants)), bump
                             the generation, install the new mesh. The
                             next tick gathers the re-packed super-bank
                             under the new `PartitionPlan`; the old
                             buffer is unreferenced and freed.

Downtime is the flip alone — the number `benchmarks/serving_bench.py
--autopilot` pins strictly below the drained `reshard_downtime_ms`.

Bit-identity: preds/margins/escalations are identical to the drained
path because (a) the engine's cross-shard reduce is exact (sharded ==
replicated, the PR-4 contract) and (b) the queue is FIFO either way —
the drained path serves the backlog under the old shard count, the flip
path serves it under the new one, and the two agree bit for bit.
Asserted on the forced 2x2 mesh in `tests/test_fleet.py` and the
CI fleet-smoke job.

A prepared buffer is generation-stamped: any registry mutation between
prepare and flip (tenant churn won the race) makes it stale, `flip`
raises, and the caller re-prepares — the autopilot does exactly that.
"""
from __future__ import annotations

import dataclasses
import time

from repro.serve.control import (ReconfigureError, ReconfigureReport,
                                 _FROZEN_REGISTRY_FIELDS, install_mesh)
from repro.serve.registry import PreparedBank, RegistryError
from repro.serve.spec import ServiceSpec


@dataclasses.dataclass
class PreparedReshard:
    """A shadow super-bank ready to flip to, plus the spec it implements."""

    spec: ServiceSpec  # the target spec (only mesh/bank_shards differ)
    prepared: PreparedBank  # registry.prepare_reshard output
    build_s: float  # shadow-build wall time (overlapped with serving)

    @property
    def stale(self) -> bool:
        return self._registry.generation != self.prepared.source_generation

    _registry: object = None  # the registry the buffer was built from


def prepare(service, new_spec: ServiceSpec) -> PreparedReshard:
    """Build the re-packed shadow bank for ``new_spec`` while ``service``
    keeps serving. Only a shard-count (mesh) change may be pending:
    engine/scheduler/cascade deltas change how queued requests are served
    and therefore still need the drained `reconfigure` path."""
    new_spec.validate()
    old = service.spec
    for field in _FROZEN_REGISTRY_FIELDS:
        if getattr(new_spec.registry, field) != getattr(old.registry, field):
            raise ReconfigureError(
                f"registry.{field} cannot change live; build a fresh "
                "service")
    if (new_spec.engine != old.engine
            or new_spec.scheduler != old.scheduler
            or new_spec.cascade != old.cascade):
        raise ReconfigureError(
            "rolling reshard only covers mesh/bank_shards changes "
            "(queued requests must serve identically across the flip); "
            "use reconfigure for engine/scheduler/cascade deltas")
    if new_spec.mesh.install:
        ndev = len(service._avail_devices())
        if ndev % new_spec.mesh.bank_shards:
            raise ReconfigureError(
                f"mesh.bank_shards={new_spec.mesh.bank_shards} does not "
                f"divide the {ndev} available devices")
    t0 = time.perf_counter()
    prepared = service.registry.prepare_reshard(new_spec.mesh.bank_shards)
    return PreparedReshard(spec=new_spec, prepared=prepared,
                           build_s=time.perf_counter() - t0,
                           _registry=service.registry)


def flip(service, prep: PreparedReshard) -> ReconfigureReport:
    """Adopt the shadow bank between ticks: swap arrays/offsets, install
    the new mesh (generation bump -> scheduler re-trace), re-derive the
    cascade view. NO drain — the queue rides through and the next tick
    dispatches under the new `PartitionPlan`. Raises `RegistryError` when
    the buffer went stale (registry mutated since prepare)."""
    old = service.spec
    t0 = time.perf_counter()
    moved = service.registry.adopt_prepared(prep.prepared)  # may raise
    actions = [
        f"flipped double-buffered super-bank {old.mesh.bank_shards} -> "
        f"{prep.spec.mesh.bank_shards} ({moved} tenant runs re-packed "
        "off-path, 0 re-registrations, 0 drained)"]
    service.obs.emit("reshard", bank_shards_from=old.mesh.bank_shards,
                     bank_shards_to=prep.spec.mesh.bank_shards)
    if prep.spec.mesh.install:
        install_mesh(prep.spec.mesh, devices=service._devices)
        actions.append(
            f"installed ({prep.spec.mesh.data_axis}, "
            f"{prep.spec.mesh.model_axis}={prep.spec.mesh.bank_shards}) "
            "mesh (generation bump -> scheduler re-trace)")
    service._apply_cascade(prep.spec)
    service.spec = prep.spec
    downtime_s = time.perf_counter() - t0
    service.obs.emit("buffer_flip",
                     bank_shards_from=old.mesh.bank_shards,
                     bank_shards_to=prep.spec.mesh.bank_shards,
                     tenants_moved=moved,
                     flip_ms=round(downtime_s * 1e3, 4),
                     build_ms=round(prep.build_s * 1e3, 4))
    return ReconfigureReport(spec=prep.spec, actions=tuple(actions),
                             drained=[], downtime_s=downtime_s,
                             tenants_moved=moved)


__all__ = ["PreparedReshard", "prepare", "flip", "RegistryError"]
