"""Sharded, asynchronous, fault-tolerant checkpointing.

Design (1000+-node oriented, exercised here at host scale):
  - pytrees are flattened to key-paths and saved as .npy per leaf inside a
    step directory (`step_000042/`), plus a `manifest.json` (tree structure,
    shapes, dtypes) — a real deployment writes per-host shard files; the
    format here is the host-local equivalent with the same atomicity rules;
  - writes go to `step_X.tmp/` and are atomically renamed after fsync, so a
    killed run never leaves a half-written "latest" (crash-consistency);
  - an async writer thread overlaps device->host transfer + IO with the next
    training steps (`save(..., blocking=False)`);
  - `latest_step`/`restore` pick up the newest complete checkpoint, so a
    restarted job resumes from the last durable step (see repro.ft.elastic
    for restoring onto a different mesh).
"""
from __future__ import annotations

import json
import os
import pathlib
import queue
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_SEP = "::"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._worker: threading.Thread | None = None
        self._error: BaseException | None = None

    # ---- paths ----
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:08d}"

    def latest_step(self) -> int | None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp") and (p / "manifest.json").exists())
        return steps[-1] if steps else None

    # ---- save ----
    def save(self, step: int, tree: PyTree, *, blocking: bool = True) -> None:
        # a failed async write must not be silently dropped: surface the
        # worker's exception on the NEXT save (or wait()), not never
        self._raise_pending()
        # snapshot to host memory NOW (device buffers may be donated next step)
        flat = _flatten(jax.device_get(tree))
        treedef = jax.tree_util.tree_structure(tree)
        if blocking:
            self._write(step, flat, treedef)
        else:
            self._ensure_worker()
            self._q.put((step, flat, treedef))

    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._write(*item)
            except BaseException as e:  # surfaced on wait()
                self._error = e

    def wait(self) -> None:
        """Block until queued async saves are durable."""
        if self._worker and self._worker.is_alive():
            self._q.put(None)
            self._worker.join()
            self._worker = None
        self._raise_pending()

    def _raise_pending(self) -> None:
        """Re-raise a worker-thread failure recorded by `_drain`. Called at
        every `save`/`wait` entry so an async checkpoint that failed to hit
        disk is reported on the next checkpoint attempt instead of being
        dropped silently (the restart would resume from a stale step)."""
        if self._error:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, flat: dict[str, np.ndarray], treedef) -> None:
        final = self._step_dir(step)
        tmp = final.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "treedef": str(treedef), "leaves": {}}
        for i, (key, arr) in enumerate(flat.items()):
            fname = f"leaf_{i:05d}.npy"
            logical = str(arr.dtype)
            if logical not in ("float32", "float64", "int32", "int64",
                               "uint32", "bool", "int8", "uint8", "int16"):
                # ml_dtypes (bfloat16, fp8) round-trip as raw bits
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": logical}
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---- restore ----
    def restore(self, step: int, like: PyTree, *, shardings: PyTree | None = None
                ) -> PyTree:
        """Restore into the structure of `like` (values ignored).

        shardings: optional pytree of Sharding to device_put each leaf with —
        this is the elastic-re-mesh path: the same checkpoint restores onto
        any mesh (repro.ft.elastic.remesh_restore).
        """
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like = jax.tree_util.tree_flatten_with_path(like)[0]
        leaves = []
        for path, leaf in flat_like:
            key = _SEP.join(
                str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                for k in path)
            info = manifest["leaves"][key]
            arr = np.load(d / info["file"])
            logical = jnp.dtype(info["dtype"])
            if arr.dtype != logical:
                arr = arr.view(logical)  # raw-bit round-trip (bf16/fp8)
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            # committed jax arrays (donation-compatible)
            tree = jax.tree_util.tree_map(jnp.asarray, tree)
        return tree

    def restore_dict(self, step: int) -> dict:
        """Restore a checkpoint saved from (nested) string-keyed dicts back
        into plain nested dicts of host numpy arrays — no `like` tree
        needed, the manifest alone drives the load.

        This is the service-snapshot path (`repro.serve.snapshot`): a
        restarting process has nothing to build a `like` tree from until it
        has read the checkpoint, so the structure must come from the
        manifest. Only dict-of-dict trees round-trip this way (key paths
        are re-split on the separator); pytrees with list/tuple/custom
        nodes should use `restore`.
        """
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        out: dict = {}
        for key, info in manifest["leaves"].items():
            arr = np.load(d / info["file"])
            logical = np.dtype(info["dtype"])
            if arr.dtype != logical:
                arr = arr.view(logical)  # raw-bit round-trip (bf16/fp8)
            node = out
            parts = key.split(_SEP)
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
        return out
