"""Sharding rules: logical param/activation axes -> mesh PartitionSpecs.

Mesh axes (launch/mesh.py): single pod ("data", "model") = (16, 16);
multi-pod ("pod", "data", "model") = (2, 16, 16). "model" is the tensor/
expert-parallel axis; ("pod","data") is the data-parallel + FSDP axis.

Two rule sets:
  tp      — params sharded over "model" only (replicated across data): decode
            latency path for small models.
  fsdp_tp — additionally shards the non-TP weight axis over ("pod","data")
            (ZeRO-3); GSPMD inserts the gather/reduce-scatter pairs. Required
            for >=14B training and >=42B serving.

Rules are by param-tree path, so they apply to any architecture in the zoo.
All "layers/*" leaves carry a leading stacked-layer axis (never sharded).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import lm

PyTree = Any


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return out


def param_spec(names: list[str], ndim: int, *, mode: str, fsdp) -> P:
    """PartitionSpec for one param leaf addressed by its tree path."""
    w = fsdp if mode == "fsdp_tp" else None
    in_layers = names[0] == "layers"
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""

    def wrap(*spec):
        # prepend the stacked-layer axis
        return P(*(((None,) + spec) if in_layers else spec))

    # --- non-layer leaves ---
    if not in_layers:
        if names[0] == "embed":
            # d over model, vocab unsharded: the token gather stays local
            # (GSPMD handles gathers over non-indexed sharded dims only).
            return P(None, "model")  # (V, d)
        if names[0] == "unembed":
            return P(w, "model") if leaf == "w" else P("model")
        return P(None)  # final_norm etc.

    # --- norms / scalars ---
    if leaf in ("scale", "bias") or parent.endswith("norm") or leaf in (
            "A_log", "D", "dt_bias", "conv_w"):
        return wrap(*([None] * (ndim - 1)))

    # --- MoE experts: E over "model" (expert parallelism) ---
    if parent == "moe" or (len(names) >= 3 and names[-3] == "moe"):
        if parent == "router":
            return wrap(w, None)  # (d, E)
        if leaf in ("gate", "up"):
            return wrap("model", w, None)  # (E, d, f)
        if leaf == "down":
            return wrap("model", None, w)  # (E, f, d)
        # shared expert (mlp-shaped)
        if parent in ("gate", "up"):
            return wrap(w, "model") if leaf == "w" else wrap("model")
        if parent == "down":
            return wrap("model", w) if leaf == "w" else wrap(w)

    # --- attention ---
    if parent in ("q", "k", "v"):
        return wrap(w, "model") if leaf == "w" else wrap("model")
    if parent == "o":
        return wrap("model", w) if leaf == "w" else wrap(w)
    # MLA projections
    if parent in ("q_a", "kv_a"):
        return wrap(w, None) if leaf == "w" else wrap(None)
    if parent in ("q_b", "kv_b"):
        return wrap(w, "model") if leaf == "w" else wrap("model")

    # --- dense MLP ---
    if parent in ("gate", "up"):
        return wrap(w, "model") if leaf == "w" else wrap("model")
    if parent == "down":
        return wrap("model", w) if leaf == "w" else wrap(w)

    # --- SSM (mamba2/hymba): packed projections; TP on the model axis is a
    # documented hillclimb item (DESIGN.md) — baseline shards FSDP only. ---
    if parent == "in_proj":
        return wrap(w, None) if leaf == "w" else wrap(None)
    if parent == "out_proj":
        return wrap(None, w) if leaf == "w" else wrap(w)

    return wrap(*([None] * (ndim - 1)))


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on axes whose size does not divide the mesh extent
    (pjit rejects explicit non-divisible shardings; e.g. mamba2's vocab
    50280 % 16, hymba's 32001, hubert's 504, and batch=1 decode)."""
    fitted = []
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    for dim, axis in zip(shape, entries):
        if axis is None:
            fitted.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        fitted.append(axis if dim % size == 0 else None)
    return P(*fitted)


def fit_tree(specs: PyTree, shapes: PyTree, mesh: Mesh) -> PyTree:
    """fit_spec over a pytree of specs + matching ShapeDtypeStructs."""
    return jax.tree_util.tree_map(
        lambda s, x: fit_spec(s, x.shape, mesh), specs, shapes,
        is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg: lm.ArchConfig, mesh: Mesh, mode: str = "fsdp_tp") -> PyTree:
    """Pytree of PartitionSpec matching init_params(cfg) (divisibility-fitted)."""
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    fsdp = dp_axes(mesh)

    def spec(path, leaf):
        raw = param_spec(_path_names(path), leaf.ndim, mode=mode, fsdp=fsdp)
        return fit_spec(raw, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, shapes)


def opt_state_specs(param_sp: PyTree) -> Any:
    """Optimiser moments mirror the params; step is replicated."""
    from repro.optim.optimizers import OptState

    return OptState(step=P(), mu=param_sp, nu=param_sp)


def batch_specs(cfg: lm.ArchConfig, mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    specs = {"inputs": P(dp, None, None) if cfg.input_mode == "embeds" else P(dp, None),
             "labels": P(dp, None)}
    if cfg.rope == "mrope":
        specs["positions"] = P(None, dp, None)
    return specs


def cache_specs(cfg: lm.ArchConfig, mesh: Mesh) -> lm.Cache:
    """Serving-cache shardings.

    Attention KV: sequence axis over "model" (flash-decoding style partial
    attention; GSPMD inserts the softmax reductions) — robust to any kv-head
    count. SSM states: heads over "model". Batch always over data.
    """
    dp = dp_axes(mesh)
    k = v = c_kv = k_rope = conv = ssm = None
    if cfg.ssm or cfg.hybrid:
        conv = P(None, dp, None, None)
        ssm = P(None, dp, "model", None, None)
    if cfg.mla:
        c_kv = P(None, dp, "model", None)
        k_rope = P(None, dp, "model", None)
    elif cfg.uses_attention:
        if cfg.sliding_window:
            k = v = P(None, dp, None, None, None)  # small ring buffer
        else:
            k = v = P(None, dp, "model", None, None)
    return lm.Cache(k=k, v=v, c_kv=c_kv, k_rope=k_rope, conv=conv, ssm=ssm,
                    length=P())


def to_shardings(specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def register_zero3_constraints(cfg: lm.ArchConfig, mesh: Mesh, mode: str) -> None:
    """Install gather-at-use constraints (see distributed.context).

    Storage sharding is `mode` (fsdp_tp shards a weight axis over dp);
    compute sharding is the "tp" rule set. Constraining each layer's params
    to compute sharding inside the scan body makes GSPMD all-gather exactly
    one layer's weights at a time (ZeRO-3 streaming); gradients are
    reduce-scattered back by the transpose of the same constraint.
    """
    from repro.distributed import context as mesh_ctx

    if mode != "fsdp_tp":
        mesh_ctx.set_layer_constraint(None)
        mesh_ctx.set_head_constraint(None)
        return
    compute = param_specs(cfg, mesh, "tp")
    layer_compute = jax.tree_util.tree_map(
        lambda s: P(*s[1:]), compute["layers"],
        is_leaf=lambda x: isinstance(x, P))
    head_compute = {k: v for k, v in compute.items() if k != "layers"}

    def constrain_layer(layer_p):
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            layer_p, layer_compute)

    def constrain_head(head_p):
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            head_p, {k: head_compute[k] for k in head_p})

    mesh_ctx.set_layer_constraint(constrain_layer)
    mesh_ctx.set_head_constraint(constrain_head)


def validate_divisibility(cfg: lm.ArchConfig, mesh: Mesh, mode: str) -> list[str]:
    """Report param axes that do not divide evenly over their mesh axes
    (GSPMD pads these — allowed, but we surface them for the roofline)."""
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(cfg, mesh, mode)
    msgs = []

    def check(path, leaf, spec):
        names = "/".join(_path_names(path))
        for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % size:
                msgs.append(f"{names}: dim {dim} % {size} != 0 (padded)")

    jax.tree_util.tree_map_with_path(check, shapes, specs)
    return msgs
