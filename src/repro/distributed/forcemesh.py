"""REPRO_FORCE_MESH: forced host-device meshes for tests, CI and benches.

Setting ``REPRO_FORCE_MESH=DxM`` (e.g. ``2x2``) asks a process to run on a
forced-CPU mesh of D data-parallel x M model (bank-shard) host devices, so
the engine's 2D `PartitionPlan` — batch over "data", template-bank class
rows over "model" — is exercised end to end without TPUs. The tier-1 CI
matrix runs a ``2x2`` entry and the serving-bench smoke adds a sharded row
through the same switch.

Two-phase by necessity: ``--xla_force_host_platform_device_count`` is read
when jax initialises its CPU backend, so the flag must be in ``XLA_FLAGS``
*before* anything touches jax devices, while building the mesh obviously
needs jax. Hence:

    from repro.distributed import forcemesh   # imports NO jax
    forcemesh.apply_xla_flags()               # phase 1: before jax init
    ...
    forcemesh.install()                       # phase 2: mesh -> context

`tests/conftest.py` runs phase 1 at import and phase 2 at session start;
the benchmarks run both at the top of `main()` (jax untouched until then).
"""
from __future__ import annotations

import os

ENV = "REPRO_FORCE_MESH"
_FLAG = "--xla_force_host_platform_device_count"


def parse(spec: str) -> tuple[int, int]:
    """"2x2" -> (data=2, model=2); raises ValueError on malformed specs."""
    try:
        d, m = spec.lower().split("x")
        d, m = int(d), int(m)
    except ValueError:
        raise ValueError(
            f"{ENV} must look like 'DxM' (e.g. 2x2), got {spec!r}") from None
    if d < 1 or m < 1:
        raise ValueError(f"{ENV} axes must be >= 1, got {spec!r}")
    return d, m


def env_spec() -> tuple[int, int] | None:
    """The (data, model) shape requested via the environment, or None."""
    spec = os.environ.get(ENV, "").strip()
    return parse(spec) if spec else None


def apply_xla_flags(spec: tuple[int, int] | None = None) -> bool:
    """Phase 1: put the forced host-device count into ``XLA_FLAGS``.

    MUST run before jax initialises its backend (first device/array use).
    Returns True when a forced mesh is requested. Idempotent; an existing
    forced count in ``XLA_FLAGS`` is left alone (the caller set it — e.g.
    the subprocess test helpers).
    """
    spec = spec if spec is not None else env_spec()
    if spec is None:
        return False
    flags = os.environ.get("XLA_FLAGS", "")
    if _FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_FLAG}={spec[0] * spec[1]}".strip()
    return True


def install(spec: tuple[int, int] | None = None):
    """Phase 2: build the (data=D, model=M) mesh and install it into
    `repro.distributed.context`. Imports jax — call only after phase 1.

    Returns the mesh, or None when no forced mesh is requested.
    """
    spec = spec if spec is not None else env_spec()
    if spec is None:
        return None
    import jax

    from repro.distributed import context

    d, m = spec
    if len(jax.devices()) < d * m:
        raise RuntimeError(
            f"{ENV}={d}x{m} needs {d * m} devices but jax initialised "
            f"{len(jax.devices())}; apply_xla_flags() must run before "
            "anything touches jax")
    mesh = jax.make_mesh((d, m), ("data", "model"))
    context.set_mesh_axes("data", "model", mesh)
    return mesh
