"""Process-wide mesh-axis context.

Model code (e.g. the MoE dispatch buffer) occasionally needs
`with_sharding_constraint` hints, but must stay mesh-agnostic and runnable on
a single CPU device. Launchers set the axis names here; when unset, model
code applies no constraints.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
from jax.sharding import PartitionSpec as P


class MeshAxes(NamedTuple):
    dp: tuple | str  # data-parallel axes ("data" or ("pod","data"))
    model: str


_AXES: MeshAxes | None = None
_MESH = None
_GENERATION = 0


def set_mesh_axes(dp, model: str = "model", mesh=None) -> None:
    global _AXES, _MESH, _GENERATION
    _AXES = MeshAxes(dp, model)
    _MESH = mesh
    _GENERATION += 1


def get_mesh():
    return _MESH


def generation() -> int:
    """Monotonic mesh-change counter, bumped by `set_mesh_axes`/`clear`.

    Jitted callers that bake the mesh decision into their trace (the engine's
    `PartitionPlan`, `with_sharding_constraint` hints) thread this as a
    *static* argument — e.g. `hybrid._fused_forward` and the serving
    scheduler's tick — so installing a different mesh keys a fresh
    executable instead of silently replaying the stale one.
    """
    return _GENERATION


def clear() -> None:
    global _AXES, _MESH, _LAYER_CONSTRAINT, _HEAD_CONSTRAINT, _GENERATION
    _AXES = None
    _MESH = None
    _LAYER_CONSTRAINT = None
    _HEAD_CONSTRAINT = None
    _GENERATION += 1


def get() -> MeshAxes | None:
    return _AXES


def constrain(x, *spec) -> jax.Array:
    """Apply a sharding constraint if a mesh context is active.

    spec entries: "dp", "model", or None — translated via the active axes.
    """
    ax = get()
    if ax is None:
        return x
    resolved = tuple(ax.dp if s == "dp" else (ax.model if s == "model" else None)
                     for s in spec)
    return jax.lax.with_sharding_constraint(x, P(*resolved))


# --- ZeRO-3 gather-at-use -------------------------------------------------
# FSDP-sharded weights must be all-gathered to their TP compute sharding at
# the point of use; left to its own devices GSPMD sometimes resolves the
# dp-axis conflict by all-gathering the *batch* instead (observed on the
# embed/unembed einsums: 8 GB of batch traffic vs 16 MB of weight traffic).
# The step builders register a constraint fn mapping a single layer's param
# subtree to compute shardings; model code applies it at layer entry.

_LAYER_CONSTRAINT = None
_HEAD_CONSTRAINT = None


def set_layer_constraint(fn) -> None:
    global _LAYER_CONSTRAINT
    _LAYER_CONSTRAINT = fn


def set_head_constraint(fn) -> None:
    global _HEAD_CONSTRAINT
    _HEAD_CONSTRAINT = fn


def constrain_layer(layer_params):
    return _LAYER_CONSTRAINT(layer_params) if _LAYER_CONSTRAINT else layer_params


def constrain_head(head_params):
    return _HEAD_CONSTRAINT(head_params) if _HEAD_CONSTRAINT else head_params
