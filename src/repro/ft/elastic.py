"""Elastic scaling + straggler mitigation.

Elastic re-mesh: a checkpoint written on mesh A restores onto mesh B with a
different data-parallel degree (node loss / scale-up). Because checkpoints
are stored as full logical arrays (repro.checkpoint) and shardings are
recomputed from the *target* mesh's rules, `remesh_restore` is just
restore + device_put with the new shardings; the training batch schedule is
rescaled so the global batch is preserved (grad-accum picks up the slack).

Straggler mitigation: `StragglerMonitor` tracks per-step heartbeats; steps
whose stragglers exceed the deadline are flagged so the launcher can (a)
skip the slow host's microbatch contribution this step (bounded staleness)
or (b) trigger elastic re-mesh without it. On a single host we exercise the
bookkeeping + policy logic; the collective hooks are where a multi-host
deployment plugs in.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any


from repro.checkpoint.checkpointer import Checkpointer

PyTree = Any


def remesh_restore(ckpt: Checkpointer, step: int, like: PyTree,
                   new_mesh, new_specs: PyTree) -> PyTree:
    """Restore `step` onto a different mesh/sharding (elastic restart)."""
    from repro.distributed.sharding import to_shardings

    shardings = to_shardings(new_specs, new_mesh)
    return ckpt.restore(step, like, shardings=shardings)


def rescale_schedule(global_batch: int, old_hosts: int, new_hosts: int,
                     per_host_batch: int) -> dict:
    """Keep the global batch constant across an elastic resize via
    gradient accumulation."""
    new_per_step = new_hosts * per_host_batch
    accum = max(1, -(-global_batch // new_per_step))
    return {
        "grad_accum_steps": accum,
        "per_host_batch": per_host_batch,
        "effective_global_batch": accum * new_per_step,
    }


@dataclass
class StragglerMonitor:
    """Deadline-based straggler detection over per-host step heartbeats."""

    n_hosts: int
    deadline_factor: float = 2.0  # x median step time
    min_deadline_s: float = 1.0
    history: list[float] = field(default_factory=list)
    flagged: dict[int, int] = field(default_factory=dict)  # host -> strikes
    evict_after: int = 3
    #: optional telemetry feed — `(verdict, flagged) -> None`, called after
    #: every heartbeat. The serving tier points this at
    #: `repro.obs.FlightRecorder.record_straggler`, so per-host strike
    #: counts and the current deadline surface as registry gauges.
    sink: Any = None

    def _publish(self, verdict: dict) -> None:
        if self.sink is not None:
            self.sink(verdict, dict(self.flagged))

    def step_times(self, times_s: dict[int, float]) -> dict:
        """Feed per-host durations for one step; returns the policy verdict."""
        med = sorted(times_s.values())[len(times_s) // 2]
        self.history.append(med)
        deadline = max(self.min_deadline_s, self.deadline_factor * med)
        slow = [h for h, t in times_s.items() if t > deadline]
        for h in slow:
            self.flagged[h] = self.flagged.get(h, 0) + 1
        for h in list(self.flagged):
            if h not in slow:
                self.flagged[h] = 0
        evict = [h for h, strikes in self.flagged.items()
                 if strikes >= self.evict_after]
        verdict = {
            "deadline_s": deadline,
            "stragglers": slow,
            "evict": evict,  # launcher responds with elastic re-mesh
            "skip_contribution": slow,  # bounded-staleness option
        }
        self._publish(verdict)
        return verdict

    def observe(self, host: int, dt_s: float, *, window: int = 64) -> dict:
        """Single-stream variant of `step_times`: one duration per call,
        compared against the rolling median of recent history instead of a
        same-step cross-host median (which is degenerate at n=1).

        This is the serving-tier heartbeat: the micro-batch scheduler feeds
        every tick's wall time here (`repro.serve.scheduler`), so a tick
        that blows past ``deadline_factor`` x the recent median — a stuck
        collective, a device fallen off the mesh, an accidental retrace
        storm — accrues strikes, and ``evict`` firing is the control
        plane's cue to shed load or shrink the mesh
        (`HybridService.handle_device_loss`). Same strike/decay/evict
        policy as `step_times`.
        """
        hist = self.history[-window:]
        baseline = sorted(hist)[len(hist) // 2] if hist else dt_s
        self.history.append(dt_s)
        deadline = max(self.min_deadline_s, self.deadline_factor * baseline)
        slow = [host] if dt_s > deadline else []
        for h in slow:
            self.flagged[h] = self.flagged.get(h, 0) + 1
        if not slow and self.flagged.get(host):
            self.flagged[host] = 0
        evict = [h for h, strikes in self.flagged.items()
                 if strikes >= self.evict_after]
        verdict = {
            "deadline_s": deadline,
            "stragglers": slow,
            "evict": evict,
            "skip_contribution": slow,
        }
        self._publish(verdict)
        return verdict


class Heartbeat:
    """Minimal liveness tracker the launcher polls between steps."""

    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.last: dict[int, float] = {}

    def beat(self, host: int, now: float | None = None) -> None:
        self.last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last.items() if now - t > self.timeout_s]
