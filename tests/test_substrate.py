"""Substrate tests: data pipeline, optimizers, checkpointing, compression,
fault-tolerance policies, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data import pipeline, synthetic
from repro.ft import elastic
from repro.optim import compression, optimizers as optim


class TestData:
    def test_deterministic(self):
        a = synthetic.load("train", n_per_class=10, seed=3)
        b = synthetic.load("train", n_per_class=10, seed=3)
        assert np.array_equal(a.images, b.images)

    def test_split_disjoint_stats(self):
        tr = synthetic.load("train", n_per_class=20)
        te = synthetic.load("test", n_per_class=20)
        assert not np.array_equal(tr.images[:20], te.images[:20])

    def test_shapes_and_range(self):
        d = synthetic.load("train", n_per_class=5)
        assert d.images.shape == (50, 32, 32, 3)
        assert d.images.min() >= 0.0 and d.images.max() <= 1.0
        assert sorted(np.unique(d.labels)) == list(range(10))

    def test_grayscale_formula(self):
        img = np.zeros((1, 2, 2, 3), np.float32)
        img[..., 0] = 1.0  # pure red
        g = synthetic.to_grayscale(img)
        assert g.shape == (1, 2, 2, 1)
        assert g[0, 0, 0, 0] == pytest.approx(0.2989)

    def test_host_shard_partition(self):
        slices = [pipeline.host_shard(103, i, 4) for i in range(4)]
        ids = np.concatenate([np.arange(103)[s] for s in slices])
        assert np.array_equal(np.sort(ids), np.arange(103))

    def test_batches_with_curriculum_limit(self):
        x = np.arange(100)[:, None]
        y = np.arange(100)
        order = np.argsort(-y)  # reverse
        got = [yy for _, yy in pipeline.batches(
            x, y, 10, order=order, limit=30, shuffle=False)]
        assert np.concatenate(got).min() >= 70

    def test_prefetch_preserves_order(self):
        it = pipeline.prefetch(iter(range(20)), size=4)
        assert list(it) == list(range(20))


class TestOptim:
    def test_adamw_quadratic_convergence(self):
        opt = optim.adamw(0.1)
        params = {"x": jnp.asarray(5.0)}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: (p["x"] - 2.0) ** 2)(params)
            params, state = opt.update(g, state, params)
        assert float(params["x"]) == pytest.approx(2.0, abs=0.05)

    def test_sgd_momentum(self):
        opt = optim.sgd(0.05, momentum=0.9)
        params = {"x": jnp.asarray(4.0)}
        state = opt.init(params)
        for _ in range(150):
            g = jax.grad(lambda p: (p["x"] + 1.0) ** 2)(params)
            params, state = opt.update(g, state, params)
        assert float(params["x"]) == pytest.approx(-1.0, abs=0.05)

    def test_cosine_schedule(self):
        f = optim.cosine_schedule(1.0, 100, warmup=10)
        assert float(f(jnp.asarray(0))) == pytest.approx(0.0)
        assert float(f(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
        assert float(f(jnp.asarray(100))) == pytest.approx(0.0, abs=0.01)

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = optim.clip_by_global_norm(g, 1.0)
        assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


class TestCheckpoint:
    def _tree(self, key):
        return {"w": jax.random.normal(key, (8, 8)),
                "b": jax.random.normal(jax.random.fold_in(key, 1), (8,)
                                       ).astype(jnp.bfloat16),
                "step": jnp.asarray(3, jnp.int32)}

    def test_roundtrip_bf16(self, tmp_path):
        ck = Checkpointer(tmp_path)
        tree = self._tree(jax.random.PRNGKey(0))
        ck.save(7, tree)
        got = ck.restore(7, jax.tree_util.tree_map(jnp.zeros_like, tree))
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(tree)):
            assert a.dtype == b.dtype
            assert bool(jnp.all(a == b))

    def test_latest_and_gc(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        tree = self._tree(jax.random.PRNGKey(1))
        for s in (1, 5, 9):
            ck.save(s, tree)
        assert ck.latest_step() == 9
        assert not (tmp_path / "step_00000001").exists()  # gc'd

    def test_async_save(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(2, self._tree(jax.random.PRNGKey(2)), blocking=False)
        ck.wait()
        assert ck.latest_step() == 2

    def test_atomicity_tmp_never_latest(self, tmp_path):
        """A leftover .tmp dir (simulated crash) is never picked up."""
        ck = Checkpointer(tmp_path)
        ck.save(1, self._tree(jax.random.PRNGKey(3)))
        (tmp_path / "step_00000009.tmp").mkdir()
        assert ck.latest_step() == 1

    def test_resume_equivalence(self, tmp_path):
        """train N then M more == train N, checkpoint, restore, M more."""
        opt = optim.adamw(0.05)

        def run(steps, params, state):
            for i in range(steps):
                g = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
                params, state = opt.update(g, state, params)
            return params, state

        p0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 4))}
        s0 = opt.init(p0)
        pa, sa = run(6, p0, s0)

        pb, sb = run(3, p0, s0)
        ck = Checkpointer(tmp_path)
        ck.save(3, {"p": pb, "s": sb})
        restored = ck.restore(3, {"p": pb, "s": sb})
        pc, sc = run(3, restored["p"], restored["s"])
        np.testing.assert_allclose(pa["w"], pc["w"], rtol=1e-6)


class TestCompression:
    def test_error_feedback_identity(self):
        """deq_t + err_t == grad_t + err_{t-1} (lossless accounting)."""
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,)) * 0.1}
        err = compression.init_error_state(g)
        deq, new_err = compression.compress_decompress(g, err)
        np.testing.assert_allclose(
            np.asarray(deq["w"] + new_err["w"]),
            np.asarray(g["w"] + err["w"]), rtol=1e-5, atol=1e-7)

    def test_quantisation_error_bounded(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(1), (128,))}
        err = compression.init_error_state(g)
        deq, new_err = compression.compress_decompress(g, err)
        bound = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        assert float(jnp.max(jnp.abs(new_err["w"]))) <= bound * 0.5 + 1e-7

    def test_convergence_with_compression(self):
        """EF-compressed SGD still converges on a quadratic."""
        opt = optim.sgd(0.05, momentum=0.0)
        params = {"x": jnp.asarray(4.0)}
        state = opt.init(params)
        err = compression.init_error_state(params)
        for _ in range(300):
            g = jax.grad(lambda p: (p["x"] - 1.5) ** 2)(params)
            g, err = compression.compress_decompress(g, err)
            params, state = opt.update(g, state, params)
        assert float(params["x"]) == pytest.approx(1.5, abs=0.05)

    def test_ratio(self):
        g = {"w": jnp.zeros((1000,))}
        assert compression.compression_ratio(g) > 3.9


class TestFaultTolerance:
    def test_straggler_flag_and_evict(self):
        mon = elastic.StragglerMonitor(n_hosts=4, deadline_factor=2.0,
                                       min_deadline_s=0.0, evict_after=2)
        verdict = None
        for _ in range(2):
            verdict = mon.step_times({0: 1.0, 1: 1.0, 2: 1.0, 3: 9.0})
        assert verdict["stragglers"] == [3]
        assert verdict["evict"] == [3]

    def test_straggler_recovers(self):
        mon = elastic.StragglerMonitor(n_hosts=2, min_deadline_s=0.0)
        mon.step_times({0: 1.0, 1: 9.0})
        v = mon.step_times({0: 1.0, 1: 1.0})
        assert v["stragglers"] == [] and v["evict"] == []

    def test_heartbeat(self):
        hb = elastic.Heartbeat(timeout_s=5.0)
        hb.beat(0, now=0.0)
        hb.beat(1, now=8.0)
        assert hb.dead_hosts(now=9.0) == [0]

    def test_rescale_schedule_preserves_global_batch(self):
        s = elastic.rescale_schedule(256, old_hosts=8, new_hosts=6,
                                     per_host_batch=8)
        assert s["effective_global_batch"] >= 256
        assert s["grad_accum_steps"] == 6


class TestServeEngine:
    def test_batched_generation(self):
        from repro.serve.engine import Engine, Request
        from repro.models import lm as lm_mod
        from repro import configs
        cfg = configs.get("tinyllama-1.1b", smoke=True)
        params = lm_mod.init_params(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, batch_size=3, max_len=64)
        reqs = [Request(prompt=np.arange(5 + i) % cfg.vocab,
                        max_new_tokens=4 + i) for i in range(5)]
        out = eng.generate(reqs)
        for i, r in enumerate(out):
            assert r.done and len(r.out) == 4 + i
            assert all(0 <= t < cfg.vocab for t in r.out)

    def test_encoder_rejected(self):
        from repro.serve.engine import Engine
        from repro import configs
        cfg = configs.get("hubert-xlarge", smoke=True)
        with pytest.raises(ValueError):
            Engine(cfg, params=None)
