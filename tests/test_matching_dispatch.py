"""Kernel/reference parity for the matching dispatch layer (PR 1).

Property-style sweeps (chex variants, à la the SNIPPETS.md pattern) asserting
the Pallas kernels (interpret mode on this CPU container) match the pure-jnp
references on non-block-multiple shapes — exercising the padded-column
corrections, `valid` masking, and the fused binarize->match->WTA epilogue —
plus coverage for the backend dispatch API and the block autotuner cache.
"""
import chex
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import matching, quant
from repro.core.templates import TemplateBank
from repro.kernels import layout, tuning
from repro.kernels.acam_match import ops as match_ops
from repro.kernels.acam_similarity import ops as sim_ops


def _bank(key, c, k, n, *, invalidate_some=True) -> TemplateBank:
    tmpl = (jax.random.uniform(key, (c, k, n)) > 0.5).astype(jnp.float32)
    lo = (jax.random.uniform(jax.random.fold_in(key, 1), (c, k, n)) > 0.6
          ).astype(jnp.float32)
    hi = jnp.maximum(lo, (jax.random.uniform(jax.random.fold_in(key, 2),
                                             (c, k, n)) > 0.4
                          ).astype(jnp.float32))
    valid = jnp.ones((c, k), bool)
    if invalidate_some and k > 1:
        valid = valid.at[0, k - 1].set(False).at[c - 1, 0].set(False)
    thr = jax.random.normal(jax.random.fold_in(key, 3), (n,)) * 0.1
    return TemplateBank(tmpl, lo, hi, valid, thr)


# the paper's deployment geometry (N=784 forces padded feature columns:
# neither 784 nor the ragged batches are block multiples)
PARITY_SHAPES = [(1, 5, 2, 784), (3, 5, 2, 784), (257, 5, 2, 784),
                 (9, 10, 1, 300), (33, 10, 3, 784)]


class TestFeatureCountParity:
    @pytest.mark.parametrize("b,c,k,n", PARITY_SHAPES)
    def test_scores_exact(self, b, c, k, n):
        key = jax.random.PRNGKey(b * n + c)
        bank = _bank(key, c, k, n)
        q = (jax.random.uniform(jax.random.fold_in(key, 4), (b, n)) > 0.5
             ).astype(jnp.float32)
        got = matching.feature_count_scores(q, bank.templates, bank.valid,
                                            backend="kernel")
        want = matching.feature_count_scores_ref(q, bank.templates, bank.valid)
        # bipolar-matmul identity is integer-exact: bit-for-bit equality,
        # including the -inf rows from `valid` masking
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bool_queries(self):
        # bool arrays must binarise through a float 0.5 threshold, not a
        # bool-dtype one (True), which would zero every query bit
        key = jax.random.PRNGKey(2)
        bank = _bank(key, 5, 2, 784, invalidate_some=False)
        q = jax.random.uniform(jax.random.fold_in(key, 4), (9, 784)) > 0.5
        got = matching.feature_count_scores(q.astype(bool),
                                            bank.templates.astype(bool),
                                            backend="kernel")
        want = matching.feature_count_scores_ref(q.astype(jnp.float32),
                                                 bank.templates)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_no_valid_mask(self):
        key = jax.random.PRNGKey(0)
        bank = _bank(key, 4, 2, 96, invalidate_some=False)
        q = (jax.random.uniform(key, (17, 96)) > 0.5).astype(jnp.float32)
        got = matching.feature_count_scores(q, bank.templates,
                                            backend="kernel")
        want = matching.feature_count_scores_ref(q, bank.templates)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestSimilarityParity:
    @pytest.mark.parametrize("b,c,k,n", PARITY_SHAPES)
    def test_scores_close(self, b, c, k, n):
        key = jax.random.PRNGKey(b + c * n)
        bank = _bank(key, c, k, n)
        q = jax.random.uniform(jax.random.fold_in(key, 4), (b, n))
        got = matching.similarity_scores(q, bank.lower, bank.upper,
                                         bank.valid, alpha=0.7,
                                         backend="kernel")
        want = matching.similarity_scores_ref(q, bank.lower, bank.upper,
                                              bank.valid, alpha=0.7)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


class TestFusedClassify(chex.TestCase):
    # chex.TestCase is absltest-based: sweep methods in-test rather than via
    # pytest.mark.parametrize (which doesn't compose with variants)
    @chex.variants(with_jit=True, without_jit=True)
    def test_classify_features_parity(self):
        key = jax.random.PRNGKey(11)
        bank = _bank(key, 10, 2, 784)
        feats = jax.random.normal(jax.random.fold_in(key, 5), (37, 784))

        for method in ("feature_count", "similarity"):
            fn = self.variant(
                lambda f, m=method: matching.classify_features(
                    f, bank, method=m, backend="kernel"))
            pred_k, pc_k = fn(feats)
            pred_r, pc_r = matching.classify_features(
                feats, bank, method=method, backend="reference")
            np.testing.assert_array_equal(np.asarray(pred_k),
                                          np.asarray(pred_r))
            np.testing.assert_allclose(np.asarray(pc_k), np.asarray(pc_r),
                                       rtol=1e-5, atol=1e-6)


class TestClassifyBinaryQueries:
    @pytest.mark.parametrize("b", [1, 3, 257])
    @pytest.mark.parametrize("method", ["feature_count", "similarity"])
    def test_classify_binary_queries(self, b, method):
        key = jax.random.PRNGKey(b)
        bank = _bank(key, 10, 2, 784)
        feats = jax.random.normal(jax.random.fold_in(key, 5), (b, 784))
        q = quant.binarize(feats, bank.thresholds)
        pred_k, pc_k = matching.classify(q, bank, method=method,
                                         backend="kernel")
        pred_r, pc_r = matching.classify(q, bank, method=method,
                                         backend="reference")
        np.testing.assert_array_equal(np.asarray(pred_k), np.asarray(pred_r))
        np.testing.assert_allclose(np.asarray(pc_k), np.asarray(pc_r),
                                   rtol=1e-5, atol=1e-6)


class TestFusedOpsDirect:
    def test_fused_ops_direct(self):
        """classify_fused == two-stage kernel == reference, same bank."""
        key = jax.random.PRNGKey(3)
        c, k, n = 6, 3, 300
        bank = _bank(key, c, k, n)
        feats = jax.random.normal(jax.random.fold_in(key, 9), (29, n))
        pred_f, pc_f = match_ops.classify_fused(feats, bank.thresholds,
                                                bank.templates, bank.valid)
        pred_t, pc_t = match_ops.classify(feats, bank.thresholds,
                                          bank.templates.reshape(c * k, n),
                                          bank.valid.reshape(c * k), c)
        np.testing.assert_array_equal(np.asarray(pred_f), np.asarray(pred_t))
        np.testing.assert_allclose(np.asarray(pc_f), np.asarray(pc_t), atol=0)

        pred_s, pc_s = sim_ops.classify_fused(feats, bank.thresholds,
                                              bank.lower, bank.upper,
                                              bank.valid, alpha=1.0)
        q = quant.binarize(feats, bank.thresholds)
        pred_r, pc_r = matching.classify(q, bank, method="similarity",
                                         backend="reference")
        np.testing.assert_array_equal(np.asarray(pred_s), np.asarray(pred_r))
        np.testing.assert_allclose(np.asarray(pc_s), np.asarray(pc_r),
                                   rtol=1e-5, atol=1e-6)


class TestBackendDispatch:
    def test_set_get_roundtrip(self):
        old = matching.get_backend()
        try:
            for b in ("kernel", "reference", "auto"):
                matching.set_backend(b)
                assert matching.get_backend() == b
            with pytest.raises(ValueError):
                matching.set_backend("cuda")
        finally:
            matching.set_backend(old)

    def test_auto_tiny_uses_reference_semantics(self):
        # below TINY_ELEMENTS auto == reference; above, auto == kernel;
        # either way results agree, which is what deployments observe.
        key = jax.random.PRNGKey(1)
        bank = _bank(key, 4, 1, 32, invalidate_some=False)
        q = (jax.random.uniform(key, (2, 32)) > 0.5).astype(jnp.float32)
        assert 2 * 4 * 1 * 32 < matching.TINY_ELEMENTS
        got = matching.feature_count_scores(q, bank.templates, backend="auto")
        want = matching.feature_count_scores_ref(q, bank.templates)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_invalid_backend_kw(self):
        key = jax.random.PRNGKey(1)
        bank = _bank(key, 4, 1, 32, invalidate_some=False)
        q = jnp.zeros((2, 32))
        with pytest.raises(ValueError):
            matching.feature_count_scores(q, bank.templates, backend="gpuuu")


class TestKmajorLayout:
    def test_roundtrip(self):
        key = jax.random.PRNGKey(0)
        c, k, n = 10, 3, 17
        arr = jax.random.normal(key, (c, k, n))
        flat = layout.flatten_kmajor(arr, c)
        cp = layout.padded_classes(c)
        assert flat.shape == (k * cp, n)
        for kk in range(k):
            np.testing.assert_array_equal(
                np.asarray(flat[kk * cp: kk * cp + c]),
                np.asarray(arr[:, kk, :]))
            # padded class rows are zero
            assert not np.asarray(flat[kk * cp + c: (kk + 1) * cp]).any()

    def test_valid_rows(self):
        valid = jnp.array([[True, False], [True, True]])
        v = layout.valid_kmajor(valid, 2)
        cp = layout.padded_classes(2)
        assert v.shape == (2 * cp,)
        assert v[0] == 1.0 and v[1] == 1.0          # k=0: both classes valid
        assert v[cp] == 0.0 and v[cp + 1] == 1.0    # k=1: class 0 invalid
        assert float(v.sum()) == 3.0


class TestTuning:
    def test_cache_roundtrip(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "blocks.json"))
        tuning.clear_cache_for_tests()
        try:
            shape = (64, 10, 784)
            assert tuning.get_block("acam_match", shape, jnp.float32) == \
                tuning.default_block("acam_match")

            calls = []

            def run(block):
                calls.append(block)
                return jnp.zeros((4, 4))

            best = tuning.autotune("acam_match", shape, jnp.float32, run,
                                   cands=[(128, 128, 256), (128, 128, 512)],
                                   iters=1)
            assert best in calls
            tuning.clear_cache_for_tests()
            assert tuning.get_block("acam_match", shape, jnp.float32) == best
            # other shapes still fall back to the default
            assert tuning.get_block("acam_match", (8, 8, 8), jnp.float32) == \
                tuning.default_block("acam_match")
        finally:
            tuning.clear_cache_for_tests()

    def test_candidates_aligned(self):
        for kernel in ("acam_match", "acam_similarity"):
            cands = tuning.candidates(kernel)
            assert cands, kernel
            for bm, bn, bk in cands:
                assert bn % 128 == 0 and bk % 128 == 0
                assert bm % 8 == 0 or bm < 8

    def test_failing_candidates_skipped(self):
        def run(block):
            if block[0] == 128:
                raise RuntimeError("VMEM OOM")
            return jnp.zeros(())

        best = tuning.autotune("acam_match", (1, 1, 1), jnp.float32, run,
                               cands=[(128, 128, 256), (256, 128, 256)],
                               iters=1, save=False)
        assert best == (256, 128, 256)
