"""Unit tests for the paper's core modules (Eq. 1-12, §II, §V-D)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import acam, distill, energy, matching, prune, quant, templates


# ---------------------------------------------------------------------------
# distillation (Eq. 1-4)
# ---------------------------------------------------------------------------

class TestDistill:
    def test_kd_loss_zero_for_identical_logits(self):
        z = jax.random.normal(jax.random.PRNGKey(0), (8, 10))
        assert float(distill.kd_loss(z, z, 4.0)) == pytest.approx(0.0, abs=1e-5)

    def test_kd_loss_nonnegative(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        zs = jax.random.normal(k1, (16, 10)) * 3
        zt = jax.random.normal(k2, (16, 10)) * 3
        assert float(distill.kd_loss(zs, zt, 2.0)) >= 0.0

    @given(st.floats(1.0, 10.0))
    @settings(max_examples=10, deadline=None)
    def test_t_squared_scaling_keeps_gradient_magnitude(self, t):
        """Eq. 2's T^2 factor: gradients stay O(1) across temperatures."""
        zs = jnp.array([[1.0, -1.0, 0.5, 2.0]])
        zt = jnp.array([[2.0, 0.0, -1.0, 1.0]])
        g = jax.grad(lambda z: distill.kd_loss(z, zt, t))(zs)
        assert 1e-3 < float(jnp.max(jnp.abs(g))) < 10.0

    def test_composite_loss_endpoints(self):
        """Eq. 1: alpha=0 -> pure CE; alpha=1 -> pure KD."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(2))
        zs = jax.random.normal(k1, (4, 10))
        zt = jax.random.normal(k2, (4, 10))
        y = jnp.arange(4)
        l0 = distill.distillation_loss(zs, zt, y, alpha=0.0, temperature=3.0)
        assert float(l0) == pytest.approx(float(distill.cross_entropy(zs, y)), rel=1e-6)
        l1 = distill.distillation_loss(zs, zt, y, alpha=1.0, temperature=3.0)
        assert float(l1) == pytest.approx(float(distill.kd_loss(zs, zt, 3.0)), rel=1e-6)

    def test_curriculum_orders_easy_to_hard(self):
        """Eq. 4: the teacher-confident sample must sort first."""
        zt = jnp.array([[10.0, -10.0], [0.1, 0.0], [-10.0, 10.0]])
        y = jnp.array([0, 0, 0])  # sample 0 easy, 2 hardest
        order = distill.curriculum_order(zt, y)
        assert list(np.asarray(order)) == [0, 1, 2]

    def test_pacing_schedule_monotone(self):
        sched = distill.CurriculumSchedule(0.3, 5)
        avail = [sched.available(e, 1000) for e in range(7)]
        assert avail[0] == 300 and avail[-1] == 1000
        assert all(a <= b for a, b in zip(avail, avail[1:]))


# ---------------------------------------------------------------------------
# pruning (Eq. 5-7)
# ---------------------------------------------------------------------------

class TestPrune:
    def test_schedule_endpoints(self):
        assert float(prune.polynomial_sparsity(0, 100)) == pytest.approx(0.5)
        assert float(prune.polynomial_sparsity(100, 100)) == pytest.approx(0.8)

    @given(st.integers(1, 99))
    @settings(max_examples=20, deadline=None)
    def test_schedule_monotone_in_bounds(self, t):
        s = float(prune.polynomial_sparsity(t, 100))
        s_next = float(prune.polynomial_sparsity(t + 1, 100))
        assert 0.5 <= s <= s_next <= 0.8

    def test_prune_achieves_sparsity(self):
        w = {"a": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
        pruned, masks = prune.prune_tree(w, 0.8)
        assert prune.sparsity_of(pruned) == pytest.approx(0.8, abs=0.01)

    def test_biases_untouched(self):
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 32)),
                "b": jnp.ones((32,))}
        pruned, _ = prune.prune_tree(tree, 0.9)
        assert bool(jnp.all(pruned["b"] == 1.0))

    def test_masks_persistent_under_gradients(self):
        w = {"a": jax.random.normal(jax.random.PRNGKey(0), (32, 32))}
        pruned, masks = prune.prune_tree(w, 0.7)
        g = {"a": jnp.ones((32, 32))}
        g = prune.mask_gradients(g, masks)
        stepped = jax.tree_util.tree_map(lambda p, gg: p - 0.1 * gg, pruned, g)
        stepped = prune.apply_masks(stepped, masks)
        assert prune.sparsity_of(stepped) >= 0.69

    def test_global_vs_per_tensor_ranking(self):
        tree = {"small": jnp.full((16, 16), 0.01),
                "big": jnp.full((16, 16), 1.0)}
        pruned_g, _ = prune.prune_tree(tree, 0.5, global_ranking=True)
        # global ranking kills the uniformly-small tensor first
        assert float(jnp.sum(pruned_g["small"] != 0)) == 0.0
        assert float(jnp.sum(pruned_g["big"] != 0)) == 256.0

    @given(st.integers(2, 40), st.integers(2, 40))
    @settings(max_examples=15, deadline=None)
    def test_sparse_roundtrip(self, h, w_):
        w = jax.random.normal(jax.random.PRNGKey(h * w_), (h, w_))
        pruned, _ = prune.prune_tree({"w": w}, 0.6)
        s = prune.to_sparse(pruned["w"])
        assert bool(jnp.allclose(prune.from_sparse(s), pruned["w"]))


# ---------------------------------------------------------------------------
# quantisation (§II-C)
# ---------------------------------------------------------------------------

class TestQuant:
    @given(st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_int8_error_bound(self, seed):
        w = jax.random.normal(jax.random.PRNGKey(seed), (32, 32))
        q = quant.fake_quant_int8(w)
        scale = float(jnp.max(jnp.abs(w))) / 127.0
        assert float(jnp.max(jnp.abs(q - w))) <= scale * 0.5 + 1e-7

    def test_ste_gradient_is_identity(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
        g = jax.grad(lambda x: jnp.sum(quant.fake_quant_int8(x) * 2.0))(w)
        assert bool(jnp.allclose(g, 2.0))

    def test_mean_below_median_for_relu_sparse(self):
        """Fig. 1's premise: sparse ReLU features push the mean below the
        median-of-nonzeros... and below the median when >50% are zero."""
        x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(0), (4000, 16)) - 0.8)
        mean_t = quant.feature_thresholds(x, "mean")
        med_t = quant.feature_thresholds(x, "median")
        assert bool(jnp.all(mean_t >= med_t))  # median is 0, mean positive
        # and the mean keeps low-magnitude activations discriminative:
        binz = quant.binarize(x, mean_t)
        assert 0.0 < float(binz.mean()) < 0.5

    def test_binarize_output_binary(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        b, thr = quant.binarize_with_stats(x, "mean")
        assert set(np.unique(np.asarray(b))) <= {0.0, 1.0}


# ---------------------------------------------------------------------------
# templates + matching (Eq. 8-12, §II-D)
# ---------------------------------------------------------------------------

def _clustered_features(key, n_per=40, classes=4, dim=32, spread=0.3):
    centers = jax.random.normal(key, (classes, dim)) * 2.0
    feats, labels = [], []
    for c in range(classes):
        k = jax.random.fold_in(key, c)
        feats.append(centers[c] + spread * jax.random.normal(k, (n_per, dim)))
        labels += [c] * n_per
    return jnp.concatenate(feats), jnp.asarray(labels)


class TestTemplates:
    def test_kmeans_partitions(self):
        x, _ = _clustered_features(jax.random.PRNGKey(0), classes=3)
        cents, assign = templates.kmeans(x, 3)
        assert cents.shape == (3, 32)
        assert len(set(np.asarray(assign).tolist())) == 3

    @given(st.integers(2, 4))
    @settings(max_examples=8, deadline=None)
    def test_silhouette_range(self, k):
        x, _ = _clustered_features(jax.random.PRNGKey(k), n_per=20, classes=k)
        _, assign = templates.kmeans(x, k)
        s = float(templates.silhouette_score(x, assign, k))
        assert -1.0 <= s <= 1.0

    def test_template_bank_shapes_valid(self):
        x, y = _clustered_features(jax.random.PRNGKey(2))
        bank = templates.generate_templates(x, y, 4, k=2)
        assert bank.templates.shape == (4, 2, 32)
        assert bool(jnp.all(bank.valid))
        assert bool(jnp.all(bank.upper >= bank.lower))
        vals = np.unique(np.asarray(bank.templates))
        assert set(vals.tolist()) <= {0.0, 1.0}

    def test_matching_classifies_clustered_data(self):
        x, y = _clustered_features(jax.random.PRNGKey(3))
        bank = templates.generate_templates(x, y, 4, k=1)
        q = quant.binarize(x, bank.thresholds)
        pred_fc, _ = matching.classify(q, bank, method="feature_count")
        pred_s, _ = matching.classify(q, bank, method="similarity")
        assert float(jnp.mean(pred_fc == y)) > 0.9
        assert float(jnp.mean(pred_s == y)) > 0.9

    def test_binary_convergence_of_fc_and_similarity(self):
        """Paper §V-B: in the fully-binary regime both matching models give
        identical decisions."""
        x, y = _clustered_features(jax.random.PRNGKey(4), classes=5)
        bank = templates.generate_templates(x, y, 5, k=1, binary_windows=True)
        # windows collapsed to the point template => same ranking
        bank = bank._replace(lower=bank.templates, upper=bank.templates)
        q = quant.binarize(x, bank.thresholds)
        pred_fc, _ = matching.classify(q, bank, method="feature_count")
        pred_s, _ = matching.classify(q, bank, method="similarity")
        assert bool(jnp.all(pred_fc == pred_s))

    def test_multi_template_max_pool(self):
        scores = jnp.asarray([[[1.0, 5.0], [3.0, 2.0]]])  # (B=1, C=2, K=2)
        pred, per_class = matching.classify_scores(scores)
        assert per_class.tolist() == [[5.0, 3.0]]
        assert int(pred[0]) == 0

    def test_select_k_by_silhouette(self):
        x, y = _clustered_features(jax.random.PRNGKey(5), n_per=30)
        best, scores = templates.select_k_by_silhouette(x, y, 4, (1, 2))
        assert best in (1, 2) and set(scores) == {1, 2}


# ---------------------------------------------------------------------------
# ACAM device models (§III)
# ---------------------------------------------------------------------------

class TestACAMDevice:
    def _bank(self, key):
        x, y = _clustered_features(key)
        bank = templates.generate_templates(x, y, 4, k=1)
        q = quant.binarize(x, bank.thresholds)
        return bank, q, y

    @pytest.mark.parametrize("cell", ["6T4R", "3T1R"])
    def test_sense_matches_ideal_ranking(self, cell):
        bank, q, y = self._bank(jax.random.PRNGKey(0))
        cfg = acam.ACAMConfig(cell=cell)
        arr = acam.program(bank.templates.reshape(4, 32),
                           bank.templates.reshape(4, 32),
                           bank.valid.reshape(4), cfg)
        winner = acam.wta(acam.sense(arr, q))
        acc = float(jnp.mean(winner == y))
        assert acc > 0.9

    def test_matchline_voltage_saturates(self):
        cfg = acam.ACAMConfig()
        arr = acam.program(jnp.zeros((2, 64)), jnp.ones((2, 64)),
                           jnp.ones(2, bool), cfg)
        v = acam.matchline_voltage(arr, jnp.full((1, 64), 0.5))
        assert float(v.max()) <= cfg.vdd + 1e-9

    def test_programming_noise_changes_windows(self):
        cfg = acam.ACAMConfig(sigma_program=0.3)
        lo, hi = jnp.full((4, 16), 0.4), jnp.full((4, 16), 0.6)
        arr = acam.program(lo, hi, jnp.ones(4, bool), cfg,
                           key=jax.random.PRNGKey(0))
        assert not bool(jnp.allclose(arr.lower, lo))
        assert bool(jnp.all(arr.upper >= arr.lower))

    def test_soft_sense_differentiable_and_close_to_hard(self):
        bank, q, _ = self._bank(jax.random.PRNGKey(1))
        cfg = acam.ACAMConfig(cell="3T1R", beta=50.0)
        arr = acam.program(bank.lower.reshape(4, 32), bank.upper.reshape(4, 32),
                           bank.valid.reshape(4), cfg)
        hard = acam.sense(arr, q[:16])
        soft = acam.soft_sense(arr, q[:16])
        assert bool(jnp.all(jnp.argmax(hard, -1) == jnp.argmax(soft, -1)))
        g = jax.grad(lambda lo: acam.soft_sense(
            arr._replace(lower=lo), q[:16]).sum())(arr.lower)
        assert float(jnp.max(jnp.abs(g))) > 0.0

    def test_calibration_improves_separation(self):
        bank, q, y = self._bank(jax.random.PRNGKey(2))
        cfg = acam.ACAMConfig(cell="3T1R", sigma_program=0.5)
        arr = acam.program(bank.lower.reshape(4, 32), bank.upper.reshape(4, 32),
                           bank.valid.reshape(4), cfg, key=jax.random.PRNGKey(3))
        acc0 = float(jnp.mean(acam.wta(acam.sense(arr, q)) == y))
        cal = acam.calibrate_windows(arr, q, y.astype(jnp.int32), steps=60)
        acc1 = float(jnp.mean(acam.wta(acam.sense(cal, q)) == y))
        assert acc1 >= acc0

    def test_search_energy_matches_eq14(self):
        cfg = acam.ACAMConfig()
        arr = acam.program(jnp.zeros((10, 784)), jnp.ones((10, 784)),
                           jnp.ones(10, bool), cfg)
        assert float(acam.search_energy(arr)) == pytest.approx(1.4504e-9, rel=1e-3)


# ---------------------------------------------------------------------------
# energy model (§V-D)
# ---------------------------------------------------------------------------

class TestEnergy:
    def test_paper_numbers(self):
        """§V-D regression: the printed constants, in paper_faithful mode."""
        n = energy.paper_numbers()
        assert n["backend_nj"] == pytest.approx(1.45, abs=0.01)  # Eq. 14
        assert n["frontend_nj"] == pytest.approx(96.07, abs=0.05)
        assert n["total_nj"] == pytest.approx(97.52, abs=0.05)
        assert n["teacher_uj"] == pytest.approx(78.06, abs=0.05)
        # the paper prints ~792x; the exact arithmetic lands at ~800x
        assert n["reduction_x"] == pytest.approx(792, rel=0.02)

    def test_effective_ops_arithmetic(self):
        """effective = MACs * (1 - sparsity) - softmax head ops, and both
        the front-end and teacher charge the same 20.23 fJ/op figure."""
        rep = energy.hybrid_report(paper_faithful=True)
        per_op = energy.per_op_energy(bits=8, paper_faithful=True)
        assert per_op == pytest.approx(20.23e-15, rel=1e-3)
        effective = round(23_785_120 * 0.2) - 7_850
        assert rep.frontend_j == pytest.approx(effective * per_op, rel=1e-9)
        assert rep.teacher_j == pytest.approx(3_858_551_808 * per_op,
                                              rel=1e-9)

    def test_physical_vs_paper_units(self):
        """The recorded unit slip: the paper applied Horowitz pJ as fJ."""
        assert energy.PAPER_UNIT_SLIP == pytest.approx(1e-3)
        # exactly 1000x per op, for both op widths
        for bits in (8, 32):
            assert energy.per_op_energy(bits=bits, paper_faithful=False) \
                == pytest.approx(
                    1000 * energy.per_op_energy(bits=bits,
                                                paper_faithful=True))
        rep_paper = energy.hybrid_report(paper_faithful=True)
        rep_phys = energy.hybrid_report(paper_faithful=False)
        assert rep_phys.frontend_j == pytest.approx(
            rep_paper.frontend_j * 1000, rel=1e-6)
        assert rep_phys.teacher_j == pytest.approx(
            rep_paper.teacher_j * 1000, rel=1e-6)
        # Eq. 14 is physically consistent as printed: no slip on the ACAM
        assert rep_phys.backend_j == rep_paper.backend_j
        # the headline reduction is nearly unit-independent (the fixed 1.45nJ
        # ACAM term weighs less against the 1000x larger physical front-end)
        assert rep_phys.reduction == pytest.approx(rep_paper.reduction, rel=0.05)
