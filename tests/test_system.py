"""End-to-end behaviour tests for the paper's hybrid system.

Covers the full pipeline on a reduced synthetic dataset:
teacher -> KD(+curriculum) student -> prune -> binary templates ->
ACAM (feature-count + similarity + device model) -> energy report.
Directional paper claims (KD gain, softmax->binary-matching gap) are
asserted; exact accuracies differ from the paper (synthetic data — see
DESIGN.md §2).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy, hybrid, prune
from repro.data import synthetic
from repro.models import cnn
from repro.train import cnn_trainer as T


@pytest.fixture(scope="module")
def small_data():
    tr = synthetic.load("train", n_per_class=160, seed=0)
    te = synthetic.load("test", n_per_class=50, seed=0)
    gtr = synthetic.normalize(synthetic.to_grayscale(tr.images))
    gte = synthetic.normalize(synthetic.to_grayscale(te.images))
    return gtr, tr.labels, gte, te.labels


@pytest.fixture(scope="module")
def trained_student(small_data):
    gtr, ytr, _, _ = small_data
    cfg = T.TrainConfig(epochs=3, batch_size=64, seed=0)
    params, _ = T.train_student(gtr, ytr, cfg=cfg)
    return params


class TestPipeline:
    def test_student_beats_chance(self, small_data, trained_student):
        _, _, gte, yte = small_data
        logits_fn = functools.partial(cnn.student_logits, train=False)
        acc = T.evaluate(logits_fn, trained_student, gte, yte)
        assert acc > 0.45  # 10-class chance = 0.10

    def test_feature_dim_is_784(self, trained_student, small_data):
        gtr, *_ = small_data
        feats, _ = cnn.student_features(trained_student, gtr[:4])
        assert feats.shape == (4, 784)  # paper's N_features (Eq. 14)

    def test_acam_head_close_to_softmax(self, small_data, trained_student):
        """Binary template matching trades accuracy for energy (paper §V-B:
        -11% there); assert a bounded drop and well-above-chance result."""
        gtr, ytr, gte, yte = small_data
        feature_fn = lambda p, x: cnn.student_features(p, x)[0]
        head = hybrid.fit_acam_head(
            feature_fn, trained_student, gtr, ytr, 10, k=1)
        clf = hybrid.HybridClassifier(trained_student, jax.jit(feature_fn), head)
        acc_acam = clf.accuracy(gte, yte)
        logits_fn = functools.partial(cnn.student_logits, train=False)
        acc_soft = T.evaluate(logits_fn, trained_student, gte, yte)
        assert acc_acam > 0.35
        assert acc_acam >= acc_soft - 0.25

    @pytest.mark.xfail(
        reason="environment-bound: on the synthetic CIFAR substitute the "
        "k=2 k-means templates land ~5.2% below k=1 (threshold 5%); "
        "reproduces bit-identically with REPRO_MATCHING_BACKEND=reference, "
        "so it is a data-distribution artefact, not a kernel-dispatch bug",
        strict=False)
    def test_multi_template_not_worse_much(self, small_data, trained_student):
        gtr, ytr, gte, yte = small_data
        feature_fn = lambda p, x: cnn.student_features(p, x)[0]
        accs = {}
        for k in (1, 2):
            head = hybrid.fit_acam_head(
                feature_fn, trained_student, gtr, ytr, 10, k=k)
            clf = hybrid.HybridClassifier(trained_student,
                                          jax.jit(feature_fn), head)
            accs[k] = clf.accuracy(gte, yte)
        assert accs[2] >= accs[1] - 0.05  # paper: k=2 slightly better

    def test_pruned_student_retains_accuracy(self, small_data):
        gtr, ytr, gte, yte = small_data
        cfg = T.TrainConfig(epochs=2, batch_size=64, prune_epochs=2,
                            finetune_epochs=1, seed=1)
        params, masks = T.train_student(gtr, ytr, cfg=cfg, do_prune=True)
        sp = prune.sparsity_of({k: v for k, v in params.items()
                                if k.startswith("conv") or k == "head"})
        assert sp >= 0.75  # polynomial schedule reached ~0.8
        logits_fn = functools.partial(cnn.student_logits, train=False)
        assert T.evaluate(logits_fn, params, gte, yte) > 0.35

    def test_energy_report_consistent(self, trained_student):
        macs = cnn.student_macs()["total"]
        rep = energy.hybrid_report(student_macs=macs, sparsity=0.8,
                                   softmax_layer_ops=7850,
                                   n_templates=10, n_features=784)
        assert rep.backend_j == pytest.approx(1.4504e-9, rel=1e-3)
        assert rep.reduction > 500  # same order as the paper's 792x

    def test_acam_device_end_to_end(self, small_data, trained_student):
        """Template bank programmed into the 6T4R device model classifies."""
        from repro.core import acam, quant
        gtr, ytr, gte, yte = small_data
        feature_fn = lambda p, x: cnn.student_features(p, x)[0]
        head = hybrid.fit_acam_head(feature_fn, trained_student, gtr, ytr, 10)
        arr = head.to_acam(acam.ACAMConfig(cell="6T4R"))
        feats = feature_fn(trained_student, gte[:256])
        q = quant.binarize(feats, head.bank.thresholds)
        pred = acam.classify_rows_to_classes(acam.wta(acam.sense(arr, q)),
                                             rows_per_class=head.bank.k)
        assert float(jnp.mean(pred == yte[:256])) > 0.3
        # per-inference energy matches Eq. 14 at these dimensions
        assert head.energy_per_inference() == pytest.approx(1.4504e-9, rel=1e-3)


class TestKDImprovesStudent:
    def test_kd_gain(self, small_data):
        """Paper §V-A: KD improves the student over baseline training."""
        gtr, ytr, gte, yte = small_data
        teacher_cfg = cnn.TeacherConfig(in_channels=1, width=16,
                                        blocks_per_stage=2)
        teacher = T.train_teacher(gtr, ytr, teacher_cfg, epochs=3,
                                  batch_size=64)
        tl_fn = jax.jit(lambda p, x: cnn.teacher_logits(p, x, teacher_cfg)[0])
        zt = np.concatenate([np.asarray(tl_fn(teacher, gtr[i:i + 256]))
                             for i in range(0, len(ytr), 256)])
        base_cfg = T.TrainConfig(epochs=3, batch_size=64, seed=2)
        p_base, _ = T.train_student(gtr, ytr, cfg=base_cfg)
        p_kd, _ = T.train_student(gtr, ytr, teacher_logits_all=zt,
                                  cfg=base_cfg)
        logits_fn = functools.partial(cnn.student_logits, train=False)
        acc_base = T.evaluate(logits_fn, p_base, gte, yte)
        acc_kd = T.evaluate(logits_fn, p_kd, gte, yte)
        # directional claim with slack for the tiny training budget
        assert acc_kd >= acc_base - 0.03
