"""Per-architecture smoke tests (reduced configs, one fwd/train step on CPU,
shape + finiteness assertions) plus serving-consistency checks."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import lm

ARCHS = configs.list_archs()


def _batch(cfg, key, b=2, s=32):
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(key, (b, s), 0, cfg.vocab)
    else:
        inputs = jax.random.normal(key, (b, s, cfg.d_model), jnp.bfloat16)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab)
    out = {"inputs": inputs, "labels": labels}
    if cfg.rope == "mrope":
        out["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))
    return out


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = configs.get(arch, smoke=True)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, jax.random.PRNGKey(1))
        logits, aux = jax.jit(
            lambda p, b: lm.forward(p, cfg, b["inputs"], b.get("positions"))
        )(params, batch)
        assert logits.shape == (2, 32, cfg.vocab)
        assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
        assert not bool(jnp.isnan(aux))

    def test_train_step_decreases_loss(self, arch):
        """One SGD step on a repeated batch must reduce the loss."""
        cfg = configs.get(arch, smoke=True)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, jax.random.PRNGKey(2))
        loss_fn = jax.jit(lambda p: lm.loss_fn(p, cfg, batch))
        l0 = loss_fn(params)
        g = jax.jit(jax.grad(lambda p: lm.loss_fn(p, cfg, batch)))(params)
        params2 = jax.tree_util.tree_map(
            lambda p, gg: (p.astype(jnp.float32) - 0.3 * gg.astype(jnp.float32)
                           ).astype(p.dtype), params, g)
        l1 = loss_fn(params2)
        assert float(l1) < float(l0)
        assert jnp.isfinite(l0) and jnp.isfinite(l1)

    def test_full_config_registered(self, arch):
        cfg = configs.get(arch)
        assert cfg.n_layers >= 22 and cfg.vocab >= 504
        assert cfg.param_count() > 1e9  # full configs are billion-scale


class TestParamCounts:
    """Full configs land near their advertised sizes."""

    @pytest.mark.parametrize("arch,lo,hi", [
        ("qwen2.5-14b", 13e9, 16e9),
        ("tinyllama-1.1b", 1.0e9, 1.25e9),
        ("qwen3-1.7b", 1.5e9, 2.2e9),
        # 35.2B is what the brief's exact config yields (64L x d5120 x ff27392)
        ("qwen1.5-32b", 30e9, 36e9),
        ("phi3.5-moe-42b-a6.6b", 39e9, 45e9),
        # +33B over nominal: homogeneous 61-layer MoE scan vs 58 MoE + 3
        # dense layers (documented deviation, DESIGN.md §6)
        ("deepseek-v3-671b", 620e9, 710e9),
        ("qwen2-vl-72b", 66e9, 76e9),
        ("hymba-1.5b", 1.2e9, 1.9e9),
        ("hubert-xlarge", 0.9e9, 1.3e9),
        ("mamba2-2.7b", 2.4e9, 3.0e9),
    ])
    def test_param_count_band(self, arch, lo, hi):
        n = configs.get(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"

    def test_moe_active_counts(self):
        ds = configs.get("deepseek-v3-671b")
        assert 30e9 <= ds.active_param_count() <= 45e9  # ~37B active
        phi = configs.get("phi3.5-moe-42b-a6.6b")
        assert 5e9 <= phi.active_param_count() <= 9e9  # ~6.6B active


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert_xlarge"])
def test_decode_matches_forward(arch):
    """prefill+decode teacher-forcing == full forward (KV/SSM/MLA caches)."""
    cfg = configs.get(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    B, S = 2, 24
    params = lm.init_params(key, cfg)
    if cfg.input_mode == "tokens":
        seq = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    else:
        seq = jax.random.normal(key, (B, S + 1, cfg.d_model), jnp.bfloat16)
    full, _ = jax.jit(lambda p, x: lm.forward(p, cfg, x))(params, seq)
    pl_, cache = jax.jit(
        lambda p, x: lm.prefill(p, cfg, x, max_len=S + 4))(params, seq[:, :S])
    dl, _ = jax.jit(
        lambda p, t, c: lm.decode_step(p, cfg, t, c)
    )(params, seq[:, S:S + 1], cache)
    scale = float(jnp.max(jnp.abs(full[:, S].astype(jnp.float32)))) + 1e-6
    err = float(jnp.max(jnp.abs(
        dl[:, 0].astype(jnp.float32) - full[:, S].astype(jnp.float32))))
    # MLA decode uses the weight-absorbed (higher-precision) path, and the
    # SSD chunked scan runs bf16 operands with f32 accumulation while the
    # single-step decode path is f32 (matching Mamba2 reference kernels) ->
    # bf16-level divergence expected there; everything else is exact.
    tol = (0.08 * scale if (cfg.mla or cfg.ssm or cfg.hybrid)
           else 1e-3 * scale + 1e-4)
    assert err <= tol, f"decode mismatch {err} vs scale {scale}"


def test_encoder_prefill_only():
    cfg = configs.get("hubert-xlarge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.bfloat16)
    logits, _ = jax.jit(lambda p, x: lm.prefill(p, cfg, x))(params, x)
    assert logits.shape == (2, cfg.vocab)


def test_sliding_window_ring_buffer():
    """hymba: decode beyond the window must keep matching a fresh prefill."""
    cfg = configs.get("hymba-1.5b", smoke=True)  # window 64
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    S = 70  # > window
    seq = jax.random.randint(jax.random.PRNGKey(1), (1, S + 1), 0, cfg.vocab)
    full, _ = jax.jit(lambda p, x: lm.forward(p, cfg, x))(params, seq)
    _, cache = jax.jit(lambda p, x: lm.prefill(p, cfg, x, max_len=S + 4)
                       )(params, seq[:, :S])
    dl, _ = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c)
                    )(params, seq[:, S:S + 1], cache)
    err = float(jnp.max(jnp.abs(
        dl[:, 0].astype(jnp.float32) - full[:, S].astype(jnp.float32))))
    assert err < 6e-2  # bf16 SSD scan vs f32 decode step (see tolerance note)


class TestHeadPadding:
    """Mesh-alignment head padding (§Perf cell B) is exact at init."""

    def test_padded_equals_unpadded(self):
        import dataclasses
        cfg0 = dataclasses.replace(
            configs.get("qwen2.5-14b", smoke=True),
            n_heads=10, n_kv_heads=2, head_dim=16, d_model=96, d_ff=128)
        cfg1 = dataclasses.replace(cfg0, head_pad_multiple=4)
        assert lm._pad_geom(cfg1) == (12, 4, 2, 3)
        key = jax.random.PRNGKey(0)
        p0, p1 = lm.init_params(key, cfg0), lm.init_params(key, cfg1)
        x = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg0.vocab)
        l0, _ = lm.forward(p0, cfg0, x)
        l1, _ = lm.forward(p1, cfg1, x)
        err = float(jnp.max(jnp.abs(l0.astype(jnp.float32)
                                    - l1.astype(jnp.float32))))
        assert err < 1e-3

    def test_mha_dead_head_padding(self):
        import dataclasses
        cfg0 = dataclasses.replace(
            configs.get("qwen1.5-32b", smoke=True),
            n_heads=5, n_kv_heads=5, head_dim=16, d_model=80, d_ff=128)
        cfg1 = dataclasses.replace(cfg0, head_pad_multiple=4)
        assert lm._pad_geom(cfg1) == (8, 8, 1, 1)
        key = jax.random.PRNGKey(3)
        p0, p1 = lm.init_params(key, cfg0), lm.init_params(key, cfg1)
        x = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg0.vocab)
        l0, _ = lm.forward(p0, cfg0, x)
        l1, _ = lm.forward(p1, cfg1, x)
        err = float(jnp.max(jnp.abs(l0.astype(jnp.float32)
                                    - l1.astype(jnp.float32))))
        assert err < 1e-3

    def test_unsupported_geometry_noop(self):
        import dataclasses
        cfg = dataclasses.replace(configs.get("hymba-1.5b", smoke=True),
                                  head_pad_multiple=16)
        # kv=2 divides 16 -> supported here; force kv=5-like case:
        cfg = dataclasses.replace(cfg, n_heads=10, n_kv_heads=5)
        assert lm._pad_geom(cfg) is None  # 16 % 5 != 0 -> no-op
