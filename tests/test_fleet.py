"""Self-driving fleet (`repro.fleet`): manifests, policy, autopilot.

Five layers of coverage:

  * `FleetManifest`/`TenantSpec` as value objects: validation, JSON
    round-trip, order-insensitive identity, tau unit conversion, and the
    `.npz` bank-checkpoint round-trip behind `materialize`;
  * `apply_manifest` edge cases on a live service: tau-unit-only change
    (pure retune), evict + re-add of the same id in one apply (epoch
    bump), a checkpoint-path change forcing the bank reload, and the
    no-op manifest (zero transitions, zero retraces — jit cache size
    asserted);
  * the satellite bugfix: registry eviction debt is reclaimed by
    `compact()`, placement-invariant (served results and surviving banks
    bit-identical across the re-pack);
  * the policy as a pure function: per-rule unit tests from hand-built
    frozen views, purity/determinism property-tested, `RegistryView`
    JSON round-trip (what every logged `policy_decision` carries);
  * the autopilot loop: double-buffered rolling reshard (prepare between
    ticks, flip at a boundary, no drain, bit-identical), stale-buffer
    rejection, drained-responses FIFO contract (`take_drained`), and
    log-only reconstruction — replaying `explain` over the JSONL
    event log's frozen views reproduces the executed action stream.

The forced-mesh (2x2) flip runs as a subprocess, mirroring
`test_service_spec.TestForcedMeshControlPlane`.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.distributed import context
from repro.fleet import (Autopilot, FleetManifest, ManifestError, PolicySpec,
                         RegistryView, TenantSpec, decide, diff_manifests,
                         explain, load_bank, materialize, save_bank,
                         should_compact, view_of)
from repro.fleet import reshard as reshard_lib
from repro.match.config import EngineConfig
from repro.serve.acam_service import (ClassifyRequest, make_synthetic_tenant,
                                      sample_tenant_queries)
from repro.serve.control import HybridService, ReconfigureError
from repro.serve.registry import RegistryError, TemplateBankRegistry
from repro.serve.spec import (CascadeSpec, MeshSpec, ObsSpec, RegistrySpec,
                              SchedulerSpec, ServiceSpec)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
N = 64


def _spec(backend="reference", *, bank_shards=1, slots=16, tau=6.0,
          telemetry_dir=None, **engine_kw):
    return ServiceSpec(
        registry=RegistrySpec(num_features=N, initial_classes=256),
        engine=EngineConfig(backend=backend, margin=True, **engine_kw),
        mesh=MeshSpec(bank_shards=bank_shards, install=False),
        scheduler=SchedulerSpec(slots=slots),
        cascade=CascadeSpec(tau=tau, tau_units="count"),
        obs=ObsSpec(telemetry_dir=telemetry_dir),
    )


def _manifest(tenants=4, classes=40, **tenant_kw):
    """Seeds match `_protos`, so manifest-registered tenants serve the
    same queries as imperatively-registered ones."""
    return FleetManifest(tenants=tuple(
        TenantSpec(f"t{t}", seed=1000 + 17 * t, num_classes=classes,
                   **tenant_kw)
        for t in range(tenants)))


def _protos(tenants=4, classes=40):
    return {f"t{t}": make_synthetic_tenant(1000 + 17 * t,
                                           num_classes=classes,
                                           num_features=N)[2]
            for t in range(tenants)}


def _requests(protos, per_tenant=30, noise=0.9):
    reqs = []
    for i, (tid, p) in enumerate(sorted(protos.items())):
        f, _ = sample_tenant_queries(7 + i, p, per_tenant, noise=noise)
        reqs += [ClassifyRequest(tid, f[j]) for j in range(per_tenant)]
    return reqs


def _signature(responses):
    return [(r.tenant_id, r.pred, r.escalated, round(r.margin, 6))
            for r in responses]


@pytest.fixture
def no_mesh():
    saved_axes, saved_mesh = context.get(), context.get_mesh()
    context.clear()
    try:
        yield
    finally:
        context.clear()
        if saved_axes is not None:
            context.set_mesh_axes(saved_axes.dp, saved_axes.model,
                                  saved_mesh)


# ---------------------------------------------------------------------------
# Manifest value objects
# ---------------------------------------------------------------------------


class TestManifestValue:
    def test_json_roundtrip_and_hash(self):
        m = _manifest(3).validate()
        again = FleetManifest.from_json(m.to_json())
        assert again == m.normalized()
        assert hash(again) == hash(m.normalized())

    def test_order_insensitive_identity(self):
        a = _manifest(3)
        b = FleetManifest(tenants=tuple(reversed(a.tenants)))
        assert a != b  # raw tuples differ...
        assert a.normalized() == b.normalized()  # ...the identity doesn't

    def test_file_roundtrip(self, tmp_path):
        m = _manifest(2, tau=5.0, tau_units="count")
        path = tmp_path / "fleet.json"
        path.write_text(m.to_json())
        assert FleetManifest.from_file(str(path)) == m.normalized()

    def test_validate_rejects_bad_tenants(self, tmp_path):
        with pytest.raises(ManifestError, match="exactly one bank source"):
            TenantSpec("t", seed=1, checkpoint="x.npz").validate()
        with pytest.raises(ManifestError, match="exactly one bank source"):
            TenantSpec("t").validate()
        with pytest.raises(ManifestError, match="non-empty"):
            TenantSpec("", seed=1).validate()
        with pytest.raises(ManifestError, match="tau_units"):
            TenantSpec("t", seed=1, tau_units="volts").validate()
        with pytest.raises(ManifestError, match="tau must be"):
            TenantSpec("t", seed=1, tau=-1.0).validate()
        with pytest.raises(ManifestError, match="duplicate"):
            FleetManifest(tenants=(TenantSpec("t", seed=1),
                                   TenantSpec("t", seed=2))).validate()

    def test_tau_in_units(self):
        from repro.fleet import tau_in_units

        assert tau_in_units(None, "count", "fraction", N) is None
        assert tau_in_units(6.0, "count", "count", N) == 6.0
        assert tau_in_units(6.0, "count", "fraction", N) == \
            pytest.approx(6.0 / N)
        assert tau_in_units(0.1, "fraction", "count", N) == \
            pytest.approx(0.1 * N)


class TestManifestDiff:
    def test_add_evict_update_retune(self):
        old = _manifest(3)
        by = old.by_id()
        new = FleetManifest(tenants=(
            by["t0"],                                   # unchanged
            by["t1"]._replace(seed=999),                # bank source moved
            by["t2"]._replace(tau=3.0),                 # tau-only
            TenantSpec("t9", seed=5),                   # new
        ))
        d = diff_manifests(old, new)
        assert d.add == ("t9",)
        assert d.evict == ()
        assert d.update == ("t1",)
        assert d.retune == ("t2",)
        assert not d.empty

    def test_tau_units_only_change_is_retune(self):
        old = _manifest(1, tau=6.0, tau_units="count")
        new = FleetManifest(tenants=(
            old.tenants[0]._replace(tau=6.0 / N, tau_units="fraction"),))
        d = diff_manifests(old, new)
        assert d.retune == ("t0",) and not d.update and not d.add

    def test_epoch_bump_is_evict_plus_add(self):
        old = _manifest(2)
        new = FleetManifest(tenants=(
            old.tenants[0]._replace(epoch=1), old.tenants[1]))
        d = diff_manifests(old, new)
        assert d.evict == ("t0",) and d.add == ("t0",)

    def test_checkpoint_path_change_is_update(self):
        a = TenantSpec("t0", checkpoint="a.npz")
        d = diff_manifests(FleetManifest(tenants=(a,)),
                           FleetManifest(tenants=(
                               a._replace(checkpoint="b.npz"),)))
        assert d.update == ("t0",)

    def test_noop_diff_is_empty(self):
        m = _manifest(3)
        assert diff_manifests(m, m).empty
        assert diff_manifests(m.normalized(), m).empty


class TestBankCheckpoint:
    def test_save_load_roundtrip(self, tmp_path):
        bank, head, _ = make_synthetic_tenant(7, num_classes=12,
                                              num_features=N)
        path = str(tmp_path / "t.npz")
        save_bank(path, bank, head=head)
        loaded, lhead = load_bank(path)
        np.testing.assert_array_equal(np.asarray(bank.templates),
                                      loaded.templates)
        np.testing.assert_array_equal(np.asarray(bank.valid), loaded.valid)
        np.testing.assert_array_equal(np.asarray(head[0]), lhead[0])

    def test_load_headless_and_missing_fields(self, tmp_path):
        bank, _, _ = make_synthetic_tenant(7, num_classes=4,
                                           num_features=N)
        path = str(tmp_path / "t.npz")
        save_bank(path, bank)
        _, head = load_bank(path)
        assert head is None
        bad = str(tmp_path / "bad.npz")
        np.savez(bad, templates=np.zeros((1, 1, N)))
        with pytest.raises(ManifestError, match="missing arrays"):
            load_bank(bad)

    def test_materialize_seed_matches_fixture(self):
        t = TenantSpec("t0", seed=42, num_classes=8)
        bank, head = materialize(t, N)
        ref, ref_head, _ = make_synthetic_tenant(42, num_classes=8,
                                                 num_features=N)
        np.testing.assert_array_equal(np.asarray(bank.templates),
                                      np.asarray(ref.templates))
        assert head is not None
        assert materialize(t._replace(head=False), N)[1] is None

    def test_materialize_feature_mismatch(self, tmp_path):
        bank, _, _ = make_synthetic_tenant(7, num_classes=4,
                                           num_features=32)
        path = str(tmp_path / "t.npz")
        save_bank(path, bank)
        with pytest.raises(ManifestError, match="features"):
            materialize(TenantSpec("t0", checkpoint=path), N)


# ---------------------------------------------------------------------------
# apply_manifest on a live service (the satellite edge cases)
# ---------------------------------------------------------------------------


class TestApplyManifest:
    def test_initial_apply_registers_and_serves(self, no_mesh):
        svc = HybridService.from_spec(_spec())
        rep = svc.apply_manifest(_manifest())
        assert rep.added == ("t0", "t1", "t2", "t3")
        assert len(svc.registry) == 4
        sig = _signature(svc.serve(_requests(_protos())))
        assert any(s[2] for s in sig) and any(not s[2] for s in sig)

    def test_manifest_matches_imperative_registration(self, no_mesh):
        """A manifest-born fleet serves bit-identically to the same
        tenants registered by hand (same seeds, same placements)."""
        reqs = _requests(_protos())
        a = HybridService.from_spec(_spec())
        a.apply_manifest(_manifest())
        b = HybridService.from_spec(_spec())
        for t in range(4):
            bank, head, _ = make_synthetic_tenant(1000 + 17 * t,
                                                  num_classes=40,
                                                  num_features=N)
            b.register_tenant(f"t{t}", bank, head=head)
        assert _signature(a.serve(reqs)) == _signature(b.serve(reqs))

    def test_noop_apply_zero_transitions_zero_retraces(self, no_mesh):
        from repro.serve import scheduler as sched_lib

        svc = HybridService.from_spec(_spec())
        svc.apply_manifest(_manifest())
        reqs = _requests(_protos())
        base = _signature(svc.serve(reqs))  # compiles every bucket shape
        gen0 = svc.registry.generation
        size0 = sched_lib._batched_classify._cache_size()
        rep = svc.apply_manifest(_manifest())  # equal manifest, re-applied
        assert rep.empty
        assert rep.added == rep.evicted == rep.updated == rep.retuned == ()
        assert svc.registry.generation == gen0  # no device-cache bump
        assert _signature(svc.serve(reqs)) == base
        assert sched_lib._batched_classify._cache_size() == size0

    def test_tau_unit_change_retunes_without_reload(self, no_mesh):
        svc = HybridService.from_spec(_spec())
        svc.apply_manifest(_manifest(tau=6.0, tau_units="count"))
        reqs = _requests(_protos())
        base = _signature(svc.serve(reqs))
        gen0 = svc.registry.generation
        # the SAME threshold written in fraction units: a retune-only diff
        # that must not move the cascade (6 counts == 6/N fraction)
        rep = svc.apply_manifest(_manifest(tau=6.0 / N,
                                           tau_units="fraction"))
        assert rep.retuned == ("t0", "t1", "t2", "t3")
        assert rep.updated == () and rep.added == () and rep.evicted == ()
        assert svc.registry.generation == gen0  # registry untouched
        assert _signature(svc.serve(reqs)) == base
        # a genuinely different tau DOES move the cascade
        svc.apply_manifest(_manifest(tau=float(N), tau_units="count"))
        assert _signature(svc.serve(reqs)) != base

    def test_epoch_bump_evicts_and_readds_in_one_apply(self, no_mesh):
        svc = HybridService.from_spec(_spec())
        svc.apply_manifest(_manifest())
        reqs = _requests(_protos())
        base = _signature(svc.serve(reqs))
        m = _manifest()
        bumped = FleetManifest(tenants=(
            m.tenants[0]._replace(epoch=1),) + m.tenants[1:])
        rep = svc.apply_manifest(bumped)
        assert rep.evicted == ("t0",) and rep.added == ("t0",)
        assert len(svc.registry) == 4  # same population after the cycle
        assert _signature(svc.serve(reqs)) == base  # same bank, same result

    def test_checkpoint_path_change_forces_bank_reload(self, no_mesh,
                                                       tmp_path):
        bank_a, head_a, proto_a = make_synthetic_tenant(
            11, num_classes=12, num_features=N)
        bank_b, head_b, _ = make_synthetic_tenant(
            22, num_classes=12, num_features=N)
        pa, pb = str(tmp_path / "a.npz"), str(tmp_path / "b.npz")
        save_bank(pa, bank_a, head=head_a)
        save_bank(pb, bank_b, head=head_b)
        svc = HybridService.from_spec(_spec())
        svc.apply_manifest(FleetManifest(tenants=(
            TenantSpec("t0", checkpoint=pa),)))
        np.testing.assert_array_equal(
            np.asarray(svc.registry.bank_of("t0").templates),
            np.asarray(bank_a.templates))
        rep = svc.apply_manifest(FleetManifest(tenants=(
            TenantSpec("t0", checkpoint=pb),)))
        assert rep.updated == ("t0",)
        np.testing.assert_array_equal(
            np.asarray(svc.registry.bank_of("t0").templates),
            np.asarray(bank_b.templates))

    def test_apply_adopts_imperatively_registered_tenants(self, no_mesh):
        svc = HybridService.from_spec(_spec())
        bank, head, _ = make_synthetic_tenant(1000, num_classes=40,
                                              num_features=N)
        svc.register_tenant("t0", bank, head=head)
        rep = svc.apply_manifest(_manifest(1))  # same t0, declared now
        assert rep.added == ("t0",)  # adopted via the hot update path
        assert len(svc.registry) == 1

    def test_validate_runs_at_apply(self, no_mesh):
        svc = HybridService.from_spec(_spec())
        with pytest.raises(ManifestError):
            svc.apply_manifest(FleetManifest(tenants=(TenantSpec("x"),)))


# ---------------------------------------------------------------------------
# Compaction (the eviction-debt bugfix)
# ---------------------------------------------------------------------------


class TestCompaction:
    def test_eviction_never_reclaimed_then_compact_does(self):
        reg = TemplateBankRegistry(N, class_bucket=16, initial_classes=128,
                                   bank_shards=1)
        for t in range(6):  # 6 x 48 rows: grows 128 -> 256 -> 512
            bank, _, _ = make_synthetic_tenant(600 + t, num_classes=40,
                                               num_features=N)
            reg.register(f"t{t}", bank)
        assert reg.capacity_classes == 512
        for t in (0, 1, 2, 3):
            reg.evict(f"t{t}")
        # the bug: eviction frees buckets but capacity never shrinks
        assert reg.capacity_classes == 512
        banks_before = {t: np.asarray(reg.bank_of(t).templates)
                        for t in ("t4", "t5")}
        freed = reg.compact()
        assert freed > 0
        assert reg.capacity_classes == 96  # 2 x 48 rows re-packed tight
        for t in ("t4", "t5"):
            np.testing.assert_array_equal(
                np.asarray(reg.bank_of(t).templates), banks_before[t])

    def test_compact_noop_when_tight(self):
        reg = TemplateBankRegistry(N, class_bucket=16, initial_classes=128,
                                   bank_shards=1)
        for t in range(2):  # 2 x 64 rows: capacity fully used
            bank, _, _ = make_synthetic_tenant(1 + t, num_classes=64,
                                               num_features=N)
            reg.register(f"t{t}", bank)
        assert reg.compact() == 0
        assert reg.capacity_classes == 128
        # unused initial slack IS reclaimable, even with no eviction debt
        half = TemplateBankRegistry(N, class_bucket=16, initial_classes=128,
                                    bank_shards=1)
        bank, _, _ = make_synthetic_tenant(9, num_classes=40,
                                           num_features=N)
        half.register("t0", bank)
        assert half.compact() == 80
        assert half.capacity_classes == 48

    def test_placement_invariant_round_trip(self, no_mesh):
        """The acceptance shape: register -> evict -> compact -> serve is
        bit-identical to never having had the evicted tenants at all."""
        svc = HybridService.from_spec(_spec())
        svc.apply_manifest(_manifest(6))
        for t in (1, 4):
            svc.evict_tenant(f"t{t}")
        survivors = {t: p for t, p in _protos(6).items()
                     if t not in ("t1", "t4")}
        reqs = _requests(survivors)
        before = _signature(svc.serve(reqs))
        cap0 = svc.registry.capacity_classes
        freed = svc.compact_registry()
        assert freed > 0 and svc.registry.capacity_classes < cap0
        assert _signature(svc.serve(reqs)) == before
        # re-registering an evicted tenant lands in the compacted bank
        svc.apply_manifest(_manifest(6))
        assert _signature(svc.serve(reqs)) == before


# ---------------------------------------------------------------------------
# Double-buffered rolling reshard
# ---------------------------------------------------------------------------


class TestRollingReshard:
    def _boot(self):
        svc = HybridService.from_spec(_spec())
        svc.apply_manifest(_manifest())
        return svc, _requests(_protos())

    def test_flip_no_drain_bit_identity(self, no_mesh):
        """The tentpole contract: queued work rides across the flip
        untouched, and the flipped bank serves bit-identically to the
        drained `reconfigure` transition."""
        svc, reqs = self._boot()
        base = _signature(svc.serve(reqs))

        # the drained alternative on a twin service
        twin = HybridService.from_spec(_spec())
        twin.apply_manifest(_manifest())
        twin.serve(reqs)
        for r in reqs[:16]:
            twin.submit(r)
        twin_report = twin.reconfigure(twin.spec._replace(
            mesh=twin.spec.mesh._replace(bank_shards=2)))
        drained_then = _signature(twin_report.drained) \
            + _signature(twin.serve(reqs[16:]))

        # the rolling path: queue the same burst, flip, then serve
        for r in reqs[:16]:
            svc.submit(r)
        prep = reshard_lib.prepare(svc, svc.spec._replace(
            mesh=svc.spec.mesh._replace(bank_shards=2)))
        report = svc.rolling_reshard(prep.spec, prepared=prep)
        assert report.drained == []  # NO drain: that's the point
        assert svc.registry.bank_shards == 2
        flipped = []
        while svc.scheduler.qsize:
            flipped.extend(svc.step())
        rolled = _signature(flipped) + _signature(svc.serve(reqs[16:]))
        assert rolled == drained_then
        assert _signature(svc.serve(reqs)) == base

    def test_prepare_rejects_non_mesh_deltas(self, no_mesh):
        svc, _ = self._boot()
        with pytest.raises(ReconfigureError, match="rolling reshard"):
            reshard_lib.prepare(svc, svc.spec._replace(
                engine=svc.spec.engine._replace(backend="kernel"),
                mesh=svc.spec.mesh._replace(bank_shards=2)))

    def test_stale_buffer_rejected(self, no_mesh):
        svc, _ = self._boot()
        prep = reshard_lib.prepare(svc, svc.spec._replace(
            mesh=svc.spec.mesh._replace(bank_shards=2)))
        assert not prep.stale
        # tenant churn between prepare and flip invalidates the buffer
        bank, head, _ = make_synthetic_tenant(9999, num_classes=8,
                                              num_features=N)
        svc.register_tenant("late", bank, head=head)
        assert prep.stale
        with pytest.raises(RegistryError, match="re-prepare"):
            svc.rolling_reshard(prep.spec, prepared=prep)
        assert svc.registry.bank_shards == 1  # live bank untouched

    def test_rolling_reshard_prepares_inline_when_not_given(self, no_mesh):
        svc, reqs = self._boot()
        base = _signature(svc.serve(reqs))
        report = svc.rolling_reshard(svc.spec._replace(
            mesh=svc.spec.mesh._replace(bank_shards=2)))
        assert report.drained == [] and svc.spec.mesh.bank_shards == 2
        assert _signature(svc.serve(reqs)) == base


# ---------------------------------------------------------------------------
# The policy: pure function from frozen telemetry to the next spec
# ---------------------------------------------------------------------------


def _view(spec=None, **kw):
    spec = spec or _spec()
    base = dict(spec=spec, tenants=4, shard_rows_used=(128,),
                rows_per_shard=256, capacity_classes=256,
                fused_rows_per_shard=512, vmem_budget_rows=2048,
                queue_depth=0, p99_ms=1.0, rolling_fill=8.0, slots=16,
                devices=4, backend_j=1e-7, frontend_j=1e-5)
    base.update(kw)
    return RegistryView(**base)


class TestPolicy:
    def test_hold_below_every_threshold(self):
        v = _view()
        action, reason, spec = explain(v)
        assert action == "hold" and spec == v.spec
        assert decide(v) == v.spec

    def test_escalate_on_row_pressure(self):
        v = _view(shard_rows_used=(224,))  # 224/256 = 0.875 >= 0.75
        action, reason, spec = explain(v)
        assert action == "escalate_shards"
        assert spec.mesh.bank_shards == 2
        assert "fullest shard" in reason
        spec.validate()  # proposed spec is always a valid spec

    def test_escalate_on_vmem_pressure(self):
        v = _view(fused_rows_per_shard=2048)  # at MAX_FUSED_ROWS
        action, _, spec = explain(v)
        assert action == "escalate_shards" and spec.mesh.bank_shards == 2

    def test_escalation_respects_device_divisibility(self):
        inst = _spec()._replace(mesh=MeshSpec(bank_shards=4, install=True))
        v = _view(spec=inst, shard_rows_used=(64, 64, 64, 60),
                  rows_per_shard=64)
        # doubling to 8 shards needs 8 | devices: held at 4 devices...
        assert explain(v)[0] == "hold"
        # ...allowed at 8, capped by max_bank_shards regardless
        assert explain(_view(spec=inst, shard_rows_used=(64,) * 4,
                             rows_per_shard=64, devices=8)
                       )[0] == "escalate_shards"
        assert explain(_view(spec=inst, shard_rows_used=(64,) * 4,
                             rows_per_shard=64, devices=8),
                       PolicySpec(max_bank_shards=4))[0] == "hold"

    def test_swap_backend_when_ledger_dominated(self):
        v = _view(spec=_spec("kernel"), backend_j=9.5e-6, frontend_j=5e-7)
        action, reason, spec = explain(v)
        assert action == "swap_backend"
        assert spec.engine.backend == "device"
        assert spec.engine.device_noise == "per_shard"  # shard-legal
        # already on the device backend: nothing to swap
        assert explain(_view(spec=_spec("device"), backend_j=9.5e-6,
                             frontend_j=5e-7))[0] == "hold"
        # below the energy floor the ledger is ignored
        assert explain(v, PolicySpec(min_energy_j=1.0))[0] == "hold"

    def test_widen_slots_under_saturation(self):
        v = _view(rolling_fill=16.0, queue_depth=64)
        action, _, spec = explain(v)
        assert action == "widen_slots" and spec.scheduler.slots == 32
        # saturation without a queue is steady state, not pressure
        assert explain(_view(rolling_fill=16.0, queue_depth=8))[0] == "hold"
        # at the slot ceiling there is nothing to widen
        assert explain(_view(rolling_fill=16.0, queue_depth=64),
                       PolicySpec(max_slots=16))[0] == "hold"

    def test_priority_order_is_fixed(self):
        # row pressure AND saturation: shards win (rule 1 before rule 3)
        v = _view(shard_rows_used=(224,), rolling_fill=16.0,
                  queue_depth=64)
        assert explain(v)[0] == "escalate_shards"

    def test_should_compact(self):
        assert should_compact(_view(shard_rows_used=(64,),
                                    capacity_classes=256))
        assert not should_compact(_view(shard_rows_used=(224,),
                                        capacity_classes=256))
        # minimal aligned capacity: nothing to give back
        assert not should_compact(_view(shard_rows_used=(4,),
                                        capacity_classes=16))

    def test_view_json_roundtrip(self):
        v = _view(shard_rows_used=(96, 128), rows_per_shard=128)
        d = json.loads(json.dumps(v.to_dict()))
        assert RegistryView.from_dict(d) == v

    def test_view_of_reads_only_health(self, no_mesh):
        svc = HybridService.from_spec(_spec())
        svc.apply_manifest(_manifest())
        v = view_of(svc)
        h = svc.health()
        assert v.tenants == h["tenants"] == 4
        assert v.shard_rows_used == tuple(h["shard_rows_used"])
        assert v.capacity_classes == h["capacity_classes"]
        assert v.vmem_budget_rows == h["vmem_budget_rows"]
        assert v.spec == svc.spec

    @settings(max_examples=50, deadline=None)
    @given(used=st.integers(0, 256), queue=st.integers(0, 512),
           fill=st.floats(0.0, 16.0), fused=st.integers(0, 4096),
           backend_j=st.floats(0.0, 1e-4), devices=st.integers(1, 16))
    def test_decide_pure_and_deterministic(self, used, queue, fill, fused,
                                           backend_j, devices):
        """The acceptance property: same frozen view + policy in, same
        spec out, no mutation, and the proposal is always a valid spec
        drawn from the fixed action set."""
        v = _view(spec=_spec("kernel"), shard_rows_used=(used,),
                  queue_depth=queue, rolling_fill=fill,
                  fused_rows_per_shard=fused, backend_j=backend_j,
                  devices=devices)
        pol = PolicySpec()
        first, second = explain(v, pol), explain(v, pol)
        assert first == second
        assert decide(v, pol) == first[2]
        assert first[0] in ("hold", "escalate_shards", "swap_backend",
                            "widen_slots")
        first[2].validate()
        # the view the decision was logged with replays identically
        assert explain(RegistryView.from_dict(
            json.loads(json.dumps(v.to_dict()))), pol) == first


# ---------------------------------------------------------------------------
# Autopilot
# ---------------------------------------------------------------------------


class TestAutopilot:
    def _drive(self, svc, pilot, reqs, burst=8):
        responses, executed, i = [], [], 0
        while i < len(reqs) or svc.scheduler.qsize:
            for r in reqs[i:i + burst]:
                svc.submit(r)
            i += burst
            responses.extend(svc.step())
            act = pilot.observe_tick()
            if act:
                executed.append(act)
            responses.extend(pilot.take_drained())
        return responses, executed

    def test_escalates_via_buffer_flip_and_reconstructs(self, no_mesh,
                                                        tmp_path):
        """End-to-end: row pressure -> escalate_shards (shadow prepared
        between ticks) -> buffer_flip at the next boundary, bit-identical
        to a pinned service — and the whole action stream reconstructs
        from the JSONL event log alone."""
        from repro.obs import read_events

        reqs = _requests(_protos(), per_tenant=40)
        pinned = HybridService.from_spec(_spec())
        pinned.apply_manifest(_manifest())
        base = _signature(pinned.serve(reqs))

        svc = HybridService.from_spec(_spec(
            telemetry_dir=str(tmp_path)))
        svc.apply_manifest(_manifest())  # 192/256 rows: at the threshold
        pol = PolicySpec(interval=2, hysteresis=1, cooldown=4,
                         max_bank_shards=4)
        pilot = Autopilot(svc, policy=pol)
        responses, executed = self._drive(svc, pilot, reqs)

        assert "escalate_shards" in executed
        assert "buffer_flip" in executed
        assert svc.registry.bank_shards > 1
        assert _signature(responses) == base

        events = read_events(svc.obs.events.path)
        flips = [e for e in events if e["kind"] == "buffer_flip"]
        decisions = [e for e in events if e["kind"] == "policy_decision"]
        assert len(flips) == executed.count("buffer_flip")
        assert len(decisions) == len(pilot.actions)
        # log-only reconstruction: replay the pure policy over each
        # logged frozen view; the action stream must match exactly
        for e, recorded in zip(decisions, pilot.actions):
            view = RegistryView.from_dict(e["view"])
            act = explain(view, pol)[0]
            if act == "hold" and should_compact(view, pol):
                act = "compact"
            assert act == e["action"] == recorded["action"]
            assert e["tick"] == recorded["tick"]

    def test_hysteresis_and_cooldown_gate_actions(self, no_mesh):
        svc = HybridService.from_spec(_spec())
        svc.apply_manifest(_manifest())  # at the escalation threshold
        pol = PolicySpec(interval=1, hysteresis=3, cooldown=100,
                         max_bank_shards=4)
        pilot = Autopilot(svc, policy=pol)
        assert pilot.observe_tick() is None  # streak 1
        assert pilot.observe_tick() is None  # streak 2
        assert pilot.observe_tick() == "escalate_shards"  # streak 3: act
        assert pilot.observe_tick() == "buffer_flip"  # pending flip lands
        # cooldown: no further evaluation despite standing pressure
        for _ in range(10):
            assert pilot.observe_tick() is None

    def test_widen_slots_drains_through_take_drained(self, no_mesh):
        """The FIFO contract around drained reconfigures: every submitted
        request surfaces exactly once, in submission order."""
        svc = HybridService.from_spec(_spec(slots=4))
        svc.apply_manifest(_manifest(2, classes=10))  # low occupancy
        reqs = _requests(_protos(2, classes=10), per_tenant=40)
        pinned = HybridService.from_spec(_spec(slots=4))
        pinned.apply_manifest(_manifest(2, classes=10))
        base = _signature(pinned.serve(reqs))

        pol = PolicySpec(interval=2, hysteresis=1, cooldown=4)
        pilot = Autopilot(svc, policy=pol)
        # flood the queue so rule 3 fires (fill saturated + queue standing)
        responses, executed = self._drive(svc, pilot, reqs, burst=20)
        assert "widen_slots" in executed
        assert svc.spec.scheduler.slots > 4
        assert _signature(responses) == base

    def test_stale_pending_reprepared_after_churn(self, no_mesh):
        svc = HybridService.from_spec(_spec())
        svc.apply_manifest(_manifest())
        pol = PolicySpec(interval=1, hysteresis=1, cooldown=2,
                         max_bank_shards=4)
        pilot = Autopilot(svc, policy=pol)
        assert pilot.observe_tick() == "escalate_shards"  # shadow prepared
        # churn lands between prepare and flip: the buffer goes stale
        bank, head, _ = make_synthetic_tenant(4242, num_classes=8,
                                              num_features=N)
        svc.register_tenant("late", bank, head=head)
        assert pilot.observe_tick() is None  # stale: re-prepared, no flip
        assert pilot.observe_tick() == "buffer_flip"  # fresh buffer lands
        assert svc.registry.bank_shards == 2
        assert "late" in svc.registry


# ---------------------------------------------------------------------------
# health() carries the controller inputs (satellite 1)
# ---------------------------------------------------------------------------


class TestHealthControllerInputs:
    def test_fleet_fields_present_and_consistent(self, no_mesh):
        svc = HybridService.from_spec(_spec())
        svc.apply_manifest(_manifest())
        svc.serve(_requests(_protos(), per_tenant=8))
        h = svc.health()
        assert h["tenants"] == 4
        assert h["bank_shards"] == 1
        assert len(h["shard_rows_used"]) == 1
        assert sum(h["shard_rows_used"]) == 4 * 48  # 40 -> 48-row buckets
        assert h["rows_per_shard"] == h["capacity_classes"] == 256
        assert h["vmem_budget_rows"] == 2048
        assert h["fused_rows_per_shard"] > 0
        assert h["rolling_batch_fill"] > 0
        assert h["slots"] == 16 and h["devices"] >= 1
        assert h["p99_ms"] >= 0
        assert h["energy_backend_j"] > 0
        assert h["energy_frontend_j"] >= 0

    def test_shard_rows_used_splits_by_shard(self):
        reg = TemplateBankRegistry(N, class_bucket=16, initial_classes=256,
                                   bank_shards=2)
        bank, _, _ = make_synthetic_tenant(5, num_classes=40,
                                           num_features=N)
        reg.register("t0", bank)
        used = reg.shard_rows_used()
        assert len(used) == 2
        assert sum(used) == 48 and used[0] == 48  # first-fit: shard 0


# ---------------------------------------------------------------------------
# Forced 2x2 mesh: the flip under a real (data, model) mesh
# ---------------------------------------------------------------------------


def run_sub(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FORCE_MESH", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


class TestForcedMeshRollingReshard:
    def test_flip_bit_identity_on_2x2(self):
        """The tentpole acceptance under a real mesh: the double-buffered
        flip 1 -> 2 shards re-installs the (data, model) mesh with NO
        drain and serves bit-identically."""
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=4"
            from repro import match
            from repro.fleet import FleetManifest, TenantSpec
            from repro.fleet import reshard as reshard_lib
            from repro.match.config import EngineConfig
            from repro.serve.acam_service import (ClassifyRequest,
                                                  make_synthetic_tenant,
                                                  sample_tenant_queries)
            from repro.serve.control import HybridService
            from repro.serve.spec import (CascadeSpec, MeshSpec,
                                          RegistrySpec, SchedulerSpec,
                                          ServiceSpec)

            spec = ServiceSpec(
                registry=RegistrySpec(num_features=64, initial_classes=256),
                engine=EngineConfig(backend="kernel", margin=True),
                mesh=MeshSpec(bank_shards=1),  # install=True: spec owns it
                scheduler=SchedulerSpec(slots=16),
                cascade=CascadeSpec(tau=6.0, tau_units="count"))
            svc = HybridService.from_spec(spec)
            svc.apply_manifest(FleetManifest(tenants=tuple(
                TenantSpec(f"t{t}", seed=1000 + 17 * t, num_classes=40)
                for t in range(4))))
            protos = {f"t{t}": make_synthetic_tenant(
                          1000 + 17 * t, num_classes=40,
                          num_features=64)[2] for t in range(4)}
            reqs = []
            for i, (tid, p) in enumerate(sorted(protos.items())):
                f, _ = sample_tenant_queries(7 + i, p, 24, noise=0.9)
                reqs += [ClassifyRequest(tid, f[j]) for j in range(24)]
            sig = lambda rs: [(r.tenant_id, r.pred, r.escalated,
                               round(r.margin, 6)) for r in rs]
            base = sig(svc.serve(reqs))
            assert match.bank_shards_in_mesh() == 1

            for r in reqs[:16]:
                svc.submit(r)
            prep = reshard_lib.prepare(svc, spec._replace(
                mesh=MeshSpec(bank_shards=2)))
            report = svc.rolling_reshard(prep.spec, prepared=prep)
            assert report.drained == []          # no drain across the flip
            assert match.bank_shards_in_mesh() == 2
            assert svc.registry.bank_shards == 2
            flipped = []
            while svc.scheduler.qsize:
                flipped.extend(svc.step())
            assert sig(flipped) == base[:16]     # queued work, sharded bank
            assert sig(svc.serve(reqs)) == base  # full stream bit-identity
            plan, _ = match.plan_for(
                batch=16, num_classes=svc.registry.capacity_classes)
            assert plan.bank_shards == 2, plan
            print("OK flip", report.downtime_s)
            """, timeout=900)
        assert "OK flip" in out
