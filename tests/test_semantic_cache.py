"""ACAM semantic cache router: spec plumbing, featurizers, hit/miss
routing, energy attribution, durability, live backend swaps."""
import tempfile

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve import spec as spec_lib
from repro.serve.engine import Engine, Request
from repro.serve.semantic_cache import (PromptRequest, ResponseStore,
                                        SemanticCacheService,
                                        embedding_featurizer,
                                        hashing_featurizer,
                                        synthetic_prompt_trace)

N_FEATURES = 64


def make_spec(**router_kw):
    router_kw.setdefault("max_templates", 8)
    router_kw.setdefault("response_capacity", 16)
    return spec_lib.ServiceSpec(
        registry=spec_lib.RegistrySpec(num_features=N_FEATURES),
        scheduler=spec_lib.SchedulerSpec(slots=8),
        cascade=spec_lib.CascadeSpec(backend="lm", tau=8.0,
                                     tau_units="count"),
        router=spec_lib.RouterSpec(**router_kw),
        mesh=spec_lib.MeshSpec(install=False))


@pytest.fixture(scope="module")
def lm_stack():
    cfg = configs.get("tinyllama-1.1b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_engine(cfg, params, **kw):
    kw.setdefault("batch_size", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("temperature", 0.7)
    return Engine(cfg, params, **kw)


class TestFeaturizers:
    def test_hashing_deterministic_and_seeded(self):
        f1 = hashing_featurizer(N_FEATURES, seed=3)
        f2 = hashing_featurizer(N_FEATURES, seed=3)
        f3 = hashing_featurizer(N_FEATURES, seed=4)
        p = np.arange(12, dtype=np.int32)
        np.testing.assert_array_equal(f1(p), f2(p))
        assert not np.array_equal(f1(p), f3(p))

    def test_hashing_separates_short_prompts(self):
        # dense per-gram signatures: even 2-token prompts must not
        # collide past the hit_score floor after binarisation
        f = hashing_featurizer(N_FEATURES, seed=0)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, 512, size=2) for _ in range(20)]
        bits = np.stack([(f(p) > 0).astype(np.float32) for p in prompts])
        agree = bits @ bits.T + (1 - bits) @ (1 - bits).T
        off = agree[~np.eye(len(prompts), dtype=bool)]
        assert off.max() < 0.9 * N_FEATURES

    def test_embedding_featurizer_shapes(self, lm_stack):
        cfg, params = lm_stack
        f = embedding_featurizer(np.asarray(params["embed"]),
                                 num_features=N_FEATURES, seed=0)
        v = f(np.arange(5))
        assert v.shape == (N_FEATURES,) and v.dtype == np.float32


class TestRouterSpec:
    def test_json_round_trip(self):
        spec = make_spec(hit_score=0.8, admit_on_miss=False,
                         featurizer="embedding", featurizer_seed=3)
        again = spec_lib.ServiceSpec.from_json(spec.to_json())
        assert again == spec
        assert again.router.hit_score == 0.8
        assert again.cascade.backend == "lm"

    def test_from_dict_defaults_router(self):
        d = make_spec().to_dict()
        del d["router"]
        spec = spec_lib.ServiceSpec.from_dict(d)
        assert spec.router == spec_lib.RouterSpec()

    def test_lm_backend_rejects_shed(self):
        spec = make_spec()._replace(
            cascade=spec_lib.CascadeSpec(backend="lm", shed_queue=10))
        with pytest.raises(ValueError, match="shed"):
            spec.validate()

    def test_bad_backend_and_hit_score(self):
        with pytest.raises(ValueError, match="backend"):
            make_spec()._replace(cascade=spec_lib.CascadeSpec(
                backend="gpu")).validate()
        with pytest.raises(ValueError, match="hit_score"):
            make_spec(hit_score=1.5).validate()
        with pytest.raises(ValueError, match="response_capacity"):
            make_spec(response_capacity=4, max_templates=8).validate()


class TestResponseStore:
    def test_lru_eviction_and_state_round_trip(self):
        s = ResponseStore(2)
        assert s.put(("a", 0), (1,)) == []
        assert s.put(("a", 1), (2,)) == []
        s.get(("a", 0))  # refresh: row 1 becomes LRU
        assert s.put(("a", 2), (3,)) == [("a", 1)]
        assert s.oldest_row("a") == 0
        s2 = ResponseStore(2)
        s2.load_state(s.state())
        assert s2.state() == s.state()
        assert s2.put(("a", 3), (4,)) == [("a", 0)]  # order survived


class TestRouting:
    def test_miss_admit_hit_replay(self, lm_stack):
        cfg, params = lm_stack
        svc = SemanticCacheService.from_spec(
            make_spec(), engine=make_engine(cfg, params))
        svc.add_tenant("edge-0")
        trace = synthetic_prompt_trace(7, vocab=cfg.vocab, n_unique=4,
                                       n_requests=12)
        out = svc.serve_prompts(PromptRequest("edge-0", p, max_new_tokens=6)
                                for p in trace)
        hits = [r for r in out if r.cache_hit]
        misses = [r for r in out if not r.cache_hit and r.error is None]
        # slots=8: tick 1 serves 8 cold requests (within-tick repeats
        # dedupe on admit), tick 2's 4 repeats all hit
        assert len(misses) == 8 and len(hits) == 4
        decoded = {r.template_id: r.tokens for r in misses}
        for r in hits:
            assert r.tokens == decoded[r.template_id]
            assert r.score >= 0.9 * N_FEATURES  # exact match
        m = svc.metrics()
        assert m["classify_dispatches"] == m["ticks"]  # ONE fused dispatch
        ev = svc.obs.cache_events
        assert ev.value(event="hit") == len(hits)
        assert ev.value(event="miss") == len(misses)
        assert ev.value(event="insert") == 4  # deduped, not 8

    def test_energy_ledger_bit_exact_and_asymmetric(self, lm_stack):
        cfg, params = lm_stack
        svc = SemanticCacheService.from_spec(
            make_spec(), engine=make_engine(cfg, params))
        svc.add_tenant("edge-0")
        trace = synthetic_prompt_trace(3, vocab=cfg.vocab, n_unique=2,
                                       n_requests=8)
        # two bursts: burst 1 admits the uniques, burst 2's repeats hit
        out = svc.serve_prompts(PromptRequest("edge-0", p, max_new_tokens=6)
                                for p in trace[:2])
        out += svc.serve_prompts(PromptRequest("edge-0", p, max_new_tokens=6)
                                 for p in trace[2:])
        assert abs(sum(r.energy_j for r in out)
                   - svc.obs.ledger.fleet_j()) < 1e-18
        hit_j = max(r.energy_j for r in out if r.cache_hit)
        miss_j = min(r.energy_j for r in out if not r.cache_hit)
        assert miss_j > 100 * hit_j  # the paper's asymmetry, LM-sized

    def test_disabled_cache_bit_identical_to_bare_engine(self, lm_stack):
        cfg, params = lm_stack
        trace = synthetic_prompt_trace(5, vocab=cfg.vocab, n_unique=4,
                                       n_requests=4)
        svc = SemanticCacheService.from_spec(
            make_spec(enabled=False),
            engine=make_engine(cfg, params, batch_size=8))
        svc.add_tenant("edge-0")
        out = svc.serve_prompts(PromptRequest("edge-0", p, max_new_tokens=6)
                                for p in trace)
        assert not any(r.cache_hit for r in out)
        ref_eng = make_engine(cfg, params, batch_size=8)
        refs = ref_eng.generate([Request(prompt=p, max_new_tokens=6)
                                 for p in trace])
        assert [list(r.tokens) for r in out] == [r.out for r in refs]

    def test_template_churn_under_tiny_bank(self, lm_stack):
        cfg, params = lm_stack
        svc = SemanticCacheService.from_spec(
            make_spec(max_templates=2, response_capacity=2),
            engine=make_engine(cfg, params))
        svc.add_tenant("edge-0")
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, size=10).astype(np.int32)
                   for _ in range(5)]
        for p in prompts:  # sequential: each tick = one distinct prompt
            (r,) = svc.serve_prompts([PromptRequest("edge-0", p,
                                                    max_new_tokens=4)])
            assert not r.cache_hit
        ev = svc.obs.cache_events
        assert ev.value(event="insert") == 5
        assert ev.value(event="evict") == 3  # 5 inserts into 2 rows
        assert len(svc._store) <= 2
        # the survivors still hit
        (r,) = svc.serve_prompts([PromptRequest("edge-0", prompts[-1],
                                                max_new_tokens=4)])
        assert r.cache_hit

    def test_cold_tenant_never_fabricates_hit(self, lm_stack):
        cfg, params = lm_stack
        svc = SemanticCacheService.from_spec(
            make_spec(admit_on_miss=False),
            engine=make_engine(cfg, params))
        svc.add_tenant("edge-0")
        trace = synthetic_prompt_trace(1, vocab=cfg.vocab, n_unique=2,
                                       n_requests=6)
        out = svc.serve_prompts(PromptRequest("edge-0", p, max_new_tokens=4)
                                for p in trace)
        assert not any(r.cache_hit for r in out)  # nothing ever admitted
        assert svc.obs.cache_events.value(event="insert") == 0


class TestDurability:
    def test_snapshot_restore_engine_less_hits(self, lm_stack):
        cfg, params = lm_stack
        svc = SemanticCacheService.from_spec(
            make_spec(), engine=make_engine(cfg, params))
        svc.add_tenant("edge-0")
        trace = synthetic_prompt_trace(11, vocab=cfg.vocab, n_unique=3,
                                       n_requests=6)
        out = svc.serve_prompts(PromptRequest("edge-0", p, max_new_tokens=5)
                                for p in trace)
        decoded = {r.template_id: r.tokens for r in out if not r.cache_hit}
        from repro.checkpoint.checkpointer import Checkpointer

        with tempfile.TemporaryDirectory() as d:
            svc.snapshot(Checkpointer(d))
            svc2, report = SemanticCacheService.restore(Checkpointer(d))
            # template bank + response store round-trip bit-identically
            assert svc2._store.state() == svc._store.state()
            s1, s2 = svc._templates["edge-0"], svc2._templates["edge-0"]
            np.testing.assert_array_equal(s1.bits, s2.bits)
            np.testing.assert_array_equal(s1.valid, s2.valid)
            # hits serve with NO engine attached
            replay = svc2.serve_prompts(
                PromptRequest("edge-0", p, max_new_tokens=5)
                for p in trace[:3])
            assert all(r.cache_hit for r in replay)
            assert [r.tokens for r in replay] == \
                [decoded[r.template_id] for r in replay]

    def test_restored_miss_without_engine_raises(self, lm_stack):
        cfg, params = lm_stack
        svc = SemanticCacheService.from_spec(
            make_spec(), engine=make_engine(cfg, params))
        svc.add_tenant("edge-0")
        from repro.checkpoint.checkpointer import Checkpointer

        with tempfile.TemporaryDirectory() as d:
            svc.snapshot(Checkpointer(d))
            svc2, _ = SemanticCacheService.restore(Checkpointer(d))
            svc2.submit_prompt(PromptRequest(
                "edge-0", np.arange(8, dtype=np.int32)))
            with pytest.raises(RuntimeError, match="decode engine"):
                svc2.step_routed()


class TestBackendSwap:
    def test_cnn_lm_swap_drains_queued_work_both_ways(self, lm_stack):
        from repro.serve.acam_service import (ClassifyRequest,
                                              make_synthetic_tenant,
                                              sample_tenant_queries)

        cfg, params = lm_stack
        spec = make_spec()
        svc = SemanticCacheService.from_spec(
            spec, engine=make_engine(cfg, params))
        svc.add_tenant("edge-0")
        trace = synthetic_prompt_trace(2, vocab=cfg.vocab, n_unique=3,
                                       n_requests=3)
        for p in trace:
            svc.submit_prompt(PromptRequest("edge-0", p, max_new_tokens=4))
        # lm -> cnn: the queued prompts drain under the OLD (lm) backend
        cnn = spec._replace(cascade=spec.cascade._replace(backend="cnn"))
        report = svc.reconfigure(cnn)
        routed = svc.collect_routed(report.drained)
        assert len(routed) == 3 and all(r.error is None for r in routed)
        assert all(len(r.tokens) == 4 for r in routed)
        assert svc.spec.cascade.backend == "cnn"
        # cnn -> lm with queued classify traffic
        bank, head, protos = make_synthetic_tenant(
            3, num_features=N_FEATURES)
        svc.register_tenant("clf-0", bank, head=head)
        feats, _ = sample_tenant_queries(4, protos, 3)
        for f in feats:
            svc.submit(ClassifyRequest("clf-0", f))
        report2 = svc.reconfigure(spec)
        assert len(report2.drained) == 3
        assert all(r.error is None for r in report2.drained)
        assert svc.spec.cascade.backend == "lm"
