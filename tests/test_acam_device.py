"""Device-model coverage for `repro.core.acam` (paper §III).

Previously untested surfaces: the 3T1R precharging cell's dual-rail
behavioural model, the `sigma_program` RRAM-variability path, and the
differentiable (sigmoid-windowed) surrogate used for template calibration.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acam


def _programmed(key, rows=6, cells=32, *, cell="3T1R", sigma=0.0,
                with_key=True):
    k1, k2, k3 = jax.random.split(key, 3)
    lo = jax.random.uniform(k1, (rows, cells), minval=0.05, maxval=0.45)
    hi = lo + jax.random.uniform(k2, (rows, cells), minval=0.05, maxval=0.5)
    valid = jnp.ones((rows,), bool)
    cfg = acam.ACAMConfig(cell=cell, sigma_program=sigma)
    return acam.program(lo, hi, valid, cfg, k3 if with_key else None), lo, hi


class TestDualRail3T1R:
    def test_dual_rail_counts_agree_with_ideal_window(self):
        """At sigma=0 the two matchlines partition the mismatches exactly:
        low-side + high-side discharges == cells outside the ideal window."""
        key = jax.random.PRNGKey(0)
        prog, lo, hi = _programmed(key, sigma=0.0)
        q = jax.random.uniform(jax.random.fold_in(key, 9), (17, 32),
                               minval=-0.2, maxval=1.2)
        low, high = acam.dual_rail_mismatch(prog, q)
        in_window = jnp.sum(acam.cell_match(prog, q), axis=-1)
        cells = lo.shape[-1]
        np.testing.assert_array_equal(np.asarray(low + high),
                                      np.asarray(cells - in_window))
        # the rails are mutually exclusive per cell: a query value cannot be
        # both below the lower and above the upper bound
        ql = jnp.sum((q[:, None, :] < prog.lower[None]), axis=-1)
        qh = jnp.sum((q[:, None, :] > prog.upper[None]), axis=-1)
        np.testing.assert_array_equal(np.asarray(low), np.asarray(ql))
        np.testing.assert_array_equal(np.asarray(high), np.asarray(qh))

    def test_3t1r_sense_equals_window_fraction(self):
        key = jax.random.PRNGKey(1)
        prog, _, _ = _programmed(key, sigma=0.0)
        q = jax.random.uniform(jax.random.fold_in(key, 2), (9, 32))
        s = acam.sense(prog, q)
        frac = jnp.mean(acam.cell_match(prog, q), axis=-1)
        np.testing.assert_allclose(np.asarray(s), np.asarray(frac),
                                   rtol=1e-6)

    def test_invalid_rows_never_win_wta(self):
        key = jax.random.PRNGKey(2)
        prog, _, _ = _programmed(key, sigma=0.0)
        prog = prog._replace(valid=jnp.array([True, False] * 3))
        q = jax.random.uniform(jax.random.fold_in(key, 3), (25, 32))
        winners = acam.wta(acam.sense(prog, q))
        assert np.all(np.asarray(winners) % 2 == 0)


class TestSigmaProgram:
    def test_sigma_zero_programs_exact_windows(self):
        key = jax.random.PRNGKey(3)
        prog, lo, hi = _programmed(key, sigma=0.0)
        np.testing.assert_array_equal(np.asarray(prog.lower), np.asarray(lo))
        np.testing.assert_array_equal(np.asarray(prog.upper), np.asarray(hi))

    def test_sigma_positive_perturbs_but_never_inverts(self):
        key = jax.random.PRNGKey(4)
        prog, lo, hi = _programmed(key, sigma=0.15)
        assert not np.array_equal(np.asarray(prog.lower), np.asarray(lo))
        assert np.all(np.asarray(prog.upper >= prog.lower))

    def test_sigma_without_key_is_deterministic_noop(self):
        key = jax.random.PRNGKey(5)
        prog, lo, hi = _programmed(key, sigma=0.15, with_key=False)
        np.testing.assert_array_equal(np.asarray(prog.lower), np.asarray(lo))

    def test_variability_degrades_gracefully(self):
        """Small programming noise shifts scores but keeps them in range."""
        key = jax.random.PRNGKey(6)
        prog, _, _ = _programmed(key, sigma=0.05)
        q = jax.random.uniform(jax.random.fold_in(key, 7), (11, 32))
        s = acam.sense(prog, q)
        arr = np.asarray(s)
        assert np.all(arr >= 0.0) and np.all(arr <= 1.0)


class TestSoftSenseSurrogate:
    def test_gradients_finite_and_flowing(self):
        """The 3T1R differentiability claim: gradients of the sigmoid
        surrogate w.r.t. the programmed windows are finite and non-zero."""
        key = jax.random.PRNGKey(8)
        prog, _, _ = _programmed(key, sigma=0.0)
        q = jax.random.uniform(jax.random.fold_in(key, 1), (13, 32))

        def loss(bounds):
            lo, hi = bounds
            sim = acam.soft_sense(prog._replace(lower=lo, upper=hi), q)
            return -jnp.mean(jax.nn.log_softmax(sim * 10.0, axis=-1)[:, 0])

        glo, ghi = jax.grad(loss)((prog.lower, prog.upper))
        for g in (glo, ghi):
            arr = np.asarray(g)
            assert np.all(np.isfinite(arr))
            assert np.abs(arr).max() > 0.0

    def test_soft_sense_tracks_hard_sense(self):
        """With a sharp sigmoid the surrogate approaches the hard 3T1R
        match fraction away from the window edges."""
        key = jax.random.PRNGKey(9)
        prog, _, _ = _programmed(key, sigma=0.0)
        prog = prog._replace(config=prog.config._replace(beta=400.0))
        q = jax.random.uniform(jax.random.fold_in(key, 2), (7, 32))
        hard = jnp.mean(acam.cell_match(prog, q), axis=-1)
        soft = acam.soft_sense(prog, q)
        np.testing.assert_allclose(np.asarray(soft), np.asarray(hard),
                                   atol=0.08)

    def test_calibration_improves_row_loss(self):
        key = jax.random.PRNGKey(10)
        prog, _, _ = _programmed(key, rows=4, cells=16, sigma=0.0)
        feats = jax.random.uniform(jax.random.fold_in(key, 3), (32, 16))
        labels = jnp.arange(32) % 4

        def row_loss(p):
            sim = acam.soft_sense(p, feats)
            logp = jax.nn.log_softmax(sim * 10.0, axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, labels[:, None],
                                                 axis=-1))

        before = float(row_loss(prog))
        after = float(row_loss(acam.calibrate_windows(prog, feats, labels,
                                                      steps=60, lr=0.05)))
        assert after < before
