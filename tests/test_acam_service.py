"""Multi-tenant ACAM serving subsystem tests (registry/scheduler/service).

Asserts the serving contract from the margins kernel up:

  * ONE bank gather + ONE fused classify dispatch per micro-batch tick,
    regardless of how many tenants the batch mixes;
  * per-tenant predictions match the reference backend applied to each
    tenant's own bank (class windows never leak across tenants);
  * the confidence cascade escalates exactly the below-margin requests;
  * hot register / update / evict leave device shapes (and so jit caches)
    untouched in the steady state;
  * the margins kernel variant agrees with the jnp `window_margin` oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import matching
from repro.serve import acam_service as svc_lib
from repro.serve.acam_service import (ACAMService, AdmissionError,
                                      ClassifyRequest, ServiceConfig)
from repro.serve.registry import RegistryError, TemplateBankRegistry

N_FEATURES = 64
N_CLASSES = 6
N_TENANTS = 8
SLOTS = 16


def _make_service(margin_tau=5.0, slots=SLOTS, max_queue=4096):
    svc = ACAMService(
        N_FEATURES,
        config=ServiceConfig(slots=slots, margin_tau=margin_tau,
                             max_queue=max_queue))
    banks, protos = {}, {}
    for t in range(N_TENANTS):
        bank, head, p = svc_lib.make_synthetic_tenant(
            200 + t, num_classes=N_CLASSES, num_features=N_FEATURES)
        tid = f"tenant-{t}"
        svc.register_tenant(tid, bank, head=head)
        banks[tid], protos[tid] = bank, p
    return svc, banks, protos


def _mixed_requests(protos, per_tenant=12, *, noise=0.9, seed=3):
    rng = np.random.RandomState(seed)
    reqs, truth = [], []
    for ti, (tid, p) in enumerate(protos.items()):
        feats, labels = svc_lib.sample_tenant_queries(
            seed + 31 * ti, p, per_tenant, noise=noise)
        for i in range(per_tenant):
            reqs.append(ClassifyRequest(tid, feats[i]))
            truth.append(int(labels[i]))
    order = rng.permutation(len(reqs))
    return [reqs[i] for i in order], [truth[i] for i in order]


class TestServiceEndToEnd:
    @pytest.fixture(scope="class")
    def served(self):
        from repro import match as match_lib

        svc, banks, protos = _make_service()
        calls = {"n": 0}
        # count dispatches at the engine layer (what the scheduler calls)
        orig = match_lib.MatchEngine.classify_serve

        def counting(self, *args, **kwargs):
            calls["n"] += 1
            return orig(self, *args, **kwargs)

        match_lib.MatchEngine.classify_serve = counting
        try:
            reqs, truth = _mixed_requests(protos)
            responses = svc.serve(reqs)
        finally:
            match_lib.MatchEngine.classify_serve = orig
        return svc, banks, reqs, truth, responses, calls["n"]

    def test_one_gather_one_kernel_call_per_batch(self, served):
        svc, _, reqs, _, responses, n_calls = served
        stats = svc.scheduler.stats
        expected_ticks = -(-len(reqs) // SLOTS)
        assert stats.ticks == expected_ticks
        assert stats.classify_dispatches == expected_ticks
        # the engine-level counting wrapper sees the *trace*, not every
        # execution: the jitted tick traces once and replays; 1 <= traces
        # <= ticks proves the scheduler routes through MatchEngine and no
        # per-request or per-tenant dispatch sneaks in
        assert 1 <= n_calls <= expected_ticks
        assert len(responses) == len(reqs)

    def test_per_tenant_predictions_match_reference(self, served):
        # accepted-at-ACAM responses must equal the reference backend run on
        # the tenant's own bank (escalated ones carry the CNN-head decision,
        # asserted in test_escalated_predictions_use_cnn_head)
        _, banks, reqs, _, responses, _ = served
        checked = 0
        for req, resp in zip(reqs, responses):
            if resp.escalated:
                continue
            bank = banks[req.tenant_id]
            want, _ = matching.classify_features(
                jnp.asarray(req.features)[None, :], bank,
                backend="reference")
            assert resp.pred == int(want[0]), req.tenant_id
            checked += 1
        assert checked > 0

    def test_cascade_escalates_exactly_below_margin(self, served):
        svc, banks, reqs, _, responses, _ = served
        tau = svc.config.margin_tau
        for req, resp in zip(reqs, responses):
            bank = banks[req.tenant_id]
            _, per_class = matching.classify_features(
                jnp.asarray(req.features)[None, :], bank,
                backend="reference")
            _, margin = matching.window_margin(per_class,
                                               cap=float(N_FEATURES))
            want_escalate = float(margin[0]) < tau
            assert resp.escalated == want_escalate
            np.testing.assert_allclose(resp.margin, float(margin[0]),
                                       rtol=1e-5, atol=1e-5)
        assert any(r.escalated for r in responses)
        assert any(not r.escalated for r in responses)

    def test_escalated_predictions_use_cnn_head(self, served):
        svc, _, reqs, _, responses, _ = served
        for req, resp in zip(reqs, responses):
            if not resp.escalated:
                continue
            w, b = svc.head_of(req.tenant_id)
            logits = req.features @ w + b
            assert resp.pred == int(np.argmax(logits))

    def test_energy_attribution(self, served):
        svc, _, reqs, _, responses, _ = served
        for req, resp in zip(reqs, responses):
            rt = svc._tenants[req.tenant_id]
            want = rt.backend_j + (svc._frontend_j if resp.escalated else 0.0)
            assert resp.energy_j == pytest.approx(want)
        m = svc.metrics()
        assert m["nj_per_request"] > 0
        assert 0 < m["escalation_rate"] < 1
        assert m["occupancy"] > 0

    def test_mixed_tenants_in_one_tick(self, served):
        _, _, reqs, _, _, _ = served
        # the shuffled stream really does put several tenants in one batch
        assert len({r.tenant_id for r in reqs[:SLOTS]}) > 1


class TestCascadeAccuracy:
    def test_escalation_recovers_low_margin_requests(self):
        """With noisy queries the cascade (ACAM + CNN head on low-margin)
        must be at least as accurate as ACAM alone."""
        svc, _, protos = _make_service(margin_tau=10.0)
        reqs, truth = _mixed_requests(protos, per_tenant=16, noise=1.2,
                                      seed=11)
        responses = svc.serve(reqs)
        acc = np.mean([r.pred == y for r, y in zip(responses, truth)])

        svc2, _, _ = _make_service(margin_tau=-1.0)  # never escalate
        responses2 = svc2.serve(reqs)
        acc2 = np.mean([r.pred == y for r, y in zip(responses2, truth)])
        assert acc >= acc2
        assert acc > 0.5


class TestAdmission:
    def test_unknown_tenant_and_bad_shape(self):
        svc, _, protos = _make_service()
        feats = np.zeros(N_FEATURES, np.float32)
        with pytest.raises(AdmissionError):
            svc.submit(ClassifyRequest("nope", feats))
        with pytest.raises(AdmissionError):
            svc.submit(ClassifyRequest("tenant-0", np.zeros(3, np.float32)))
        assert svc.metrics()["rejected"] == 2

    def test_queue_bound(self):
        svc, _, protos = _make_service(max_queue=4)
        feats = np.zeros(N_FEATURES, np.float32)
        for _ in range(4):
            svc.submit(ClassifyRequest("tenant-0", feats))
        with pytest.raises(AdmissionError, match="queue full"):
            svc.submit(ClassifyRequest("tenant-0", feats))


class TestInFlightLifecycle:
    """Hot tenant churn with requests already queued (the scheduler must
    resolve placements at tick time, not submit time)."""

    def test_evict_while_queued_yields_error_response(self):
        svc, _, protos = _make_service(slots=4)
        feats, _ = svc_lib.sample_tenant_queries(1, protos["tenant-0"], 3)
        for i in range(3):
            svc.submit(ClassifyRequest("tenant-0", feats[i]))
        svc.submit(ClassifyRequest("tenant-1",
                                   svc_lib.sample_tenant_queries(
                                       2, protos["tenant-1"], 1)[0][0]))
        svc.evict_tenant("tenant-0")
        responses = []
        while svc.scheduler.qsize:
            responses.extend(svc.step())
        assert len(responses) == 4
        dead = [r for r in responses if r.tenant_id == "tenant-0"]
        live = [r for r in responses if r.tenant_id == "tenant-1"]
        assert all(r.error is not None and r.pred == -1 for r in dead)
        assert all(r.error is None and r.pred >= 0 for r in live)
        assert svc.metrics()["failed"] == 3

    def test_update_relocation_while_queued_uses_new_window(self):
        svc = ACAMService(N_FEATURES,
                          config=ServiceConfig(slots=4, margin_tau=-1.0),
                          class_bucket=8)
        small, head_s, p_small = svc_lib.make_synthetic_tenant(
            30, num_classes=6, num_features=N_FEATURES)
        blocker, head_b, _ = svc_lib.make_synthetic_tenant(
            31, num_classes=6, num_features=N_FEATURES)
        svc.register_tenant("a", small, head=head_s)
        svc.register_tenant("blocker", blocker, head=head_b)

        big, head_big, p_big = svc_lib.make_synthetic_tenant(
            32, num_classes=12, num_features=N_FEATURES)
        feats, labels = svc_lib.sample_tenant_queries(3, p_big, 4, noise=0.3)
        for i in range(4):
            svc.submit(ClassifyRequest("a", feats[i]))
        # relocates "a" (bucket 8 -> 16, blocker occupies the next bucket)
        svc.update_tenant("a", big, head=head_big)
        assert svc.registry.get("a").offset != 0 or \
            svc.registry.get("a").c_bucket == 16
        responses = []
        while svc.scheduler.qsize:
            responses.extend(svc.step())
        # served against the NEW 12-class placement, not the stale window
        assert [r.pred for r in responses] == [int(y) for y in labels]
        assert all(r.error is None for r in responses)

    def test_bad_head_rejects_without_registry_mutation(self):
        svc, _, _ = _make_service()
        bank, _, _ = svc_lib.make_synthetic_tenant(
            40, num_classes=4, num_features=N_FEATURES)
        bad_head = (np.zeros((N_FEATURES * 2, 4), np.float32),
                    np.zeros((4,), np.float32))
        with pytest.raises(RegistryError):
            svc.register_tenant("new", bank, head=bad_head)
        assert "new" not in svc.registry  # nothing half-installed
        good_head = (np.zeros((N_FEATURES, 4), np.float32),
                     np.zeros((4,), np.float32))
        svc.register_tenant("new", bank, head=good_head)  # retry works
        feats = np.zeros(N_FEATURES, np.float32)
        svc.submit(ClassifyRequest("new", feats))
        assert all(r.error is None for r in svc.step())


class TestRegistryHotOps:
    def test_register_update_evict_keep_device_shapes(self):
        reg = TemplateBankRegistry(N_FEATURES, k_max=2, class_bucket=8,
                                   initial_classes=64)
        bank0, _, _ = svc_lib.make_synthetic_tenant(
            1, num_classes=N_CLASSES, num_features=N_FEATURES)
        e0 = reg.register("a", bank0)
        shape0 = reg.device_bank().templates.shape
        thr0 = reg.thresholds_table().shape

        bank1, _, _ = svc_lib.make_synthetic_tenant(
            2, num_classes=4, k=2, num_features=N_FEATURES)
        reg.register("b", bank1)
        reg.update("a", bank0)
        reg.evict("b")
        assert reg.device_bank().templates.shape == shape0
        assert reg.thresholds_table().shape == thr0
        # freed range is reused (no capacity growth on re-register)
        e2 = reg.register("c", bank1)
        assert reg.device_bank().templates.shape == shape0
        assert e2.offset != e0.offset or "a" not in reg

    def test_device_bank_cached_per_generation(self):
        reg = TemplateBankRegistry(N_FEATURES)
        bank, _, _ = svc_lib.make_synthetic_tenant(
            3, num_classes=N_CLASSES, num_features=N_FEATURES)
        reg.register("a", bank)
        b1 = reg.device_bank()
        assert reg.device_bank() is b1  # cache hit: no re-upload per tick
        reg.update("a", bank)
        assert reg.device_bank() is not b1  # mutation invalidates

    def test_window_and_contents(self):
        reg = TemplateBankRegistry(N_FEATURES, class_bucket=8)
        bank, _, _ = svc_lib.make_synthetic_tenant(
            4, num_classes=N_CLASSES, num_features=N_FEATURES)
        entry = reg.register("a", bank)
        lo, hi = entry.window
        assert hi - lo == N_CLASSES
        sb = reg.device_bank()
        np.testing.assert_array_equal(
            np.asarray(sb.templates[lo:hi, :entry.k]),
            np.asarray(bank.templates))
        np.testing.assert_array_equal(
            np.asarray(sb.valid[lo:hi, :entry.k]), np.asarray(bank.valid))

    def test_registry_errors(self):
        reg = TemplateBankRegistry(N_FEATURES, k_max=1)
        bank, _, _ = svc_lib.make_synthetic_tenant(
            5, num_classes=N_CLASSES, num_features=N_FEATURES)
        reg.register("a", bank)
        with pytest.raises(RegistryError):
            reg.register("a", bank)  # duplicate
        with pytest.raises(RegistryError):
            reg.get("ghost")
        bank_k2, _, _ = svc_lib.make_synthetic_tenant(
            6, num_classes=N_CLASSES, k=2, num_features=N_FEATURES)
        with pytest.raises(RegistryError):
            reg.register("b", bank_k2)  # k exceeds k_max
        bank_n, _, _ = svc_lib.make_synthetic_tenant(
            7, num_classes=N_CLASSES, num_features=N_FEATURES * 2)
        with pytest.raises(RegistryError):
            reg.register("c", bank_n)  # wrong feature dim

    def test_update_relocates_and_invalidates_old_range(self):
        reg = TemplateBankRegistry(N_FEATURES, class_bucket=8,
                                   initial_classes=64)
        small, _, _ = svc_lib.make_synthetic_tenant(
            20, num_classes=6, num_features=N_FEATURES)
        big, _, _ = svc_lib.make_synthetic_tenant(
            21, num_classes=12, num_features=N_FEATURES)
        reg.register("a", small)
        # a neighbour occupies the adjacent bucket so "a" cannot grow in
        # place and must relocate
        reg.register("b", small)
        e_old = reg.get("a")
        reg.update("a", big)
        e_new = reg.get("a")
        assert e_new.num_classes == 12 and e_new.c_bucket == 16
        assert e_new.offset != e_old.offset
        # the vacated range holds no stale valid rows
        sb = reg.device_bank()
        old_rows = np.asarray(
            sb.valid[e_old.offset:e_old.offset + e_old.c_bucket])
        assert not old_rows.any()
        np.testing.assert_array_equal(
            np.asarray(sb.templates[e_new.offset:e_new.offset + 12,
                                    :e_new.k]),
            np.asarray(big.templates))
        assert reg.stats()["programmed_rows"] == 12 + 6  # "a" big + "b"

    def test_capacity_growth_by_doubling(self):
        reg = TemplateBankRegistry(N_FEATURES, class_bucket=16,
                                   initial_classes=32)
        for t in range(4):  # 4 x 16-row buckets > 32 rows -> one grow
            bank, _, _ = svc_lib.make_synthetic_tenant(
                10 + t, num_classes=10, num_features=N_FEATURES)
            reg.register(f"t{t}", bank)
        assert reg.capacity_classes == 64
        assert len(reg) == 4


class TestMarginsKernelParity:
    @pytest.mark.parametrize("b,c,k,n", [(3, 5, 2, 784), (37, 10, 2, 300),
                                         (257, 10, 1, 784)])
    def test_fused_margins_matches_oracle(self, b, c, k, n):
        import jax

        from repro.core.templates import TemplateBank

        key = jax.random.PRNGKey(b + c)
        tmpl = (jax.random.uniform(key, (c, k, n)) > 0.5).astype(jnp.float32)
        valid = jnp.ones((c, k), bool)
        if k > 1:
            valid = valid.at[0, k - 1].set(False)
        thr = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.1
        bank = TemplateBank(tmpl, jnp.zeros_like(tmpl), jnp.ones_like(tmpl),
                            valid, thr)
        feats = jax.random.normal(jax.random.fold_in(key, 2), (b, n))
        rng = np.random.RandomState(b)
        lo = rng.randint(0, c, size=b).astype(np.int32)
        hi = np.minimum(lo + rng.randint(1, c, size=b), c).astype(np.int32)
        lo[0], hi[0] = 0, 0  # an empty (padding) window

        pred_k, pc_k, m_k = matching.classify_features_margin(
            feats, bank, jnp.asarray(lo), jnp.asarray(hi), backend="kernel")
        _, pc_r = matching.classify_features(feats, bank,
                                             backend="reference")
        pred_r, m_r = matching.window_margin(pc_r, jnp.asarray(lo),
                                             jnp.asarray(hi), cap=float(n))
        np.testing.assert_array_equal(np.asarray(pred_k), np.asarray(pred_r))
        np.testing.assert_allclose(np.asarray(pc_k), np.asarray(pc_r),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r),
                                   rtol=1e-5, atol=1e-5)
        assert float(m_k[0]) == 0.0  # empty window -> margin 0

    def test_single_class_window_margin_clamped(self):
        import jax

        from repro.core.templates import TemplateBank

        c, k, n = 4, 1, 128
        key = jax.random.PRNGKey(0)
        tmpl = (jax.random.uniform(key, (c, k, n)) > 0.5).astype(jnp.float32)
        bank = TemplateBank(tmpl, jnp.zeros_like(tmpl), jnp.ones_like(tmpl),
                            jnp.ones((c, k), bool), jnp.zeros((n,)))
        feats = jax.random.normal(key, (4, n))
        lo = jnp.array([1, 0, 0, 0], jnp.int32)
        hi = jnp.array([2, 4, 4, 4], jnp.int32)  # row 0: single-class window
        _, _, margin = matching.classify_features_margin(
            feats, bank, lo, hi, backend="kernel")
        assert float(margin[0]) == pytest.approx(float(n))  # clamped to cap
        assert np.all(np.isfinite(np.asarray(margin)))


class TestSchedulerOccupancy:
    def test_fill_stats_observable(self):
        svc, _, protos = _make_service(slots=8)
        tid = "tenant-0"
        feats, _ = svc_lib.sample_tenant_queries(1, protos[tid], 11)
        for i in range(11):  # 11 requests over 8 slots -> fills 8 + 3
            svc.submit(ClassifyRequest(tid, feats[i]))
        while svc.scheduler.qsize:
            svc.step()
        s = svc.scheduler.stats
        assert s.ticks == 2
        assert s.min_fill == 3 and s.max_fill == 8
        assert s.occupancy == pytest.approx(11 / 16)
